"""Euclidean metric: MXU matmul expansion + exact squared-threshold sweep."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.metrics.base import Metric, orthonormal_projection, register_metric


def sq_threshold(eps) -> np.float32:
    """Largest float32 t with sqrt(t) <= eps — the exact squared ε-ball.

    float32 sqrt is correctly rounded and monotone, so
    {v : sqrt(v) <= ε} = {v : v <= t} for this t, and the compacted sweep
    can threshold *squared* distances bit-identically to thresholding
    sqrt'd ones while evaluating sqrt only on the O(nnz) survivors.
    Found by bisection over the float32 bit lattice (positive floats
    order like their bit patterns): 31 host-side sqrts, no device work.
    """
    e = np.float32(eps)
    if np.isnan(e) or e < 0:
        return np.float32(np.nan)          # v <= NaN is never true: no hits
    if np.isinf(e):
        return np.float32(np.inf)
    lo, hi = np.uint32(0), np.uint32(0x7F7FFFFF)     # 0.0 .. max finite
    while lo < hi:
        mid = np.uint32((np.uint64(lo) + np.uint64(hi) + np.uint64(1)) // 2)
        if np.sqrt(mid.view(np.float32), dtype=np.float32) <= e:
            lo = mid
        else:
            hi = np.uint32(mid - 1)
    return lo.view(np.float32)


@register_metric
class EuclideanMetric(Metric):
    """(n, d) float32 vectors under L2; Pallas kernels on TPU, the fused
    squared-threshold mask sweep on XLA/CPU."""

    name = "euclidean"

    def canonicalize(self, data):
        if isinstance(data, tuple) and len(data) == 1:
            data = data[0]
        return (np.ascontiguousarray(np.asarray(data, dtype=np.float32)),)

    def pairwise(self, q, c):
        return ref.pairwise_euclidean(q[0], c[0])

    def tile(self, q, c, use_pallas: bool = False):
        return ops.pairwise_euclidean(q[0], c[0], use_pallas=use_pallas)

    def mask_threshold(self, eps: float):
        # exact squared image of the ε-ball: the hit plane below is bit
        # identical to thresholding sqrt'd distances without m·n sqrts
        return jnp.asarray(sq_threshold(eps))

    def mask_tile(self, q, c, thresh):
        hit, cross, x2, y2 = ops.eps_mask_tile(q[0], c[0], thresh)
        return hit, (cross, x2, y2)

    def gather_pairs(self, payload, flat):
        return ops.eps_gather_pairs(*payload, flat)

    def eps_count(self, q, c, eps, weights, use_pallas: bool = False):
        return ops.eps_count(q[0], c[0], eps, weights, use_pallas=use_pallas)

    def eps_compact(self, q, c, eps, cap: int, use_pallas: bool = False):
        return ops.eps_compact(q[0], c[0], eps, cap, use_pallas=use_pallas)

    def screened_eps_compact(self, q, c, sq, sc, eps, s2t, cap: int,
                             num_valid=None, use_pallas: bool = False):
        return ops.screened_eps_compact(q[0], c[0], sq, sc, eps, s2t, cap,
                                        num_valid=num_valid,
                                        use_pallas=use_pallas)

    def screened_eps_count(self, q, c, sq, sc, eps, s2t, weights,
                           num_valid=None, use_pallas: bool = False):
        return ops.screened_eps_count(q[0], c[0], sq, sc, eps, s2t, weights,
                                      num_valid=num_valid,
                                      use_pallas=use_pallas)

    def project(self, canon, k, seed: int = 0):
        # orthonormal projection: ||P^T x - P^T y|| <= ||x - y|| holds
        # deterministically, so the identity lower_bound is a true bound
        return orthonormal_projection(canon[0], k, seed)
