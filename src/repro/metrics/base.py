"""The ``Metric`` protocol + registry — the paper's flexibility claim as API.

FINEX's headline claim (d) is flexibility "in terms of applicable data
types and distance functions": nothing in the index theory (Thm 5.6,
§5.4) is Euclidean-specific — only the neighborhood *materialization*
touches raw data. This module owns that seam. A ``Metric`` packages
everything metric-specific behind a fixed kernel contract, so the engine,
the sharded CSR-emit, the fingerprint, the npz round-trip and the serving
layer never branch on metric names again:

  * ``canonicalize(data)``  — raw user data → the tuple of row-aligned
    host arrays that defines the dataset identity (hashed byte-for-byte
    by ``dataset_fingerprint``) and is uploaded by ``device_state``.
  * ``pairwise(q, c)``      — the distance formula as pure traceable jnp
    (the oracle; also what runs inside ``shard_map`` on the mesh).
  * ``tile`` / ``mask_threshold`` + ``mask_tile`` + ``gather_pairs`` /
    ``eps_count`` / ``eps_compact`` — the engine's kernel contract: a
    dense tile, the fused bool-plane + O(nnz) pair gather, the fused
    threshold-count, and the fused threshold+emit capacity slots. The
    base class derives all of them from ``pairwise`` (jit'd, byte-exact
    vs the dense plane), so a user metric only needs the formula;
    built-ins override with their Pallas kernels.

Resolution goes through a registry: ``get_metric("euclidean")``,
``get_metric("jaccard")`` etc. keep the historical string API working
(every ``metric=`` argument in the repo accepts a name *or* a ``Metric``
instance), and ``register_metric`` admits user-defined distance callables
(dense fallback path — no Pallas kernel required).
"""
from __future__ import annotations

import json
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

#: device-side dataset state: one row-aligned array tuple (axis 0 = objects)
State = Tuple[jax.Array, ...]


class Metric:
    """Base metric: distance semantics + the engine's kernel contract.

    Subclasses must set ``name`` and implement ``canonicalize`` and
    ``pairwise``; everything else has byte-exact derived defaults.
    ``params`` must be JSON-serializable — it travels through npz archives
    and is part of the dataset identity whenever non-empty.
    """

    name: str = "?"

    def __init__(self, **params):
        self.params: Dict[str, Any] = dict(params)
        self._jit_cache: Dict[Any, Callable] = {}

    # ------------------------------------------------------------- identity
    @property
    def spec(self) -> str:
        """Stable identity token: registry name + canonical params."""
        if not self.params:
            return self.name
        return f"{self.name}{json.dumps(self.params, sort_keys=True)}"

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.spec!r})"

    def fingerprint_head(self, canon: Tuple[np.ndarray, ...]) -> str:
        """Prefix of ``dataset_fingerprint``: metric + shape + dtype (the
        historical euclidean/jaccard format, byte-for-byte)."""
        a = canon[0]
        shape = "x".join(map(str, a.shape))
        return f"{self.spec}:{shape}:{a.dtype}"

    def fingerprint_update(self, hasher, canon: Tuple[np.ndarray, ...]) -> None:
        """Feed the canonical arrays into the content hash."""
        for a in canon:
            hasher.update(np.ascontiguousarray(a).tobytes())

    # ------------------------------------------------------- data plumbing
    def canonicalize(self, data) -> Tuple[np.ndarray, ...]:
        """Raw user data → tuple of row-aligned host arrays (idempotent:
        feeding the result back must return equal arrays)."""
        raise NotImplementedError

    def device_state(self, canon: Tuple[np.ndarray, ...]) -> State:
        return tuple(jnp.asarray(a) for a in canon)

    @staticmethod
    def take(state: State, rows) -> State:
        """Row subset of a dataset state (same tuple structure)."""
        return tuple(a[rows] for a in state)

    @classmethod
    def synthesize(cls, rng: np.random.Generator, n: int, d: int = 8):
        """A small random dataset this metric accepts — test/bench support
        so contract suites can auto-parametrize over the registry."""
        return rng.normal(size=(n, d)).astype(np.float32)

    # ---------------------------------------------------- distance kernels
    def pairwise(self, q: State, c: State) -> jax.Array:
        """(m, n) float32 distances between the rows of two states — pure
        traceable jnp; the semantic oracle every other kernel must match,
        and the formula the sharded CSR-emit runs inside ``shard_map``."""
        raise NotImplementedError

    def _jit(self, key, make: Callable[[], Callable]) -> Callable:
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = self._jit_cache[key] = make()
        return fn

    def tile(self, q: State, c: State, use_pallas: bool = False) -> jax.Array:
        """Dense distance tile (jit'd ``pairwise``). ``use_pallas`` is a
        hint honored by metrics that carry a compiled kernel."""
        return self._jit("tile", lambda: jax.jit(self.pairwise))(q, c)

    def mask_threshold(self, eps: float) -> jax.Array:
        """Per-sweep device threshold for ``mask_tile``. Metrics with an
        exact monotone transform (e.g. euclidean's squared-distance
        lattice bisection) return the transformed threshold here."""
        return jnp.float32(eps)

    def mask_tile(self, q: State, c: State, thresh: jax.Array
                  ) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
        """Fused threshold plane: (bool hit tile, resident payload). Only
        the hit plane crosses to the host; ``gather_pairs`` later pulls
        the O(nnz) surviving distances from the payload."""
        def make():
            def f(q, c, t):
                d = self.pairwise(q, c)
                return d <= t, (d,)
            return jax.jit(f)
        return self._jit("mask", make)(q, c, thresh)

    def gather_pairs(self, payload: Tuple[jax.Array, ...], flat: jax.Array
                     ) -> jax.Array:
        """Distances of the surviving row-major pair ids ``flat`` — bit
        exact gathers of the same buffers the hit plane came from."""
        def make():
            return jax.jit(lambda p, f: p[0].reshape(-1)[f])
        return self._jit("gather", make)(payload, flat)

    def eps_count(self, q: State, c: State, eps, weights: jax.Array,
                  use_pallas: bool = False) -> jax.Array:
        """Fused weighted |N_ε| per query row (no dense plane to host)."""
        def make():
            def f(q, c, e, w):
                d = self.pairwise(q, c)
                return jnp.where(d <= e, w[None, :].astype(jnp.float32),
                                 0.0).sum(-1)
            return jax.jit(f)
        return self._jit("count", make)(q, c, eps, weights)

    def eps_compact(self, q: State, c: State, eps, cap: int,
                    use_pallas: bool = False):
        """Fused threshold + emit into per-row capacity slots — the slot
        path of the materialize sweep (``ref.eps_compact_tile`` contract:
        true lengths may exceed ``cap`` so overflow stays detectable)."""
        def make():
            def f(q, c, e):
                return ref.eps_compact_tile(self.pairwise(q, c), e, cap)
            return jax.jit(f)
        return self._jit(("compact", cap), make)(q, c, eps)


class CallableMetric(Metric):
    """User-defined distance callable behind the full kernel contract.

    ``pairwise_fn(q_arrays..., c_arrays...)`` gets the unpacked state
    tuples and must return the (m, n) float32 distance tile in pure jnp
    ops (it is jit'd, swept tile-by-tile, and run inside ``shard_map`` on
    meshes). The dense fallback paths do the rest — no Pallas required.
    """

    def __init__(self, name: str, pairwise_fn: Callable, *,
                 dtype=np.float32, arity: int = 1,
                 synthesize: Optional[Callable] = None, **params):
        super().__init__(**params)
        self.name = name
        self._fn = pairwise_fn
        self._dtypes = (np.dtype(dtype),) if arity == 1 else tuple(
            np.dtype(t) for t in dtype)
        self._synthesize = synthesize

    def canonicalize(self, data):
        arity = len(self._dtypes)
        parts = (data,) if arity == 1 else tuple(data)
        if arity == 1 and isinstance(data, tuple) and len(data) == 1:
            parts = data
        return tuple(np.ascontiguousarray(np.asarray(p, dtype=t))
                     for p, t in zip(parts, self._dtypes))

    def pairwise(self, q, c):
        return self._fn(*q, *c)

    def synthesize(self, rng, n, d=8):  # type: ignore[override]
        if self._synthesize is not None:
            return self._synthesize(rng, n)
        return rng.normal(size=(n, d)).astype(self._dtypes[0]) \
            if len(self._dtypes) == 1 else super().synthesize(rng, n, d)


# --------------------------------------------------------------- registry
MetricLike = Union[str, Metric]

_REGISTRY: Dict[str, Callable[..., Metric]] = {}
# default-params resolutions share one instance per name: the derived
# kernel jit caches live on the instance, so handing every engine a
# fresh instance would recompile the tile/mask/count/compact kernels
# per build instead of once per process
_DEFAULT_INSTANCES: Dict[str, Metric] = {}


def register_metric(name_or_metric, pairwise_fn: Optional[Callable] = None,
                    *, overwrite: bool = False, **kw):
    """Admit a metric into the registry.

    Three forms:
      * ``register_metric(MetricSubclass)`` — class with a ``name``
      * ``register_metric("name", factory)`` — explicit factory/class
      * ``register_metric("name", distance_callable, dtype=..., ...)`` —
        a plain jnp distance function, wrapped in ``CallableMetric``
        (the no-Pallas dense path); extra kwargs become default params.

    Returns whatever was registered so it can be used as a decorator.
    """
    if isinstance(name_or_metric, type) and issubclass(name_or_metric, Metric):
        cls = name_or_metric
        _register(cls.name, cls, overwrite)
        return cls
    name = str(name_or_metric)
    if pairwise_fn is None:
        raise TypeError("register_metric(name, ...) needs a Metric factory "
                        "or a pairwise distance callable")
    if isinstance(pairwise_fn, type) and issubclass(pairwise_fn, Metric):
        _register(name, pairwise_fn, overwrite)
        return pairwise_fn

    def factory(**params):
        merged = dict(kw)
        merged.update(params)
        return CallableMetric(name, pairwise_fn, **merged)

    _register(name, factory, overwrite)
    return pairwise_fn


def _register(name: str, factory, overwrite: bool) -> None:
    if not overwrite and name in _REGISTRY:
        raise ValueError(f"metric {name!r} is already registered "
                         "(pass overwrite=True to replace it)")
    _REGISTRY[name] = factory
    _DEFAULT_INSTANCES.pop(name, None)


def registered_metrics() -> Tuple[str, ...]:
    """Sorted names of every registered metric."""
    return tuple(sorted(_REGISTRY))


def get_metric(spec: MetricLike, **params) -> Metric:
    """Resolve a metric name (or pass an instance through).

    This is the deprecation shim for the historical string API: every
    ``metric="euclidean"`` / ``"jaccard"`` call in the repo lands here.
    Unknown names fail with the registered alternatives spelled out.
    """
    if isinstance(spec, Metric):
        if params:
            raise TypeError("params are only accepted with a metric *name*; "
                            f"got an instance {spec!r} plus {params}")
        return spec
    if isinstance(spec, str):
        factory = _REGISTRY.get(spec)
        if factory is None:
            raise ValueError(
                f"unknown metric {spec!r}; registered metrics are "
                f"{list(registered_metrics())} (register_metric() adds "
                "user-defined distance functions)")
        if params:
            return factory(**params)
        m = _DEFAULT_INSTANCES.get(spec)
        if m is None:
            m = _DEFAULT_INSTANCES[spec] = factory()
        return m
    raise TypeError(f"metric must be a name or a Metric instance, got "
                    f"{type(spec).__name__}")
