"""The ``Metric`` protocol + registry — the paper's flexibility claim as API.

FINEX's headline claim (d) is flexibility "in terms of applicable data
types and distance functions": nothing in the index theory (Thm 5.6,
§5.4) is Euclidean-specific — only the neighborhood *materialization*
touches raw data. This module owns that seam. A ``Metric`` packages
everything metric-specific behind a fixed kernel contract, so the engine,
the sharded CSR-emit, the fingerprint, the npz round-trip and the serving
layer never branch on metric names again:

  * ``canonicalize(data)``  — raw user data → the tuple of row-aligned
    host arrays that defines the dataset identity (hashed byte-for-byte
    by ``dataset_fingerprint``) and is uploaded by ``device_state``.
  * ``pairwise(q, c)``      — the distance formula as pure traceable jnp
    (the oracle; also what runs inside ``shard_map`` on the mesh).
  * ``tile`` / ``mask_threshold`` + ``mask_tile`` + ``gather_pairs`` /
    ``eps_count`` / ``eps_compact`` — the engine's kernel contract: a
    dense tile, the fused bool-plane + O(nnz) pair gather, the fused
    threshold-count, and the fused threshold+emit capacity slots. The
    base class derives all of them from ``pairwise`` (jit'd, byte-exact
    vs the dense plane), so a user metric only needs the formula;
    built-ins override with their Pallas kernels.

Resolution goes through a registry: ``get_metric("euclidean")``,
``get_metric("jaccard")`` etc. keep the historical string API working
(every ``metric=`` argument in the repo accepts a name *or* a ``Metric``
instance), and ``register_metric`` admits user-defined distance callables
(dense fallback path — no Pallas kernel required).
"""
from __future__ import annotations

import json
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

#: device-side dataset state: one row-aligned array tuple (axis 0 = objects)
State = Tuple[jax.Array, ...]


class Metric:
    """Base metric: distance semantics + the engine's kernel contract.

    Subclasses must set ``name`` and implement ``canonicalize`` and
    ``pairwise``; everything else has byte-exact derived defaults.
    ``params`` must be JSON-serializable — it travels through npz archives
    and is part of the dataset identity whenever non-empty.
    """

    name: str = "?"

    def __init__(self, **params):
        self.params: Dict[str, Any] = dict(params)
        self._jit_cache: Dict[Any, Callable] = {}

    # ------------------------------------------------------------- identity
    @property
    def spec(self) -> str:
        """Stable identity token: registry name + canonical params."""
        if not self.params:
            return self.name
        return f"{self.name}{json.dumps(self.params, sort_keys=True)}"

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.spec!r})"

    def fingerprint_head(self, canon: Tuple[np.ndarray, ...]) -> str:
        """Prefix of ``dataset_fingerprint``: metric + shape + dtype (the
        historical euclidean/jaccard format, byte-for-byte)."""
        a = canon[0]
        shape = "x".join(map(str, a.shape))
        return f"{self.spec}:{shape}:{a.dtype}"

    def fingerprint_update(self, hasher, canon: Tuple[np.ndarray, ...]) -> None:
        """Feed the canonical arrays into the content hash."""
        for a in canon:
            hasher.update(np.ascontiguousarray(a).tobytes())

    # ------------------------------------------------------- data plumbing
    def canonicalize(self, data) -> Tuple[np.ndarray, ...]:
        """Raw user data → tuple of row-aligned host arrays (idempotent:
        feeding the result back must return equal arrays)."""
        raise NotImplementedError

    def device_state(self, canon: Tuple[np.ndarray, ...]) -> State:
        return tuple(jnp.asarray(a) for a in canon)

    @staticmethod
    def take(state: State, rows) -> State:
        """Row subset of a dataset state (same tuple structure)."""
        return tuple(a[rows] for a in state)

    @classmethod
    def synthesize(cls, rng: np.random.Generator, n: int, d: int = 8):
        """A small random dataset this metric accepts — test/bench support
        so contract suites can auto-parametrize over the registry."""
        return rng.normal(size=(n, d)).astype(np.float32)

    # ---------------------------------------------------- distance kernels
    def pairwise(self, q: State, c: State) -> jax.Array:
        """(m, n) float32 distances between the rows of two states — pure
        traceable jnp; the semantic oracle every other kernel must match,
        and the formula the sharded CSR-emit runs inside ``shard_map``."""
        raise NotImplementedError

    def _jit(self, key, make: Callable[[], Callable]) -> Callable:
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = self._jit_cache[key] = make()
        return fn

    def tile(self, q: State, c: State, use_pallas: bool = False) -> jax.Array:
        """Dense distance tile (jit'd ``pairwise``). ``use_pallas`` is a
        hint honored by metrics that carry a compiled kernel."""
        return self._jit("tile", lambda: jax.jit(self.pairwise))(q, c)

    def mask_threshold(self, eps: float) -> jax.Array:
        """Per-sweep device threshold for ``mask_tile``. Metrics with an
        exact monotone transform (e.g. euclidean's squared-distance
        lattice bisection) return the transformed threshold here."""
        return jnp.float32(eps)

    def mask_tile(self, q: State, c: State, thresh: jax.Array
                  ) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
        """Fused threshold plane: (bool hit tile, resident payload). Only
        the hit plane crosses to the host; ``gather_pairs`` later pulls
        the O(nnz) surviving distances from the payload."""
        def make():
            def f(q, c, t):
                d = self.pairwise(q, c)
                return d <= t, (d,)
            return jax.jit(f)
        return self._jit("mask", make)(q, c, thresh)

    def gather_pairs(self, payload: Tuple[jax.Array, ...], flat: jax.Array
                     ) -> jax.Array:
        """Distances of the surviving row-major pair ids ``flat`` — bit
        exact gathers of the same buffers the hit plane came from."""
        def make():
            return jax.jit(lambda p, f: p[0].reshape(-1)[f])
        return self._jit("gather", make)(payload, flat)

    def eps_count(self, q: State, c: State, eps, weights: jax.Array,
                  use_pallas: bool = False) -> jax.Array:
        """Fused weighted |N_ε| per query row (no dense plane to host)."""
        def make():
            def f(q, c, e, w):
                d = self.pairwise(q, c)
                return jnp.where(d <= e, w[None, :].astype(jnp.float32),
                                 0.0).sum(-1)
            return jax.jit(f)
        return self._jit("count", make)(q, c, eps, weights)

    def eps_compact(self, q: State, c: State, eps, cap: int,
                    use_pallas: bool = False):
        """Fused threshold + emit into per-row capacity slots — the slot
        path of the materialize sweep (``ref.eps_compact_tile`` contract:
        true lengths may exceed ``cap`` so overflow stays detectable)."""
        def make():
            def f(q, c, e):
                return ref.eps_compact_tile(self.pairwise(q, c), e, cap)
            return jax.jit(f)
        return self._jit(("compact", cap), make)(q, c, eps)

    def screened_eps_count(self, q: State, c: State, sq: jax.Array,
                           sc: jax.Array, eps, s2t, weights: jax.Array,
                           num_valid=None, use_pallas: bool = False):
        """Projection-pruned ``eps_count``: AND the hit plane with the
        screen bound plane (a superset of true hits by the lower-bound
        contract, so counts are bit-identical) and report per-row
        candidate counts.  ``sq``/``sc`` are float32 screen embeddings,
        ``s2t`` the slack-inflated squared screen threshold;
        ``num_valid`` masks pow2-padded corpus columns."""
        def make():
            def f(q, c, sq, sc, e, t, w, nv):
                d = self.pairwise(q, c)
                keep = ref.screened_hit_tile(
                    jnp.ones(d.shape, bool), sq, sc, t, nv)[0]
                cand = jnp.sum(keep.astype(jnp.int32), axis=1)
                counts = jnp.where((d <= e) & keep,
                                   w[None, :].astype(jnp.float32), 0.0).sum(-1)
                return counts, cand
            return jax.jit(f)
        nv = jnp.int32(c[0].shape[0] if num_valid is None else num_valid)
        return self._jit("scount", make)(q, c, sq, sc, eps, s2t, weights, nv)

    def screened_eps_compact(self, q: State, c: State, sq: jax.Array,
                             sc: jax.Array, eps, s2t, cap: int,
                             num_valid=None, use_pallas: bool = False):
        """Projection-pruned ``eps_compact``: screened-out pairs get an
        ``inf`` distance before the slot emit, so the slots are
        byte-identical to the unscreened sweep (the screen only removes
        provable non-hits).  Returns ``(lens, cols, dvals, cand)``.
        Metrics with fused Pallas kernels override this with the
        tile-skipping screened emit kernel."""
        def make():
            def f(q, c, sq, sc, e, t, nv):
                d = self.pairwise(q, c)
                keep = ref.screened_hit_tile(
                    jnp.ones(d.shape, bool), sq, sc, t, nv)[0]
                cand = jnp.sum(keep.astype(jnp.int32), axis=1)
                lens, cols, dvals = ref.eps_compact_tile(
                    jnp.where(keep, d, jnp.inf), e, cap)
                return lens, cols, dvals, cand
            return jax.jit(f)
        nv = jnp.int32(c[0].shape[0] if num_valid is None else num_valid)
        return self._jit(("scompact", cap), make)(q, c, sq, sc, eps, s2t, nv)

    # ------------------------------------------------------- prune screen
    def project(self, canon: Tuple[np.ndarray, ...], k: int,
                seed: int = 0) -> Optional[np.ndarray]:
        """Host-side screen embedding: (n, k') float64 points E such that
        ``lower_bound(||E(x) - E(y)||_2) <= pairwise(x, y)`` for every
        pair — the contract behind the projection-pruned exact sweep.

        Returning ``None`` (the default) declares "no bound": the engine
        runs the unpruned full sweep, which is always correct.  The screen
        space is *always* Euclidean — per-metric semantics live entirely
        in the embedding and in :meth:`lower_bound` — so the engine's
        bucket/ball machinery stays metric-oblivious.  The embedding runs
        in float64 on the host; the exact device kernels never see it
        (the screen can only *rule out* pairs, never admit false ones).
        """
        return None

    def lower_bound(self, screen_dist: np.ndarray) -> np.ndarray:
        """Monotone map from screen-space Euclidean distance to a true
        distance lower bound.  Identity by default (correct whenever the
        embedding is itself contractive, e.g. a JL/orthonormal projection
        under euclidean or cityblock)."""
        return screen_dist


class CallableMetric(Metric):
    """User-defined distance callable behind the full kernel contract.

    ``pairwise_fn(q_arrays..., c_arrays...)`` gets the unpacked state
    tuples and must return the (m, n) float32 distance tile in pure jnp
    ops (it is jit'd, swept tile-by-tile, and run inside ``shard_map`` on
    meshes). The dense fallback paths do the rest — no Pallas required.

    Pruning is opt-in: pass ``project=`` (``(canon, k, seed) -> (n, k')
    float64`` or ``None``) and optionally ``lower_bound=`` (monotone
    screen-distance → true-distance lower bound, identity by default) to
    let the engine's projection screen skip provably-empty tiles.  With
    no ``project`` the metric rides the unpruned full sweep.
    """

    def __init__(self, name: str, pairwise_fn: Callable, *,
                 dtype=np.float32, arity: int = 1,
                 synthesize: Optional[Callable] = None,
                 project: Optional[Callable] = None,
                 lower_bound: Optional[Callable] = None, **params):
        super().__init__(**params)
        self.name = name
        self._fn = pairwise_fn
        self._dtypes = (np.dtype(dtype),) if arity == 1 else tuple(
            np.dtype(t) for t in dtype)
        self._synthesize = synthesize
        self._project = project
        self._lower_bound = lower_bound

    def canonicalize(self, data):
        arity = len(self._dtypes)
        parts = (data,) if arity == 1 else tuple(data)
        if arity == 1 and isinstance(data, tuple) and len(data) == 1:
            parts = data
        return tuple(np.ascontiguousarray(np.asarray(p, dtype=t))
                     for p, t in zip(parts, self._dtypes))

    def pairwise(self, q, c):
        return self._fn(*q, *c)

    def synthesize(self, rng, n, d=8):  # type: ignore[override]
        if self._synthesize is not None:
            return self._synthesize(rng, n)
        return rng.normal(size=(n, d)).astype(self._dtypes[0]) \
            if len(self._dtypes) == 1 else super().synthesize(rng, n, d)

    def project(self, canon, k, seed: int = 0):
        if self._project is None:
            return None
        return self._project(canon, k, seed)

    def lower_bound(self, screen_dist):
        if self._lower_bound is None:
            return screen_dist
        return self._lower_bound(screen_dist)


def orthonormal_projection(x: np.ndarray, k: int, seed: int = 0
                           ) -> np.ndarray:
    """(n, d) → (n, min(k, d)) float64 contractive screen embedding.

    Columns of the projector are orthonormal (QR of a seeded gaussian),
    so ``||P^T(x - y)||_2 <= ||x - y||_2`` holds *deterministically* —
    unlike a raw JL sketch, whose distortion is only probabilistic and
    could admit a false prune.  When ``d <= k`` the embedding is the
    identity (the screen bound is then the exact euclidean distance).
    """
    x = np.asarray(x, dtype=np.float64)
    n, d = x.shape
    if d <= k:
        return np.ascontiguousarray(x)
    g = np.random.default_rng(seed).standard_normal((d, k))
    q, _ = np.linalg.qr(g)                       # (d, k), orthonormal cols
    return x @ q


# --------------------------------------------------------------- registry
MetricLike = Union[str, Metric]

_REGISTRY: Dict[str, Callable[..., Metric]] = {}
# default-params resolutions share one instance per name: the derived
# kernel jit caches live on the instance, so handing every engine a
# fresh instance would recompile the tile/mask/count/compact kernels
# per build instead of once per process
_DEFAULT_INSTANCES: Dict[str, Metric] = {}


def register_metric(name_or_metric, pairwise_fn: Optional[Callable] = None,
                    *, overwrite: bool = False, **kw):
    """Admit a metric into the registry.

    Three forms:
      * ``register_metric(MetricSubclass)`` — class with a ``name``
      * ``register_metric("name", factory)`` — explicit factory/class
      * ``register_metric("name", distance_callable, dtype=..., ...)`` —
        a plain jnp distance function, wrapped in ``CallableMetric``
        (the no-Pallas dense path); extra kwargs become default params.

    Returns whatever was registered so it can be used as a decorator.
    """
    if isinstance(name_or_metric, type) and issubclass(name_or_metric, Metric):
        cls = name_or_metric
        _register(cls.name, cls, overwrite)
        return cls
    name = str(name_or_metric)
    if pairwise_fn is None:
        raise TypeError("register_metric(name, ...) needs a Metric factory "
                        "or a pairwise distance callable")
    if isinstance(pairwise_fn, type) and issubclass(pairwise_fn, Metric):
        _register(name, pairwise_fn, overwrite)
        return pairwise_fn

    def factory(**params):
        merged = dict(kw)
        merged.update(params)
        return CallableMetric(name, pairwise_fn, **merged)

    _register(name, factory, overwrite)
    return pairwise_fn


def _register(name: str, factory, overwrite: bool) -> None:
    if not overwrite and name in _REGISTRY:
        raise ValueError(f"metric {name!r} is already registered "
                         "(pass overwrite=True to replace it)")
    _REGISTRY[name] = factory
    _DEFAULT_INSTANCES.pop(name, None)


def registered_metrics() -> Tuple[str, ...]:
    """Sorted names of every registered metric."""
    return tuple(sorted(_REGISTRY))


def get_metric(spec: MetricLike, **params) -> Metric:
    """Resolve a metric name (or pass an instance through).

    This is the deprecation shim for the historical string API: every
    ``metric="euclidean"`` / ``"jaccard"`` call in the repo lands here.
    Unknown names fail with the registered alternatives spelled out.
    """
    if isinstance(spec, Metric):
        if params:
            raise TypeError("params are only accepted with a metric *name*; "
                            f"got an instance {spec!r} plus {params}")
        return spec
    if isinstance(spec, str):
        factory = _REGISTRY.get(spec)
        if factory is None:
            raise ValueError(
                f"unknown metric {spec!r}; registered metrics are "
                f"{list(registered_metrics())} (register_metric() adds "
                "user-defined distance functions)")
        if params:
            return factory(**params)
        m = _DEFAULT_INSTANCES.get(spec)
        if m is None:
            m = _DEFAULT_INSTANCES[spec] = factory()
        return m
    raise TypeError(f"metric must be a name or a Metric instance, got "
                    f"{type(spec).__name__}")
