"""Pluggable metric registry — FINEX's "flexible in data types and
distance functions" claim as a real API surface.

    from repro.metrics import get_metric, register_metric

    m = get_metric("euclidean")              # built-ins: euclidean,
    m = get_metric("jaccard")                # jaccard, cosine, cityblock
    register_metric("mine", my_pairwise_fn)  # user distance, dense path

Every ``metric=`` argument in the repo (engine, index, store, service,
fingerprints, npz round-trips) resolves through :func:`get_metric`, so
names and ``Metric`` instances are interchangeable everywhere.
"""
from repro.metrics.base import (CallableMetric, Metric, MetricLike,
                                get_metric, register_metric,
                                registered_metrics)
from repro.metrics.euclidean import EuclideanMetric, sq_threshold
from repro.metrics.jaccard import JaccardMetric
from repro.metrics.extra import CityblockMetric, CosineMetric

__all__ = [
    "Metric", "MetricLike", "CallableMetric",
    "get_metric", "register_metric", "registered_metrics",
    "EuclideanMetric", "JaccardMetric", "CosineMetric", "CityblockMetric",
    "sq_threshold",
]
