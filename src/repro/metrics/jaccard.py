"""Jaccard metric over packed-bitmap set data (process-mining workloads).

Prune-screen bound (why over-admission is the only possible failure mode)
------------------------------------------------------------------------
The projection screen needs an (n, k) embedding ``E`` with
``lower_bound(||E(a) − E(b)||₂) <= d_J(a, b)`` for *every* pair — a
deterministic inequality, not an estimate in expectation.  Classic
minhash gives an unbiased Jaccard *estimator* whose two-sided error
could under- as well as over-estimate the distance, so a screen built on
raw minhash signatures could prune a true neighbor.  We instead keep the
sketch but drop the estimator: a one-permutation *bucketed bitmap*
sketch whose bound direction is provable.

1.  Embed each set as its normalized indicator ``u_A = 1_A / √|A|``
    (a unit vector).  Since ``|A ∪ B| >= √(|A||B|)``,

        d_J(A,B) = 1 − |A∩B| / |A∪B|
                 >= 1 − |A∩B| / √(|A||B|)
                 = ½·||u_A − u_B||₂².

2.  Reduce dimension with one seeded permutation of the universe's bit
    positions into ``k'`` near-equal groups ``g_1..g_k'`` (the minhash
    bucketing, minus the min).  Universes up to the embedding cap use
    singleton groups — the embedding is then exactly ``u_A`` and the
    only slack is step 1's; wider universes share groups, which shrinks
    screen distances (admitting more) but never inflates them.  The
    group indicators ``v_j = 1_{g_j} / √|g_j|`` are orthonormal (groups
    are disjoint), and the sketch coordinate is the projection
    coefficient

        E(A)_j = ⟨u_A, v_j⟩ = |A ∩ g_j| / (√|g_j|·√|A|),

    an exact popcount ratio — no hashing noise.  Orthogonal projection
    is contractive (Cauchy–Schwarz/Bessel), so
    ``||E(A) − E(B)||₂ <= ||u_A − u_B||₂`` holds *deterministically*
    and the bound of step 1 survives:  ``lower_bound(s) = s²/2``.

3.  Empty sets get an extra indicator coordinate (the cosine-metric
    convention): ``E(∅) = (0,…,0,1)``, nonempty sets carry 0 there.
    Then s(∅,∅) = 0 (bound 0 = d_J) and s(∅,A)² = ||E(A)||² + 1 <= 2,
    i.e. bound <= 1 = d_J — tight exactly when the sketch captures all
    of ``u_A``'s mass.

Every remaining slack is one-sided by construction: coarser groups only
*shrink* the screen distance (projection discards mass), which admits
more candidates, never fewer true ones.  The engine's
``screen_thresholds`` folds the float32 rounding slack on top: it
bisects ``sup{s : s²/2 <= ε}`` in float64 and inflates the squared
device threshold by an ulp-dominating margin, so every rounding
direction — sketch, threshold, device float32 — can only over-admit.
The exact device kernels then re-check every admitted pair, so the CSR
stays byte-identical to the unpruned sweep.
"""
from __future__ import annotations

import numpy as np

from repro.kernels import ops, ref
from repro.metrics.base import Metric, register_metric


@register_metric
class JaccardMetric(Metric):
    """Sets as (bits (n, W) uint32, sizes (n,) int32) from
    ``neighbors.bitset.pack_sets``; |r ∩ s| is AND + popcount on the VPU.
    The two-array state (bitmaps + cardinalities) lives entirely behind
    the protocol — callers never unpack it."""

    name = "jaccard"

    def canonicalize(self, data):
        bits, sizes = data
        return (np.ascontiguousarray(np.asarray(bits, dtype=np.uint32)),
                np.ascontiguousarray(np.asarray(sizes, dtype=np.int32)))

    def pairwise(self, q, c):
        return ref.jaccard_distance(q[0], q[1], c[0], c[1])

    def tile(self, q, c, use_pallas: bool = False):
        return ops.jaccard_distance(q[0], q[1], c[0], c[1],
                                    use_pallas=use_pallas)

    def mask_tile(self, q, c, thresh):
        hit, d = ops.jaccard_mask_tile(q[0], q[1], c[0], c[1], thresh)
        return hit, (d,)

    def gather_pairs(self, payload, flat):
        return ops.gather_flat(payload[0], flat)

    def eps_count(self, q, c, eps, weights, use_pallas: bool = False):
        return ops.jaccard_eps_count(q[0], q[1], c[0], c[1], eps, weights,
                                     use_pallas=use_pallas)

    def eps_compact(self, q, c, eps, cap: int, use_pallas: bool = False):
        return ops.jaccard_eps_compact(q[0], q[1], c[0], c[1], eps, cap,
                                       use_pallas=use_pallas)

    def project(self, canon, k, seed: int = 0):
        # the bucketed bitmap sketch from the module docstring: unpack
        # the (n, W) uint32 bitmaps to per-bit indicators, assign each
        # bit position to one of k near-equal groups by one seeded
        # permutation, and keep the exact projection coefficient
        # |A ∩ g_j| / (√|g_j|·√|A|) per group (plus the empty-set
        # indicator coordinate). Content-only + seed-deterministic, so
        # insert strips project identically to the full sweep.
        bits, sizes = canon
        n, w = bits.shape
        universe = 32 * w
        # below the cap the groups are singletons and the embedding is
        # the exact normalized indicator u_A (the bound is then as tight
        # as step 1 allows); grouping only kicks in for universes too
        # wide to embed directly, where it trades tightness for memory
        # (each shared group can only shrink the screen distance — still
        # sound, just admits more)
        k_eff = max(1, min(universe, max(int(k), 1024)))
        # bit order within the uint8 view is irrelevant as long as it is
        # a fixed bijection of positions — 'little' matches pack_sets
        indic = np.unpackbits(
            bits.view(np.uint8), axis=1, bitorder="little")
        group = np.random.default_rng(seed).permutation(universe) % k_eff
        onehot = np.zeros((universe, k_eff), dtype=np.float64)
        onehot[np.arange(universe), group] = 1.0
        gcount = indic.astype(np.float64) @ onehot        # |A ∩ g_j|
        gsize = np.bincount(group, minlength=k_eff).astype(np.float64)
        sz = sizes.astype(np.float64)
        empty = sz == 0.0
        denom = np.sqrt(np.maximum(gsize, 1.0))[None, :] \
            * np.sqrt(np.where(empty, 1.0, sz))[:, None]
        e = gcount / denom
        e[empty] = 0.0
        return np.concatenate([e, empty[:, None].astype(np.float64)],
                              axis=-1)

    def lower_bound(self, screen_dist):
        return np.square(screen_dist) * 0.5

    @classmethod
    def synthesize(cls, rng, n, d=8):
        from repro.neighbors.bitset import pack_sets
        universe = 64
        sets = [rng.choice(universe, size=rng.integers(1, 12), replace=False)
                for _ in range(n)]
        return pack_sets(sets, universe=universe)
