"""Jaccard metric over packed-bitmap set data (process-mining workloads)."""
from __future__ import annotations

import numpy as np

from repro.kernels import ops, ref
from repro.metrics.base import Metric, register_metric


@register_metric
class JaccardMetric(Metric):
    """Sets as (bits (n, W) uint32, sizes (n,) int32) from
    ``neighbors.bitset.pack_sets``; |r ∩ s| is AND + popcount on the VPU.
    The two-array state (bitmaps + cardinalities) lives entirely behind
    the protocol — callers never unpack it."""

    name = "jaccard"

    def canonicalize(self, data):
        bits, sizes = data
        return (np.ascontiguousarray(np.asarray(bits, dtype=np.uint32)),
                np.ascontiguousarray(np.asarray(sizes, dtype=np.int32)))

    def pairwise(self, q, c):
        return ref.jaccard_distance(q[0], q[1], c[0], c[1])

    def tile(self, q, c, use_pallas: bool = False):
        return ops.jaccard_distance(q[0], q[1], c[0], c[1],
                                    use_pallas=use_pallas)

    def mask_tile(self, q, c, thresh):
        hit, d = ops.jaccard_mask_tile(q[0], q[1], c[0], c[1], thresh)
        return hit, (d,)

    def gather_pairs(self, payload, flat):
        return ops.gather_flat(payload[0], flat)

    def eps_count(self, q, c, eps, weights, use_pallas: bool = False):
        return ops.jaccard_eps_count(q[0], q[1], c[0], c[1], eps, weights,
                                     use_pallas=use_pallas)

    def eps_compact(self, q, c, eps, cap: int, use_pallas: bool = False):
        return ops.jaccard_eps_compact(q[0], q[1], c[0], c[1], eps, cap,
                                       use_pallas=use_pallas)

    @classmethod
    def synthesize(cls, rng, n, d=8):
        from repro.neighbors.bitset import pack_sets
        universe = 64
        sets = [rng.choice(universe, size=rng.integers(1, 12), replace=False)
                for _ in range(n)]
        return pack_sets(sets, universe=universe)
