"""Cosine and cityblock metrics — proof the registry seam carries metrics
the seed never special-cased, with zero engine/emit changes.

Cityblock runs entirely on the base class's derived kernel contract
(jit'd dense tile, fused mask sweep, ``ref.eps_compact_tile`` slot emit),
so it exercises exactly the code path a user-registered metric gets.
Cosine additionally carries fused Pallas count/emit kernels: the dataset
is unit-normalized (with a zero-row indicator coordinate) once per
sweep, after which cosine distance is a single MXU matmul away — the
same tile machinery as euclidean.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.metrics.base import Metric, orthonormal_projection, register_metric


@register_metric
class CosineMetric(Metric):
    """d(x, y) = 1 − x·y / (‖x‖‖y‖) over (n, d) float32 vectors.

    Implemented over *augmented unit rows* (``ref.cosine_normalize``):
    every row is normalized once and extended with a zero-row indicator
    coordinate, after which the distance is ``clip(1 − x̂·ŷ, 0, 2)`` —
    one matmul per tile, which is what lets the fused Pallas count/emit
    kernels reuse the euclidean MXU machinery verbatim.

    Zero-vector convention mirrors Jaccard's empty-set handling: two zero
    vectors are identical (distance 0); zero vs non-zero is maximally
    dissimilar (distance 1).  The indicator coordinate encodes exactly
    that — zero rows become the unit vector on the extra axis, so
    zero·zero = 1 (distance 0) and zero·nonzero = 0 (distance 1), while
    nonzero pairs pick up an exact ``+0.0`` term.
    """

    name = "cosine"

    def canonicalize(self, data):
        if isinstance(data, tuple) and len(data) == 1:
            data = data[0]
        return (np.ascontiguousarray(np.asarray(data, dtype=np.float32)),)

    def pairwise(self, q, c):
        return ref.cosine_distance(ref.cosine_normalize(q[0]),
                                   ref.cosine_normalize(c[0]))

    def eps_count(self, q, c, eps, weights, use_pallas: bool = False):
        return ops.cosine_eps_count(q[0], c[0], eps, weights,
                                    use_pallas=use_pallas)

    def eps_compact(self, q, c, eps, cap: int, use_pallas: bool = False):
        return ops.cosine_eps_compact(q[0], c[0], eps, cap,
                                      use_pallas=use_pallas)

    def screened_eps_compact(self, q, c, sq, sc, eps, s2t, cap: int,
                             num_valid=None, use_pallas: bool = False):
        return ops.screened_eps_compact(
            ref.cosine_normalize(q[0]), ref.cosine_normalize(c[0]),
            sq, sc, eps, s2t, cap, num_valid=num_valid,
            use_pallas=use_pallas, cosine=True)

    def screened_eps_count(self, q, c, sq, sc, eps, s2t, weights,
                           num_valid=None, use_pallas: bool = False):
        return ops.screened_eps_count(
            ref.cosine_normalize(q[0]), ref.cosine_normalize(c[0]),
            sq, sc, eps, s2t, weights, num_valid=num_valid,
            use_pallas=use_pallas, cosine=True)

    def project(self, canon, k, seed: int = 0):
        # the float64 mirror of ``ref.cosine_normalize``: screen distance
        # s = ||x̂a − ŷa||₂ satisfies s²/2 = d_cos exactly (2 − 2·x̂·ŷ for
        # vector pairs, and the indicator coordinate reproduces both
        # zero-row conventions), so the bound below is tight
        x = np.asarray(canon[0], dtype=np.float64)
        nrm = np.sqrt(np.sum(x * x, axis=-1, keepdims=True))
        zero = nrm == 0.0
        unit = np.divide(x, np.where(zero, 1.0, nrm))
        return np.concatenate([unit, zero.astype(np.float64)], axis=-1)

    def lower_bound(self, screen_dist):
        return np.square(screen_dist) * 0.5


@register_metric
class CityblockMetric(Metric):
    """L1 (Manhattan) distance over (n, d) float32 vectors.

    The (m, n, d) broadcast is sliced along the feature axis so the
    intermediate stays (m, n, dc) — the same VMEM-budget trick the packed
    Jaccard intersection uses on 64k-corpus tiles.
    """

    name = "cityblock"

    def __init__(self, feature_chunk: int = 8, **params):
        # feature_chunk goes through params so it survives the npz
        # round-trip and distinguishes fingerprints: different chunkings
        # produce bitwise-different float sums
        super().__init__(feature_chunk=int(feature_chunk), **params)
        self.feature_chunk = int(feature_chunk)

    def canonicalize(self, data):
        if isinstance(data, tuple) and len(data) == 1:
            data = data[0]
        return (np.ascontiguousarray(np.asarray(data, dtype=np.float32)),)

    def pairwise(self, q, c):
        x = q[0].astype(jnp.float32)
        y = c[0].astype(jnp.float32)
        m, d = x.shape
        acc = jnp.zeros((m, y.shape[0]), jnp.float32)
        dc = self.feature_chunk
        for w0 in range(0, d, dc):
            acc = acc + jnp.abs(x[:, None, w0:w0 + dc]
                                - y[None, :, w0:w0 + dc]).sum(-1)
        return acc

    def project(self, canon, k, seed: int = 0):
        # ||x − y||₂ <= ||x − y||₁ and the projection is contractive, so
        # the euclidean screen distance lower-bounds the L1 distance with
        # the identity lower_bound
        return orthonormal_projection(canon[0], k, seed)
