"""Cosine and cityblock metrics — proof the registry seam carries metrics
the seed never special-cased, with zero engine/emit changes.

Both run entirely on the base class's derived kernel contract (jit'd
dense tile, fused mask sweep, ``ref.eps_compact_tile`` slot emit), so
they exercise exactly the code path a user-registered metric gets.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.metrics.base import Metric, register_metric


@register_metric
class CosineMetric(Metric):
    """d(x, y) = 1 − x·y / (‖x‖‖y‖) over (n, d) float32 vectors.

    Zero-vector convention mirrors Jaccard's empty-set handling: two zero
    vectors are identical (distance 0); zero vs non-zero is maximally
    dissimilar (distance 1).
    """

    name = "cosine"

    def canonicalize(self, data):
        if isinstance(data, tuple) and len(data) == 1:
            data = data[0]
        return (np.ascontiguousarray(np.asarray(data, dtype=np.float32)),)

    def pairwise(self, q, c):
        x = q[0].astype(jnp.float32)
        y = c[0].astype(jnp.float32)
        nx = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True))     # (m, 1)
        ny = jnp.sqrt(jnp.sum(y * y, axis=-1, keepdims=True)).T   # (1, n)
        denom = nx * ny
        sim = jnp.where(denom > 0.0,
                        (x @ y.T) / jnp.where(denom > 0.0, denom, 1.0),
                        jnp.where((nx == 0.0) & (ny == 0.0), 1.0, 0.0))
        return jnp.clip(1.0 - sim, 0.0, 2.0).astype(jnp.float32)


@register_metric
class CityblockMetric(Metric):
    """L1 (Manhattan) distance over (n, d) float32 vectors.

    The (m, n, d) broadcast is sliced along the feature axis so the
    intermediate stays (m, n, dc) — the same VMEM-budget trick the packed
    Jaccard intersection uses on 64k-corpus tiles.
    """

    name = "cityblock"

    def __init__(self, feature_chunk: int = 8, **params):
        # feature_chunk goes through params so it survives the npz
        # round-trip and distinguishes fingerprints: different chunkings
        # produce bitwise-different float sums
        super().__init__(feature_chunk=int(feature_chunk), **params)
        self.feature_chunk = int(feature_chunk)

    def canonicalize(self, data):
        if isinstance(data, tuple) and len(data) == 1:
            data = data[0]
        return (np.ascontiguousarray(np.asarray(data, dtype=np.float32)),)

    def pairwise(self, q, c):
        x = q[0].astype(jnp.float32)
        y = c[0].astype(jnp.float32)
        m, d = x.shape
        acc = jnp.zeros((m, y.shape[0]), jnp.float32)
        dc = self.feature_chunk
        for w0 in range(0, d, dc):
            acc = acc + jnp.abs(x[:, None, w0:w0 + dc]
                                - y[None, :, w0:w0 + dc]).sum(-1)
        return acc
