"""Pallas TPU kernel for the projection-screen bucket-bound plane.

The projection-pruned sweep (``neighbors.engine``) decides per
(query tile × kd-bucket) whether the bucket can possibly contain an
ε-neighbor of any row in the tile: bucket b survives iff

    min_q ||E(q) − c_b||²  <=  (s_t + r_b)² + slack

with ``E`` the float32 screen embedding, ``c_b``/``r_b`` the bucket
center/radius and ``s_t`` the bisected screen threshold.  The left-hand
side is an (ntiles, nb) plane over the whole dataset — host numpy built
it through PR 6, which the ROADMAP flags as the scaling ceiling for
10M+ rows.  This kernel evaluates it on device: one MXU matmul per
(tile × center block) with a row-min reduction, so only the (nb,)
minima per tile (and later the bool survival plane) ever leave the
accelerator.

Numerical contract: the minima are float32 with MXU-expansion rounding,
compared against thresholds inflated by the same ``1e-4·(m2+1)`` slack
as the pair-level screen test (``screen_thresholds``), which dominates
every rounding source (expansion, float64→float32 embedding
quantization, threshold rounding).  Rounding can therefore only admit
an extra bucket — never prune a true neighbor.  Padded query rows use a
large-coordinate fill so they cannot lower any minimum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# padded query rows sit at distance ~1e16 from every real center: far
# beyond any threshold, so padding never creates a surviving bucket
_PAD_FILL = 1e8


def _pad_to(a: jax.Array, mult: int, axis: int, value=0.0) -> jax.Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def _bound_min2_kernel(x_ref, c_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                       # (TM, k)
    c = c_ref[...].astype(jnp.float32)                       # (TN, k)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)              # (TM, 1)
    c2 = jnp.sum(c * c, axis=-1, keepdims=True).T            # (1, TN)
    cross = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    d2 = jnp.maximum(x2 + c2 - 2.0 * cross, 0.0)             # (TM, TN)
    o_ref[...] = jnp.min(d2, axis=0, keepdims=True)          # (1, TN)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def bound_min2_pallas(pts: jax.Array, centers: jax.Array,
                      tm: int = 256, tn: int = 128,
                      interpret: bool = False) -> jax.Array:
    """(m, k) screen tile × (nb, k) bucket centers → (nb,) float32
    per-center minimum squared distance over the tile's rows.

    One sweep tile's row of the bucket-bound plane; the grid walks
    center blocks while the (padded) query tile stays resident in VMEM.
    """
    nb, k = centers.shape
    xp = _pad_to(pts.astype(jnp.float32), tm, 0, value=_PAD_FILL)
    cp = _pad_to(centers.astype(jnp.float32), tn, 0)
    grid = (cp.shape[0] // tn,)
    out = pl.pallas_call(
        _bound_min2_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((xp.shape[0], k), lambda j: (0, 0)),
                  pl.BlockSpec((tn, k), lambda j: (j, 0))],
        out_specs=pl.BlockSpec((1, tn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, cp.shape[0]), jnp.float32),
        interpret=interpret,
    )(xp, cp)
    return out[0, :nb]
