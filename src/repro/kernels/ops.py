"""Public jit'd wrappers over the Pallas kernels with jnp fallbacks.

On the TPU target the Pallas kernels run compiled; on this CPU container
they run in interpret mode (Python-level execution of the kernel body),
which is semantically exact but slow — so the default execution path on CPU
is the pure-jnp oracle from ``ref.py`` (same math, XLA-compiled). Kernel
tests exercise the interpret path explicitly against the oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.pairwise import pairwise_euclidean_pallas, eps_count_pallas
from repro.kernels.jaccard import (jaccard_distance_pallas,
                                   jaccard_eps_count_pallas)
from repro.kernels.kthdist import dist_histogram_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def pairwise_euclidean(x, y, use_pallas: bool = False):
    if use_pallas:
        return pairwise_euclidean_pallas(x, y, interpret=not _on_tpu())
    return ref.pairwise_euclidean(x, y)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def jaccard_distance(bits_a, size_a, bits_b, size_b, use_pallas: bool = False):
    if use_pallas:
        return jaccard_distance_pallas(bits_a, size_a, bits_b, size_b,
                                       interpret=not _on_tpu())
    return ref.jaccard_distance(bits_a, size_a, bits_b, size_b)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def eps_count(x, y, eps, weights, use_pallas: bool = False):
    """Weighted |N_eps| counts of x-rows against corpus y (euclidean)."""
    if use_pallas:
        return eps_count_pallas(x, y, eps, weights, interpret=not _on_tpu())
    d = ref.pairwise_euclidean(x, y)
    return jnp.where(d <= eps, weights[None, :].astype(jnp.float32), 0.0).sum(-1)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def jaccard_eps_count(bits_a, size_a, bits_b, size_b, eps, weights,
                      use_pallas: bool = False):
    if use_pallas:
        return jaccard_eps_count_pallas(bits_a, size_a, bits_b, size_b, eps,
                                        weights, interpret=not _on_tpu())
    d = ref.jaccard_distance(bits_a, size_a, bits_b, size_b)
    return jnp.where(d <= eps, weights[None, :].astype(jnp.float32), 0.0).sum(-1)


@functools.partial(jax.jit, static_argnames=("nbins", "use_pallas"))
def dist_histogram(x, y, edges, nbins: int = 16, use_pallas: bool = False):
    if use_pallas:
        return dist_histogram_pallas(x, y, edges, nbins=nbins,
                                     interpret=not _on_tpu())
    d = ref.pairwise_euclidean(x, y)
    return ref.tile_histogram(d, edges).astype(jnp.float32)
