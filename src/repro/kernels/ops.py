"""Public jit'd wrappers over the Pallas kernels with jnp fallbacks.

On the TPU target the Pallas kernels run compiled; on this CPU container
they run in interpret mode (Python-level execution of the kernel body),
which is semantically exact but slow — so the default execution path on CPU
is the pure-jnp oracle from ``ref.py`` (same math, XLA-compiled). Kernel
tests exercise the interpret path explicitly against the oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bounds import bound_min2_pallas
from repro.kernels.pairwise import (pairwise_euclidean_pallas,
                                    eps_count_pallas, eps_emit_pallas,
                                    cosine_eps_count_pallas,
                                    cosine_eps_emit_pallas,
                                    screened_eps_emit_pallas)
from repro.kernels.jaccard import (jaccard_distance_pallas,
                                   jaccard_eps_count_pallas,
                                   jaccard_eps_emit_pallas)
from repro.kernels.kthdist import dist_histogram_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def pairwise_euclidean(x, y, use_pallas: bool = False):
    if use_pallas:
        return pairwise_euclidean_pallas(x, y, interpret=not _on_tpu())
    return ref.pairwise_euclidean(x, y)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def jaccard_distance(bits_a, size_a, bits_b, size_b, use_pallas: bool = False):
    if use_pallas:
        return jaccard_distance_pallas(bits_a, size_a, bits_b, size_b,
                                       interpret=not _on_tpu())
    return ref.jaccard_distance(bits_a, size_a, bits_b, size_b)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def eps_count(x, y, eps, weights, use_pallas: bool = False):
    """Weighted |N_eps| counts of x-rows against corpus y (euclidean)."""
    if use_pallas:
        return eps_count_pallas(x, y, eps, weights, interpret=not _on_tpu())
    d = ref.pairwise_euclidean(x, y)
    return jnp.where(d <= eps, weights[None, :].astype(jnp.float32), 0.0).sum(-1)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def jaccard_eps_count(bits_a, size_a, bits_b, size_b, eps, weights,
                      use_pallas: bool = False):
    if use_pallas:
        return jaccard_eps_count_pallas(bits_a, size_a, bits_b, size_b, eps,
                                        weights, interpret=not _on_tpu())
    d = ref.jaccard_distance(bits_a, size_a, bits_b, size_b)
    return jnp.where(d <= eps, weights[None, :].astype(jnp.float32), 0.0).sum(-1)


@functools.partial(jax.jit, static_argnames=("cap", "use_pallas"))
def eps_compact(x, y, eps, cap: int, use_pallas: bool = False):
    """Fused ε-threshold + emit: per-row compacted (col, dist) slots.

    Returns ``(lens, cols, dvals)`` — see ``ref.eps_compact_tile``.  On
    TPU this is the capacity-capped fast path of the materialize sweep:
    the dense distance plane never reaches HBM/host.  True per-row
    lengths may exceed ``cap``; the caller re-extracts overflow rows
    from a dense tile (byte-identical fallback).
    """
    if use_pallas:
        return eps_emit_pallas(x, y, eps, cap, interpret=not _on_tpu())
    d = ref.pairwise_euclidean(x, y)
    return ref.eps_compact_tile(d, eps, cap)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def cosine_eps_count(x, y, eps, weights, use_pallas: bool = False):
    """Weighted |N_eps| counts under cosine distance.

    Rows are augmented-unit-normalized once (``ref.cosine_normalize``)
    and the fused euclidean-style tile kernels take over.
    """
    xa = ref.cosine_normalize(x)
    ya = ref.cosine_normalize(y)
    if use_pallas:
        return cosine_eps_count_pallas(xa, ya, eps, weights,
                                       interpret=not _on_tpu())
    d = ref.cosine_distance(xa, ya)
    return jnp.where(d <= eps, weights[None, :].astype(jnp.float32), 0.0).sum(-1)


@functools.partial(jax.jit, static_argnames=("cap", "use_pallas"))
def cosine_eps_compact(x, y, eps, cap: int, use_pallas: bool = False):
    """Fused ε-threshold + emit under cosine distance; contract of
    ``eps_compact``."""
    xa = ref.cosine_normalize(x)
    ya = ref.cosine_normalize(y)
    if use_pallas:
        return cosine_eps_emit_pallas(xa, ya, eps, cap,
                                      interpret=not _on_tpu())
    d = ref.cosine_distance(xa, ya)
    return ref.eps_compact_tile(d, eps, cap)


@functools.partial(jax.jit,
                   static_argnames=("cap", "use_pallas", "cosine"))
def screened_eps_compact(x, y, sx, sy, eps, s2t, cap: int, num_valid=None,
                         use_pallas: bool = False, cosine: bool = False):
    """Projection-pruned fused emit (euclidean or cosine tile math).

    ``sx``/``sy`` are screen embeddings, ``s2t`` the slack-inflated
    squared screen threshold, ``num_valid`` the unpadded corpus extent.
    Returns ``(lens, cols, dvals, cand)`` — byte-identical slots to the
    unscreened ``eps_compact`` (the screen only removes provable
    non-hits) plus per-row candidate counts.  Cosine callers pass
    pre-normalized augmented rows.
    """
    if use_pallas:
        return screened_eps_emit_pallas(x, y, sx, sy, eps, s2t, cap,
                                        interpret=not _on_tpu(),
                                        num_valid=num_valid, cosine=cosine)
    d = ref.cosine_distance(x, y) if cosine else ref.pairwise_euclidean(x, y)
    keep, _ = ref.screened_hit_tile(jnp.ones(d.shape, bool), sx, sy, s2t,
                                    y.shape[0] if num_valid is None
                                    else num_valid)
    cand = jnp.sum(keep.astype(jnp.int32), axis=1)
    d_scr = jnp.where(keep, d, jnp.inf)
    lens, cols, dvals = ref.eps_compact_tile(d_scr, eps, cap)
    return lens, cols, dvals, cand


@functools.partial(jax.jit, static_argnames=("use_pallas", "cosine"))
def screened_eps_count(x, y, sx, sy, eps, s2t, weights, num_valid=None,
                       use_pallas: bool = False, cosine: bool = False):
    """Projection-pruned weighted |N_eps| counts; returns
    ``(counts, cand)``.  Counts are bit-identical to the unscreened path
    (the screen mask is a superset of the hit plane by the lower-bound
    contract)."""
    del use_pallas  # counts are bandwidth-trivial; oracle path everywhere
    d = ref.cosine_distance(x, y) if cosine else ref.pairwise_euclidean(x, y)
    keep, _ = ref.screened_hit_tile(jnp.ones(d.shape, bool), sx, sy, s2t,
                                    y.shape[0] if num_valid is None
                                    else num_valid)
    cand = jnp.sum(keep.astype(jnp.int32), axis=1)
    w = weights[None, :].astype(jnp.float32)
    counts = jnp.where((d <= eps) & keep, w, 0.0).sum(-1)
    return counts, cand


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def bound_min2(pts, centers, use_pallas: bool = False):
    """Device-side bucket-bound row: per-center min squared screen
    distance over a sweep tile → (nb,) float32.  The (ntiles, nb) plane
    the host used to build in numpy is now ``jnp.stack`` of these rows,
    resident on device until the per-ε survival compare."""
    if use_pallas:
        return bound_min2_pallas(pts, centers, interpret=not _on_tpu())
    return ref.bound_min2_tile(pts, centers)


@jax.jit
def bound_survive(min2, thresh):
    """Per-ε bucket survival: compare the device-resident bound plane
    against slack-inflated squared thresholds ``(s_t + r_b)² + slack``
    (float64-bisected on host, one (nb,) float32 upload per ε).  Only
    this bool plane crosses back to the host."""
    return min2 <= thresh


@functools.partial(jax.jit, static_argnames=("cap", "use_pallas"))
def jaccard_eps_compact(bits_a, size_a, bits_b, size_b, eps, cap: int,
                        use_pallas: bool = False):
    """Fused ε-threshold + emit under Jaccard distance (set data)."""
    if use_pallas:
        return jaccard_eps_emit_pallas(bits_a, size_a, bits_b, size_b, eps,
                                       cap, interpret=not _on_tpu())
    d = ref.jaccard_distance(bits_a, size_a, bits_b, size_b)
    return ref.eps_compact_tile(d, eps, cap)


# ---------------------------------------------------------------------------
# Compacted-sweep helpers for backends without a compiled emit kernel
# (the CPU/XLA path of ``NeighborEngine.materialize``): the device emits a
# bool hit plane and keeps the expensive intermediates resident; the host
# turns the plane into flat pair ids (cheap, vectorized); a second jit
# gathers ONLY the surviving pairs' distances — O(nnz) float traffic
# instead of the O(m·n) dense plane.
# ---------------------------------------------------------------------------

@jax.jit
def eps_mask_tile(x, y, sq_thresh):
    """Fused matmul + squared-distance threshold → (hit, cross, x2, y2).

    ``sq_thresh`` must be the *exact* squared image of the ε-ball (see
    ``repro.metrics.sq_threshold``): because float32 sqrt is correctly
    rounded and monotone, {d² : sqrt(d²) ≤ ε} = {d² ≤ T} for the right T,
    so the hit plane is bit-identical to thresholding sqrt'd distances —
    without evaluating m·n square roots.  ``cross``/``x2``/``y2`` stay on
    device for ``eps_gather_pairs``.
    """
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    x2 = jnp.sum(xf * xf, axis=-1, keepdims=True)
    y2 = jnp.sum(yf * yf, axis=-1, keepdims=True).T
    cross = xf @ yf.T
    hit = (x2 + y2 - 2.0 * cross) <= sq_thresh
    return hit, cross, x2[:, 0], y2[0]


@jax.jit
def eps_gather_pairs(cross, x2, y2, flat):
    """sqrt'd distances of the surviving pairs only.

    ``flat`` are row-major pair ids into the (m, n) tile (padded; excess
    entries are junk the caller slices off).  Reconstructs
    ``sqrt(max(x2 + y2 - 2·cross, 0))`` from the *same* cross-product
    buffer the hit plane was computed from, so the emitted float bits are
    identical to the dense plane's.
    """
    n = cross.shape[1]
    r = flat // n
    c = flat - r * n
    v = cross.reshape(-1)[flat]
    return jnp.sqrt(jnp.maximum(x2[r] + y2[c] - 2.0 * v, 0.0))


@jax.jit
def jaccard_mask_tile(bits_a, size_a, bits_b, size_b, eps):
    """Fused Jaccard tile + threshold → (hit, dists); dists stay on device
    for ``gather_flat`` (the Jaccard plane has no cheap factored form, so
    the compacted win is skipping the O(m·n) float transfer, not the
    distance math)."""
    d = ref.jaccard_distance(bits_a, size_a, bits_b, size_b)
    return d <= eps, d


@jax.jit
def gather_flat(dists, flat):
    """Row-major gather of surviving pair distances from a resident tile."""
    return dists.reshape(-1)[flat]


@functools.partial(jax.jit, static_argnames=("nbins", "use_pallas"))
def dist_histogram(x, y, edges, nbins: int = 16, use_pallas: bool = False):
    if use_pallas:
        return dist_histogram_pallas(x, y, edges, nbins=nbins,
                                     interpret=not _on_tpu())
    d = ref.pairwise_euclidean(x, y)
    return ref.tile_histogram(d, edges).astype(jnp.float32)
