"""Pallas TPU kernels for the pairwise-Euclidean distance plane.

The TPU-native formulation of the paper's neighborhood computation: the
(m × n) distance matrix is produced in (TM × TN) VMEM tiles, where the cross
term is a single MXU matmul per tile pair:

    d²(x, y) = ‖x‖² + ‖y‖² − 2·x·yᵀ

Three kernels:
  * ``pairwise_euclidean_pallas`` — emits the distance tile (for CSR
    extraction / verification sub-matrices).
  * ``eps_count_pallas`` — *fused* threshold counting: the (TM × TN) tile
    never leaves VMEM; only per-row weighted neighbor counts |N_ε| are
    written. This is the build-time hot loop (the paper's o.N attribute).
  * ``eps_emit_pallas`` — *fused* threshold + compaction: surviving
    (col, dist) pairs are scattered into per-row capacity slots while the
    distance tile stays in VMEM, so HBM/host traffic for the ε-sweep is
    O(m·cap) ≈ O(nnz) instead of O(m·n).  The count pass sizes the slots;
    overflow rows keep their first ``cap`` hits and report a true length
    > cap so the caller can fall back to a dense tile for just those rows.

Tiles default to 128×128: MXU-aligned on the matmul dims, and the fp32
working set (TM·d + TN·d + TM·TN floats, d ≤ 4k) stays well under the
~16 MiB/core VMEM budget of a v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pad_to(a: jax.Array, mult: int, axis: int, value=0.0) -> jax.Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def _dist_tile_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                       # (TM, d)
    y = y_ref[...].astype(jnp.float32)                       # (TN, d)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)              # (TM, 1)
    y2 = jnp.sum(y * y, axis=-1, keepdims=True).T            # (1, TN)
    cross = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[...] = jnp.sqrt(jnp.maximum(x2 + y2 - 2.0 * cross, 0.0))


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def pairwise_euclidean_pallas(x: jax.Array, y: jax.Array,
                              tm: int = 128, tn: int = 128,
                              interpret: bool = False) -> jax.Array:
    """(m, d) × (n, d) → (m, n) float32 Euclidean distances."""
    m, d = x.shape
    n, _ = y.shape
    xp = _pad_to(x.astype(jnp.float32), tm, 0)
    yp = _pad_to(y.astype(jnp.float32), tn, 0)
    grid = (xp.shape[0] // tm, yp.shape[0] // tn)
    out = pl.pallas_call(
        _dist_tile_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, d), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], yp.shape[0]), jnp.float32),
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n]


def _count_kernel(n_valid, tn, x_ref, y_ref, eps_ref, w_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    y2 = jnp.sum(y * y, axis=-1, keepdims=True).T
    cross = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    dist = jnp.sqrt(jnp.maximum(x2 + y2 - 2.0 * cross, 0.0))    # (TM, TN)
    col = j * tn + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    w = w_ref[...].astype(jnp.float32)                           # (1, TN)
    hit = jnp.where((dist <= eps_ref[0, 0]) & (col < n_valid), w, 0.0)
    o_ref[...] += jnp.sum(hit, axis=1, keepdims=True)


def emit_tile_slots(hit, col, dist, cap, cc, len_ref, col_ref, dist_ref):
    """Shared in-kernel slot fill for the fused emit kernels.

    Scatter-free: slots are filled by a chunked one-hot reduction over the
    tile's column axis (VPU compare + select + sum — the (TM, TN, CC)
    intermediate stays in VMEM).  Each slot is written by exactly one
    (tile, column) across the whole corpus sweep, because the per-row
    cursor advances monotonically, so ``+=`` composes the corpus tiles.
    The per-row cursor in ``len_ref`` advances by the tile's TRUE hit
    counts — overflow stays detectable.  Both metric kernels route
    through this helper so their emit semantics cannot diverge.
    """
    cursor = len_ref[...]                                       # (TM, 1)
    incl = jnp.cumsum(hit.astype(jnp.int32), axis=1)
    pos = cursor + incl - 1           # target slot of each surviving pair

    def emit_chunk(k, _):
        base = k * cc
        slot = base + jax.lax.broadcasted_iota(jnp.int32, (1, 1, cc), 2)
        oh = (pos[:, :, None] == slot) & hit[:, :, None]        # (TM,TN,CC)
        col_ref[:, pl.ds(base, cc)] += jnp.sum(
            jnp.where(oh, col[:, :, None], 0), axis=1)
        dist_ref[:, pl.ds(base, cc)] += jnp.sum(
            jnp.where(oh, dist[:, :, None], 0.0), axis=1)
        return 0

    jax.lax.fori_loop(0, cap // cc, emit_chunk, 0)
    len_ref[...] = cursor + incl[:, -1:]


def _emit_kernel(n_valid, tn, cap, cc, x_ref, y_ref, eps_ref,
                 len_ref, col_ref, dist_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        len_ref[...] = jnp.zeros_like(len_ref)
        col_ref[...] = jnp.zeros_like(col_ref)
        dist_ref[...] = jnp.zeros_like(dist_ref)

    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    y2 = jnp.sum(y * y, axis=-1, keepdims=True).T
    cross = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    dist = jnp.sqrt(jnp.maximum(x2 + y2 - 2.0 * cross, 0.0))    # (TM, TN)
    col = j * tn + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    hit = (dist <= eps_ref[0, 0]) & (col < n_valid)
    emit_tile_slots(hit, col, dist, cap, cc, len_ref, col_ref, dist_ref)


@functools.partial(jax.jit,
                   static_argnames=("cap", "tm", "tn", "cc", "interpret"))
def eps_emit_pallas(x: jax.Array, y: jax.Array, eps: jax.Array, cap: int,
                    tm: int = 128, tn: int = 128, cc: int = 128,
                    interpret: bool = False):
    """Fused ε-threshold + emit: per-row compacted (col, dist) slots.

    Returns ``(lens, cols, dvals)`` exactly as ``ref.eps_compact_tile``
    over the full distance plane: lens (m,) int32 true hit counts (may
    exceed ``cap``), cols (m, cap) int32 ascending neighbor ids, dvals
    (m, cap) float32 distances.  The (TM × TN) distance tile never leaves
    VMEM; traffic is O(m·d + n·d + m·cap) ≈ O(nnz) for a well-sized
    capacity, vs O(m·n) for the dense plane.  ``cap`` must be a multiple
    of the emit chunk ``cc``.  The slot fill is O(TM·TN·cap) VPU work per
    tile pair — sized for capacity-capped sweeps (cap ≪ n); a sort-based
    in-tile compaction would trade that for MXU-unfriendly data movement.
    """
    if cap % cc != 0:
        raise ValueError(f"cap ({cap}) must be a multiple of cc ({cc})")
    m, d = x.shape
    n, _ = y.shape
    xp = _pad_to(x.astype(jnp.float32), tm, 0)
    yp = _pad_to(y.astype(jnp.float32), tn, 0)
    eps_arr = jnp.asarray(eps, jnp.float32).reshape(1, 1)
    grid = (xp.shape[0] // tm, yp.shape[0] // tn)
    kernel = functools.partial(_emit_kernel, n, tn, cap, cc)
    lens, cols, dvals = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_specs=[pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((tm, cap), lambda i, j: (i, 0)),
                   pl.BlockSpec((tm, cap), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.int32),
                   jax.ShapeDtypeStruct((xp.shape[0], cap), jnp.int32),
                   jax.ShapeDtypeStruct((xp.shape[0], cap), jnp.float32)],
        interpret=interpret,
    )(xp, yp, eps_arr)
    return lens[:m, 0], cols[:m], dvals[:m]


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def eps_count_pallas(x: jax.Array, y: jax.Array, eps: jax.Array,
                     weights: jax.Array, tm: int = 128, tn: int = 128,
                     interpret: bool = False) -> jax.Array:
    """Fused |N_ε| count: (m,) float32 weighted neighbor counts of x in y.

    The distance tile stays in VMEM; HBM traffic is O(m·d + n·d + m) instead
    of O(m·n). ``weights`` are the paper's duplicate counts (§6).
    """
    m, d = x.shape
    n, _ = y.shape
    xp = _pad_to(x.astype(jnp.float32), tm, 0)
    yp = _pad_to(y.astype(jnp.float32), tn, 0)
    wp = _pad_to(weights.astype(jnp.float32)[None, :], tn, 1)
    eps_arr = jnp.asarray(eps, jnp.float32).reshape(1, 1)
    grid = (xp.shape[0] // tm, yp.shape[0] // tn)
    kernel = functools.partial(_count_kernel, n, tn)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
                  pl.BlockSpec((1, tn), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32),
        interpret=interpret,
    )(xp, yp, eps_arr, wp)
    return out[:m, 0]
