"""Pallas TPU kernels for the pairwise-Euclidean distance plane.

The TPU-native formulation of the paper's neighborhood computation: the
(m × n) distance matrix is produced in (TM × TN) VMEM tiles, where the cross
term is a single MXU matmul per tile pair:

    d²(x, y) = ‖x‖² + ‖y‖² − 2·x·yᵀ

Three kernels:
  * ``pairwise_euclidean_pallas`` — emits the distance tile (for CSR
    extraction / verification sub-matrices).
  * ``eps_count_pallas`` — *fused* threshold counting: the (TM × TN) tile
    never leaves VMEM; only per-row weighted neighbor counts |N_ε| are
    written. This is the build-time hot loop (the paper's o.N attribute).
  * ``eps_emit_pallas`` — *fused* threshold + compaction: surviving
    (col, dist) pairs are scattered into per-row capacity slots while the
    distance tile stays in VMEM, so HBM/host traffic for the ε-sweep is
    O(m·cap) ≈ O(nnz) instead of O(m·n).  The count pass sizes the slots;
    overflow rows keep their first ``cap`` hits and report a true length
    > cap so the caller can fall back to a dense tile for just those rows.

Tiles default to 128×128: MXU-aligned on the matmul dims, and the fp32
working set (TM·d + TN·d + TM·TN floats, d ≤ 4k) stays well under the
~16 MiB/core VMEM budget of a v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pad_to(a: jax.Array, mult: int, axis: int, value=0.0) -> jax.Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def _dist_tile_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                       # (TM, d)
    y = y_ref[...].astype(jnp.float32)                       # (TN, d)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)              # (TM, 1)
    y2 = jnp.sum(y * y, axis=-1, keepdims=True).T            # (1, TN)
    cross = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[...] = jnp.sqrt(jnp.maximum(x2 + y2 - 2.0 * cross, 0.0))


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def pairwise_euclidean_pallas(x: jax.Array, y: jax.Array,
                              tm: int = 128, tn: int = 128,
                              interpret: bool = False) -> jax.Array:
    """(m, d) × (n, d) → (m, n) float32 Euclidean distances."""
    m, d = x.shape
    n, _ = y.shape
    xp = _pad_to(x.astype(jnp.float32), tm, 0)
    yp = _pad_to(y.astype(jnp.float32), tn, 0)
    grid = (xp.shape[0] // tm, yp.shape[0] // tn)
    out = pl.pallas_call(
        _dist_tile_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, d), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], yp.shape[0]), jnp.float32),
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n]


def _euclidean_tile(x_ref, y_ref):
    """Exact euclidean distance tile from two VMEM row blocks."""
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    y2 = jnp.sum(y * y, axis=-1, keepdims=True).T
    cross = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    return jnp.sqrt(jnp.maximum(x2 + y2 - 2.0 * cross, 0.0))    # (TM, TN)


def _cosine_tile(x_ref, y_ref):
    """Cosine distance tile over *augmented unit* rows
    (``ref.cosine_normalize``): one MXU matmul + clip — the euclidean
    tile machinery with the norm terms folded away."""
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    cross = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    return jnp.clip(1.0 - cross, 0.0, 2.0)


def _count_kernel(dist_fn, tn, x_ref, y_ref, eps_ref, nv_ref, w_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    dist = dist_fn(x_ref, y_ref)                                 # (TM, TN)
    col = j * tn + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    w = w_ref[...].astype(jnp.float32)                           # (1, TN)
    hit = jnp.where((dist <= eps_ref[0, 0]) & (col < nv_ref[0, 0]), w, 0.0)
    o_ref[...] += jnp.sum(hit, axis=1, keepdims=True)


_SENTINEL = 2 ** 31 - 1      # int32 max: "no entry" key for the sort fill


def _next_pow2(v: int) -> int:
    p = 1
    while p < v:
        p <<= 1
    return p


def _lane_iota(shape):
    return jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 1)


def _xor_partner(a, j):
    """Value at index ``i ^ j`` along the last axis (j a power of two).

    Bit j of i decides the direction, so two static rolls + a select
    reproduce the XOR shuffle without gathers — VPU-friendly inside a
    Pallas kernel body.
    """
    left = jnp.roll(a, -j, axis=-1)
    right = jnp.roll(a, j, axis=-1)
    return jnp.where((_lane_iota(a.shape) & j) == 0, left, right)


def _cmp_exchange(key, col, dist, j, asc):
    """One bitonic substage: compare-exchange each element with its
    ``i ^ j`` partner, co-moving the (col, dist) payload.  ``asc`` marks
    the elements inside ascending blocks (scalar True for a merge)."""
    kp = _xor_partner(key, j)
    cp = _xor_partner(col, j)
    dp = _xor_partner(dist, j)
    lower = (_lane_iota(key.shape) & j) == 0
    swap = jnp.where(lower == asc, key > kp, key < kp)
    return (jnp.where(swap, kp, key), jnp.where(swap, cp, col),
            jnp.where(swap, dp, dist))


def _bitonic_sort(key, col, dist):
    """Ascending sort along the (power-of-two) last axis."""
    w = key.shape[-1]
    k = 2
    while k <= w:
        asc = (_lane_iota(key.shape) & k) == 0
        j = k // 2
        while j >= 1:
            key, col, dist = _cmp_exchange(key, col, dist, j, asc)
            j //= 2
        k *= 2
    return key, col, dist


def _bitonic_merge(key, col, dist):
    """Ascending merge of a bitonic sequence along the last axis."""
    j = key.shape[-1] // 2
    while j >= 1:
        key, col, dist = _cmp_exchange(key, col, dist, j, True)
        j //= 2
    return key, col, dist


def emit_tile_slots(hit, col, dist, cap, cc, len_ref, col_ref, dist_ref):
    """Shared in-kernel slot fill for the fused emit kernels — sort-based.

    Each surviving pair's target slot is ``cursor + rank − 1`` (ranks from
    a row cumsum, so targets are contiguous from the cursor).  A bitonic
    sort over the tile's TN columns compacts the survivors (key = target
    slot, ``INT32_MAX`` sentinel otherwise), and one bitonic *merge*
    folds them into the running cap-wide slot buffer: the buffer's live
    keys 0..cursor−1 are already ascending, so
    ``[buffer | sentinel pad | reversed new]`` is bitonic by
    construction.  After the merge the live keys are exactly 0..count−1,
    i.e. every entry sits at the slot its key names; sentinel lanes are
    zeroed to preserve the empty-slot convention.  This is
    O(TN·log²TN + W·logW) compare-exchanges per tile (W = the padded
    cap+TN width) instead of the O(TM·TN·cap) one-hot fill it replaces.
    The per-row cursor in ``len_ref`` still advances by the tile's TRUE
    hit counts — overflow stays detectable.  Both metric kernels route
    through this helper so their emit semantics cannot diverge.
    ``cc`` (the old fill's chunk width) is retained for call-site
    compatibility and unused.
    """
    del cc
    tm, tn = hit.shape
    sent = jnp.int32(_SENTINEL)
    cursor = len_ref[...]                                       # (TM, 1)
    incl = jnp.cumsum(hit.astype(jnp.int32), axis=1)
    pos = cursor + incl - 1           # target slot of each surviving pair
    key_new = jnp.where(hit & (pos < cap), pos, sent)
    key_new, col_new, dist_new = _bitonic_sort(key_new, col, dist)
    slot = jax.lax.broadcasted_iota(jnp.int32, (tm, cap), 1)
    filled = slot < jnp.minimum(cursor, cap)
    key_old = jnp.where(filled, slot, sent)
    pad = _next_pow2(cap + tn) - cap - tn

    def cat(old, new, fill):
        parts = [old]
        if pad:
            parts.append(jnp.full((tm, pad), fill, old.dtype))
        parts.append(jnp.flip(new, axis=1))
        return jnp.concatenate(parts, axis=1)

    key_m, col_m, dist_m = _bitonic_merge(
        cat(key_old, key_new, sent),
        cat(col_ref[...], col_new, 0),
        cat(dist_ref[...], dist_new, 0.0))
    live = key_m[:, :cap] != sent
    col_ref[...] = jnp.where(live, col_m[:, :cap], 0)
    dist_ref[...] = jnp.where(live, dist_m[:, :cap], 0.0)
    len_ref[...] = cursor + incl[:, -1:]


def _emit_kernel(dist_fn, tn, cap, cc, x_ref, y_ref, eps_ref, nv_ref,
                 len_ref, col_ref, dist_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        len_ref[...] = jnp.zeros_like(len_ref)
        col_ref[...] = jnp.zeros_like(col_ref)
        dist_ref[...] = jnp.zeros_like(dist_ref)

    dist = dist_fn(x_ref, y_ref)                                 # (TM, TN)
    col = j * tn + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    hit = (dist <= eps_ref[0, 0]) & (col < nv_ref[0, 0])
    emit_tile_slots(hit, col, dist, cap, cc, len_ref, col_ref, dist_ref)


def _screened_emit_kernel(dist_fn, tn, cap, cc, x_ref, y_ref, sx_ref, sy_ref,
                          eps_ref, s2t_ref, nv_ref,
                          len_ref, col_ref, dist_ref, cand_ref):
    """Fused bound + screen + verify + emit (the tentpole kernel).

    The *screen* tile — squared euclidean distances between the k-dim
    projections — is a cheap MXU matmul; pairs above the (slack-inflated)
    screen threshold provably cannot survive ε, so the expensive exact
    distance tile is computed only under ``pl.when(alive)``: a
    (rowblock × colblock) tile with no surviving candidate is skipped
    before its distances exist.  Surviving tiles mask the exact hit plane
    with the pair-level bound (a no-op on true hits by the lower-bound
    contract) and emit through the shared sort-based slot fill.
    ``cand_ref`` accumulates per-row candidate counts — the exactness-
    preserving work the screen could not rule out.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        len_ref[...] = jnp.zeros_like(len_ref)
        col_ref[...] = jnp.zeros_like(col_ref)
        dist_ref[...] = jnp.zeros_like(dist_ref)
        cand_ref[...] = jnp.zeros_like(cand_ref)

    sx = sx_ref[...].astype(jnp.float32)
    sy = sy_ref[...].astype(jnp.float32)
    sx2 = jnp.sum(sx * sx, axis=-1, keepdims=True)
    sy2 = jnp.sum(sy * sy, axis=-1, keepdims=True).T
    scross = jax.lax.dot_general(sx, sy, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    s2 = jnp.maximum(sx2 + sy2 - 2.0 * scross, 0.0)
    col = j * tn + jax.lax.broadcasted_iota(jnp.int32, s2.shape, 1)
    keep = (s2 <= s2t_ref[0, 0]) & (col < nv_ref[0, 0])
    cand_ref[...] += jnp.sum(keep.astype(jnp.int32), axis=1, keepdims=True)

    @pl.when(jnp.any(keep))
    def _verify():
        dist = dist_fn(x_ref, y_ref)
        hit = (dist <= eps_ref[0, 0]) & keep
        emit_tile_slots(hit, col, dist, cap, cc, len_ref, col_ref, dist_ref)


def _emit_call(dist_fn, x, y, eps, cap, tm, tn, cc, interpret, num_valid):
    """Shared launch plumbing for the fused emit kernels (any tile metric)."""
    if cap % cc != 0:
        raise ValueError(f"cap ({cap}) must be a multiple of cc ({cc})")
    m, d = x.shape
    n, _ = y.shape
    xp = _pad_to(x.astype(jnp.float32), tm, 0)
    yp = _pad_to(y.astype(jnp.float32), tn, 0)
    eps_arr = jnp.asarray(eps, jnp.float32).reshape(1, 1)
    nv = jnp.asarray(n if num_valid is None else num_valid,
                     jnp.int32).reshape(1, 1)
    grid = (xp.shape[0] // tm, yp.shape[0] // tn)
    kernel = functools.partial(_emit_kernel, dist_fn, tn, cap, cc)
    lens, cols, dvals = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_specs=[pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((tm, cap), lambda i, j: (i, 0)),
                   pl.BlockSpec((tm, cap), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.int32),
                   jax.ShapeDtypeStruct((xp.shape[0], cap), jnp.int32),
                   jax.ShapeDtypeStruct((xp.shape[0], cap), jnp.float32)],
        interpret=interpret,
    )(xp, yp, eps_arr, nv)
    return lens[:m, 0], cols[:m], dvals[:m]


@functools.partial(jax.jit,
                   static_argnames=("cap", "tm", "tn", "cc", "interpret"))
def eps_emit_pallas(x: jax.Array, y: jax.Array, eps: jax.Array, cap: int,
                    tm: int = 128, tn: int = 128, cc: int = 128,
                    interpret: bool = False, num_valid=None):
    """Fused ε-threshold + emit: per-row compacted (col, dist) slots.

    Returns ``(lens, cols, dvals)`` exactly as ``ref.eps_compact_tile``
    over the full distance plane: lens (m,) int32 true hit counts (may
    exceed ``cap``), cols (m, cap) int32 ascending neighbor ids, dvals
    (m, cap) float32 distances.  The (TM × TN) distance tile never leaves
    VMEM; traffic is O(m·d + n·d + m·cap) ≈ O(nnz) for a well-sized
    capacity, vs O(m·n) for the dense plane.  ``cap`` must be a multiple
    of the legacy emit chunk ``cc`` (retained for call-site
    compatibility; the sort-based slot fill ignores it).  ``num_valid``
    masks padded columns — only column ids below it can hit (defaults to
    the corpus extent).
    """
    return _emit_call(_euclidean_tile, x, y, eps, cap, tm, tn, cc,
                      interpret, num_valid)


@functools.partial(jax.jit,
                   static_argnames=("cap", "tm", "tn", "cc", "interpret"))
def cosine_eps_emit_pallas(xa: jax.Array, ya: jax.Array, eps: jax.Array,
                           cap: int, tm: int = 128, tn: int = 128,
                           cc: int = 128, interpret: bool = False,
                           num_valid=None):
    """Fused cosine ε-threshold + emit over *augmented unit* rows
    (``ref.cosine_normalize``'d inputs).  Same contract as
    ``eps_emit_pallas``; the distance tile is one MXU matmul + clip."""
    return _emit_call(_cosine_tile, xa, ya, eps, cap, tm, tn, cc,
                      interpret, num_valid)


def _count_call(dist_fn, x, y, eps, weights, tm, tn, interpret, num_valid):
    """Shared launch plumbing for the fused count kernels."""
    m, d = x.shape
    n, _ = y.shape
    xp = _pad_to(x.astype(jnp.float32), tm, 0)
    yp = _pad_to(y.astype(jnp.float32), tn, 0)
    wp = _pad_to(weights.astype(jnp.float32)[None, :], tn, 1)
    eps_arr = jnp.asarray(eps, jnp.float32).reshape(1, 1)
    nv = jnp.asarray(n if num_valid is None else num_valid,
                     jnp.int32).reshape(1, 1)
    grid = (xp.shape[0] // tm, yp.shape[0] // tn)
    kernel = functools.partial(_count_kernel, dist_fn, tn)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
                  pl.BlockSpec((1, tn), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32),
        interpret=interpret,
    )(xp, yp, eps_arr, nv, wp)
    return out[:m, 0]


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def eps_count_pallas(x: jax.Array, y: jax.Array, eps: jax.Array,
                     weights: jax.Array, tm: int = 128, tn: int = 128,
                     interpret: bool = False, num_valid=None) -> jax.Array:
    """Fused |N_ε| count: (m,) float32 weighted neighbor counts of x in y.

    The distance tile stays in VMEM; HBM traffic is O(m·d + n·d + m) instead
    of O(m·n). ``weights`` are the paper's duplicate counts (§6).
    """
    return _count_call(_euclidean_tile, x, y, eps, weights, tm, tn,
                       interpret, num_valid)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def cosine_eps_count_pallas(xa: jax.Array, ya: jax.Array, eps: jax.Array,
                            weights: jax.Array, tm: int = 128, tn: int = 128,
                            interpret: bool = False,
                            num_valid=None) -> jax.Array:
    """Fused cosine |N_ε| count over augmented unit rows
    (``ref.cosine_normalize``'d inputs); contract of ``eps_count_pallas``."""
    return _count_call(_cosine_tile, xa, ya, eps, weights, tm, tn,
                       interpret, num_valid)


@functools.partial(jax.jit,
                   static_argnames=("cap", "tm", "tn", "cc", "interpret",
                                    "cosine"))
def screened_eps_emit_pallas(x: jax.Array, y: jax.Array,
                             sx: jax.Array, sy: jax.Array,
                             eps: jax.Array, s2t: jax.Array, cap: int,
                             tm: int = 128, tn: int = 128, cc: int = 128,
                             interpret: bool = False, num_valid=None,
                             cosine: bool = False):
    """Projection-pruned fused emit: bound tile → skip/mask → exact emit.

    ``sx``/``sy`` are the k-dim screen embeddings of ``x``/``y`` and
    ``s2t`` the slack-inflated squared screen threshold (see
    ``NeighborEngine._screen_thresholds``).  Pairs whose squared screen
    distance exceeds ``s2t`` provably cannot survive ε; tiles with no
    surviving pair never compute their exact distances.  Returns
    ``(lens, cols, dvals, cand)`` — the first three exactly as
    ``eps_emit_pallas`` over the same rows, plus ``cand`` (m,) int32
    per-row candidate counts the screen could not rule out.
    """
    if cap % cc != 0:
        raise ValueError(f"cap ({cap}) must be a multiple of cc ({cc})")
    m, d = x.shape
    n, _ = y.shape
    k = sx.shape[1]
    xp = _pad_to(x.astype(jnp.float32), tm, 0)
    yp = _pad_to(y.astype(jnp.float32), tn, 0)
    # pad screen rows with a far-away sentinel so padded rows/cols can
    # never pass the screen (they are also masked by num_valid)
    sxp = _pad_to(sx.astype(jnp.float32), tm, 0)
    syp = _pad_to(sy.astype(jnp.float32), tn, 0)
    eps_arr = jnp.asarray(eps, jnp.float32).reshape(1, 1)
    s2t_arr = jnp.asarray(s2t, jnp.float32).reshape(1, 1)
    nv = jnp.asarray(n if num_valid is None else num_valid,
                     jnp.int32).reshape(1, 1)
    grid = (xp.shape[0] // tm, yp.shape[0] // tn)
    dist_fn = _cosine_tile if cosine else _euclidean_tile
    kernel = functools.partial(_screened_emit_kernel, dist_fn, tn, cap, cc)
    lens, cols, dvals, cand = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
                  pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, k), lambda i, j: (j, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_specs=[pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((tm, cap), lambda i, j: (i, 0)),
                   pl.BlockSpec((tm, cap), lambda i, j: (i, 0)),
                   pl.BlockSpec((tm, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.int32),
                   jax.ShapeDtypeStruct((xp.shape[0], cap), jnp.int32),
                   jax.ShapeDtypeStruct((xp.shape[0], cap), jnp.float32),
                   jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.int32)],
        interpret=interpret,
    )(xp, yp, sxp, syp, eps_arr, s2t_arr, nv)
    return lens[:m, 0], cols[:m], dvals[:m], cand[:m, 0]
