"""Pallas TPU kernels for Jaccard distance over packed-bitmap sets.

CPU → TPU adaptation (DESIGN.md §2): the paper's inverted-list prefix filter
is an irregular sparse structure; on TPU, sets become (n, W) uint32 bitmaps
and |r ∩ s| is AND + popcount on the VPU, swept in (TM × TN) tiles. The
word axis W is processed in chunks inside the kernel via fori_loop so the
(TM, TN, Wc) popcount intermediate stays in VMEM (128·128·Wc·4 B; Wc = 32
→ 2 MiB).

An MXU-unpacked variant (bitmaps expanded to ±1 and intersections computed
as an int8 matmul) trades 32× memory for full MXU rate — evaluated in the
§Perf hillclimb, not the default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pairwise import _pad_to, emit_tile_slots


def _intersect_chunked(a: jax.Array, b: jax.Array, wc: int) -> jax.Array:
    """(TM, W) & (TN, W) → (TM, TN) int32 popcount intersections."""
    TM, W = a.shape
    TN = b.shape[0]
    nchunks = W // wc

    def body(c, acc):
        aw = jax.lax.dynamic_slice(a, (0, c * wc), (TM, wc))
        bw = jax.lax.dynamic_slice(b, (0, c * wc), (TN, wc))
        pc = jax.lax.population_count(aw[:, None, :] & bw[None, :, :])
        return acc + pc.astype(jnp.int32).sum(-1)

    acc0 = jnp.zeros((TM, TN), jnp.int32)
    return jax.lax.fori_loop(0, nchunks, body, acc0)


def _jaccard_tile_kernel(wc, a_ref, sa_ref, b_ref, sb_ref, o_ref):
    inter = _intersect_chunked(a_ref[...], b_ref[...], wc).astype(jnp.float32)
    union = sa_ref[...].astype(jnp.float32) + sb_ref[...].astype(jnp.float32) - inter
    o_ref[...] = jnp.where(union > 0, 1.0 - inter / union, 0.0)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "wc", "interpret"))
def jaccard_distance_pallas(bits_a: jax.Array, size_a: jax.Array,
                            bits_b: jax.Array, size_b: jax.Array,
                            tm: int = 128, tn: int = 128, wc: int = 32,
                            interpret: bool = False) -> jax.Array:
    """(m, W) × (n, W) packed bitmaps → (m, n) float32 Jaccard distances."""
    m, W = bits_a.shape
    n, _ = bits_b.shape
    ap = _pad_to(bits_a, tm, 0)
    bp = _pad_to(bits_b, tn, 0)
    Wp = max(wc, W + (-W) % wc)
    ap = _pad_to(ap, Wp, 1)
    bp = _pad_to(bp, Wp, 1)
    sap = _pad_to(size_a.astype(jnp.int32)[:, None], tm, 0)
    sbp = _pad_to(size_b.astype(jnp.int32)[None, :], tn, 1)
    grid = (ap.shape[0] // tm, bp.shape[0] // tn)
    kernel = functools.partial(_jaccard_tile_kernel, wc)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, Wp), lambda i, j: (i, 0)),
                  pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, Wp), lambda i, j: (j, 0)),
                  pl.BlockSpec((1, tn), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], bp.shape[0]), jnp.float32),
        interpret=interpret,
    )(ap, sap, bp, sbp)
    return out[:m, :n]


def _jaccard_count_kernel(n_valid, tn, wc, a_ref, sa_ref, b_ref, sb_ref,
                          eps_ref, w_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    inter = _intersect_chunked(a_ref[...], b_ref[...], wc).astype(jnp.float32)
    union = sa_ref[...].astype(jnp.float32) + sb_ref[...].astype(jnp.float32) - inter
    dist = jnp.where(union > 0, 1.0 - inter / union, 0.0)
    col = j * tn + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    w = w_ref[...].astype(jnp.float32)
    hit = jnp.where((dist <= eps_ref[0, 0]) & (col < n_valid), w, 0.0)
    o_ref[...] += jnp.sum(hit, axis=1, keepdims=True)


def _jaccard_emit_kernel(n_valid, tn, wc, cap, cc, a_ref, sa_ref, b_ref,
                         sb_ref, eps_ref, len_ref, col_ref, dist_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        len_ref[...] = jnp.zeros_like(len_ref)
        col_ref[...] = jnp.zeros_like(col_ref)
        dist_ref[...] = jnp.zeros_like(dist_ref)

    inter = _intersect_chunked(a_ref[...], b_ref[...], wc).astype(jnp.float32)
    union = sa_ref[...].astype(jnp.float32) + sb_ref[...].astype(jnp.float32) - inter
    dist = jnp.where(union > 0, 1.0 - inter / union, 0.0)       # (TM, TN)
    col = j * tn + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    hit = (dist <= eps_ref[0, 0]) & (col < n_valid)
    emit_tile_slots(hit, col, dist, cap, cc, len_ref, col_ref, dist_ref)


@functools.partial(jax.jit,
                   static_argnames=("cap", "tm", "tn", "wc", "cc", "interpret"))
def jaccard_eps_emit_pallas(bits_a: jax.Array, size_a: jax.Array,
                            bits_b: jax.Array, size_b: jax.Array,
                            eps: jax.Array, cap: int,
                            tm: int = 128, tn: int = 128, wc: int = 32,
                            cc: int = 128, interpret: bool = False):
    """Fused Jaccard ε-threshold + emit → per-row compacted (col, dist).

    The set-data twin of ``pairwise.eps_emit_pallas``: AND+popcount tiles
    stay in VMEM, only ``(lens, cols (m, cap), dvals (m, cap))`` leave the
    core.  Semantics match ``ref.eps_compact_tile`` over the dense Jaccard
    plane (true lens may exceed ``cap``; overflow rows keep the first
    ``cap`` hits and are re-extracted densely by the caller).
    """
    if cap % cc != 0:
        raise ValueError(f"cap ({cap}) must be a multiple of cc ({cc})")
    m, W = bits_a.shape
    n, _ = bits_b.shape
    ap = _pad_to(bits_a, tm, 0)
    bp = _pad_to(bits_b, tn, 0)
    Wp = max(wc, W + (-W) % wc)
    ap = _pad_to(ap, Wp, 1)
    bp = _pad_to(bp, Wp, 1)
    sap = _pad_to(size_a.astype(jnp.int32)[:, None], tm, 0)
    sbp = _pad_to(size_b.astype(jnp.int32)[None, :], tn, 1)
    eps_arr = jnp.asarray(eps, jnp.float32).reshape(1, 1)
    grid = (ap.shape[0] // tm, bp.shape[0] // tn)
    kernel = functools.partial(_jaccard_emit_kernel, n, tn, wc, cap, cc)
    lens, cols, dvals = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, Wp), lambda i, j: (i, 0)),
                  pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, Wp), lambda i, j: (j, 0)),
                  pl.BlockSpec((1, tn), lambda i, j: (0, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_specs=[pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((tm, cap), lambda i, j: (i, 0)),
                   pl.BlockSpec((tm, cap), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((ap.shape[0], 1), jnp.int32),
                   jax.ShapeDtypeStruct((ap.shape[0], cap), jnp.int32),
                   jax.ShapeDtypeStruct((ap.shape[0], cap), jnp.float32)],
        interpret=interpret,
    )(ap, sap, bp, sbp, eps_arr)
    return lens[:m, 0], cols[:m], dvals[:m]


@functools.partial(jax.jit, static_argnames=("tm", "tn", "wc", "interpret"))
def jaccard_eps_count_pallas(bits_a: jax.Array, size_a: jax.Array,
                             bits_b: jax.Array, size_b: jax.Array,
                             eps: jax.Array, weights: jax.Array,
                             tm: int = 128, tn: int = 128, wc: int = 32,
                             interpret: bool = False) -> jax.Array:
    """Fused weighted |N_ε| counts under Jaccard distance → (m,) float32."""
    m, W = bits_a.shape
    n, _ = bits_b.shape
    ap = _pad_to(bits_a, tm, 0)
    bp = _pad_to(bits_b, tn, 0)
    Wp = max(wc, W + (-W) % wc)
    ap = _pad_to(ap, Wp, 1)
    bp = _pad_to(bp, Wp, 1)
    sap = _pad_to(size_a.astype(jnp.int32)[:, None], tm, 0)
    sbp = _pad_to(size_b.astype(jnp.int32)[None, :], tn, 1)
    wp = _pad_to(weights.astype(jnp.float32)[None, :], tn, 1)
    eps_arr = jnp.asarray(eps, jnp.float32).reshape(1, 1)
    grid = (ap.shape[0] // tm, bp.shape[0] // tn)
    kernel = functools.partial(_jaccard_count_kernel, n, tn, wc)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, Wp), lambda i, j: (i, 0)),
                  pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, Wp), lambda i, j: (j, 0)),
                  pl.BlockSpec((1, tn), lambda i, j: (0, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
                  pl.BlockSpec((1, tn), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], 1), jnp.float32),
        interpret=interpret,
    )(ap, sap, bp, sbp, eps_arr, wp)
    return out[:m, 0]
