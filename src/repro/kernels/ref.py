"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each TPU kernel in ``pairwise.py``,
``jaccard.py``, ``kthdist.py`` and ``flash_swa.py`` must agree with the
corresponding function here (see tests/test_kernels.py). They are also the
fast execution path on CPU, where Pallas runs in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_sq_euclidean(x: jax.Array, y: jax.Array) -> jax.Array:
    """Squared Euclidean distances between rows of x (m,d) and y (n,d).

    Uses the MXU-friendly expansion ||x||^2 + ||y||^2 - 2 x.y^T with a
    clamp at zero (the expansion can go slightly negative in floating point).
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)        # (m, 1)
    y2 = jnp.sum(y * y, axis=-1, keepdims=True).T      # (1, n)
    d2 = x2 + y2 - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


def pairwise_euclidean(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.sqrt(pairwise_sq_euclidean(x, y))


def cosine_normalize(x: jax.Array) -> jax.Array:
    """(n, d) vectors → (n, d+1) augmented unit rows for cosine distance.

    Rows are L2-normalized and extended with a zero-row indicator
    coordinate, so ``cosine_distance`` below is a single matmul:
    zero·zero pairs dot to 1 (distance 0), zero·nonzero to 0 (distance
    1), and vector pairs pick up an exact ``+0.0`` from the indicator —
    the cosine convention of ``CosineMetric`` with euclidean-style tile
    machinery.
    """
    x = x.astype(jnp.float32)
    nrm = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True))
    zero = nrm == 0.0
    unit = jnp.where(zero, 0.0, x / jnp.where(zero, 1.0, nrm))
    return jnp.concatenate([unit, zero.astype(jnp.float32)], axis=-1)


def cosine_distance(xa: jax.Array, ya: jax.Array) -> jax.Array:
    """Cosine distances between augmented unit rows (``cosine_normalize``):
    clip(1 − xa·yaᵀ, 0, 2) — the oracle for the fused cosine kernels."""
    sim = xa.astype(jnp.float32) @ ya.astype(jnp.float32).T
    return jnp.clip(1.0 - sim, 0.0, 2.0).astype(jnp.float32)


def screen_sq_tile(sx: jax.Array, sy: jax.Array) -> jax.Array:
    """Squared euclidean distances between screen embeddings — the bound
    plane of the projection-pruned sweep.  Same MXU expansion as
    ``pairwise_sq_euclidean``; callers compare against a slack-inflated
    threshold (see ``NeighborEngine``) so float32 error here can never
    turn into a false prune."""
    return pairwise_sq_euclidean(sx, sy)


def bound_min2_tile(pts: jax.Array, centers: jax.Array) -> jax.Array:
    """Per-center minimum squared screen distance over a query tile:
    ``min_q ||pts[q] − centers[b]||²`` → (nb,) float32.  The device-side
    bucket-bound plane of the pruned sweep — one row per sweep tile,
    compared against slack-inflated ``(s_t + r_b)²`` thresholds so
    float32 expansion error can only admit an extra bucket, never prune
    one that could hold a true neighbor.  Oracle for
    ``bounds.bound_min2_pallas``."""
    return jnp.min(pairwise_sq_euclidean(pts, centers), axis=0)


def screened_hit_tile(hit: jax.Array, sx: jax.Array, sy: jax.Array,
                      s2_thresh: jax.Array, num_valid=None):
    """Screen an exact hit plane: AND in the pair-level bound mask (pairs
    whose squared screen distance exceeds ``s2_thresh`` provably cannot
    survive ε — a no-op on true hits by the lower-bound contract) and the
    padded-column mask.  Returns ``(hit', candidates)`` where
    ``candidates`` is the number of pairs the screen could not rule out —
    the work the exact kernel actually had to verify.  Oracle for the
    fused screen+verify Pallas kernel (``pairwise.screened_eps_mask``).
    """
    keep = screen_sq_tile(sx, sy) <= s2_thresh
    if num_valid is not None:
        col = jax.lax.broadcasted_iota(jnp.int32, keep.shape, 1)
        keep = keep & (col < num_valid)
    return hit & keep, jnp.sum(keep.astype(jnp.int32))


def jaccard_distance(bits_a: jax.Array, size_a: jax.Array,
                     bits_b: jax.Array, size_b: jax.Array) -> jax.Array:
    """Jaccard distances between packed-bitmap set rows.

    bits_*: (m, W) / (n, W) uint32 packed membership bitmaps.
    size_*: (m,) / (n,) int32 set cardinalities (= popcount of the row).
    Returns (m, n) float32 with d_J(r, s) = 1 - |r ∩ s| / |r ∪ s|.
    Empty-vs-empty pairs get distance 0 (identical sets).
    """
    inter = _jaccard_intersections(bits_a, bits_b)
    union = size_a[:, None] + size_b[None, :] - inter
    return jnp.where(union > 0, 1.0 - inter / union, 0.0).astype(jnp.float32)


def _jaccard_intersections(bits_a: jax.Array, bits_b: jax.Array,
                           wc: int = 2) -> jax.Array:
    """|r ∩ s| for all pairs: (m, n) int32 via AND + popcount.

    Words are processed in slices of ``wc`` so the broadcast intermediate
    is (m, n, wc), not (m, n, W) — on the 64k-corpus distributed tiles the
    full broadcast would be tens of GB.
    """
    m, W = bits_a.shape
    n = bits_b.shape[0]
    acc = jnp.zeros((m, n), jnp.int32)
    for w0 in range(0, W, wc):
        part = jax.lax.population_count(
            bits_a[:, None, w0:w0 + wc] & bits_b[None, :, w0:w0 + wc]
        ).astype(jnp.int32).sum(-1)
        acc = acc + part
    return acc


def eps_count(dists: jax.Array, eps: jax.Array) -> jax.Array:
    """Number of entries per row with distance <= eps. (m, n) -> (m,) int32."""
    return jnp.sum(dists <= eps, axis=-1).astype(jnp.int32)


def eps_compact_tile(dists: jax.Array, eps: jax.Array, cap: int,
                     col_offset=0, num_valid=None):
    """Compact an ε-thresholded distance tile into per-row (col, dist) slots.

    The oracle for the fused emit kernels (``pairwise.eps_emit_pallas``,
    ``jaccard.jaccard_eps_emit_pallas``): every surviving pair of the
    (m, n) tile is packed to the front of a fixed-width slot row, so the
    caller transfers O(m·cap) instead of the O(m·n) dense plane.

    Returns ``(lens, cols, dvals)``:
      * ``lens``  (m,) int32 — the TRUE per-row hit count, which may
        exceed ``cap``.  Overflow rows keep their first ``cap`` hits;
        callers re-extract such rows from a dense tile (the fallback path
        in ``NeighborEngine``) or retry with a larger capacity.
      * ``cols``  (m, cap) int32 — global column ids (``col_offset`` +
        tile column), ascending within each row; unfilled slots are 0.
      * ``dvals`` (m, cap) float32 — the matching distances, bit-exact
        gathers of ``dists``; unfilled slots are 0.

    ``num_valid`` masks padded columns: only global column ids
    ``< num_valid`` can hit (used by the sharded CSR-emit, where the
    corpus block is padded to the mesh's "model" extent).
    """
    m, n = dists.shape
    col = col_offset + jax.lax.broadcasted_iota(jnp.int32, (m, n), 1)
    hit = dists <= eps
    if num_valid is not None:
        hit = hit & (col < num_valid)
    incl = jnp.cumsum(hit.astype(jnp.int32), axis=1)
    lens = incl[:, -1]
    row = jax.lax.broadcasted_iota(jnp.int32, (m, n), 0)
    # hits beyond the capacity land in a dump slot that is sliced away
    pos = jnp.where(hit & (incl <= cap), incl - 1, cap)
    cols = jnp.zeros((m, cap + 1), jnp.int32).at[row, pos].set(col)[:, :cap]
    dvals = jnp.zeros((m, cap + 1), jnp.float32) \
        .at[row, pos].set(dists.astype(jnp.float32))[:, :cap]
    return lens, cols, dvals


def kth_smallest(dists: jax.Array, k: int) -> jax.Array:
    """k-th smallest value per row (1-based k). (m, n) -> (m,) float32.

    This is the MinPts-distance M(p) when ``dists`` is a full distance row
    (self-distance 0 included) and k = MinPts.
    """
    srt = jnp.sort(dists, axis=-1)
    return srt[:, k - 1]


def tile_histogram(dists: jax.Array, edges: jax.Array) -> jax.Array:
    """Per-row histogram of distances over ``edges`` bin boundaries.

    dists: (m, n); edges: (B+1,) monotone. Returns (m, B) int32 counts with
    bin b counting edges[b] <= d < edges[b+1] (last bin right-inclusive).
    Oracle for the kthdist refinement kernel.

    Loops over bins (fori) instead of broadcasting an (m, B, n) mask — the
    distributed sweep calls this on (rows × 64k-corpus) tiles where the
    broadcast intermediate would be gigabytes.
    """
    nbins = edges.shape[0] - 1

    def bin_count(b):
        lo = edges[b]
        hi = edges[b + 1]
        inside = (dists >= lo) & ((dists < hi)
                                  | ((b == nbins - 1) & (dists <= hi)))
        return inside.sum(-1).astype(jnp.int32)          # (m,)

    cols = jax.lax.map(bin_count, jnp.arange(nbins))      # (nbins, m)
    return cols.T


def sliding_window_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             window: int, causal: bool = True) -> jax.Array:
    """Reference sliding-window attention.

    q,k,v: (B, T, H, Dh) with kv already repeated to H heads. A query at
    position t attends to keys in [t-window+1, t] (causal) — the oracle for
    kernels/flash_swa.py.
    """
    B, T, H, Dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, jnp.float32))
    logits = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    ti = jnp.arange(T)[:, None]
    si = jnp.arange(T)[None, :]
    mask = (si <= ti) & (si > ti - window) if causal else (jnp.abs(si - ti) < window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
