"""Pallas TPU flash attention with sliding window (hymba / long-context).

Online-softmax attention: the (BQ × BK) score tile lives only in VMEM;
running max/denominator/accumulator carry across key blocks. With a
window w, each query block visits only ⌈(w + BQ)/BK⌉ key blocks —
O(T·w) work and O(T·hd) HBM traffic, never O(T²).

This kernel is what the dry-run's "flash" roofline variant models
(launch/hlo_analysis.py): on real TPUs it replaces the XLA attention path
of models/layers.py (the portable oracle), which materializes scores in
HBM. Validated in interpret mode against ref.sliding_window_attention.

Layout: inputs are reshaped to (B·H, T, hd) in the wrapper; grid is
(B·H, T/BQ); K/V stream through VMEM in BK-row slices of the per-head
(T, hd) block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(window, bq, bk, causal, scale, q_ref, k_ref, v_ref, o_ref):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale                  # (BQ, hd)
    hd = q.shape[-1]

    if window > 0:
        # key span of one query block: (bq-1) diagonal + (window-1) back
        nb = (window + bq + bk - 2) // bk + 1
    else:
        # causal full attention: all blocks up to the diagonal; T static
        nb = (k_ref.shape[1] + bk - 1) // bk

    def body(j, carry):
        m, l, acc = carry
        if window > 0:
            kb_last = (qi * bq + bq - 1) // bk                # diagonal end
            kb = kb_last + j - (nb - 1)                       # trailing band
        else:
            kb = j
        valid_block = kb >= 0
        if window == 0 and causal:
            valid_block = valid_block & (kb * bk <= qi * bq + bq - 1)
        kstart = jnp.maximum(kb, 0) * bk
        kblk = k_ref[0, pl.ds(kstart, bk), :].astype(jnp.float32)  # (BK, hd)
        vblk = v_ref[0, pl.ds(kstart, bk), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, kblk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = kstart + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.full((bq, bk), valid_block)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, vblk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nb, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-20)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "causal", "bq", "bk",
                                             "interpret"))
def flash_swa_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     window: int = 0, causal: bool = True,
                     bq: int = 128, bk: int = 128,
                     interpret: bool = False) -> jax.Array:
    """(B, T, H, hd) attention with KV repeated to H heads already.

    window=0 → plain causal flash attention; window>0 → sliding window.
    """
    B, T, H, hd = q.shape
    assert T % bq == 0 and T % bk == 0, (T, bq, bk)
    scale = hd ** -0.5
    qr = jnp.moveaxis(q, 2, 1).reshape(B * H, T, hd)
    kr = jnp.moveaxis(k, 2, 1).reshape(B * H, T, hd)
    vr = jnp.moveaxis(v, 2, 1).reshape(B * H, T, hd)
    grid = (B * H, T // bq)
    kernel = functools.partial(_flash_kernel, window, bq, bk, causal, scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, bq, hd), lambda bh, qi: (bh, qi, 0)),
                  pl.BlockSpec((1, T, hd), lambda bh, qi: (bh, 0, 0)),
                  pl.BlockSpec((1, T, hd), lambda bh, qi: (bh, 0, 0))],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T, hd), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return jnp.moveaxis(out.reshape(B, H, T, hd), 1, 2)


def flash_swa_attention(q, k, v, *, window: int = 0, causal: bool = True,
                        bq: int = 128, bk: int = 128,
                        interpret: bool = False) -> jax.Array:
    """GQA-aware wrapper: repeats KV heads then calls the kernel."""
    H = q.shape[2]
    kv = k.shape[2]
    if kv != H:
        k = jnp.repeat(k, H // kv, axis=2)
        v = jnp.repeat(v, H // kv, axis=2)
    return flash_swa_pallas(q, k, v, window=window, causal=causal,
                            bq=bq, bk=bk, interpret=interpret)
