"""Pallas TPU kernel for k-th-smallest-distance refinement (core distance).

M(p) — the paper's MinPts-distance (Def. 3.6) — is the k-th smallest entry
of p's distance row. Sorting n-length rows on device is wasteful; instead
the host runs a bisection over distance thresholds using per-row
histograms produced by this kernel: each call bins one (TM × n) distance
sweep into B buckets entirely in VMEM. Edges are PER ROW (each row has
its own [lo, hi) bracket), so brackets narrow B-fold per step and
M(p) converges in log_B(range/tol) steps — O(n·B) VMEM traffic per step.

At the scales the host algorithm consumes (CSR already materialized)
M(p) comes for free from the sorted lists; this kernel is the standalone/
device-resident path used by the distributed engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pairwise import _pad_to


def _hist_kernel(n_valid, tn, nbins, x_ref, y_ref, edges_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    y2 = jnp.sum(y * y, axis=-1, keepdims=True).T
    cross = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    dist = jnp.sqrt(jnp.maximum(x2 + y2 - 2.0 * cross, 0.0))     # (TM, TN)
    col = j * tn + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    valid = col < n_valid
    edges = edges_ref[...]                                        # (TM, B+1)

    def bin_body(b, acc):
        lo = edges[:, b][:, None]                                 # per row
        hi = edges[:, b + 1][:, None]
        in_bin = (dist >= lo) & ((dist < hi) | ((b == nbins - 1)
                                                & (dist <= hi)))
        cnt = jnp.sum(jnp.where(in_bin & valid, 1.0, 0.0), axis=1)
        return jax.lax.dynamic_update_slice(
            acc, (jax.lax.dynamic_slice(acc, (0, b), (acc.shape[0], 1))
                  + cnt[:, None]), (0, b))

    o_ref[...] += jax.lax.fori_loop(
        0, nbins, bin_body, jnp.zeros_like(o_ref[...]))


@functools.partial(jax.jit, static_argnames=("tm", "tn", "nbins", "interpret"))
def dist_histogram_pallas(x: jax.Array, y: jax.Array, edges: jax.Array,
                          tm: int = 128, tn: int = 128, nbins: int = 16,
                          interpret: bool = False) -> jax.Array:
    """Per-row distance histograms: (m, d) × (n, d) → (m, nbins) float32.

    ``edges``: (nbins+1,) shared bin boundaries or (m, nbins+1) per-row
    boundaries (last bin right-closed).
    """
    m, d = x.shape
    n, _ = y.shape
    xp = _pad_to(x.astype(jnp.float32), tm, 0)
    if edges.ndim == 1:
        edges = jnp.broadcast_to(edges[None, :], (m, edges.shape[0]))
    ep = _pad_to(edges.astype(jnp.float32), tm, 0)
    yp = _pad_to(y.astype(jnp.float32), tn, 0)
    grid = (xp.shape[0] // tm, yp.shape[0] // tn)
    kernel = functools.partial(_hist_kernel, n, tn, nbins)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
                  pl.BlockSpec((tm, nbins + 1), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((tm, nbins), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], nbins), jnp.float32),
        interpret=interpret,
    )(xp, yp, ep)
    return out[:m]


def kth_smallest_bisect(x, y, k: int, steps: int = 8, nbins: int = 16,
                        hi: float | None = None, tol: float = 1e-5,
                        interpret: bool = False):
    """M(p) for every row of x against corpus y via histogram bisection.

    Host driver around ``dist_histogram_pallas`` with per-row brackets:
    each step splits every row's [lo, hi) bracket ``nbins``-ways and keeps
    the bin containing the k-th smallest — precision multiplies by nbins
    per step. Returns (m,) float32.
    """
    import numpy as np
    m = x.shape[0]
    if hi is None:
        # coarse global upper bound: max row norm + max corpus norm
        xn = float(np.max(np.linalg.norm(np.asarray(x, np.float64), axis=1)))
        yn = float(np.max(np.linalg.norm(np.asarray(y, np.float64), axis=1)))
        hi = xn + yn + 1e-6
    lo_b = np.zeros(m, np.float64)
    hi_b = np.full(m, hi, np.float64)
    below = np.zeros(m)          # #distances below each row's bracket —
    #                              tracked incrementally across refinements
    xj = jnp.asarray(x, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    rows = np.arange(m)
    for _ in range(steps):
        t = np.linspace(0.0, 1.0, nbins + 1)
        edges = lo_b[:, None] + (hi_b - lo_b)[:, None] * t[None, :]
        hist = np.asarray(dist_histogram_pallas(
            xj, yj, jnp.asarray(edges, jnp.float32), nbins=nbins,
            interpret=interpret))
        cum = below[:, None] + np.cumsum(hist, axis=1)
        idx = np.argmax(cum >= k, axis=1)
        below = cum[rows, idx] - hist[rows, idx]
        lo_b = edges[rows, idx]
        hi_b = edges[rows, idx + 1]
        if np.all(hi_b - lo_b < tol):
            break
    return ((lo_b + hi_b) * 0.5).astype(np.float32)
