from repro.train.optimizer import (AdamWState, adamw_init, adamw_update,
                                   wsd_schedule, cosine_schedule)
from repro.train.step import TrainState, make_train_step, cross_entropy

__all__ = ["AdamWState", "adamw_init", "adamw_update", "wsd_schedule",
           "cosine_schedule", "TrainState", "make_train_step",
           "cross_entropy"]
