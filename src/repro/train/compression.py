"""int8 gradient compression for the cross-pod all-reduce.

At 1000+ node scale the per-step DP gradient all-reduce crosses the
slowest links (pod-to-pod DCN). This module quantizes gradients to int8
with a per-tensor scale before that reduction and dequantizes after —
4× less cross-pod traffic for <1% step-time noise at LM scales (the
classic 1-bit-Adam/PowerSGD trade-off, in its simplest robust form).

Under SPMD-with-sharding the DP reduction is implicit, so compression is
expressed as quantize→dequantize *around the gradient values themselves*:
XLA keeps the int8 representation across the all-reduce boundary when the
pattern allows, and the numerical contract (int8 resolution) is identical
either way — which is what the error-feedback state corrects for.

``compress_grads_int8`` is stateless (round-to-nearest); the
``ErrorFeedback`` wrapper carries the residual so quantization error does
not bias long runs. Property-tested in tests/test_compression.py.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh


def _quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads_int8(grads: Any, mesh: Mesh) -> Any:
    """Quantize→dequantize every gradient leaf at int8 resolution."""
    def comp(g):
        q, s = _quantize_int8(g.astype(jnp.float32))
        return _dequantize(q, s)
    return jax.tree.map(comp, grads)


class ErrorFeedback(NamedTuple):
    residual: Any

    @classmethod
    def init(cls, params: Any) -> "ErrorFeedback":
        return cls(residual=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_with_feedback(grads: Any, ef: ErrorFeedback
                           ) -> Tuple[Any, ErrorFeedback]:
    """int8 compression with error feedback: residual is re-injected."""
    def comp(g, r):
        x = g.astype(jnp.float32) + r
        q, s = _quantize_int8(x)
        deq = _dequantize(q, s)
        return deq, x - deq
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    outs = [comp(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in outs]),
            ErrorFeedback(residual=tdef.unflatten([o[1] for o in outs])))
