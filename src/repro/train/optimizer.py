"""AdamW + LR schedules (no external deps — optax is not assumed).

The optimizer state is a pytree congruent with the parameters, so the
FSDP parameter shardings apply verbatim to m/v — fully-sharded (ZeRO-ish)
optimizer state for free. minicpm trains with the WSD schedule from its
paper (arXiv:2404.06395); everything else defaults to cosine.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params: Any, moment_dtype=jnp.float32) -> AdamWState:
    """moment_dtype=bf16 halves optimizer memory — required to fit 400B-
    class models on a single 256-chip pod (DESIGN.md; llama4 cells)."""
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=moment_dtype),
                         params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def adamw_update(params: Any, grads: Any, state: AdamWState, lr: jax.Array,
                 *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, max_grad_norm: float = 1.0):
    """One AdamW step with global-norm clipping. Returns (params, state)."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)) + 1e-20)
    scale = jnp.minimum(1.0, max_grad_norm / gnorm)
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m2.astype(m.dtype), v2.astype(v.dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def wsd_schedule(peak_lr: float, warmup: int, stable: int, decay: int,
                 floor_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    """Warmup-Stable-Decay (minicpm): linear warmup, flat, then decay."""
    def lr(step):
        s = step.astype(jnp.float32)
        w = jnp.minimum(s / max(warmup, 1), 1.0)
        in_decay = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
        mult = w * (1.0 - (1.0 - floor_frac) * in_decay)
        return peak_lr * mult
    return lr


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        s = step.astype(jnp.float32)
        w = jnp.minimum(s / max(warmup, 1), 1.0)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return peak_lr * w * (floor_frac + (1 - floor_frac) * cos)
    return lr
