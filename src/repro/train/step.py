"""train_step: loss + grad accumulation + AdamW, one jit-able function.

Microbatching: the global batch is reshaped to (n_micro, micro, T) and
grads are accumulated by a lax.scan — activation memory scales with the
microbatch, gradient/optimizer memory stays fully sharded (FSDP), and the
DP gradient reduction happens once per step on the accumulated grads
(XLA turns it into reduce-scatter against the FSDP shards).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, RunConfig
from repro.models.transformer import forward
from repro.train.optimizer import AdamWState, adamw_init, adamw_update
from repro.train.compression import compress_grads_int8


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState

    @property
    def step(self):
        return self.opt.step


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  true_vocab: int) -> jax.Array:
    """Mean CE over tokens; padded-vocab columns are masked out."""
    V = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if true_vocab < V:
        pad_mask = jnp.arange(V) >= true_vocab
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def make_train_step(cfg: ModelConfig, rc: RunConfig,
                    mesh: Optional[Mesh] = None,
                    lr_fn: Optional[Callable] = None,
                    n_micro: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    batch: {"tokens": (B, T) int32, "labels": (B, T) int32} — or
    {"embeds": (B, T, d), "labels": (B, T)} for frontend-stub models.
    """
    if lr_fn is None:
        lr_fn = lambda step: jnp.float32(3e-4)

    input_key = "tokens" if cfg.embed_inputs else "embeds"

    def loss_fn(params, micro):
        logits = forward(params, micro[input_key], cfg, rc, mesh)
        return cross_entropy(logits, micro["labels"], cfg.vocab)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        B = batch["labels"].shape[0]
        assert B % n_micro == 0, (B, n_micro)
        micros = jax.tree.map(
            lambda x: x.reshape((n_micro, B // n_micro) + x.shape[1:]), batch)

        if rc.accum_mode == "loss":
            # grad-of-scanned-loss: autodiff accumulates parameter grads
            # across the micro scan, so the DP gradient reduction happens
            # ONCE per step and there is a single gradient buffer (§Perf).
            # The body must itself be checkpointed: otherwise the scan
            # saves every microbatch's residuals and activation memory
            # grows n_micro-fold.
            ckpt_loss = jax.checkpoint(
                loss_fn, policy=jax.checkpoint_policies.nothing_saveable)

            def total_loss(params):
                def body(acc, micro):
                    return acc + ckpt_loss(params, micro), None
                tot, _ = jax.lax.scan(body, jnp.float32(0.0), micros)
                return tot / n_micro
            loss, grads = jax.value_and_grad(total_loss)(state.params)
            loss_sum = loss * n_micro
        else:
            # baseline: per-micro grads accumulated in a sharded buffer
            def micro_body(acc, micro):
                g_acc, l_acc = acc
                loss, grads = jax.value_and_grad(loss_fn)(state.params, micro)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            (grads, loss_sum), _ = jax.lax.scan(micro_body, (zeros, 0.0),
                                                micros)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
        if rc.grad_compression and mesh is not None:
            grads = compress_grads_int8(grads, mesh)
        loss = loss_sum / n_micro
        lr = lr_fn(state.opt.step)
        new_params, new_opt = adamw_update(state.params, grads, state.opt, lr)
        metrics = {"loss": loss, "lr": lr,
                   "grad_norm": jnp.sqrt(sum(
                       jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads)))}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def init_state(key: jax.Array, cfg: ModelConfig) -> TrainState:
    from repro.models.transformer import init_params
    params = init_params(key, cfg)
    return TrainState(params=params, opt=adamw_init(params))
