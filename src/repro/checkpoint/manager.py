"""Fault-tolerant checkpointing: atomic, async, auto-resuming.

Layout:  <dir>/step_<n>/  arrays.npz  MANIFEST.json
Writes go to ``<dir>/.tmp_step_<n>`` and are renamed into place only after
fsync — a preempted/killed writer can never leave a half checkpoint that
``latest_step`` would pick up (tests/test_checkpoint.py kills a writer
mid-save to prove it). Saves run on a background thread (async=True) so
the train loop only blocks on the previous save's completion, not on I/O.

At single-host scale arrays are materialized and saved whole; at fleet
scale the same manifest format holds per-shard files written by each
host (jax.experimental.multihost_utils / tensorstore territory — the
restore side below is already shard-agnostic because it re-shards through
``restore_for_mesh``).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import warnings
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif hasattr(tree, "_fields"):          # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any, extra: Optional[dict] = None,
             async_: bool = False) -> None:
        # always drain a pending async writer first: two writers on the
        # same step race on the .tmp dir (rename-under-write)
        self.wait()
        if step in self.all_steps():
            return                       # already durably saved
        flat = {k: np.asarray(v) for k, v in _flatten(state).items()}
        if async_:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {}))
            self._thread.start()
        else:
            self._write(step, flat, extra or {})

    def _write(self, step: int, flat: Dict[str, np.ndarray], extra: dict):
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "|"): v for k, v in flat.items()})
        manifest = {"step": step, "keys": sorted(flat),
                    "shapes": {k: list(v.shape) for k, v in flat.items()},
                    "extra": extra}
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
        self._gc()

    def _gc(self):
        # keep-N applies to the training-state stream only: FINEX index
        # snapshots are explicit artifacts, exempt from rotation
        steps = [s for s in self.all_steps()
                 if self._step_kind(s) != "finex_index"]
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "MANIFEST.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        """Latest *training-state* step — the auto-resume anchor. Index
        snapshots share the step namespace but are not resumable train
        state, so they are skipped here (as in _gc)."""
        steps = [s for s in self.all_steps()
                 if self._step_kind(s) != "finex_index"]
        return steps[-1] if steps else None

    def load_flat(self, step: int) -> Dict[str, np.ndarray]:
        z = np.load(os.path.join(self.dir, f"step_{step}", "arrays.npz"))
        return {k.replace("|", "/"): z[k] for k in z.files}

    def restore(self, step: int, like: Any) -> Any:
        """Restore into the structure of ``like`` (a state pytree)."""
        flat = self.load_flat(step)
        return _unflatten_like(like, flat)

    # ------------------------------------------------- FINEX index state
    # A built FinexIndex is expensive, host-resident state just like an
    # optimizer pytree — it gets the same atomic tmp-rename + manifest
    # treatment so a killed writer can never publish a torn index.
    def save_index(self, step: int, index, extra: Optional[dict] = None,
                   async_: bool = False) -> None:
        """Durably save a ``repro.core.FinexIndex`` as step artifacts.

        Index snapshots are exempt from the keep-N rotation (they are
        explicit artifacts, not part of the training-state stream).
        """
        self.wait()          # an in-flight async save of this step would
        # otherwise slip past the kind check below and silently win
        if step in self.all_steps():
            # save() would silently skip an existing step — fine when it
            # already holds this very index, data loss otherwise
            prev = self._step_meta(step)
            if prev.get("kind") != "finex_index":
                raise ValueError(
                    f"step {step} already holds a non-index checkpoint; "
                    "use a distinct step for FINEX index snapshots")
            if (float(prev["eps"]) != float(index.eps)
                    or int(prev["minpts"]) != int(index.minpts)
                    or prev.get("metric") != index.metric
                    or int(prev.get("n", -1)) != index.n
                    or int(prev.get("nnz", -1)) != index.csr.nnz
                    or (bool(prev.get("fingerprint"))
                        and bool(index.fingerprint())
                        and prev["fingerprint"] != index.fingerprint())):
                raise ValueError(
                    f"step {step} already holds a different FINEX index "
                    f"(eps={prev['eps']}, minpts={prev['minpts']}, "
                    f"n={prev.get('n')}); delete it or use another step")
            return                       # idempotent: index already durable
        meta = {"kind": "finex_index", "eps": float(index.eps),
                "minpts": int(index.minpts), "metric": index.metric,
                "n": int(index.n), "nnz": int(index.csr.nnz),
                "fingerprint": index.fingerprint() or ""}
        meta.update(extra or {})
        self.save(step, index.to_arrays(), extra=meta, async_=async_)

    def _step_meta(self, step: int) -> dict:
        try:
            with open(os.path.join(self.dir, f"step_{step}",
                                   "MANIFEST.json")) as f:
                return json.load(f).get("extra", {})
        except FileNotFoundError:
            # a concurrent writer's _gc can rotate the step away between
            # all_steps() and this read — treat as kind-less, not fatal
            return {}

    def _step_kind(self, step: int) -> Optional[str]:
        return self._step_meta(step).get("kind")

    def delete_step(self, step: int) -> bool:
        """Durably remove one step's artifacts (index snapshots included).
        Returns True if something was deleted."""
        self.wait()
        final = os.path.join(self.dir, f"step_{step}")
        existed = os.path.exists(final)
        shutil.rmtree(final, ignore_errors=True)
        return existed

    # -------------------------------------------------- catalog documents
    # Small JSON documents living next to the step dirs, written with the
    # same torn-write discipline as manifests (tmp + fsync + rename).
    # ``IndexStore`` keeps its spill catalog here so spilled indexes
    # survive a process restart.
    def save_catalog(self, name: str, payload: dict) -> None:
        """Atomically publish ``<dir>/<name>.json``."""
        tmp = os.path.join(self.dir, f".tmp_{name}.json")
        final = os.path.join(self.dir, f"{name}.json")
        with open(tmp, "w") as f:
            json.dump(payload, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)                     # atomic publish

    def load_catalog(self, name: str) -> Optional[dict]:
        """The last published catalog, or None if absent/unreadable.
        A corrupt document degrades to "no catalog" (the store falls
        back to rebuilding) rather than poisoning construction."""
        try:
            with open(os.path.join(self.dir, f"{name}.json")) as f:
                return json.load(f)
        except FileNotFoundError:
            return None
        except json.JSONDecodeError:
            warnings.warn(
                f"catalog {name}.json is not valid JSON — ignoring it "
                "(spilled entries will rebuild instead of reloading)")
            return None

    def restore_index(self, step: int, data: Any = None):
        """Rebuild a ``FinexIndex`` saved by :meth:`save_index`.

        Pass ``data`` (the raw dataset) to re-attach a distance engine —
        required for ε*-queries; MinPts*-queries work without it.
        """
        if self._step_kind(step) != "finex_index":
            raise ValueError(f"step {step} does not hold a FINEX index")
        from repro.core.index import FinexIndex
        return FinexIndex.from_arrays(self.load_flat(step), data=data)


def _unflatten_like(like: Any, flat: Dict[str, np.ndarray],
                    prefix: str = "") -> Any:
    if isinstance(like, dict):
        return {k: _unflatten_like(v, flat, f"{prefix}{k}/")
                for k, v in like.items()}
    if hasattr(like, "_fields"):
        return type(like)(**{k: _unflatten_like(getattr(like, k), flat,
                                                f"{prefix}{k}/")
                             for k in like._fields})
    if isinstance(like, (list, tuple)):
        return type(like)(_unflatten_like(v, flat, f"{prefix}{i}/")
                          for i, v in enumerate(like))
    arr = flat[prefix[:-1]]
    return jax.numpy.asarray(arr, dtype=like.dtype if hasattr(like, "dtype")
                             else None)
