from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.elastic import restore_for_mesh

__all__ = ["CheckpointManager", "restore_for_mesh"]
