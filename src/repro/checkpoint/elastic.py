"""Elastic restore: resume a run on a *different* mesh/device count.

Checkpoints store logical (global) arrays; restore places each array with
the sharding derived from the *new* mesh — so a job preempted on 512
chips can resume on 256, or a single-host smoke run can be reloaded onto
an 8-device test mesh. This is the checkpoint half of elastic scaling;
the data half is free because the token stream is a pure function of
(step, dp_rank, dp_size) (repro/data/tokens.py).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.checkpoint.manager import CheckpointManager, _unflatten_like


def restore_for_mesh(mgr: CheckpointManager, step: int, like: Any,
                     mesh: Optional[Mesh],
                     sharding_for: Optional[Dict[str, NamedSharding]] = None
                     ) -> Any:
    """Restore ``like``-shaped state, placing arrays onto ``mesh``.

    ``sharding_for``: optional {flat-path: NamedSharding}; paths not listed
    are replicated. With mesh=None this is a plain host restore.
    """
    flat = mgr.load_flat(step)
    if mesh is None:
        return _unflatten_like(like, flat)

    placed: Dict[str, Any] = {}
    for path, arr in flat.items():
        sh = (sharding_for or {}).get(path)
        if sh is None:
            sh = NamedSharding(mesh, jax.sharding.PartitionSpec())
        placed[path] = jax.device_put(arr, sh)
    return _unflatten_like(like, placed)
