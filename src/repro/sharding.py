"""Sharding rules: parameter PartitionSpecs + activation constraints.

Mesh axes (launch/mesh.py):
  single-pod:  ("data", "model")           = (16, 16)
  multi-pod:   ("pod", "data", "model")    = (2, 16, 16)

Scheme (MaxText-style 2-level):
  * batch/DP  over ("pod", "data") — pure replication of params across pods
    (cross-pod traffic = one gradient all-reduce per step),
  * FSDP      over "data" only — parameter/optimizer shards gathered
    per-layer inside the scan, keeping gather traffic on in-pod links,
  * TP        over "model" — fused projection output dims, vocab, expert
    hidden dims (or the expert axis itself under EP).

Rules key off parameter *path names*, not tensor ranks, so every model
family shares one table. All sharded parameter dims are divisible by their
mesh axes by construction (vocab padding, fused head dims) — jit
in_shardings require exact divisibility.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    """The data-parallel (batch) axes of this mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axis(mesh: Mesh, over_pod: bool = False):
    """FSDP shard axes: in-pod by default; spanning pods for 400B-class
    models whose optimizer state cannot fit a single pod's HBM."""
    if over_pod and "pod" in mesh.axis_names:
        return ("pod", "data")
    return "data"


# parameter-path suffix -> spec builder. 'F' = fsdp axis, 'M' = model axis.
_PARAM_RULES: Dict[str, tuple] = {
    "embed":        ("M", None),          # vocab-parallel embedding (V, d)
    "pos_embed":    (None, None),
    "lm_head":      ("F", "M"),           # (d, V)
    "wqkv":         (None, "F", "M"),     # (L, d, fused)
    "bqkv":         (None, "M"),          # (L, fused)
    "wo":           (None, "M", "F"),     # (L, H*hd, d)
    "w_gate_up":    (None, "F", "M"),     # (L, d, 2*ff)
    "w_down":       (None, "M", "F"),     # (L, ff, d)
    "router":       (None, "F", None),    # (L, d, E)
    "shared_gate_up": (None, "F", "M"),   # (L, d, 2*sff) merged shared experts
    "shared_down":  (None, "M", "F"),     # (L, sff, d)
    "shared_gate":  (None, "F"),          # (L, d)
    # routed experts: EP shards the expert axis, expert-TP the hidden dim
    "experts_gate_up@ep": (None, "M", "F", None),   # (L, E, d, 2*ff)
    "experts_down@ep":    (None, "M", None, "F"),   # (L, E, ff, d)
    "experts_gate_up@tp": (None, None, "F", "M"),
    "experts_down@tp":    (None, None, "M", "F"),
    # mamba2 SSD
    "ssm_in":       (None, "F", "M"),     # (L, d, 2*din+2*G*S+H)
    "ssm_out":      (None, "M", "F"),     # (L, din, d)
    "ssm_conv":     (None, None, "M"),    # (L, K, din+2*G*S)
    "ssm_anorm":    (None, None),         # (L, H) A / dt_bias / D / norm
    "norm":         (None, None),         # (L, d) and final (d,)
    "scale":        (None,),
}


def param_spec(path: str, mesh: Mesh, expert_parallel: bool = True,
               fsdp_over_pod: bool = False) -> P:
    """PartitionSpec for a parameter identified by its path suffix."""
    leaf = path.split("/")[-1]
    key = leaf
    if leaf.startswith("experts_"):
        key = f"{leaf}@{'ep' if expert_parallel else 'tp'}"
    if key not in _PARAM_RULES:
        for k in _PARAM_RULES:       # prefix fallback (norm_1, norm_f, ...)
            if key.startswith(k.split("@")[0]):
                key = k if "@" not in k else key
                break
        else:
            key = "norm"
    rule = _PARAM_RULES.get(key) or _PARAM_RULES["norm"]
    fs = fsdp_axis(mesh, fsdp_over_pod)
    axes = tuple(fs if a == "F" else ("model" if a == "M" else None)
                 for a in rule)
    return P(*axes)


def check_divisible(path: str, shape: tuple, spec: P, mesh: Mesh) -> P:
    """Drop sharding on any dim the mesh does not divide (defensive)."""
    fixed = []
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            fixed.append(None)
            continue
        axsz = int(np.prod([sizes[a] for a in (ax if isinstance(ax, tuple) else (ax,))]))
        fixed.append(ax if dim % axsz == 0 else None)
    return P(*fixed)


def param_shardings(param_shapes: Dict[str, Any], mesh: Mesh,
                    expert_parallel: bool = True,
                    fsdp_over_pod: bool = False
                    ) -> Dict[str, NamedSharding]:
    """Map a flat {path: ShapeDtypeStruct} dict to NamedShardings."""
    out = {}
    for path, sds in param_shapes.items():
        spec = param_spec(path, mesh, expert_parallel, fsdp_over_pod)
        spec = check_divisible(path, sds.shape, spec, mesh)
        out[path] = NamedSharding(mesh, spec)
    return out


def batch_spec(mesh: Mesh, extra=()) -> P:
    return P(dp_axes(mesh), *extra)


def act_spec(mesh: Mesh, *, seq_sharded: bool = False) -> P:
    """(B, T, D) activation spec; optionally sequence-parallel on 'model'."""
    return P(dp_axes(mesh), "model" if seq_sharded else None, None)


def kvcache_spec(mesh: Mesh, *, batch_first_dims: int = 2) -> P:
    """(L, B, S, KV, hd): batch over DP, cache sequence over 'model'.

    Sequence-sharding the cache is what makes decode_32k fit: attention
    becomes flash-decode (partial softmax + psum over 'model'), which XLA
    SPMD derives automatically from the reduce over the sharded S axis.
    """
    return P(None, dp_axes(mesh), "model", None, None)


def ssm_state_spec(mesh: Mesh) -> P:
    """(L, B, H, hd, S): SSD decode state — shard the state dim on 'model'."""
    return P(None, dp_axes(mesh), None, None, "model")


def constrain(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
