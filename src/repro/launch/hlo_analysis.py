"""Roofline-term analysis of compiled (partitioned) HLO text.

Why parse text at all:
  * ``cost_analysis()`` has no collective-bytes entry, and
  * it counts ``while`` bodies ONCE — a scan over 80 layer groups or 16
    microbatches under-reports FLOPs/bytes by that factor (verified
    empirically; see EXPERIMENTS.md §Dry-run notes).

So this module walks the HLO computation graph:
  * builds a per-block symbol table (name → shape) to resolve operand
    sizes (HLO operands are name references),
  * recovers loop trip counts from each while-condition's comparison
    constant and multiplies everything inside accordingly,
  * accumulates three quantities per device:
      - dot FLOPs (2·M·N·K from the dot's shapes — matmuls dominate LMs),
      - HBM-traffic model: operand+result bytes of top-level instructions
        (fusion internals excluded — only fusion boundaries touch HBM),
      - collective operand/wire bytes per op type, with ring-algorithm
        wire modeling 2·(g−1)/g for all-reduce etc.

All results are per-device for the partitioned module; the dry-run
multiplies by chip count where the mandate's formulas want globals.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{(\{[\d, ]+\})")
# ops whose "operands" are control/metadata, not data
_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "copy", "after-all", "partition-id", "replica-id", "iota",
             "custom-call"}


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d.strip())
        out.append((dt, shape))
    return out


def _bytes_of(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Collective:
    op: str
    result_bytes: int
    operand_bytes: int
    group_size: int


@dataclass
class _Block:
    name: str
    collectives: List[_Collective] = field(default_factory=list)
    whiles: List[Tuple[str, str]] = field(default_factory=list)
    calls: List[Tuple[str, bool]] = field(default_factory=list)  # (tgt, fused)
    max_const: int = 1
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    attn_excess: float = 0.0   # (T,S)-sized dot traffic a flash kernel
    #                            keeps in VMEM (score dot result / probs·V
    #                            operand)


def _split_blocks(text: str) -> Dict[str, List[str]]:
    blocks: Dict[str, List[str]] = {}
    cur = None
    for line in text.splitlines():
        if cur is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(\([^{]*\))?\s*(->[^{]*)?\{",
                         line)
            if m:
                cur = m.group(1)
                blocks[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        blocks[cur].append(line)
    return blocks


def _first_paren_args(rhs: str, op_end: int) -> List[str]:
    """Operand names inside the opcode's argument parens."""
    depth = 0
    start = None
    for i in range(op_end - 1, len(rhs)):
        ch = rhs[i]
        if ch == "(":
            depth += 1
            if start is None:
                start = i + 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                inner = rhs[start:i]
                args = []
                for a in _split_top(inner):
                    a = a.strip()
                    if not a:
                        continue
                    args.append(a.split(" ")[-1].lstrip("%"))
                return args
    return []


def _split_top(s: str) -> List[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    out.append("".join(cur))
    return out


def _parse_block(name: str, lines: List[str]) -> _Block:
    blk = _Block(name=name)
    symbols: Dict[str, List[Tuple[str, Tuple[int, ...]]]] = {}
    for line in lines:
        d = _DEF_RE.match(line)
        if not d:
            continue
        lhs, rhs = d.group(1), d.group(2)
        op_m = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
        type_str = rhs[:op_m.start()] if op_m else rhs
        res_shapes = _parse_shapes(type_str)
        symbols[lhs] = res_shapes
        cm = re.search(r"constant\((\d+)\)", rhs)
        if cm:
            blk.max_const = max(blk.max_const, int(cm.group(1)))
        if not op_m:
            continue
        opcode = op_m.group(1)
        result_bytes = _bytes_of(res_shapes)
        args = _first_paren_args(rhs, op_m.end())
        operand_bytes = sum(_bytes_of(symbols.get(a, [])) for a in args)

        if opcode == "while":
            cond = re.search(r"condition=%?([\w.\-]+)", rhs)
            body = re.search(r"body=%?([\w.\-]+)", rhs)
            if cond and body:
                blk.whiles.append((cond.group(1), body.group(1)))
            continue
        if opcode in ("call", "fusion", "conditional"):
            for tgt in re.findall(r"(?:to_apply|calls)=%?([\w.\-]+)", rhs):
                blk.calls.append((tgt, opcode == "fusion"))
            # fusion boundary = HBM traffic (internals never touch HBM)
            blk.hbm_bytes += operand_bytes + result_bytes
            continue

        base = opcode
        for suf in ("-start", "-done", "-update"):
            if base.endswith(suf):
                base = base[: -len(suf)]
        if base in _COLLECTIVES and not opcode.endswith("-done"):
            if operand_bytes == 0:
                operand_bytes = result_bytes
            g = 1
            gm = _GROUPS_RE.search(rhs)
            if gm:
                g = int(gm.group(2))           # [n_groups, group_size]
            else:
                g1 = _GROUPS_V1_RE.search(rhs)
                if g1:
                    g = g1.group(1).count(",") + 1
            blk.collectives.append(_Collective(
                op=base, result_bytes=result_bytes,
                operand_bytes=operand_bytes, group_size=max(g, 1)))
            blk.hbm_bytes += operand_bytes + result_bytes
            continue

        if opcode == "dot":
            lhs_shape = symbols.get(args[0], []) if args else []
            k = 1
            cm2 = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
            if cm2 and lhs_shape:
                dims = lhs_shape[0][1]
                for ci in cm2.group(1).split(","):
                    if ci.strip():
                        k *= dims[int(ci)] if int(ci) < len(dims) else 1
            res_elems = 0
            for _, shp in res_shapes:
                n = 1
                for dd in shp:
                    n *= dd
                res_elems += n
            blk.dot_flops += 2.0 * res_elems * k
            blk.hbm_bytes += operand_bytes + result_bytes
            # attention-shaped dots: the (T,S) score matrix dwarfs the
            # (T,hd)/(S,hd) operands (score dot) or vice versa (probs·V).
            # A flash kernel never writes it to HBM.
            if result_bytes > 4 * max(operand_bytes, 1):
                blk.attn_excess += result_bytes
            elif operand_bytes > 4 * max(result_bytes, 1):
                blk.attn_excess += operand_bytes - result_bytes
            continue

        if opcode not in _SKIP_OPS:
            blk.hbm_bytes += operand_bytes + result_bytes
    return blk


def analyze_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-device while-weighted FLOPs / HBM bytes / collective bytes."""
    raw = _split_blocks(hlo_text)
    blocks = {n: _parse_block(n, ls) for n, ls in raw.items()}

    called = set()
    for b in blocks.values():
        for cond, body in b.whiles:
            called.add(cond)
            called.add(body)
        called.update(t for t, _ in b.calls)
    entries = [n for n in blocks if n not in called] or list(blocks)[:1]

    totals: Dict[str, float] = defaultdict(float)

    def visit(name: str, mult: float, in_fusion: bool, stack: tuple):
        blk = blocks.get(name)
        if blk is None or name in stack:
            return
        totals["dot_flops"] += mult * blk.dot_flops
        totals["dot_flops_unweighted"] += blk.dot_flops
        if not in_fusion:
            totals["hbm_bytes"] += mult * blk.hbm_bytes
            totals["hbm_bytes_unweighted"] += blk.hbm_bytes
        totals["attn_excess_bytes"] += mult * blk.attn_excess
        for c in blk.collectives:
            totals["collective_operand_bytes"] += mult * c.operand_bytes
            totals["collective_wire_bytes"] += mult * _wire_bytes(c)
            totals["collective_count"] += mult
            totals[f"bytes[{c.op}]"] += mult * c.operand_bytes
        for cond, body in blk.whiles:
            trip = blocks[cond].max_const if cond in blocks else 1
            visit(body, mult * max(trip, 1), in_fusion, stack + (name,))
        for tgt, fused in blk.calls:
            visit(tgt, mult, in_fusion or fused, stack + (name,))

    for e in entries:
        visit(e, 1.0, False, ())
    return dict(totals)


def _wire_bytes(c: _Collective) -> float:
    g = c.group_size
    if g <= 1:
        return 0.0
    ring = (g - 1) / g
    if c.op == "all-reduce":
        return 2.0 * ring * c.operand_bytes
    if c.op == "all-gather":
        return ring * c.result_bytes
    if c.op == "reduce-scatter":
        return ring * c.operand_bytes
    if c.op in ("all-to-all", "ragged-all-to-all"):
        return ring * c.operand_bytes
    return float(c.operand_bytes)
