"""Serving driver: batched generation against any registry architecture.

CPU/example scale — the production decode path is what decode_* dry-run
cells lower; this driver exercises the same decode_step through the
ServeEngine's slot-batched loop.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import RunConfig, ShapeConfig, get_arch
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServeEngine


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if not cfg.causal:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    rc = RunConfig(model=cfg,
                   shape=ShapeConfig("serve", args.prompt_len + args.max_new,
                                     args.slots, "decode"),
                   remat=False, dtype="float32")
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab,
                                        size=args.prompt_len).astype(np.int32),
                    max_new=args.max_new)
            for _ in range(args.requests)]
    eng = ServeEngine(params, cfg, rc, batch_slots=args.slots,
                      max_seq=args.prompt_len + args.max_new + 8,
                      temperature=args.temperature, seed=args.seed)
    t0 = time.time()
    eng.run(reqs)
    dt = time.time() - t0
    tok = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests, {tok} tokens in {dt:.1f}s "
          f"({tok / dt:.1f} tok/s, {eng.decode_steps} decode steps)")
    for r in reqs[:3]:
        print("  out:", r.out[:12], "...")
    return {"tokens": tok, "seconds": dt}


if __name__ == "__main__":
    main()
