"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
*before* the first jax initialization.

Topology: TPU v5e-class pods of 256 chips arranged (16, 16):
  * "data"  — DP/FSDP axis (16-way), in-pod ICI
  * "model" — TP axis (16-way), in-pod ICI
  * "pod"   — cross-pod data parallelism (2-way for the 512-chip dry-run);
              scales to N pods at fleet size, carrying one gradient
              all-reduce (optionally int8-compressed) per step.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types; 0.4.x has neither the enum nor the kwarg
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many (host) devices exist — tests/smoke."""
    return _make_mesh((data, model), ("data", "model"))
