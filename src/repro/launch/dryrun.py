import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the
# device count at first initialization, and the production meshes below
# need 512 placeholder host devices. Do not move them.

__doc__ = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. builds the cell's production step from input_specs (ShapeDtypeStruct
     stand-ins only — nothing is allocated),
  3. ``.lower().compile()`` — any sharding mismatch, non-divisible dim or
     compile-time OOM is a bug in the framework and fails the run,
  4. records memory_analysis / cost_analysis / while-weighted HLO terms
     (launch.hlo_analysis) and the three roofline terms into a JSON store
     that benchmarks/roofline.py and EXPERIMENTS.md read.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  python -m repro.launch.dryrun --sweep                 # all cells
  python -m repro.launch.dryrun --arch finex            # paper workload
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ARCHS, SHAPES, RunConfig, get_arch
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import auto_n_micro, build_lowerable

# --- TPU v5e-class hardware constants (mandate §Roofline) ---
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link per chip

RESULTS_PATH = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/results/dryrun.json")


def model_flops(cfg, shape) -> float:
    """Global MODEL_FLOPS: 6·N·D (train), 2·N·D (prefill/decode fwd)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch        # decode: 1 tok/seq


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: Optional[dict] = None) -> Dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec: Dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "chips": chips}

    if arch == "finex":
        from repro.neighbors.distributed import finex_dryrun_lowerable
        fn, args, shardings = finex_dryrun_lowerable(mesh)
        rec["n_micro"] = 1
        rec["model_flops"] = 2.0 * (1 << 20) ** 2 * 64   # n² d-dim distances
    elif arch == "finex-jaccard":
        from repro.neighbors.distributed import finex_jaccard_dryrun_lowerable
        fn, args, shardings = finex_jaccard_dryrun_lowerable(mesh)
        rec["n_micro"] = 1
        # AND + popcount + accumulate ≈ 3 VPU ops per packed word pair
        rec["model_flops"] = 3.0 * (1 << 20) ** 2 * 64
    elif arch == "finex-csr":
        from repro.neighbors.distributed import finex_csr_dryrun_lowerable
        fn, args, shardings = finex_csr_dryrun_lowerable(mesh)
        rec["n_micro"] = 1
        # distances + the O(n²) threshold/compact epilogue per shard
        rec["model_flops"] = 2.0 * (1 << 20) ** 2 * 64
    else:
        cfg = get_arch(arch)
        shape = SHAPES[shape_name]
        rc = RunConfig(model=cfg, shape=shape, multi_pod=multi_pod,
                       **(overrides or {}))
        skip = rc.skip_reason()
        if skip:
            rec.update(status="skipped", reason=skip)
            return rec
        rec["n_micro"] = (rc.microbatch or auto_n_micro(cfg, shape, mesh)
                          if shape.kind == "train" else 1)
        rec["model_flops"] = model_flops(cfg, shape)
        fn, args, shardings = build_lowerable(cfg, rc, mesh)

    # donate the mutable state (train state / decode cache) — production
    # steps run in place; without donation every step double-buffers GBs
    if arch.startswith("finex"):
        donate = ()
    elif SHAPES[shape_name].kind == "train":
        donate = (0,)                  # TrainState
    elif SHAPES[shape_name].kind == "decode":
        donate = (1,)                  # cache
    else:
        donate = ()
    with mesh:
        lowered = jax.jit(fn, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

    _fill_analysis(rec, compiled, t0, t_lower, t_compile)
    return rec


def _fill_analysis(rec: Dict, compiled, t0: float,
                   t_lower: float = None, t_compile: float = None) -> Dict:
    """Populate a cell record from a compiled executable (shared by the
    sweep and the §Perf variant driver)."""
    if t_lower is None:
        t_lower = t_compile = time.time()
    chips = rec["chips"]
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # jax 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    hlo = analyze_hlo(compiled.as_text())

    # cost_analysis counts while bodies once; scale its numbers by the
    # weighted/unweighted ratio from the HLO walk (keeps XLA's own per-op
    # accounting, fixes the trip counts).
    dot_w = hlo.get("dot_flops", 0.0)
    dot_u = hlo.get("dot_flops_unweighted", 0.0)
    hbm_w = hlo.get("hbm_bytes", 0.0)
    hbm_u = hlo.get("hbm_bytes_unweighted", 0.0)
    flops_mult = max(1.0, dot_w / dot_u) if dot_u else 1.0
    bytes_mult = max(1.0, hbm_w / hbm_u) if hbm_u else 1.0
    flops_dev = max(cost.get("flops", 0.0) * flops_mult, dot_w)
    bytes_dev = min(cost.get("bytes accessed", 0.0) * bytes_mult,
                    hbm_w) or hbm_w
    coll_dev = hlo.get("collective_operand_bytes", 0.0)
    wire_dev = hlo.get("collective_wire_bytes", 0.0)
    attn_excess = hlo.get("attn_excess_bytes", 0.0) * bytes_mult

    compute_term = flops_dev / PEAK_FLOPS            # = global/(chips·peak)
    memory_term = bytes_dev / HBM_BW
    collective_term = coll_dev / ICI_BW
    terms = {"compute": compute_term, "memory": memory_term,
             "collective": collective_term}
    bottleneck = max(terms, key=terms.get)
    step_time = max(terms.values())
    mf = rec["model_flops"]
    useful_ratio = mf / (flops_dev * chips) if flops_dev else 0.0
    mfu = (mf / chips / PEAK_FLOPS) / step_time if step_time else 0.0
    # flash-kernel variant: attention score/probs traffic stays in VMEM
    # (kernels/flash_swa.py on real TPUs); same FLOPs, less memory traffic
    mem_flash = max(0.0, bytes_dev - min(attn_excess, bytes_dev)) / HBM_BW
    step_flash = max(compute_term, mem_flash, collective_term)
    mfu_flash = (mf / chips / PEAK_FLOPS) / step_flash if step_flash else 0.0

    rec.update(
        status="ok",
        lower_s=round(t_lower - t0, 1),
        compile_s=round(t_compile - t_lower, 1),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            code_bytes=mem.generated_code_size_in_bytes,
            peak_per_device=(mem.argument_size_in_bytes
                             + mem.output_size_in_bytes
                             - mem.alias_size_in_bytes
                             + mem.temp_size_in_bytes)),
        cost_analysis=dict(
            flops=cost.get("flops", 0.0),
            bytes_accessed=cost.get("bytes accessed", 0.0)),
        hlo_weighted=dict(
            dot_flops_per_dev=dot_w,
            hbm_model_bytes_per_dev=hbm_w,
            flops_mult=flops_mult,
            bytes_mult=bytes_mult,
            flops_per_dev=flops_dev,
            hbm_bytes_per_dev=bytes_dev,
            collective_operand_bytes_per_dev=coll_dev,
            collective_wire_bytes_per_dev=wire_dev,
            collective_count=hlo.get("collective_count", 0.0),
            per_op={k: v for k, v in hlo.items() if k.startswith("bytes[")}),
        roofline=dict(
            compute_term_s=compute_term,
            memory_term_s=memory_term,
            collective_term_s=collective_term,
            bottleneck=bottleneck,
            step_time_s=step_time,
            model_flops_ratio=useful_ratio,
            roofline_fraction=mfu,
            memory_term_flash_s=mem_flash,
            step_time_flash_s=step_flash,
            roofline_fraction_flash=mfu_flash,
            attn_excess_bytes_per_dev=attn_excess),
    )
    return rec


def load_results(path: str = RESULTS_PATH) -> Dict[str, Dict]:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_result(rec: Dict, path: str = RESULTS_PATH) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    results = load_results(path)
    key = f"{rec['arch']}|{rec['shape']}|{rec['mesh']}"
    results[key] = rec
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id, or 'finex' for the paper cell")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells already in the results store")
    ap.add_argument("--out", default=RESULTS_PATH)
    args = ap.parse_args()

    if args.sweep:
        cells = [(a, s) for a in list(ARCHS)
                 + ["finex", "finex-jaccard", "finex-csr"]
                 for s in (["train_4k"] if a.startswith("finex")
                           else list(SHAPES))]
    else:
        assert args.arch, "--arch or --sweep required"
        shapes = [args.shape] if args.shape else (
            ["train_4k"] if args.arch.startswith("finex") else list(SHAPES))
        cells = [(args.arch, s) for s in shapes]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    existing = load_results(args.out)

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            key = f"{arch}|{shape}|{'2x16x16' if mp else '16x16'}"
            if not args.force and existing.get(key, {}).get("status") in (
                    "ok", "skipped"):
                print(f"[cached ] {key}")
                continue
            try:
                rec = run_cell(arch, shape, mp)
            except Exception as e:                        # noqa: BLE001
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "error", "error": str(e)[:2000],
                       "traceback": traceback.format_exc()[-4000:]}
                failures += 1
            save_result(rec, args.out)
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f" bottleneck={r['bottleneck']}"
                         f" frac={r['roofline_fraction']:.3f}"
                         f" compile={rec['compile_s']}s"
                         f" mem/dev={rec['memory']['peak_per_device']/2**30:.2f}GiB")
            elif status == "skipped":
                extra = f" ({rec['reason']})"
            else:
                extra = f" !! {rec['error'][:160]}"
            print(f"[{status:7s}] {key}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
