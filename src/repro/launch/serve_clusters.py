"""Clustering-service driver: batched parameter exploration as a server.

Example-scale stand-in for the production serving loop: synthesizes a few
datasets, then drains a mixed request stream (builds, single clusterings,
parameter sweeps, all-scales hierarchy reads, stats probes) through
``ClusterService`` — same-index requests coalesce into shared batched
sweeps, and the ``IndexStore`` keeps indexes warm across requests
(spilling LRU victims to disk when ``--store-dir`` is set).  Settings go
through the typed query API (``Eps``/``MinPts``/``Hierarchy``;
``--hierarchy-frac`` sets how many reads hit the condensed tree).

``--concurrent`` switches to the threaded ``ServiceFrontend``: ``--clients``
threads submit interleaved sweeps and mutations against named indexes,
``--workers`` worker threads serve coalesced per-index windows, and
admission rejections are retried with backoff.  SIGINT/SIGTERM trigger a
graceful drain in either mode — in-flight work flushes, ``--stats-json``
is still written, the trace sink is flushed — instead of dying mid-window.

    PYTHONPATH=src python -m repro.launch.serve_clusters --smoke
    PYTHONPATH=src python -m repro.launch.serve_clusters \
        --n 20000 --requests 64 --sweep-k 8 --capacity 2 --datasets 3
    PYTHONPATH=src python -m repro.launch.serve_clusters --smoke \
        --concurrent --workers 2 --clients 4
"""
from __future__ import annotations

import argparse
import json
import signal
import threading
import time

import numpy as np

from repro import obs
from repro.data.synthetic import gaussian_mixture
from repro.service import (BuildOp, BuildRequest, ClusterOp, ClusterRequest,
                           ClusterService, Eps, Hierarchy, HierarchyOp,
                           IndexStore, MinPts, MutateRequest,
                           ServiceFrontend, StatsOp, StatsRequest, SweepOp,
                           SweepRequest)
from repro.service.frontend import AdmissionError


def _one_setting(eps, minpts, rng, hierarchy_frac):
    """One typed sweep setting (the CLI speaks the typed query API;
    bare tuples still work everywhere downstream)."""
    k = rng.random()
    if k < hierarchy_frac:
        return Hierarchy()
    if k < hierarchy_frac + (1.0 - hierarchy_frac) / 2:
        return Eps(float(eps * rng.uniform(0.2, 1.0)))
    return MinPts(int(minpts * rng.integers(1, 9)))


def _request_stream(datasets, eps, minpts, n_requests, sweep_k, rng,
                    hierarchy_frac=0.15):
    """Mixed request stream: ~1/3 single clusterings, ~2/3 sweeps."""
    reqs = [BuildRequest(data=x, eps=eps, minpts=minpts) for x in datasets]
    for _ in range(n_requests):
        x = datasets[rng.integers(len(datasets))]
        if rng.random() < 0.33:
            reqs.append(ClusterRequest(
                data=x, eps=eps, minpts=minpts,
                setting=_one_setting(eps, minpts, rng, hierarchy_frac)))
        else:
            settings = [_one_setting(eps, minpts, rng, hierarchy_frac)
                        for _ in range(sweep_k)]
            reqs.append(SweepRequest(data=x, eps=eps, minpts=minpts,
                                     settings=settings))
    reqs.append(StatsRequest())
    return reqs


def _install_signal_drain(stop: threading.Event):
    """SIGINT/SIGTERM set the stop flag and raise KeyboardInterrupt in
    the main thread — both serving loops catch it and fall through to
    the drain + stats-flush path instead of dying mid-window."""
    def _graceful(signum, frame):
        stop.set()
        raise KeyboardInterrupt
    try:
        signal.signal(signal.SIGINT, _graceful)
        signal.signal(signal.SIGTERM, _graceful)
    except ValueError:
        pass       # not the main thread (embedded use): Event still works


def _run_concurrent(args, datasets, manager, stop: threading.Event) -> dict:
    """The ``--concurrent`` path: N client threads against the threaded
    frontend, mutations included."""
    fe = ServiceFrontend(
        store=IndexStore(capacity=args.capacity, manager=manager),
        workers=args.workers, window=args.window,
        max_queue=args.max_queue)
    names = [f"ds{i}" for i in range(len(datasets))]
    rejected_retries = 0
    interrupted = False
    t0 = time.perf_counter()
    try:
        for nm, x in zip(names, datasets):
            fe.submit(BuildOp(nm, x, args.eps, args.minpts)).result()
        futures = []
        lock = threading.Lock()

        def client(tid: int) -> None:
            nonlocal rejected_retries
            r = np.random.default_rng(args.seed + 1000 + tid)
            for _ in range(args.requests):
                if stop.is_set():
                    return
                nm = names[int(r.integers(len(names)))]
                x = datasets[names.index(nm)]
                k = float(r.random())
                if k < args.mutate_frac / 2:
                    pts = (x[r.integers(0, x.shape[0], size=2)]
                           + r.normal(scale=0.05, size=(2, x.shape[1])))
                    req = MutateRequest(nm, "insert", points=pts)
                elif k < args.mutate_frac:
                    # low ids are always valid: deletes never outpace
                    # inserts far enough to shrink below the seed size
                    req = MutateRequest(
                        nm, "delete", ids=[int(r.integers(0, 8))])
                elif k < args.mutate_frac + args.hierarchy_frac:
                    # all-scales read: answered from the warm condensed
                    # tree (invalidated by the interleaved mutations, so
                    # this also exercises the lazy rebuild under load)
                    req = HierarchyOp(nm)
                elif k < 0.8:
                    settings = [_one_setting(args.eps, args.minpts, r,
                                             args.hierarchy_frac)
                                for _ in range(args.sweep_k)]
                    req = SweepOp(nm, settings)
                else:
                    req = ClusterOp(nm)
                while not stop.is_set():
                    try:
                        f = fe.submit(req)
                    except AdmissionError:
                        with lock:
                            rejected_retries += 1
                        time.sleep(0.005)
                        continue
                    with lock:
                        futures.append(f)
                    break

        threads = [threading.Thread(target=client, args=(t,), daemon=True)
                   for t in range(args.clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        # the Stats verb rides the queue like any other op — its snapshot
        # is mid-stream (ops behind it still pending), the drain below
        # flushes those before the final report
        probe = fe.submit(StatsOp()).result(timeout=60)
        probe_depth = probe["frontend"]["queue_depth"]
    except KeyboardInterrupt:
        interrupted = True
        probe_depth = None
        print("signal received — draining frontend ...")
    finally:
        drained = fe.shutdown(drain=True, timeout=60.0)
    dt = time.perf_counter() - t0
    st = fe.stats()
    fr = st["frontend"]
    per_s = fr["completed"] / dt if dt > 0 else float("inf")
    print(f"frontend: {fr['completed']} responses in {dt:.2f}s "
          f"-> {per_s:.1f} responses/s "
          f"({fr['batched_sweeps']} sweep batches, "
          f"{fr['batched_deltas']} coalesced deltas, "
          f"{fr['coalesced_mutations']} mutation riders)")
    print(f"  admission: rejected={fr['rejected']} "
          f"(client retries {rejected_retries}), windows={fr['windows']}, "
          f"mid-stream queue depth {probe_depth}")
    print(f"  store: {st['store']}")
    return {"seconds": dt, "responses_per_s": per_s,
            "graceful_shutdown": drained, "interrupted": interrupted,
            "probe_queue_depth": probe_depth,
            "client_retries": rejected_retries, **st}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--eps", type=float, default=1.0)
    ap.add_argument("--minpts", type=int, default=16)
    ap.add_argument("--datasets", type=int, default=2)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--sweep-k", type=int, default=6)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--store-dir", default=None,
                    help="spill evicted indexes here (default: drop them)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny datasets / few requests")
    ap.add_argument("--concurrent", action="store_true",
                    help="serve through the threaded ServiceFrontend "
                         "(submit/Future, admission control, coalesced "
                         "mutation windows)")
    ap.add_argument("--workers", type=int, default=2,
                    help="frontend worker threads (--concurrent)")
    ap.add_argument("--clients", type=int, default=4,
                    help="submitting client threads (--concurrent); "
                         "--requests counts per client")
    ap.add_argument("--window", type=int, default=8,
                    help="dispatch window size (--concurrent)")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="intake admission bound (--concurrent)")
    ap.add_argument("--mutate-frac", type=float, default=0.2,
                    help="fraction of client ops that mutate "
                         "(--concurrent)")
    ap.add_argument("--hierarchy-frac", type=float, default=0.15,
                    help="fraction of reads that are all-scales "
                         "hierarchy queries (HierarchyOp / Hierarchy "
                         "sweep settings)")
    ap.add_argument("--stats-json", default=None, metavar="PATH",
                    help="dump the final Telemetry.snapshot() (plus the "
                         "service counters) to PATH on exit; implies "
                         "tracing on")
    ap.add_argument("--stats-every", type=int, default=0, metavar="N",
                    help="log a service stats line every N served "
                         "requests (0 = off); implies tracing on")
    args = ap.parse_args(argv)
    if args.smoke:
        args.n, args.requests, args.datasets = 800, 8, 2
    if args.stats_json or args.stats_every:
        # observability requested: turn the tracer on (REPRO_TRACE may
        # already have enabled it, with a JSONL sink attached)
        obs.enable()

    rng = np.random.default_rng(args.seed)
    datasets = [gaussian_mixture(args.n, d=args.d, k=8, seed=args.seed + i)
                for i in range(args.datasets)]
    manager = None
    if args.store_dir:
        from repro.checkpoint.manager import CheckpointManager
        manager = CheckpointManager(args.store_dir)

    stop = threading.Event()
    _install_signal_drain(stop)

    if args.concurrent:
        out = _run_concurrent(args, datasets, manager, stop)
        if args.stats_json:
            with open(args.stats_json, "w") as f:
                json.dump(out, f, indent=2, default=str)
            print(f"  stats snapshot -> {args.stats_json}")
        obs.flush()
        return out

    svc = ClusterService(store=IndexStore(capacity=args.capacity,
                                          manager=manager),
                         slots=args.slots,
                         stats_every=args.stats_every)
    reqs = _request_stream(datasets, args.eps, args.minpts, args.requests,
                           args.sweep_k, rng,
                           hierarchy_frac=args.hierarchy_frac)

    interrupted = False
    t0 = time.perf_counter()
    try:
        svc.run(reqs)
    except KeyboardInterrupt:
        interrupted = True
        print("signal received — stopping after the current window; "
              "flushing stats ...")
    dt = time.perf_counter() - t0

    st = svc.stats()
    qps = st["settings_answered"] / dt if dt > 0 else float("inf")
    print(f"served {st['requests_served']} requests "
          f"({st['settings_answered']} parameter settings) in {dt:.2f}s "
          f"-> {qps:.1f} settings/s")
    print(f"  planner batches: {st['batched_sweeps']} "
          f"(coalesced {st['coalesced_settings']} settings)")
    print(f"  store: {st['store']}")
    out = {"seconds": dt, "settings_per_s": qps,
           "interrupted": interrupted, **st}
    if args.stats_json:
        with open(args.stats_json, "w") as f:
            json.dump(out, f, indent=2, default=str)
        print(f"  stats snapshot -> {args.stats_json}")
    obs.flush()
    return out


if __name__ == "__main__":
    main()
