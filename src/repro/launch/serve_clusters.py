"""Clustering-service driver: batched parameter exploration as a server.

Example-scale stand-in for the production serving loop: synthesizes a few
datasets, then drains a mixed request stream (builds, single clusterings,
parameter sweeps, stats probes) through ``ClusterService`` — same-index
requests coalesce into shared batched sweeps, and the ``IndexStore``
keeps indexes warm across requests (spilling LRU victims to disk when
``--store-dir`` is set).

    PYTHONPATH=src python -m repro.launch.serve_clusters --smoke
    PYTHONPATH=src python -m repro.launch.serve_clusters \
        --n 20000 --requests 64 --sweep-k 8 --capacity 2 --datasets 3
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro import obs
from repro.data.synthetic import gaussian_mixture
from repro.service import (BuildRequest, ClusterRequest, ClusterService,
                           IndexStore, StatsRequest, SweepRequest)


def _request_stream(datasets, eps, minpts, n_requests, sweep_k, rng):
    """Mixed request stream: ~1/3 single clusterings, ~2/3 sweeps."""
    reqs = [BuildRequest(data=x, eps=eps, minpts=minpts) for x in datasets]
    for _ in range(n_requests):
        x = datasets[rng.integers(len(datasets))]
        if rng.random() < 0.33:
            if rng.random() < 0.5:
                setting = ("eps", float(eps * rng.uniform(0.2, 1.0)))
            else:
                setting = ("minpts", int(minpts * rng.integers(1, 9)))
            reqs.append(ClusterRequest(data=x, eps=eps, minpts=minpts,
                                       setting=setting))
        else:
            settings = []
            for _ in range(sweep_k):
                if rng.random() < 0.5:
                    settings.append(("eps",
                                     float(eps * rng.uniform(0.2, 1.0))))
                else:
                    settings.append(("minpts",
                                     int(minpts * rng.integers(1, 9))))
            reqs.append(SweepRequest(data=x, eps=eps, minpts=minpts,
                                     settings=settings))
    reqs.append(StatsRequest())
    return reqs


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--eps", type=float, default=1.0)
    ap.add_argument("--minpts", type=int, default=16)
    ap.add_argument("--datasets", type=int, default=2)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--sweep-k", type=int, default=6)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--store-dir", default=None,
                    help="spill evicted indexes here (default: drop them)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny datasets / few requests")
    ap.add_argument("--stats-json", default=None, metavar="PATH",
                    help="dump the final Telemetry.snapshot() (plus the "
                         "service counters) to PATH on exit; implies "
                         "tracing on")
    ap.add_argument("--stats-every", type=int, default=0, metavar="N",
                    help="log a service stats line every N served "
                         "requests (0 = off); implies tracing on")
    args = ap.parse_args(argv)
    if args.smoke:
        args.n, args.requests, args.datasets = 800, 8, 2
    if args.stats_json or args.stats_every:
        # observability requested: turn the tracer on (REPRO_TRACE may
        # already have enabled it, with a JSONL sink attached)
        obs.enable()

    rng = np.random.default_rng(args.seed)
    datasets = [gaussian_mixture(args.n, d=args.d, k=8, seed=args.seed + i)
                for i in range(args.datasets)]
    manager = None
    if args.store_dir:
        from repro.checkpoint.manager import CheckpointManager
        manager = CheckpointManager(args.store_dir)
    svc = ClusterService(store=IndexStore(capacity=args.capacity,
                                          manager=manager),
                         slots=args.slots,
                         stats_every=args.stats_every)
    reqs = _request_stream(datasets, args.eps, args.minpts, args.requests,
                           args.sweep_k, rng)

    t0 = time.perf_counter()
    svc.run(reqs)
    dt = time.perf_counter() - t0

    st = svc.stats()
    qps = st["settings_answered"] / dt if dt > 0 else float("inf")
    print(f"served {st['requests_served']} requests "
          f"({st['settings_answered']} parameter settings) in {dt:.2f}s "
          f"-> {qps:.1f} settings/s")
    print(f"  planner batches: {st['batched_sweeps']} "
          f"(coalesced {st['coalesced_settings']} settings)")
    print(f"  store: {st['store']}")
    if args.stats_json:
        with open(args.stats_json, "w") as f:
            json.dump({"seconds": dt, "settings_per_s": qps, **st},
                      f, indent=2, default=str)
        print(f"  stats snapshot -> {args.stats_json}")
    obs.flush()
    return {"seconds": dt, "settings_per_s": qps, **st}


if __name__ == "__main__":
    main()
