"""Training driver: data → train_step → checkpoints, fault-tolerant.

This is the end-to-end launcher the examples use (``--arch <id>`` selects
any registry config, usually a ``--smoke`` reduction on CPU):

  * auto-resume: picks up the latest intact checkpoint in --ckpt-dir;
    the data stream needs nothing but the step counter (repro.data.tokens)
  * async atomic checkpointing every --ckpt-every steps
  * --preempt-at N simulates a hard kill mid-run (the fault-tolerance
    integration test restarts the same command and checks bit-exact
    continuation)
  * elastic: restore works on a different device count (checkpoint/elastic)

At fleet scale the same loop runs SPMD under jax.distributed with the
production mesh; here meshes come from make_host_mesh.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import RunConfig, ShapeConfig, get_arch
from repro.data.tokens import TokenStream
from repro.train.optimizer import cosine_schedule, wsd_schedule
from repro.train.step import init_state, make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--preempt-at", type=int, default=None,
                    help="simulate preemption: hard-exit after this step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", args.seq_len, args.batch, "train")
    rc = RunConfig(model=cfg, shape=shape, remat=False, dtype="float32",
                   full_attn_max_seq=max(256, args.seq_len))

    if args.schedule == "wsd":        # minicpm's schedule
        lr_fn = wsd_schedule(args.lr, warmup=max(args.steps // 10, 1),
                             stable=args.steps // 2, decay=args.steps // 3)
    else:
        lr_fn = cosine_schedule(args.lr, warmup=max(args.steps // 10, 1),
                                total=args.steps)

    step_fn = jax.jit(make_train_step(cfg, rc, mesh=None, lr_fn=lr_fn,
                                      n_micro=args.n_micro))
    state = init_state(jax.random.PRNGKey(args.seed), cfg)

    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        latest = mgr.latest_step()
        if latest is not None:
            state = mgr.restore(latest, state)
            start_step = latest
            print(f"[resume] restored step {latest} from {args.ckpt_dir}")

    stream = TokenStream(cfg, args.seq_len, args.batch, seed=args.seed)
    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state, async_=True)
        if args.preempt_at is not None and step + 1 >= args.preempt_at:
            print(f"[preempt] simulating hard kill at step {step + 1}")
            if mgr:
                mgr.wait()
            os._exit(42)          # no cleanup — as brutal as a real preempt
    if mgr:
        mgr.save(args.steps, state, async_=False)
    dt = time.time() - t0
    print(f"done: {args.steps - start_step} steps in {dt:.1f}s; "
          f"loss {losses[0] if losses else float('nan'):.4f} → "
          f"{losses[-1] if losses else float('nan'):.4f}")
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "steps": args.steps - start_step}


if __name__ == "__main__":
    main()
