"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every cell.

``build_lowerable(cfg, rc, mesh)`` returns (fn, args, in_shardings) such
that ``jax.jit(fn, in_shardings=...).lower(*args).compile()`` is exactly
the production step for that (architecture × shape × mesh) cell:

  train_*    → train_step (fwd + bwd + AdamW, microbatched)
  prefill_*  → forward (full-sequence logits)
  decode_*   → decode_step (one token against the sharded cache)

No array is ever allocated: everything is ShapeDtypeStruct.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models.transformer import (cache_shapes, cache_specs, decode_step,
                                      forward, param_shapes)
from repro.sharding import check_divisible, dp_axes, param_shardings
from repro.train.optimizer import AdamWState
from repro.train.step import TrainState, make_train_step


def dp_size(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes[a] for a in dp_axes(mesh)]))


def _maybe_dp(batch: int, mesh: Mesh):
    """DP axes for a batch dim, or None when the batch doesn't divide
    (e.g. long_500k's global_batch=1 — the DP axes sit idle)."""
    return dp_axes(mesh) if batch % dp_size(mesh) == 0 else None


ACT_BUDGET_BYTES = 2e9     # saved-activation budget per device (remat'd)


def auto_n_micro(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> int:
    """Gradient-accumulation factor: keep saved layer inputs under budget."""
    dp = dp_size(mesh)
    b_local = max(1, shape.global_batch // dp)
    per_sample = cfg.n_layers * shape.seq_len * cfg.d_model * 2  # bf16
    cap = max(1, int(ACT_BUDGET_BYTES // max(per_sample, 1)))
    micro_local = 1
    for d in range(1, b_local + 1):
        if b_local % d == 0 and d <= cap:
            micro_local = d
    return b_local // micro_local


OPT_BYTES_BUDGET = 3e9        # fp32 params+m+v per device above this →
#                               bf16 Adam moments (qwen2-72b, llama4)
ACT_CHAIN_BUDGET = 2e9        # saved-activation chain above this → √-remat


def _opt_dtype(cfg: ModelConfig, mesh: Mesh):
    """bf16 Adam moments when fp32 state cannot fit the pod comfortably."""
    per_dev = cfg.param_count() * 12 / mesh.devices.size
    return jnp.bfloat16 if per_dev > OPT_BYTES_BUDGET else jnp.float32


def auto_remat_blocks(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      n_micro: int) -> int:
    """√-remat block size when the saved layer-input chain is too long."""
    dp = dp_size(mesh)
    micro_local = max(1, shape.global_batch // dp // n_micro)
    G = cfg.n_layers // cfg.scan_group
    chain = G * micro_local * shape.seq_len * cfg.d_model * 2     # bf16
    if chain <= ACT_CHAIN_BUDGET:
        return 0
    target = max(2, int(G ** 0.5))
    for k in range(target, G + 1):          # smallest divisor ≥ √G
        if G % k == 0:
            return k
    return 0


def auto_fsdp_over_pod(cfg: ModelConfig, mesh: Mesh) -> bool:
    """Span FSDP across pods when even bf16-moment state can't fit one."""
    if "pod" not in mesh.axis_names:
        return False
    pod_devices = mesh.devices.size // mesh.devices.shape[0]
    return cfg.param_count() * 8 / pod_devices > 10e9


def _state_sds(cfg: ModelConfig, mesh: Mesh) -> TrainState:
    ps = param_shapes(cfg, jnp.float32)
    od = _opt_dtype(cfg, mesh)
    zeros = {k: jax.ShapeDtypeStruct(v.shape, od) for k, v in ps.items()}
    return TrainState(
        params=ps,
        opt=AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                       m=dict(zeros), v=dict(zeros)))


def _state_shardings(cfg: ModelConfig, mesh: Mesh,
                     fsdp_over_pod: bool = False) -> TrainState:
    ps = param_shardings(param_shapes(cfg, jnp.float32), mesh,
                         cfg.expert_parallel, fsdp_over_pod)
    repl = NamedSharding(mesh, P())
    return TrainState(
        params=ps,
        opt=AdamWState(step=repl, m=dict(ps), v=dict(ps)))


def build_lowerable(cfg: ModelConfig, rc: RunConfig, mesh: Mesh
                    ) -> Tuple[Callable, tuple, Any]:
    """(fn, args_sds, in_shardings) for this cell's production step."""
    import dataclasses

    shape = rc.shape
    B, T = shape.global_batch, shape.seq_len
    dp = _maybe_dp(B, mesh)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        n_micro = rc.microbatch or auto_n_micro(cfg, shape, mesh)
        if rc.remat_blocks == 0:
            rc = dataclasses.replace(
                rc, remat_blocks=auto_remat_blocks(cfg, shape, mesh, n_micro))
        if not rc.fsdp_over_pod and auto_fsdp_over_pod(cfg, mesh):
            rc = dataclasses.replace(rc, fsdp_over_pod=True)
        step_fn = make_train_step(cfg, rc, mesh, n_micro=n_micro)
        state = _state_sds(cfg, mesh)
        state_sh = _state_shardings(cfg, mesh, rc.fsdp_over_pod)
        batch: Dict[str, Any] = {
            "labels": jax.ShapeDtypeStruct((B, T), jnp.int32)}
        batch_sh: Dict[str, Any] = {
            "labels": NamedSharding(mesh, P(dp, None))}
        if cfg.embed_inputs:
            batch["tokens"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
            batch_sh["tokens"] = NamedSharding(mesh, P(dp, None))
        else:
            batch["embeds"] = jax.ShapeDtypeStruct((B, T, cfg.d_model),
                                                   jnp.float32)
            batch_sh["embeds"] = NamedSharding(mesh, P(dp, None, None))
        return step_fn, (state, batch), (state_sh, batch_sh)

    if not rc.fsdp_over_pod and auto_fsdp_over_pod(cfg, mesh):
        rc = dataclasses.replace(rc, fsdp_over_pod=True)
    params = param_shapes(cfg, jnp.bfloat16)          # serving dtype
    params_sh = param_shardings(params, mesh, cfg.expert_parallel,
                                rc.fsdp_over_pod)

    if shape.kind == "prefill":
        # decoder prefill emits only last-token logits (sampling feeds on
        # them); encoders return the full frame-level output
        last_only = cfg.causal

        def prefill_fn(p, inputs):
            return forward(p, inputs, cfg, rc, mesh, last_only=last_only)
        if cfg.embed_inputs:
            inp = jax.ShapeDtypeStruct((B, T), jnp.int32)
            inp_sh = NamedSharding(mesh, P(dp, None))
        else:
            inp = jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.float32)
            inp_sh = NamedSharding(mesh, P(dp, None, None))
        return prefill_fn, (params, inp), (params_sh, inp_sh)

    # decode: one new token against a seq_len-deep cache
    cache = cache_shapes(cfg, B, T, jnp.bfloat16)
    cspecs = cache_specs(cfg, mesh)
    cache_sh = {}
    for k, sds in cache.items():
        spec = cspecs[k]
        if dp is None:     # batch can't shard: drop DP axes from the spec
            spec = P(*[None if a == dp_axes(mesh) else a for a in spec])
        spec = check_divisible(k, sds.shape, spec, mesh)
        cache_sh[k] = NamedSharding(mesh, spec)
    toks = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    toks_sh = NamedSharding(mesh, P(dp, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_fn(p, c, t, s):
        return decode_step(p, c, t, s, cfg, rc, mesh)

    return (decode_fn, (params, cache, toks, pos),
            (params_sh, cache_sh, toks_sh, repl))
