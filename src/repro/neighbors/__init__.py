from repro.neighbors.engine import NeighborEngine
from repro.neighbors.bitset import pack_sets

__all__ = ["NeighborEngine", "pack_sets"]
