"""The ε-neighborhood engine: device-tiled distance plane, vectorized CSR.

Density-based clustering's dominant cost — for DBSCAN, OPTICS-build,
FINEX-build and the residual verification inside ε*/MinPts*-queries alike —
is ε-neighborhood computation. This engine is the TPU adaptation of the
paper's "materialize all neighborhoods in a separate step in advance"
strategy (§6, Neighborhood Computations): distances are computed in
(row-batch × corpus) tiles on the accelerator (MXU matmul expansion for
Euclidean, VPU popcount for Jaccard over packed bitmaps) and only the
thresholded CSR neighbor lists and per-object statistics land on the host.

Every host-side step is bulk array work — tile-level 2-D ``np.nonzero``
for CSR assembly, one matmul per tile for weighted counts, and a single
segmented lexsort + cumulative-weight ``searchsorted`` over the whole CSR
for core distances. No per-object Python loops anywhere on the
materialization path (``repro.core.reference`` keeps the loop originals
for equivalence testing).

The host-facing product per object p:
  * count[p]  = |N_ε(p)|                      (the paper's  o.N)
  * csr lists = N_ε(p) with distances          (drives Algorithms 1–4)
  * kth(k)[p] = M(p) = k-th smallest distance  (the paper's core distance)

Duplicate handling (paper §6 "Data Deduplication") is supported through
``weights``: object p counts as weights[p] identical copies. Neighborhood
sizes then use weighted counts while only unique objects are materialized.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from functools import partial
from typing import Literal, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


Metric = Literal["euclidean", "jaccard"]


def dataset_fingerprint(data, metric: Metric = "euclidean",
                        weights: Optional[np.ndarray] = None) -> str:
    """Stable identity of a dataset: metric + shape + dtype + content hash.

    Computed over the same canonical representation ``NeighborEngine``
    stores (float32 vectors / uint32-packed bitmaps + int32 sizes), so the
    fingerprint of raw input data equals the fingerprint of an engine built
    from it. This is what keys the serving-side ``IndexStore`` and what
    ``FinexIndex.load(data=...)`` checks before attaching an engine.
    Non-unit duplicate ``weights`` are part of the identity (they change
    every neighborhood count); unit weights hash the same as no weights.
    """
    if weights is not None:
        w = np.ascontiguousarray(np.asarray(weights, dtype=np.int64))
        if np.all(w == 1):
            weights = None
    if metric == "euclidean":
        x = np.ascontiguousarray(np.asarray(data, dtype=np.float32))
        h = hashlib.sha256(x.tobytes())
        shape = "x".join(map(str, x.shape))
        head = f"euclidean:{shape}:{x.dtype}"
    elif metric == "jaccard":
        bits, sizes = data
        b = np.ascontiguousarray(np.asarray(bits, dtype=np.uint32))
        s = np.ascontiguousarray(np.asarray(sizes, dtype=np.int32))
        h = hashlib.sha256(b.tobytes())
        h.update(s.tobytes())
        shape = "x".join(map(str, b.shape))
        head = f"jaccard:{shape}:{b.dtype}"
    else:
        raise ValueError(f"unknown metric {metric!r}")
    if weights is not None:
        h.update(b"weights")
        h.update(w.tobytes())
        head += ":w"
    return f"{head}:{h.hexdigest()[:16]}"


@dataclass
class CSRNeighborhoods:
    """Materialized ε-neighborhoods, one row per object (self included)."""
    indptr: np.ndarray    # (n+1,) int64
    indices: np.ndarray   # (nnz,) int32 neighbor object ids
    dists: np.ndarray     # (nnz,) float32 distances
    eps: float
    _row_ids: Optional[np.ndarray] = field(
        default=None, init=False, repr=False, compare=False)

    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[i], self.indptr[i + 1]
        return self.indices[s:e], self.dists[s:e]

    def row_ids(self) -> np.ndarray:
        """(nnz,) row id per stored pair — the segment expansion used by
        weighted counts, core distances and subgraph extraction. Cached:
        the CSR is immutable after materialization and the expansion is
        an O(nnz) allocation the query path would otherwise repeat."""
        if self._row_ids is None:
            self._row_ids = np.repeat(
                np.arange(self.indptr.shape[0] - 1, dtype=np.int64),
                np.diff(self.indptr))
        return self._row_ids

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])


class NeighborEngine:
    """Batched distance plane for one dataset + metric.

    Vector data: ``data`` is (n, d) float. Set data: ``data`` is the pair
    (bits (n, W) uint32, sizes (n,) int32) from ``bitset.pack_sets``.
    """

    def __init__(self, data, metric: Metric = "euclidean",
                 weights: Optional[np.ndarray] = None,
                 batch_rows: int = 1024, use_pallas: bool = False):
        self.metric: Metric = metric
        self.use_pallas = use_pallas
        if metric == "euclidean":
            self._x = jnp.asarray(np.asarray(data, dtype=np.float32))
            self.n = int(self._x.shape[0])
        elif metric == "jaccard":
            bits, sizes = data
            self._bits = jnp.asarray(np.asarray(bits, dtype=np.uint32))
            self._sizes = jnp.asarray(np.asarray(sizes, dtype=np.int32))
            self.n = int(self._bits.shape[0])
        else:
            raise ValueError(f"unknown metric {metric!r}")
        if weights is None:
            weights = np.ones(self.n, dtype=np.int64)
        self.weights = np.asarray(weights, dtype=np.int64)
        # unit weights (no duplicates) let counts come straight from row
        # lengths instead of weighted reductions over the CSR
        self.unit_weights = bool(np.all(self.weights == 1))
        self._w_dev = jnp.asarray(self.weights.astype(np.float32))
        self.batch_rows = batch_rows
        self.distance_rows_computed = 0  # instrumentation: #row-neighborhoods
        self._fingerprint: Optional[str] = None

    def fingerprint(self) -> str:
        """``dataset_fingerprint`` of this engine's dataset (cached)."""
        if self._fingerprint is None:
            if self.metric == "euclidean":
                self._fingerprint = dataset_fingerprint(
                    np.asarray(self._x), "euclidean", weights=self.weights)
            else:
                self._fingerprint = dataset_fingerprint(
                    (np.asarray(self._bits), np.asarray(self._sizes)),
                    "jaccard", weights=self.weights)
        return self._fingerprint

    # ---------------------------------------------------------- distances
    def _dist_block(self, rows: jax.Array) -> jax.Array:
        """(B,) row ids -> (B, n) float32 distances."""
        if self.metric == "euclidean":
            return ops.pairwise_euclidean(self._x[rows], self._x,
                                          use_pallas=self.use_pallas)
        return ops.jaccard_distance(self._bits[rows], self._sizes[rows],
                                    self._bits, self._sizes,
                                    use_pallas=self.use_pallas)

    def distances_from(self, rows: np.ndarray) -> np.ndarray:
        """Distances from the given row ids to the whole dataset."""
        rows = np.asarray(rows, dtype=np.int32)
        self.distance_rows_computed += len(rows)
        out = np.empty((len(rows), self.n), dtype=np.float32)
        for s in range(0, len(rows), self.batch_rows):
            chunk = jnp.asarray(rows[s:s + self.batch_rows])
            out[s:s + len(chunk)] = np.asarray(self._dist_block(chunk))
        return out

    @staticmethod
    def _bucket(idx: np.ndarray) -> np.ndarray:
        """Pad index arrays to the next power of two (repeat index 0) so
        jit'd distance calls reuse compiled shapes instead of recompiling
        for every (candidates × cores) sub-matrix size."""
        n = len(idx)
        target = 1 << max(0, (n - 1)).bit_length()
        if target == n:
            return idx
        return np.concatenate([idx, np.zeros(target - n, idx.dtype)])

    def pair_distances(self, rows: np.ndarray, cols: np.ndarray) -> np.ndarray:
        """(len(rows), len(cols)) distance sub-matrix (for ε*-verification)."""
        rows = np.asarray(rows, dtype=np.int32)
        cols = np.asarray(cols, dtype=np.int32)
        nr, nc = len(rows), len(cols)
        self.distance_rows_computed += nr
        rp = jnp.asarray(self._bucket(rows))
        cp = jnp.asarray(self._bucket(cols))
        if self.metric == "euclidean":
            d = ops.pairwise_euclidean(self._x[rp], self._x[cp],
                                       use_pallas=self.use_pallas)
        else:
            d = ops.jaccard_distance(self._bits[rp], self._sizes[rp],
                                     self._bits[cp], self._sizes[cp],
                                     use_pallas=self.use_pallas)
        return np.asarray(d)[:nr, :nc]

    # ------------------------------------------------------ neighborhoods
    def _tile_mask(self, rows: jax.Array, eps: jax.Array):
        """Tile sweep: distances + threshold mask, both device-resident.

        The threshold runs as an eager device op on the jit'd distance
        tile (not inside a fresh jit wrapper: re-lowering the distance
        math would change XLA fusion and perturb float bits vs. the
        kernel oracles), so the host only consumes the finished (B, n)
        boolean plane and distance tile — no per-row Python work.
        """
        d = self._dist_block(rows)
        return d, d <= eps

    def materialize(self, eps: float) -> Tuple[np.ndarray, CSRNeighborhoods]:
        """Weighted counts |N_ε| and CSR neighbor lists for every object.

        Fully vectorized: each (batch_rows × n) tile is thresholded on
        device; the host turns the whole 2-D mask into CSR entries with one
        ``np.nonzero`` (row-major, so per-row neighbor lists stay sorted by
        object id) and accumulates weighted counts with one matmul per tile.
        """
        counts = np.zeros(self.n, dtype=np.int64)
        ind_chunks, dist_chunks = [], []
        lens = np.zeros(self.n, dtype=np.int64)
        eps_dev = jnp.float32(eps)
        for s in range(0, self.n, self.batch_rows):
            rows = np.arange(s, min(s + self.batch_rows, self.n),
                             dtype=np.int32)
            self.distance_rows_computed += len(rows)
            d, mask = self._tile_mask(jnp.asarray(rows), eps_dev)
            d, mask = np.asarray(d), np.asarray(mask)
            # one flat nonzero per tile; row-major order keeps per-row
            # neighbor lists sorted by object id. Row lengths fall out of
            # a searchsorted against the flat row boundaries — cheaper
            # than 2-D nonzero + bincount by ~2×
            flat = np.flatnonzero(mask)
            cc = (flat % self.n).astype(np.int32)
            ind_chunks.append(cc)
            dist_chunks.append(d.ravel()[flat])
            lens[rows] = np.diff(np.searchsorted(
                flat, np.arange(len(rows) + 1, dtype=np.int64) * self.n))
            if self.unit_weights:
                counts[rows] = lens[rows]
            else:
                # weighted counts over the surviving pairs only: O(nnz),
                # exact in float64 (weight sums < 2^53), vs. the O(B·n)
                # non-BLAS bool@int64 matmul this replaces
                rr = flat // self.n
                counts[rows] = np.bincount(
                    rr, weights=self.weights[cc].astype(np.float64),
                    minlength=len(rows)).astype(np.int64)
        indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(lens, out=indptr[1:])
        csr = CSRNeighborhoods(indptr=indptr,
                               indices=np.concatenate(ind_chunks),
                               dists=np.concatenate(dist_chunks),
                               eps=float(eps))
        return counts, csr

    def materialize_stats(self, eps: float, minpts: int
                          ) -> Tuple[np.ndarray, CSRNeighborhoods, np.ndarray]:
        """One-pass (counts, CSR, core distances) — the build-side product.

        The k-th-distance selection rides on the same tile sweep's CSR via
        the segmented sort in :meth:`core_distances`; at fleet scale the
        device-resident ``kernels.kthdist`` bisection replaces it.
        """
        counts, csr = self.materialize(eps)
        C = self.core_distances(csr, counts, self.weights, minpts)
        return counts, csr, C

    def counts_only(self, eps: float) -> np.ndarray:
        """Weighted |N_ε(p)| for all p without materializing lists."""
        counts = np.zeros(self.n, dtype=np.int64)
        eps_dev = jnp.float32(eps)
        for s in range(0, self.n, self.batch_rows):
            rows = jnp.arange(s, min(s + self.batch_rows, self.n), dtype=jnp.int32)
            self.distance_rows_computed += int(rows.shape[0])
            d = self._dist_block(rows)
            c = (jnp.where(d <= eps_dev, self._w_dev[None, :], 0.0)
                 .sum(-1).astype(jnp.int64))
            counts[int(rows[0]):int(rows[-1]) + 1] = np.asarray(c)
        return counts

    @staticmethod
    def core_distances(csr: CSRNeighborhoods, counts: np.ndarray,
                       weights: np.ndarray, minpts: int) -> np.ndarray:
        """M(p) for cores, inf otherwise (Definitions 3.6/3.7).

        With duplicate weights, M(p) is the smallest distance δ in p's sorted
        neighbor list at which the cumulative weight reaches MinPts.

        One segmented pass over the whole CSR, no per-object loop: a stable
        lexsort orders every row's neighbors by distance in place, a global
        cumulative weight turns the per-row "cumulative weight ≥ MinPts"
        threshold into ``searchsorted(cw, base + MinPts)`` (the global
        cumsum is strictly increasing, so the hit lands inside the row's
        own segment whenever the row is a core).
        """
        n = counts.shape[0]
        C = np.full(n, np.inf, dtype=np.float32)
        core = counts >= minpts
        if not core.any():
            return C
        seg = csr.row_ids()
        # single stable radix sort on a packed (row, dist) int64 key: the
        # distances are non-negative IEEE floats, whose bit patterns order
        # exactly like their values — ~3× cheaper than a 2-key lexsort
        key = (seg << np.int64(32)) | csr.dists.view(np.uint32)
        if np.all(weights == 1):
            # unit weights: the cumulative weight is just the within-row
            # rank, so the MinPts-th entry sits at a fixed offset — and no
            # permutation is needed, only sorted values (low 32 key bits)
            skey = np.sort(key)
            kth = skey[csr.indptr[:-1][core] + minpts - 1]
            C[core] = (kth & np.int64(0xFFFFFFFF)) \
                .astype(np.uint32).view(np.float32)
            return C
        order = np.argsort(key, kind="stable")    # == lexsort((dists, seg))
        sorted_d = csr.dists[order]
        cw = np.cumsum(weights[csr.indices[order]])
        base = np.where(csr.indptr[:-1] > 0, cw[csr.indptr[:-1] - 1], 0)
        hit = np.searchsorted(cw, base[core] + minpts, side="left")
        C[core] = sorted_d[hit]
        return C
