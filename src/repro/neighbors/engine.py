"""The ε-neighborhood engine: device-tiled distance plane, vectorized CSR.

Density-based clustering's dominant cost — for DBSCAN, OPTICS-build,
FINEX-build and the residual verification inside ε*/MinPts*-queries alike —
is ε-neighborhood computation. This engine is the TPU adaptation of the
paper's "materialize all neighborhoods in a separate step in advance"
strategy (§6, Neighborhood Computations): distances are computed in
(row-batch × corpus) tiles on the accelerator and the sweep is
*ε-compacted on device* — only thresholded survivors ever reach the host.

Everything metric-specific lives behind the ``repro.metrics`` protocol:
the engine holds one opaque row-aligned dataset state (float vectors for
euclidean/cosine/cityblock, packed bitmaps + sizes for Jaccard, whatever
a user-registered metric canonicalizes to) and dispatches every kernel —
dense tile, fused mask sweep, fused count, fused slot emit — through the
``Metric`` instance. The engine itself never branches on metric names.

Two compacted emit paths share the same byte-level contract:
  * slot emit (``emit="slots"`` / ``use_pallas=True``) — the metric's
    fused ``eps_compact`` kernel packs each row's surviving (col, dist)
    pairs into capacity-capped slots inside the kernel, so host traffic
    is O(rows·cap) ≈ O(nnz); rows that overflow the capacity are
    re-extracted from a dense tile (byte-identical fallback).
  * mask emit (the CPU/XLA default) — the metric's fused ``mask_tile``
    emits only the bool hit plane (euclidean thresholds *squared*
    distances exactly via ``metrics.sq_threshold``, so no m·n square
    roots are evaluated); the host flat-nonzeros the plane, and
    ``gather_pairs`` pulls the O(nnz) surviving distances from the
    still-resident device payload.  Tile k+1's device work overlaps
    tile k's host extraction (two-deep pipeline).

Every host-side step is bulk array work — ``np.flatnonzero`` over the hit
plane, a ``searchsorted`` per tile for row lengths, one weighted
``bincount`` over the finished CSR — and the CSR arrays are filled
preallocated, chunk by chunk (no double-concatenate peak).  No per-object
Python loops anywhere on the materialization path
(``repro.core.reference`` keeps the loop originals for equivalence
testing).

Bit-pinning contract: emitted distances are gathered from the *same*
device buffers their hit plane was computed from, and each metric's
threshold transform is exact by construction, so the remaining cross-jit
assumption is only that the distance *formula* compiles to the same
per-pair float ops in each wrapper — which
``tests/test_vectorized_equivalence.py`` pins byte-for-byte against the
dense ``reference_materialize`` on every emit path and metric.

The host-facing product per object p:
  * count[p]  = |N_ε(p)|                      (the paper's  o.N)
  * csr lists = N_ε(p) with distances          (drives Algorithms 1–4)
  * kth(k)[p] = M(p) = k-th smallest distance  (the paper's core distance)

Duplicate handling (paper §6 "Data Deduplication") is supported through
``weights``: object p counts as weights[p] identical copies. Neighborhood
sizes then use weighted counts while only unique objects are materialized.
"""
from __future__ import annotations

import hashlib
import time as _time
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels import ops
from repro.metrics import MetricLike, get_metric
# re-exported for backwards compatibility: these lived here before the
# metric registry (PR 4) pulled everything metric-specific into
# ``repro.metrics``
from repro.metrics import Metric, sq_threshold  # noqa: F401


def fill_slot_rows(indices: np.ndarray, dists: np.ndarray, base: np.ndarray,
                   lens: np.ndarray, cols: np.ndarray, dvals: np.ndarray
                   ) -> None:
    """Scatter per-row slot data into preallocated CSR arrays.

    ``cols``/``dvals`` are (..., cap) slot rows, ``lens`` the matching
    per-row lengths and ``base`` each row's destination offset; every row
    claims its first ``min(len, cap)`` slots.  Shared by the single-device
    slot sweep and the sharded CSR-emit assembly so the two compaction→CSR
    layouts cannot drift apart.
    """
    cap = cols.shape[-1]
    slot = np.arange(cap, dtype=np.int64)
    valid = slot < np.minimum(lens, cap)[..., None]
    dst = (base[..., None] + slot)[valid]
    indices[dst] = cols[valid]
    dists[dst] = dvals[valid]


def screen_thresholds(metric: Metric, eps: float, diam: float, m2: float
                      ) -> Tuple[float, np.float32]:
    """(s_t, s2t): screen-space distance threshold + its float32 squared
    pair-test twin, for a screen of diameter bound ``diam`` and max
    squared embedding norm ``m2``.

    ``s_t = sup{s : metric.lower_bound(s) <= eps}`` by host float64
    bisection — any pair with true distance <= eps has screen distance
    <= s_t (lower_bound is monotone), so pruning above s_t is provably
    safe.  Both thresholds are slack-inflated past their computation's
    float error (bucket tests run in float64, the pair test in float32
    on device), so rounding can cost a false *candidate*, never a false
    *prune*.  Shared by the single-device engine and the sharded emit.
    """
    def lb(s):
        return float(np.asarray(metric.lower_bound(
            np.asarray(s, dtype=np.float64))))
    eps = float(eps)
    hi = float(diam)
    if lb(hi) <= eps:
        s_t = hi
    elif lb(0.0) > eps:
        s_t = 0.0
    else:
        lo_s, hi_s = 0.0, hi
        for _ in range(80):
            mid = 0.5 * (lo_s + hi_s)
            if lb(mid) <= eps:
                lo_s = mid
            else:
                hi_s = mid
        s_t = hi_s            # upper end: >= the true sup by construction
    s2t = np.float32(s_t * s_t + 1e-4 * (m2 + 1.0))
    return s_t + 1e-9 * (1.0 + hi), s2t


def _pow2_pad(size: int, floor: int = 1 << 14) -> int:
    """Pad gather sizes to powers of two so the surviving-pair gather jit
    compiles a handful of shapes per dataset instead of one per tile."""
    p = floor
    while p < size:
        p <<= 1
    return p


def dataset_fingerprint(data, metric: MetricLike = "euclidean",
                        weights: Optional[np.ndarray] = None) -> str:
    """Stable identity of a dataset: metric + shape + dtype + content hash.

    Computed over the metric's *canonical* representation (the same
    arrays ``NeighborEngine`` uploads — float32 vectors, uint32-packed
    bitmaps + int32 sizes, …), so the fingerprint of raw input data
    equals the fingerprint of an engine built from it. This is what keys
    the serving-side ``IndexStore`` and what ``FinexIndex.load(data=...)``
    checks before attaching an engine.  The metric contributes its
    registry name (and params, when any) to the head, so the same bytes
    under different distance semantics never collide.
    Non-unit duplicate ``weights`` are part of the identity (they change
    every neighborhood count); unit weights hash the same as no weights.
    """
    m = get_metric(metric)
    if weights is not None:
        w = np.ascontiguousarray(np.asarray(weights, dtype=np.int64))
        if np.all(w == 1):
            weights = None
    canon = m.canonicalize(data)
    h = hashlib.sha256()
    m.fingerprint_update(h, canon)
    head = m.fingerprint_head(canon)
    if weights is not None:
        h.update(b"weights")
        h.update(w.tobytes())
        head += ":w"
    return f"{head}:{h.hexdigest()[:16]}"


@dataclass
class CSRNeighborhoods:
    """Materialized ε-neighborhoods, one row per object (self included)."""
    indptr: np.ndarray    # (n+1,) int64
    indices: np.ndarray   # (nnz,) int32 neighbor object ids
    dists: np.ndarray     # (nnz,) float32 distances
    eps: float
    _row_ids: Optional[np.ndarray] = field(
        default=None, init=False, repr=False, compare=False)

    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[i], self.indptr[i + 1]
        return self.indices[s:e], self.dists[s:e]

    def row_bounds(self) -> Tuple[np.ndarray, np.ndarray]:
        """(starts, ends) of each row's segment in ``indices``/``dists``.

        The row-addressed access contract shared with
        ``repro.core.delta.SlackCSR``: consumers that only ever slice
        ``indices[starts[i]:ends[i]]`` (the ordering sweep, the subset
        gathers) work unchanged on slack-padded layouts where rows are
        not contiguous. For a packed CSR this is just the indptr split.
        """
        return self.indptr[:-1], self.indptr[1:]

    def row_ids(self) -> np.ndarray:
        """(nnz,) row id per stored pair — the segment expansion used by
        weighted counts, core distances and subgraph extraction. Cached:
        the CSR is immutable after materialization and the expansion is
        an O(nnz) allocation the query path would otherwise repeat."""
        if self._row_ids is None:
            self._row_ids = np.repeat(
                np.arange(self.indptr.shape[0] - 1, dtype=np.int64),
                np.diff(self.indptr))
        return self._row_ids

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])


class NeighborEngine:
    """Batched distance plane for one dataset + metric.

    ``metric`` is a registry name or a ``repro.metrics.Metric`` instance;
    ``data`` is whatever that metric canonicalizes — (n, d) float arrays
    for the vector metrics, the (bits, sizes) pair from
    ``bitset.pack_sets`` for Jaccard, etc.
    """

    def __init__(self, data, metric: MetricLike = "euclidean",
                 weights: Optional[np.ndarray] = None,
                 batch_rows: int = 256, use_pallas: bool = False,
                 emit: str = "auto", slot_cap: int = 256,
                 prune: str = "auto", screen_k: int = 8,
                 screen_bucket: int = 8):
        if emit not in ("auto", "slots", "mask"):
            raise ValueError(f"emit must be 'auto', 'slots' or 'mask', "
                             f"got {emit!r}")
        if prune not in ("auto", "on", "off"):
            raise ValueError(f"prune must be 'auto', 'on' or 'off', "
                             f"got {prune!r}")
        self.metric: Metric = get_metric(metric)
        self.use_pallas = use_pallas
        # ε-compacted emit strategy: "slots" = fused per-row capacity
        # slots (the Pallas kernels on TPU; their jnp oracle otherwise),
        # "mask" = bool-plane + surviving-pair gather (the fast XLA/CPU
        # path), "auto" = slots when the Pallas kernels are in play
        self.emit = emit
        # slot capacity, snapped to a power of two ≥ 128 (the Pallas emit
        # kernels require a multiple of their chunk size) and adapted
        # upward when rows overflow
        self._slot_cap = 1 << max(7, (int(slot_cap) - 1).bit_length())
        # instrumentation for benchmarks: what did the last materialize
        # sweep actually move host<->device, and which path did it take.
        # ``last_materialize`` tracks the most recent *full* sweep (and
        # stays the back-compat name); ``last_full_materialize`` is its
        # explicit alias and ``last_strip`` the most recent incremental
        # strip sweep — kept in separate fields so a post-insert
        # ``stats()["pruning"]`` still reflects the last full sweep
        self.last_materialize: dict = {}
        self.last_full_materialize: dict = {}
        self.last_strip: dict = {}
        self._state = self.metric.device_state(self.metric.canonicalize(data))
        self.n = int(self._state[0].shape[0])
        if weights is None:
            weights = np.ones(self.n, dtype=np.int64)
        self.weights = np.asarray(weights, dtype=np.int64)
        if self.weights.size and self.weights.min() < 1:
            # weights are duplicate multiplicities (paper §6): a count
            # below 1 has no meaning and would silently skew every
            # neighborhood count and core distance
            raise ValueError("duplicate weights must be >= 1")
        # unit weights (no duplicates) let counts come straight from row
        # lengths instead of weighted reductions over the CSR
        self.unit_weights = bool(np.all(self.weights == 1))
        self._w_dev = jnp.asarray(self.weights.astype(np.float32))
        # 256-row sweep tiles: the (B, n) cross-product tile stays
        # cache-sized on CPU hosts and the two-deep pipeline gets a finer
        # overlap grain (measurably faster than 1024 at n=20k); the tile
        # extent never affects the per-pair float bits
        self.batch_rows = batch_rows
        self.distance_rows_computed = 0  # instrumentation: #row-neighborhoods
        self._fingerprint: Optional[str] = None
        # projection-prune screen: "on" forces it whenever the metric
        # declares a bound (``Metric.project``), "off" disables it, "auto"
        # engages it above ~2k rows (below that the unpruned sweep is a
        # couple of dispatches and the screen build dominates).  The built
        # structure is cached per dataset state; False memoizes "metric
        # has no bound" so project() is probed once.
        self.prune = prune
        self.screen_k = int(screen_k)
        self.screen_bucket = max(8, int(screen_bucket))
        self._screen = None

    @property
    def metric_name(self) -> str:
        """The metric's registry name (the string serialized into npz
        archives and checkpoint manifests)."""
        return self.metric.name

    def fingerprint(self) -> str:
        """``dataset_fingerprint`` of this engine's dataset (cached)."""
        if self._fingerprint is None:
            # the canonical host arrays round-trip bit-exactly through the
            # device state, so hashing the pulled-back state equals
            # hashing the original input
            canon = tuple(np.asarray(a) for a in self._state)
            self._fingerprint = dataset_fingerprint(
                canon if len(canon) > 1 else canon[0], self.metric,
                weights=self.weights)
        return self._fingerprint

    # ---------------------------------------------------------- distances
    def _dist_block(self, rows: jax.Array) -> jax.Array:
        """(B,) row ids -> (B, n) float32 distances."""
        return self.metric.tile(self.metric.take(self._state, rows),
                                self._state, use_pallas=self.use_pallas)

    def distances_from(self, rows: np.ndarray) -> np.ndarray:
        """Distances from the given row ids to the whole dataset."""
        rows = np.asarray(rows, dtype=np.int32)
        self.distance_rows_computed += len(rows)
        out = np.empty((len(rows), self.n), dtype=np.float32)
        for s in range(0, len(rows), self.batch_rows):
            chunk = jnp.asarray(rows[s:s + self.batch_rows])
            out[s:s + len(chunk)] = np.asarray(self._dist_block(chunk))
        return out

    @staticmethod
    def _bucket(idx: np.ndarray) -> np.ndarray:
        """Pad index arrays to the next power of two (repeat index 0) so
        jit'd distance calls reuse compiled shapes instead of recompiling
        for every (candidates × cores) sub-matrix size."""
        n = len(idx)
        target = 1 << max(0, (n - 1)).bit_length()
        if target == n:
            return idx
        return np.concatenate([idx, np.zeros(target - n, idx.dtype)])

    def pair_distances(self, rows: np.ndarray, cols: np.ndarray) -> np.ndarray:
        """(len(rows), len(cols)) distance sub-matrix (for ε*-verification)."""
        rows = np.asarray(rows, dtype=np.int32)
        cols = np.asarray(cols, dtype=np.int32)
        nr, nc = len(rows), len(cols)
        self.distance_rows_computed += nr
        rp = jnp.asarray(self._bucket(rows))
        cp = jnp.asarray(self._bucket(cols))
        d = self.metric.tile(self.metric.take(self._state, rp),
                             self.metric.take(self._state, cp),
                             use_pallas=self.use_pallas)
        return np.asarray(d)[:nr, :nc]

    # ------------------------------------------------------ neighborhoods
    def _tile_bounds(self):
        """Host-side (start, end) row bounds of every sweep tile."""
        return [(s, min(s + self.batch_rows, self.n))
                for s in range(0, self.n, self.batch_rows)]

    def _rows(self, s: int, e: int):
        """Device state of the sweep tile's query rows [s, e)."""
        return self.metric.take(self._state, slice(s, e))

    # ------------------------------------------------------- prune screen
    def _screen_get(self):
        """The cached projection-prune screen, or None when pruning is off
        / the metric declares no bound (``project() is None``) / the
        dataset is too small for "auto"."""
        if self.prune == "off" or \
                (self.prune == "auto" and self.n < 2048):
            return None
        if self._screen is None:
            with obs.span("engine.screen_build", n=self.n,
                          metric=self.metric.name):
                self._screen = self._screen_build() or False
            if self._screen is not False and obs.enabled():
                obs.count("engine.screen_builds")
        return self._screen or None

    def _screen_build(self):
        """Build the screen structure: one host float64 projection of the
        dataset (``Metric.project``), kd-median buckets over it, and the
        ε-independent tile→bucket-center distance minima.

        Everything here is *bound side* only — the exact device kernels
        never see the screen, so a bug in the projection can at worst
        cost pruning, never exactness... except a violated lower-bound
        contract, which the property suite pins per metric.
        """
        canon = tuple(np.asarray(a) for a in self._state)
        E = self.metric.project(canon, self.screen_k)
        if E is None:
            return None
        E = np.asarray(E, dtype=np.float64)
        if E.ndim != 2 or E.shape[0] != self.n:
            raise ValueError(
                f"Metric.project must return (n, k') points; got shape "
                f"{E.shape} for n={self.n}")
        # centering is a translation (screen distances are invariant) but
        # shrinks the float32 magnitudes the device screen works with
        mean = E.mean(axis=0, keepdims=True) if self.n else np.zeros((1, 1))
        E = E - mean
        # kd-median buckets: contiguous segments of ``order``, split on
        # the widest screen dimension until <= screen_bucket points.
        # Small leaves matter: the ball bound prunes nothing once bucket
        # radii dwarf the screen threshold (high-dim kd cells grow fast)
        order = np.arange(self.n, dtype=np.int64)
        bounds = []
        stack = [(0, self.n)]
        while stack:
            lo, hi = stack.pop()
            if hi - lo <= self.screen_bucket:
                bounds.append((lo, hi))
                continue
            seg = order[lo:hi]
            pts = E[seg]
            width = pts.max(axis=0) - pts.min(axis=0)
            dim = int(np.argmax(width))
            if width[dim] <= 0.0:
                # duplicate rows: no dimension separates them — emit as
                # one (radius-0) bucket whatever its size
                bounds.append((lo, hi))
                continue
            vals = pts[:, dim]
            m = hi - lo
            srt = np.argsort(vals, kind="stable")
            mid = m // 2
            pivot = vals[srt[mid]]
            lo_cnt = int(np.count_nonzero(vals < pivot))
            if lo_cnt != mid:
                # the median value is tied (mass-at-a-value dims — e.g.
                # the mostly-zero coordinates of a sparse set embedding):
                # a positional split would scatter equal values across
                # both children, leaving them overlapping in space and
                # their radii as wide as the parent.  Snap to the nearest
                # tie boundary so the children are disjoint in value.
                hi_cnt = int(np.count_nonzero(vals <= pivot))
                cands = [c for c in (lo_cnt, hi_cnt) if 0 < c < m]
                mid = min(cands, key=lambda c: abs(c - m // 2))
            order[lo:hi] = seg[srt]
            stack.append((lo, lo + mid))
            stack.append((lo + mid, hi))
        bounds.sort()
        nb = len(bounds)
        starts = np.array([lo for lo, hi in bounds], dtype=np.int64)
        sizes = np.array([hi - lo for lo, hi in bounds], dtype=np.int64)
        Eo = E[order]
        centers = np.add.reduceat(Eo, starts, axis=0) / sizes[:, None]
        lab = np.repeat(np.arange(nb, dtype=np.int32), sizes)
        d2row = np.sum((Eo - centers[lab]) ** 2, axis=1)
        radii = np.sqrt(np.maximum.reduceat(d2row, starts))
        # bucket id per ORIGINAL row id: the per-tile sub-corpus is then
        # one O(n) ``flatnonzero(surviving[bid])`` — ascending global ids,
        # so screened CSR rows come out ascending like the full sweep
        bid = np.empty(self.n, dtype=np.int32)
        bid[order] = lab
        tb = self.batch_rows
        tiles = [(s, min(s + tb, self.n)) for s in range(0, self.n, tb)]
        m2 = float(np.max(np.sum(E * E, axis=1))) if self.n else 0.0
        E32 = np.ascontiguousarray(E, dtype=np.float32)
        return {
            "E32": E32,
            # the dataset re-uploaded in bucket order: sweep tiles then
            # take their query rows by *slice* instead of a per-tile
            # device gather (the corpus stays the original-order state)
            "state_perm": self.metric.take(
                self._state, jnp.asarray(order.astype(np.int32))),
            "E32o": np.ascontiguousarray(E32[order]),
            "order": order, "bid": bid, "tiles": tiles,
            # lazy device-resident caches: the ε-independent (ntiles, nb)
            # min² bound plane and the uploaded float32 bucket centers
            "min2": None, "centers_dev": None,
            "centers": centers, "radii": radii, "screen_eval_s": 0.0,
            "m2": m2, "diam": 2.0 * np.sqrt(m2) + 1.0, "mean": mean,
        }

    def _screen_centers_dev(self, scr):
        """The bucket centers as a device-resident float32 array (one
        upload per screen build, shared by every bound evaluation)."""
        if scr["centers_dev"] is None:
            scr["centers_dev"] = jnp.asarray(
                np.ascontiguousarray(scr["centers"], dtype=np.float32))
        return scr["centers_dev"]

    def _screen_min2(self, scr):
        """The ε-independent (ntiles, nb) tile→bucket-center *squared*
        distance minima, evaluated on device (``kernels.ops.bound_min2``)
        on first full-sweep use and cached device-resident on the screen.

        Lazy on purpose: insert strips bound their own query rows against
        the bucket centers directly and never read this plane, so a
        mutation-heavy workload (screen rebuilt after every
        ``append_rows``/``keep_rows``) skips its O(n·nb) cost entirely.
        Tile-by-tile so the (n, nb) float plane never materializes — on
        host OR device; only per-ε bool survival rows cross back.
        """
        if scr["min2"] is None:
            t0 = _time.perf_counter()
            centers = self._screen_centers_dev(scr)
            rows = [ops.bound_min2(jnp.asarray(scr["E32o"][s:e]), centers,
                                   use_pallas=self.use_pallas)
                    for s, e in scr["tiles"]]
            min2 = (jnp.stack(rows) if rows
                    else jnp.zeros((0, len(scr["radii"])), jnp.float32))
            min2.block_until_ready()
            scr["min2"] = min2
            scr["screen_eval_s"] += _time.perf_counter() - t0
        return scr["min2"]

    def _screen_thresholds(self, eps: float, scr):
        """(s_t, s2t) for this engine's screen — see
        :func:`screen_thresholds`."""
        return screen_thresholds(self.metric, eps, scr["diam"], scr["m2"])

    def _bucket_thresholds(self, s_t: float, scr) -> np.ndarray:
        """Per-bucket squared survival thresholds ``(s_t + r_b)²``,
        computed in host float64 and inflated by the same
        ``1e-4·(m2 + 1)`` slack as the pair-level screen test before the
        float32 cast — the margin dominates every float32 error in the
        device bound evaluation (embedding quantization, MXU expansion,
        the cast itself), so a device comparison against these can admit
        an extra bucket but never prune one holding a true neighbor."""
        r = np.asarray(scr["radii"], dtype=np.float64)
        return ((r + float(s_t)) ** 2
                + 1e-4 * (scr["m2"] + 1.0)).astype(np.float32)

    def _screen_surv(self, eps: float, scr) -> Tuple[np.ndarray, float,
                                                     np.float32]:
        """Per-ε bucket survival plane: compare the device-resident min²
        bounds against the slack-inflated bucket thresholds *on device*
        and pull back only the (ntiles, nb) bool plane.  Returns
        ``(surv, s_t, s2t)``."""
        s_t, s2t = self._screen_thresholds(eps, scr)
        min2 = self._screen_min2(scr)
        t0 = _time.perf_counter()
        surv = np.asarray(ops.bound_survive(
            min2, jnp.asarray(self._bucket_thresholds(s_t, scr))))
        scr["screen_eval_s"] += _time.perf_counter() - t0
        return surv, s_t, s2t

    @staticmethod
    def _screen_cols(scr, surv: np.ndarray) -> Tuple[np.ndarray, int]:
        """Surviving sub-corpus for a query tile from its bucket survival
        row (bucket b survives iff ``min² <= (s_t + r_b)² + slack`` — the
        triangle inequality in screen space, evaluated device-side by
        ``_screen_surv``).  Returns (ascending member ids, #surviving
        buckets) — membership is one O(n) mask lookup through the
        per-row bucket ids."""
        k = int(np.count_nonzero(surv))
        if k == 0:
            return np.zeros(0, np.int32), 0
        return np.flatnonzero(surv[scr["bid"]]).astype(np.int32), k

    def screen_admit(self, rows: np.ndarray, cols: np.ndarray,
                     eps: float) -> Optional[np.ndarray]:
        """Pair-level screen admission plane for an explicit
        (rows × cols) verification sub-matrix — the ε*-query hook.

        ``admit[i, j] == False`` certifies ``d(rows[i], cols[j]) > eps``
        (lower-bound contract), so a verifier may skip those pairs
        without computing their distance; ``None`` when no screen is
        active for this engine/metric.  Evaluated host-side in float64
        over the float32 screen embeddings against the same
        slack-inflated squared threshold as the device pair test
        (``screen_thresholds``), so embedding quantization and the
        expansion's rounding can only over-admit — never hide a true
        neighbor.
        """
        scr = self._screen_get()
        if scr is None:
            return None
        _, s2t = self._screen_thresholds(eps, scr)
        a = scr["E32"][np.asarray(rows, np.int64)].astype(np.float64)
        b = scr["E32"][np.asarray(cols, np.int64)].astype(np.float64)
        d2 = (np.sum(a * a, axis=1)[:, None]
              + np.sum(b * b, axis=1)[None, :] - 2.0 * (a @ b.T))
        return d2 <= float(s2t)

    @staticmethod
    def _pad_ids(idx: np.ndarray) -> np.ndarray:
        """Pad a gathered sub-corpus to an eighth-pow2 grid (repeat id 0):
        a handful of compiled shapes per dataset like ``_bucket``, but
        ≤ 12.5% padded columns where pure pow2 padding can waste ~2×."""
        n = len(idx)
        p = 1 << max(0, (n - 1)).bit_length()
        q = p >> 3
        if q:
            p = min(p, ((n + q - 1) // q) * q)
        target = max(p, 8)
        if target == n:
            return idx
        return np.concatenate([idx, np.zeros(target - n, idx.dtype)])

    def _perm_csr_to_original(self, order: np.ndarray, lens_perm: np.ndarray,
                              tiles: list, ind_chunks: list,
                              dist_chunks: list):
        """Scatter a bucket-permuted sweep's per-tile CSR chunks straight
        into original-row-order arrays — one O(nnz) pass, no intermediate
        permuted CSR, no gather.  Chunks are released as they are
        consumed.  Returns ``(lens, [indices], [dists])`` with the single
        chunk already final (``materialize`` adopts it without copying).
        """
        n = self.n
        lens = np.zeros(n, dtype=np.int64)
        lens[order] = lens_perm
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(lens, out=indptr[1:])
        nnz = int(indptr[-1])
        gdt = np.int32 if nnz < 2 ** 31 else np.int64
        starts_perm = indptr[:-1][order]   # destination start, permuted rows
        indices = np.empty(nnz, dtype=np.int32)
        dists = np.empty(nnz, dtype=np.float32)
        for i, (s, e) in enumerate(tiles):
            ci, cd = ind_chunks[i], dist_chunks[i]
            if ci.size:
                tl = lens_perm[s:e]
                local = np.zeros(e - s, dtype=np.int64)
                np.cumsum(tl[:-1], out=local[1:])   # chunk-local row starts
                dst = (np.repeat((starts_perm[s:e] - local).astype(gdt), tl)
                       + np.arange(ci.size, dtype=gdt))
                indices[dst] = ci
                dists[dst] = cd
            ind_chunks[i] = dist_chunks[i] = None
        return lens, [indices], [dists]

    def _sweep_screened(self, eps: float, scr, use_slots: bool):
        """Projection-pruned compacted sweep — the tentpole path.

        Rows are swept in bucket order (spatially coherent tiles in
        screen space), each tile's corpus shrinks to the union of
        surviving buckets' members, and the surviving (tile × bucket)
        work runs through the usual emit machinery — the pair-level
        screen additionally masks inside surviving tiles on the slot
        path.  Both prune levels only remove *provable* non-hits
        (lower-bound contract + float slack), so the emitted CSR is
        byte-identical to the unpruned sweep; the final row reorder is
        O(nnz).

        Tiles where the screen barely bites (surviving sub-corpus close
        to the whole dataset) escape to a plain full-corpus tile — same
        entries, none of the gather/padding overhead — so a hostile
        geometry costs at most the screen build, never a slower sweep.
        """
        n = self.n
        order = scr["order"]
        nb = len(scr["radii"])
        surv, s_t, s2t = self._screen_surv(eps, scr)
        eps_dev = jnp.float32(eps)
        thresh = self.metric.mask_threshold(eps)
        tiles = scr["tiles"]
        tiles_skipped = 0
        tile_subs = []
        for t in range(len(tiles)):
            sub, k = self._screen_cols(scr, surv[t])
            tiles_skipped += nb - k
            # hybrid escape: pruning under ~30% is not worth the gather
            tile_subs.append(None if sub.size > 0.7 * n else sub)
        lens_perm = np.zeros(n, dtype=np.int64)
        ind_chunks: list = []
        dist_chunks: list = []
        pending_gather: list = []
        host_bytes = 0
        fallback_rows = 0
        cand_pairs = 0
        tb = max((e - s) for s, e in tiles) if tiles else 1
        flat_dtype = (np.int32 if tb * _pow2_pad(n, 1) < 2 ** 31
                      else np.int64)

        def dispatch(i):
            s, e = tiles[i]
            sub = tile_subs[i]
            if sub is not None and sub.size == 0:
                return None
            q_state = self.metric.take(scr["state_perm"], slice(s, e))
            cap = self._slot_cap              # pinned at dispatch time: the
            # pipeline runs one tile ahead, so an overflow-triggered cap
            # growth must not change how the in-flight tile is decoded
            if sub is None:                   # full-corpus escape tile
                if use_slots:
                    out = self.metric.eps_compact(
                        q_state, self._state, eps_dev, cap,
                        use_pallas=self.use_pallas)
                else:
                    out = self.metric.mask_tile(q_state, self._state, thresh)
                return None, None, out, cap
            sub_p = self._pad_ids(sub)
            c_state = self.metric.take(self._state, jnp.asarray(sub_p))
            if use_slots:
                sq = jnp.asarray(scr["E32o"][s:e])
                sc = jnp.asarray(scr["E32"][sub_p])
                out = self.metric.screened_eps_compact(
                    q_state, c_state, sq, sc, eps_dev, s2t, cap,
                    num_valid=int(sub.size), use_pallas=self.use_pallas)
            else:
                out = self.metric.mask_tile(q_state, c_state, thresh)
            return sub, sub_p, out, cap

        pend = dispatch(0) if tiles else None
        for i, (s, e) in enumerate(tiles):
            got = pend
            if i + 1 < len(tiles):
                pend = dispatch(i + 1)        # overlaps this tile's host work
            self.distance_rows_computed += e - s
            if got is None:                   # every bucket pruned
                ind_chunks.append(np.zeros(0, np.int32))
                dist_chunks.append(np.zeros(0, np.float32))
                continue
            sub, sub_p, out, cap = got
            if use_slots:
                if sub is None:
                    tl, tc, td = out
                    cand_pairs += (e - s) * n
                else:
                    tl, tc, td, cd = out
                    cand_pairs += int(np.asarray(cd).sum())
                tl = np.asarray(tl).astype(np.int64)
                tc, td = np.asarray(tc), np.asarray(td)
                host_bytes += tl.nbytes + tc.nbytes + td.nbytes
                lens_perm[s:e] = tl
                over = tl > cap
                if over.any():
                    # dense fallback against the FULL corpus: overflow
                    # rows re-extract their whole (global) row, exactly
                    # like the unpruned slot sweep
                    fallback_rows += int(over.sum())
                    grows = order[s:e][over].astype(np.int32)
                    d_over = np.asarray(self._dist_block(
                        jnp.asarray(self._bucket(grows))))[:len(grows)]
                    host_bytes += d_over.nbytes
                    oflat = np.flatnonzero(d_over <= np.float32(eps))
                    ocols = (oflat % n).astype(np.int32)
                    odists = d_over.ravel()[oflat]
                    osplit = np.searchsorted(
                        oflat, np.arange(1, len(grows), dtype=np.int64) * n)
                    while self._slot_cap < int(tl.max()):
                        self._slot_cap <<= 1
                tile_nnz = int(tl.sum())
                t_indptr = np.zeros(e - s + 1, dtype=np.int64)
                np.cumsum(tl, out=t_indptr[1:])
                t_ind = np.empty(tile_nnz, dtype=np.int32)
                t_dist = np.empty(tile_nnz, dtype=np.float32)
                # slot cols are local sub-corpus ids (ascending members,
                # so the gather preserves CSR ordering) — or already
                # global on escape tiles
                fill_slot_rows(t_ind, t_dist, t_indptr[:-1],
                               np.where(over, 0, tl),
                               tc if sub is None else sub[tc], td)
                if over.any():
                    obase = np.repeat(t_indptr[:-1][over],
                                      np.diff(np.concatenate(
                                          ([0], osplit, [len(oflat)]))))
                    odst = obase + np.arange(len(oflat)) - np.repeat(
                        np.concatenate(([0], osplit)),
                        np.diff(np.concatenate(([0], osplit, [len(oflat)]))))
                    t_ind[odst] = ocols
                    t_dist[odst] = odists
                ind_chunks.append(t_ind)
                dist_chunks.append(t_dist)
            else:
                hit, payload = out
                if sub is None:
                    cand_pairs += (e - s) * n
                    tl, cols, dv, k, nbytes = self._mask_extract(
                        hit, payload, n, flat_dtype)
                    ind_chunks.append(cols)    # already global ids
                else:
                    cand_pairs += (e - s) * int(sub.size)
                    tl, cols, dv, k, nbytes = self._mask_extract(
                        hit, payload, int(sub_p.size), flat_dtype,
                        num_valid=int(sub.size))
                    ind_chunks.append(sub[cols])  # local → global ids
                lens_perm[s:e] = tl
                pending_gather.append((len(ind_chunks) - 1, k, dv))
                host_bytes += nbytes
        if not use_slots:
            dist_at = {i: np.asarray(dv)[:k] for i, k, dv in pending_gather}
            dist_chunks = [dist_at.get(i, np.zeros(0, np.float32))
                           for i in range(len(ind_chunks))]
        lens, ind_chunks, dist_chunks = self._perm_csr_to_original(
            order, lens_perm, tiles, ind_chunks, dist_chunks)
        self.last_materialize = {
            "mode": "slots" if use_slots else "mask",
            "metric": self.metric.name,
            "tiles": len(tiles),
            "cap": self._slot_cap if use_slots else None,
            "fallback_rows": fallback_rows, "host_bytes": host_bytes,
            "host_bytes_dense": self._dense_sweep_bytes(),
            "pruning": {
                "screened": True, "screen_k": int(scr["E32"].shape[1]),
                "buckets": nb, "tiles_total": nb * len(tiles),
                "tiles_skipped": int(tiles_skipped),
                "candidate_pairs": int(cand_pairs),
                "candidate_fraction": float(cand_pairs) / max(1, n * n),
                # the bucket-bound plane + per-ε survival compare run on
                # device (kernels.ops.bound_min2/bound_survive) — this is
                # their cumulative wall-clock since the screen was built
                "screen_eval_device": True,
                "screen_eval_s": float(scr["screen_eval_s"]),
            },
        }
        self.last_full_materialize = self.last_materialize
        return lens, ind_chunks, dist_chunks

    def materialize(self, eps: float) -> Tuple[np.ndarray, CSRNeighborhoods]:
        """Weighted counts |N_ε| and CSR neighbor lists for every object.

        The sweep is ε-compacted on device (see the module docstring):
        only thresholded survivors — O(nnz) pair payload plus the bool hit
        plane (mask path) or per-row capacity slots (slot path) — ever
        cross to the host, instead of the dense (batch_rows × n) float
        plane.  Per-row neighbor lists come out sorted by object id and
        the CSR arrays are filled into a single preallocated buffer pair;
        the result is byte-identical to the dense reference
        (``repro.core.reference.reference_materialize``).
        """
        with obs.span("engine.materialize", n=self.n, eps=float(eps),
                      metric=self.metric.name) as sp:
            counts, csr = self._materialize_impl(eps)
            if obs.enabled():
                rep = self.last_full_materialize
                nnz = int(csr.indptr[-1])
                sp.annot(mode=rep.get("mode"), nnz=nnz,
                         host_bytes=rep.get("host_bytes"))
                obs.count("engine.materializes")
                obs.count("engine.host_bytes",
                          int(rep.get("host_bytes") or 0))
                obs.observe("engine.csr_nnz", nnz)
                pruning = rep.get("pruning") or {}
                if pruning.get("screened"):
                    obs.count("engine.tiles_skipped",
                              int(pruning.get("tiles_skipped") or 0))
                    obs.observe(
                        "engine.candidate_fraction",
                        float(pruning.get("candidate_fraction") or 0.0))
        return counts, csr

    def _materialize_impl(self, eps: float
                          ) -> Tuple[np.ndarray, CSRNeighborhoods]:
        # untraced body of :meth:`materialize`
        use_slots = self.emit == "slots" or (self.emit == "auto"
                                             and self.use_pallas)
        scr = self._screen_get()
        if scr is not None:
            lens, ind_chunks, dist_chunks = self._sweep_screened(
                eps, scr, use_slots)
        elif use_slots:
            lens, ind_chunks, dist_chunks = self._sweep_slots(eps)
        else:
            lens, ind_chunks, dist_chunks = self._sweep_mask(eps)

        indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(lens, out=indptr[1:])
        nnz = int(indptr[-1])
        if len(ind_chunks) == 1 and ind_chunks[0].size == nnz:
            # the screened sweep scatters into final arrays itself
            indices, dists = ind_chunks[0], dist_chunks[0]
        else:
            # preallocate once, fill chunk by chunk (chunks are freed as
            # they are consumed — no concatenate holding chunks + result
            # at peak)
            indices = np.empty(nnz, dtype=np.int32)
            dists = np.empty(nnz, dtype=np.float32)
            off = 0
            for i in range(len(ind_chunks)):
                k = ind_chunks[i].size
                indices[off:off + k] = ind_chunks[i]
                dists[off:off + k] = dist_chunks[i]
                ind_chunks[i] = dist_chunks[i] = None
                off += k
        csr = CSRNeighborhoods(indptr=indptr, indices=indices, dists=dists,
                               eps=float(eps))
        if self.unit_weights:
            counts = lens.copy()
        else:
            # weighted counts over the surviving pairs only: O(nnz), exact
            # in float64 (weight sums < 2^53)
            counts = np.bincount(
                csr.row_ids(), weights=self.weights[indices].astype(np.float64),
                minlength=self.n).astype(np.int64)
        return counts, csr

    def _mask_extract(self, hit, payload, nc: int, flat_dtype,
                      num_valid: Optional[int] = None):
        """One tile of the mask path: bool hit plane -> (per-row lens,
        sorted cols, in-flight distance gather, #survivors, host bytes).

        Shared by the full sweep, the screened sweep and
        ``strip_materialize`` — all are required to produce byte-identical
        entries for the incremental insert contract, so the extraction
        must be one piece of code.  ``num_valid`` masks the pow2-padding
        columns of a gathered sub-corpus (screened sweeps only).
        """
        mask = np.asarray(hit)
        flat = np.flatnonzero(mask)
        cols = (flat % nc).astype(np.int32)
        if num_valid is not None and num_valid < nc:
            # padded columns repeat row 0 and can hit: drop them from the
            # flat ids (an O(hits) filter — the mask is never copied)
            keep = cols < num_valid
            flat = flat[keep]
            cols = cols[keep]
        lens = np.diff(np.searchsorted(
            flat, np.arange(mask.shape[0] + 1, dtype=np.int64) * nc))
        pad = _pow2_pad(flat.size)
        fpad = np.zeros(pad, dtype=flat_dtype)
        fpad[:flat.size] = flat
        dv = self.metric.gather_pairs(payload, jnp.asarray(fpad))
        return lens, cols, dv, flat.size, mask.nbytes + fpad.nbytes + pad * 4

    def _sweep_mask(self, eps: float):
        """Compacted sweep, mask path: fused threshold plane + O(nnz)
        surviving-pair gather, two-deep pipelined (tile k+1's device work
        overlaps tile k's host extraction)."""
        n = self.n
        lens = np.zeros(n, dtype=np.int64)
        ind_chunks: list = []
        pending_gather: list = []
        host_bytes = 0
        thresh = self.metric.mask_threshold(eps)

        def dispatch(se):
            s, e = se
            return self.metric.mask_tile(self._rows(s, e), self._state,
                                         thresh)

        tiles = self._tile_bounds()
        pend = dispatch(tiles[0]) if tiles else None
        flat_dtype = np.int32 if self.batch_rows * n < 2 ** 31 else np.int64
        for i, (s, e) in enumerate(tiles):
            hit, payload = pend
            if i + 1 < len(tiles):
                pend = dispatch(tiles[i + 1])      # overlaps the host work
            self.distance_rows_computed += e - s
            tl, cols, dv, k, nbytes = self._mask_extract(
                hit, payload, n, flat_dtype)
            lens[s:e] = tl
            ind_chunks.append(cols)
            pending_gather.append((k, dv))
            host_bytes += nbytes
        dist_chunks = [np.asarray(dv)[:k] for k, dv in pending_gather]
        self.last_materialize = {
            "mode": "mask", "metric": self.metric.name,
            "tiles": len(tiles), "cap": None,
            "fallback_rows": 0, "host_bytes": host_bytes,
            "host_bytes_dense": self._dense_sweep_bytes(),
            "pruning": {"screened": False},
        }
        self.last_full_materialize = self.last_materialize
        return lens, ind_chunks, dist_chunks

    def _sweep_slots(self, eps: float):
        """Compacted sweep, slot path: the fused emit kernels pack each
        row's survivors into ``cap`` slots on device; rows longer than the
        capacity fall back to a dense tile (byte-identical) and the
        capacity adapts upward for the rest of the sweep."""
        n = self.n
        lens = np.zeros(n, dtype=np.int64)
        ind_chunks: list = []
        dist_chunks: list = []
        host_bytes = 0
        fallback_rows = 0
        eps_dev = jnp.float32(eps)
        for s, e in self._tile_bounds():
            cap = self._slot_cap
            self.distance_rows_computed += e - s
            tl, tc, td = self.metric.eps_compact(
                self._rows(s, e), self._state, eps_dev, cap,
                use_pallas=self.use_pallas)
            tl = np.asarray(tl).astype(np.int64)
            tc, td = np.asarray(tc), np.asarray(td)
            host_bytes += tl.nbytes + tc.nbytes + td.nbytes
            lens[s:e] = tl
            over = tl > cap
            if over.any():
                # dense-tile fallback for the overflow rows only; bucket
                # the row list to pow2 so the jit'd distance call reuses
                # compiled shapes across tiles with different overflows
                fallback_rows += int(over.sum())
                rows = (s + np.flatnonzero(over)).astype(np.int32)
                d_over = np.asarray(self._dist_block(
                    jnp.asarray(self._bucket(rows))))[:len(rows)]
                host_bytes += d_over.nbytes
                oflat = np.flatnonzero(d_over <= np.float32(eps))
                ocols = (oflat % n).astype(np.int32)
                odists = d_over.ravel()[oflat]
                osplit = np.searchsorted(
                    oflat, np.arange(1, len(rows), dtype=np.int64) * n)
                # grow the capacity for the rest of the sweep
                while self._slot_cap < int(tl.max()):
                    self._slot_cap <<= 1
            # stitch slot rows and fallback rows back into row order
            # (overflow rows claim zero slots — their whole row comes
            # from the dense fallback)
            tile_nnz = int(tl.sum())
            t_indptr = np.zeros(e - s + 1, dtype=np.int64)
            np.cumsum(tl, out=t_indptr[1:])
            t_ind = np.empty(tile_nnz, dtype=np.int32)
            t_dist = np.empty(tile_nnz, dtype=np.float32)
            fill_slot_rows(t_ind, t_dist, t_indptr[:-1],
                           np.where(over, 0, tl), tc, td)
            if over.any():
                obase = np.repeat(t_indptr[:-1][over],
                                  np.diff(np.concatenate(
                                      ([0], osplit, [len(oflat)]))))
                odst = obase + np.arange(len(oflat)) - np.repeat(
                    np.concatenate(([0], osplit)),
                    np.diff(np.concatenate(([0], osplit, [len(oflat)]))))
                t_ind[odst] = ocols
                t_dist[odst] = odists
            ind_chunks.append(t_ind)
            dist_chunks.append(t_dist)
        self.last_materialize = {
            "mode": "slots", "metric": self.metric.name,
            "tiles": len(self._tile_bounds()),
            "cap": self._slot_cap, "fallback_rows": fallback_rows,
            "host_bytes": host_bytes,
            "host_bytes_dense": self._dense_sweep_bytes(),
            "pruning": {"screened": False},
        }
        self.last_full_materialize = self.last_materialize
        return lens, ind_chunks, dist_chunks

    def strip_materialize(self, rows_state, eps: float, corpus=None,
                          batch_rows: Optional[int] = None
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """ε-compacted neighborhoods of arbitrary query rows vs ``corpus``
        (default: the full dataset state) — the (m, n) strip sweep behind
        incremental index maintenance.

        Same bit contract as the mask path of :meth:`materialize`: the
        per-pair distance bits of every registered metric depend only on
        that pair's rows (never on the tile extent or the other rows in
        the tile), so strip entries are byte-identical to the matching
        entries of a full sweep over the mutated dataset.

        Returns ``(lens, cols, dists)``: per-query-row survivor counts
        plus the flat row-major (col, dist) pairs, cols ascending within
        each row (the CSR ordering).

        When the projection screen is active and the corpus is the
        engine's own dataset, the strip reuses it: the query rows are
        projected with the *same* deterministic projector
        (``Metric.project`` is seeded) and centered by the corpus screen
        mean, and each strip tile sweeps only its surviving buckets'
        members — entries stay byte-identical by the usual superset
        argument.
        """
        nq = int(rows_state[0].shape[0])
        with obs.span("engine.strip", rows=nq, eps=float(eps),
                      metric=self.metric.name) as sp:
            lens, cols, dists = self._strip_impl(
                rows_state, eps, corpus=corpus, batch_rows=batch_rows)
        # the strip records its own report — it must NOT clobber
        # ``last_materialize``/``last_full_materialize``, so post-insert
        # stats keep describing the last full sweep
        self.last_strip = {
            "mode": "strip", "metric": self.metric.name,
            "rows": nq, "eps": float(eps),
            "corpus": (self.n if corpus is None
                       else int(corpus[0].shape[0])),
            "nnz": int(cols.size),
            "screened": bool(corpus is None and self._screen),
        }
        sp.annot(nnz=int(cols.size))
        if obs.enabled():
            obs.count("engine.strips")
        return lens, cols, dists

    def _strip_impl(self, rows_state, eps: float, corpus=None,
                    batch_rows: Optional[int] = None
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        # untraced body of :meth:`strip_materialize`
        E_q = None
        if corpus is None:
            scr = self._screen_get()
            if scr is not None:
                E_q = self.metric.project(
                    tuple(np.asarray(a) for a in rows_state), self.screen_k)
                if E_q is not None:
                    E_q = np.ascontiguousarray(
                        np.asarray(E_q, dtype=np.float64) - scr["mean"],
                        dtype=np.float32)
                    s_t, _ = self._screen_thresholds(eps, scr)
                    # strips bound their own query rows against the bucket
                    # centers through the same device kernel as the full
                    # sweep; float32 quantization of the projected rows is
                    # covered by the bucket thresholds' slack
                    thr_dev = jnp.asarray(self._bucket_thresholds(s_t, scr))
                    centers_dev = self._screen_centers_dev(scr)
        corpus = self._state if corpus is None else corpus
        nc = int(corpus[0].shape[0])
        nq = int(rows_state[0].shape[0])
        if batch_rows is None:
            # strips are narrow: tile by pair budget (~2^24) instead of
            # the cache-sized sweep default, so a single-row insert is a
            # couple of dispatches rather than n/batch_rows of them
            batch_rows = max(self.batch_rows, (1 << 24) // max(nc, 1))
        thresh = self.metric.mask_threshold(eps)
        lens = np.zeros(nq, dtype=np.int64)
        cols_chunks: list = []
        dist_chunks: list = []
        flat_dtype = (np.int32 if batch_rows * _pow2_pad(nc, 1) < 2 ** 31
                      else np.int64)
        for s in range(0, nq, batch_rows):
            e = min(s + batch_rows, nq)
            self.distance_rows_computed += e - s
            sub = None
            if E_q is not None:
                t0 = _time.perf_counter()
                surv = np.asarray(ops.bound_survive(
                    ops.bound_min2(jnp.asarray(E_q[s:e]), centers_dev,
                                   use_pallas=self.use_pallas), thr_dev))
                scr["screen_eval_s"] += _time.perf_counter() - t0
                sub, _ = self._screen_cols(scr, surv)
                if sub.size == 0:
                    cols_chunks.append(np.zeros(0, np.int32))
                    dist_chunks.append(np.zeros(0, np.float32))
                    continue
                if sub.size > 0.7 * nc:       # hybrid full-corpus escape
                    sub = None
            if sub is not None:
                sub_p = self._pad_ids(sub)
                hit, payload = self.metric.mask_tile(
                    self.metric.take(rows_state, slice(s, e)),
                    self.metric.take(self._state, jnp.asarray(sub_p)),
                    thresh)
                tl, cols, dv, k, _ = self._mask_extract(
                    hit, payload, int(sub_p.size), flat_dtype,
                    num_valid=int(sub.size))
                cols = sub[cols]
            else:
                hit, payload = self.metric.mask_tile(
                    self.metric.take(rows_state, slice(s, e)), corpus, thresh)
                tl, cols, dv, k, _ = self._mask_extract(
                    hit, payload, nc, flat_dtype)
            lens[s:e] = tl
            cols_chunks.append(cols)
            dist_chunks.append(np.asarray(dv)[:k])
        cols = (np.concatenate(cols_chunks) if cols_chunks
                else np.zeros(0, dtype=np.int32))
        dists = (np.concatenate(dist_chunks) if dist_chunks
                 else np.zeros(0, dtype=np.float32))
        return lens, cols, dists

    # ------------------------------------------------------ row mutation
    def state_snapshot(self):
        """Cheap (reference-only) snapshot of the mutable dataset state —
        ``FinexIndex.insert``/``delete`` restore it if a delta fails
        midway, so the engine can never end up holding a different row
        set than the ordering it is attached to."""
        return (self._state, self.weights, self.n, self.unit_weights,
                self._w_dev, self._fingerprint, self._screen)

    def state_restore(self, snap) -> None:
        (self._state, self.weights, self.n, self.unit_weights,
         self._w_dev, self._fingerprint, self._screen) = snap

    def append_rows(self, data, weights: Optional[np.ndarray] = None) -> int:
        """Extend the dataset with new rows (incremental insert support).

        ``data`` is anything the metric canonicalizes; its canonical
        arrays must match the existing rows' trailing shape and dtype
        (for jaccard: pack new sets against the same universe). Returns
        the number of appended rows. Invalidate-and-recompute semantics
        for the fingerprint: the engine hashes the mutated dataset on
        next use.
        """
        canon_new = self.metric.canonicalize(data)
        canon_old = tuple(np.asarray(a) for a in self._state)
        if len(canon_new) != len(canon_old):
            raise ValueError(
                f"appended data canonicalizes to {len(canon_new)} arrays, "
                f"dataset has {len(canon_old)}")
        for a_old, a_new in zip(canon_old, canon_new):
            if a_old.shape[1:] != a_new.shape[1:] \
                    or a_old.dtype != a_new.dtype:
                raise ValueError(
                    "appended rows have incompatible canonical shape/dtype "
                    f"{a_new.shape[1:]}/{a_new.dtype} vs dataset "
                    f"{a_old.shape[1:]}/{a_old.dtype} (for jaccard, pack "
                    "new sets with the dataset's universe)")
        m = int(canon_new[0].shape[0])
        if weights is None:
            w_new = np.ones(m, dtype=np.int64)
        else:
            w_new = np.asarray(weights, dtype=np.int64)
            if w_new.shape != (m,):
                raise ValueError(
                    f"weights shape {w_new.shape} != ({m},)")
            if w_new.size and w_new.min() < 1:
                raise ValueError("duplicate weights must be >= 1")
        self._state = self.metric.device_state(tuple(
            np.concatenate([o, a]) for o, a in zip(canon_old, canon_new)))
        self.weights = np.concatenate([self.weights, w_new])
        self.n += m
        self.unit_weights = bool(np.all(self.weights == 1))
        self._w_dev = jnp.asarray(self.weights.astype(np.float32))
        self._fingerprint = None
        self._screen = None
        return m

    def keep_rows(self, keep: np.ndarray) -> None:
        """Restrict the dataset to ``keep`` (bool mask over rows) —
        incremental delete support. Surviving rows get compacted ids in
        the original order (``np.delete`` semantics)."""
        keep = np.asarray(keep, dtype=bool)
        if keep.shape != (self.n,):
            raise ValueError(f"keep mask shape {keep.shape} != ({self.n},)")
        idx = np.flatnonzero(keep)
        if idx.size == 0:
            raise ValueError("cannot delete every object")
        self._state = self.metric.device_state(tuple(
            np.asarray(a)[idx] for a in self._state))
        self.weights = self.weights[idx]
        self.n = int(idx.size)
        self.unit_weights = bool(np.all(self.weights == 1))
        self._w_dev = jnp.asarray(self.weights.astype(np.float32))
        self._fingerprint = None
        self._screen = None

    def _dense_sweep_bytes(self) -> int:
        """What the pre-compaction sweep moved to the host: a float32
        distance plane plus a bool mask per tile."""
        return self.n * self.n * 5

    def materialize_stats(self, eps: float, minpts: int
                          ) -> Tuple[np.ndarray, CSRNeighborhoods, np.ndarray]:
        """One-pass (counts, CSR, core distances) — the build-side product.

        The k-th-distance selection rides on the same compacted sweep's
        CSR via the segmented sort in :meth:`core_distances`; at fleet
        scale the device-resident ``kernels.kthdist`` bisection replaces
        it.
        """
        counts, csr = self.materialize(eps)
        C = self.core_distances(csr, counts, self.weights, minpts)
        return counts, csr, C

    def counts_only(self, eps: float) -> np.ndarray:
        """Weighted |N_ε(p)| for all p without materializing lists.

        Routed through the metric's fused ``eps_count`` kernel: the
        distance tile is reduced to per-row counts on device (in VMEM on
        TPU), so only O(rows) floats cross to the host per tile — no
        dense plane, no list storage.  When the projection screen is
        active the count kernel sees only each tile's surviving
        sub-corpus (``screened_eps_count``) — counts stay bit-identical
        because the screen mask is a superset of the hit plane.
        """
        counts = np.zeros(self.n, dtype=np.int64)
        eps_dev = jnp.float32(eps)
        scr = self._screen_get()
        if scr is not None:
            order = scr["order"]
            surv, s_t, s2t = self._screen_surv(eps, scr)
            for t, (s, e) in enumerate(scr["tiles"]):
                self.distance_rows_computed += e - s
                sub, _ = self._screen_cols(scr, surv[t])
                if sub.size == 0:
                    continue
                q_state = self.metric.take(scr["state_perm"], slice(s, e))
                if sub.size > 0.7 * self.n:   # hybrid full-corpus escape
                    c = self.metric.eps_count(
                        q_state, self._state,
                        eps_dev, self._w_dev, use_pallas=self.use_pallas)
                else:
                    sub_p = self._pad_ids(sub)
                    c, _cand = self.metric.screened_eps_count(
                        q_state,
                        self.metric.take(self._state, jnp.asarray(sub_p)),
                        jnp.asarray(scr["E32o"][s:e]),
                        jnp.asarray(scr["E32"][sub_p]),
                        eps_dev, s2t, self._w_dev[jnp.asarray(sub_p)],
                        num_valid=int(sub.size), use_pallas=self.use_pallas)
                counts[order[s:e]] = np.asarray(c).astype(np.int64)
            return counts
        for s, e in self._tile_bounds():
            self.distance_rows_computed += e - s
            c = self.metric.eps_count(self._rows(s, e), self._state, eps_dev,
                                      self._w_dev,
                                      use_pallas=self.use_pallas)
            counts[s:e] = np.asarray(c).astype(np.int64)
        return counts

    @staticmethod
    def core_distances(csr: CSRNeighborhoods, counts: np.ndarray,
                       weights: np.ndarray, minpts: int) -> np.ndarray:
        """M(p) for cores, inf otherwise (Definitions 3.6/3.7).

        With duplicate weights, M(p) is the smallest distance δ in p's sorted
        neighbor list at which the cumulative weight reaches MinPts.

        One segmented pass over the whole CSR, no per-object loop: a stable
        lexsort orders every row's neighbors by distance in place, a global
        cumulative weight turns the per-row "cumulative weight ≥ MinPts"
        threshold into ``searchsorted(cw, base + MinPts)`` (the global
        cumsum is strictly increasing, so the hit lands inside the row's
        own segment whenever the row is a core).
        """
        n = counts.shape[0]
        C = np.full(n, np.inf, dtype=np.float32)
        core = counts >= minpts
        if not core.any():
            return C
        seg = csr.row_ids()
        # single stable radix sort on a packed (row, dist) int64 key: the
        # distances are non-negative IEEE floats, whose bit patterns order
        # exactly like their values — ~3× cheaper than a 2-key lexsort
        key = (seg << np.int64(32)) | csr.dists.view(np.uint32)
        if np.all(weights == 1):
            # unit weights: the cumulative weight is just the within-row
            # rank, so the MinPts-th entry sits at a fixed offset — and no
            # permutation is needed, only sorted values (low 32 key bits)
            skey = np.sort(key)
            kth = skey[csr.indptr[:-1][core] + minpts - 1]
            C[core] = (kth & np.int64(0xFFFFFFFF)) \
                .astype(np.uint32).view(np.float32)
            return C
        order = np.argsort(key, kind="stable")    # == lexsort((dists, seg))
        sorted_d = csr.dists[order]
        cw = np.cumsum(weights[csr.indices[order]])
        base = np.where(csr.indptr[:-1] > 0, cw[csr.indptr[:-1] - 1], 0)
        hit = np.searchsorted(cw, base[core] + minpts, side="left")
        C[core] = sorted_d[hit]
        return C
