"""The ε-neighborhood engine: device-tiled distance plane, vectorized CSR.

Density-based clustering's dominant cost — for DBSCAN, OPTICS-build,
FINEX-build and the residual verification inside ε*/MinPts*-queries alike —
is ε-neighborhood computation. This engine is the TPU adaptation of the
paper's "materialize all neighborhoods in a separate step in advance"
strategy (§6, Neighborhood Computations): distances are computed in
(row-batch × corpus) tiles on the accelerator and the sweep is
*ε-compacted on device* — only thresholded survivors ever reach the host.

Everything metric-specific lives behind the ``repro.metrics`` protocol:
the engine holds one opaque row-aligned dataset state (float vectors for
euclidean/cosine/cityblock, packed bitmaps + sizes for Jaccard, whatever
a user-registered metric canonicalizes to) and dispatches every kernel —
dense tile, fused mask sweep, fused count, fused slot emit — through the
``Metric`` instance. The engine itself never branches on metric names.

Two compacted emit paths share the same byte-level contract:
  * slot emit (``emit="slots"`` / ``use_pallas=True``) — the metric's
    fused ``eps_compact`` kernel packs each row's surviving (col, dist)
    pairs into capacity-capped slots inside the kernel, so host traffic
    is O(rows·cap) ≈ O(nnz); rows that overflow the capacity are
    re-extracted from a dense tile (byte-identical fallback).
  * mask emit (the CPU/XLA default) — the metric's fused ``mask_tile``
    emits only the bool hit plane (euclidean thresholds *squared*
    distances exactly via ``metrics.sq_threshold``, so no m·n square
    roots are evaluated); the host flat-nonzeros the plane, and
    ``gather_pairs`` pulls the O(nnz) surviving distances from the
    still-resident device payload.  Tile k+1's device work overlaps
    tile k's host extraction (two-deep pipeline).

Every host-side step is bulk array work — ``np.flatnonzero`` over the hit
plane, a ``searchsorted`` per tile for row lengths, one weighted
``bincount`` over the finished CSR — and the CSR arrays are filled
preallocated, chunk by chunk (no double-concatenate peak).  No per-object
Python loops anywhere on the materialization path
(``repro.core.reference`` keeps the loop originals for equivalence
testing).

Bit-pinning contract: emitted distances are gathered from the *same*
device buffers their hit plane was computed from, and each metric's
threshold transform is exact by construction, so the remaining cross-jit
assumption is only that the distance *formula* compiles to the same
per-pair float ops in each wrapper — which
``tests/test_vectorized_equivalence.py`` pins byte-for-byte against the
dense ``reference_materialize`` on every emit path and metric.

The host-facing product per object p:
  * count[p]  = |N_ε(p)|                      (the paper's  o.N)
  * csr lists = N_ε(p) with distances          (drives Algorithms 1–4)
  * kth(k)[p] = M(p) = k-th smallest distance  (the paper's core distance)

Duplicate handling (paper §6 "Data Deduplication") is supported through
``weights``: object p counts as weights[p] identical copies. Neighborhood
sizes then use weighted counts while only unique objects are materialized.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.metrics import MetricLike, get_metric
# re-exported for backwards compatibility: these lived here before the
# metric registry (PR 4) pulled everything metric-specific into
# ``repro.metrics``
from repro.metrics import Metric, sq_threshold  # noqa: F401


def fill_slot_rows(indices: np.ndarray, dists: np.ndarray, base: np.ndarray,
                   lens: np.ndarray, cols: np.ndarray, dvals: np.ndarray
                   ) -> None:
    """Scatter per-row slot data into preallocated CSR arrays.

    ``cols``/``dvals`` are (..., cap) slot rows, ``lens`` the matching
    per-row lengths and ``base`` each row's destination offset; every row
    claims its first ``min(len, cap)`` slots.  Shared by the single-device
    slot sweep and the sharded CSR-emit assembly so the two compaction→CSR
    layouts cannot drift apart.
    """
    cap = cols.shape[-1]
    slot = np.arange(cap, dtype=np.int64)
    valid = slot < np.minimum(lens, cap)[..., None]
    dst = (base[..., None] + slot)[valid]
    indices[dst] = cols[valid]
    dists[dst] = dvals[valid]


def _pow2_pad(size: int, floor: int = 1 << 14) -> int:
    """Pad gather sizes to powers of two so the surviving-pair gather jit
    compiles a handful of shapes per dataset instead of one per tile."""
    p = floor
    while p < size:
        p <<= 1
    return p


def dataset_fingerprint(data, metric: MetricLike = "euclidean",
                        weights: Optional[np.ndarray] = None) -> str:
    """Stable identity of a dataset: metric + shape + dtype + content hash.

    Computed over the metric's *canonical* representation (the same
    arrays ``NeighborEngine`` uploads — float32 vectors, uint32-packed
    bitmaps + int32 sizes, …), so the fingerprint of raw input data
    equals the fingerprint of an engine built from it. This is what keys
    the serving-side ``IndexStore`` and what ``FinexIndex.load(data=...)``
    checks before attaching an engine.  The metric contributes its
    registry name (and params, when any) to the head, so the same bytes
    under different distance semantics never collide.
    Non-unit duplicate ``weights`` are part of the identity (they change
    every neighborhood count); unit weights hash the same as no weights.
    """
    m = get_metric(metric)
    if weights is not None:
        w = np.ascontiguousarray(np.asarray(weights, dtype=np.int64))
        if np.all(w == 1):
            weights = None
    canon = m.canonicalize(data)
    h = hashlib.sha256()
    m.fingerprint_update(h, canon)
    head = m.fingerprint_head(canon)
    if weights is not None:
        h.update(b"weights")
        h.update(w.tobytes())
        head += ":w"
    return f"{head}:{h.hexdigest()[:16]}"


@dataclass
class CSRNeighborhoods:
    """Materialized ε-neighborhoods, one row per object (self included)."""
    indptr: np.ndarray    # (n+1,) int64
    indices: np.ndarray   # (nnz,) int32 neighbor object ids
    dists: np.ndarray     # (nnz,) float32 distances
    eps: float
    _row_ids: Optional[np.ndarray] = field(
        default=None, init=False, repr=False, compare=False)

    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[i], self.indptr[i + 1]
        return self.indices[s:e], self.dists[s:e]

    def row_ids(self) -> np.ndarray:
        """(nnz,) row id per stored pair — the segment expansion used by
        weighted counts, core distances and subgraph extraction. Cached:
        the CSR is immutable after materialization and the expansion is
        an O(nnz) allocation the query path would otherwise repeat."""
        if self._row_ids is None:
            self._row_ids = np.repeat(
                np.arange(self.indptr.shape[0] - 1, dtype=np.int64),
                np.diff(self.indptr))
        return self._row_ids

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])


class NeighborEngine:
    """Batched distance plane for one dataset + metric.

    ``metric`` is a registry name or a ``repro.metrics.Metric`` instance;
    ``data`` is whatever that metric canonicalizes — (n, d) float arrays
    for the vector metrics, the (bits, sizes) pair from
    ``bitset.pack_sets`` for Jaccard, etc.
    """

    def __init__(self, data, metric: MetricLike = "euclidean",
                 weights: Optional[np.ndarray] = None,
                 batch_rows: int = 256, use_pallas: bool = False,
                 emit: str = "auto", slot_cap: int = 256):
        if emit not in ("auto", "slots", "mask"):
            raise ValueError(f"emit must be 'auto', 'slots' or 'mask', "
                             f"got {emit!r}")
        self.metric: Metric = get_metric(metric)
        self.use_pallas = use_pallas
        # ε-compacted emit strategy: "slots" = fused per-row capacity
        # slots (the Pallas kernels on TPU; their jnp oracle otherwise),
        # "mask" = bool-plane + surviving-pair gather (the fast XLA/CPU
        # path), "auto" = slots when the Pallas kernels are in play
        self.emit = emit
        # slot capacity, snapped to a power of two ≥ 128 (the Pallas emit
        # kernels require a multiple of their chunk size) and adapted
        # upward when rows overflow
        self._slot_cap = 1 << max(7, (int(slot_cap) - 1).bit_length())
        # instrumentation for benchmarks: what did the last materialize
        # sweep actually move host<->device, and which path did it take
        self.last_materialize: dict = {}
        self._state = self.metric.device_state(self.metric.canonicalize(data))
        self.n = int(self._state[0].shape[0])
        if weights is None:
            weights = np.ones(self.n, dtype=np.int64)
        self.weights = np.asarray(weights, dtype=np.int64)
        if self.weights.size and self.weights.min() < 1:
            # weights are duplicate multiplicities (paper §6): a count
            # below 1 has no meaning and would silently skew every
            # neighborhood count and core distance
            raise ValueError("duplicate weights must be >= 1")
        # unit weights (no duplicates) let counts come straight from row
        # lengths instead of weighted reductions over the CSR
        self.unit_weights = bool(np.all(self.weights == 1))
        self._w_dev = jnp.asarray(self.weights.astype(np.float32))
        # 256-row sweep tiles: the (B, n) cross-product tile stays
        # cache-sized on CPU hosts and the two-deep pipeline gets a finer
        # overlap grain (measurably faster than 1024 at n=20k); the tile
        # extent never affects the per-pair float bits
        self.batch_rows = batch_rows
        self.distance_rows_computed = 0  # instrumentation: #row-neighborhoods
        self._fingerprint: Optional[str] = None

    @property
    def metric_name(self) -> str:
        """The metric's registry name (the string serialized into npz
        archives and checkpoint manifests)."""
        return self.metric.name

    def fingerprint(self) -> str:
        """``dataset_fingerprint`` of this engine's dataset (cached)."""
        if self._fingerprint is None:
            # the canonical host arrays round-trip bit-exactly through the
            # device state, so hashing the pulled-back state equals
            # hashing the original input
            canon = tuple(np.asarray(a) for a in self._state)
            self._fingerprint = dataset_fingerprint(
                canon if len(canon) > 1 else canon[0], self.metric,
                weights=self.weights)
        return self._fingerprint

    # ---------------------------------------------------------- distances
    def _dist_block(self, rows: jax.Array) -> jax.Array:
        """(B,) row ids -> (B, n) float32 distances."""
        return self.metric.tile(self.metric.take(self._state, rows),
                                self._state, use_pallas=self.use_pallas)

    def distances_from(self, rows: np.ndarray) -> np.ndarray:
        """Distances from the given row ids to the whole dataset."""
        rows = np.asarray(rows, dtype=np.int32)
        self.distance_rows_computed += len(rows)
        out = np.empty((len(rows), self.n), dtype=np.float32)
        for s in range(0, len(rows), self.batch_rows):
            chunk = jnp.asarray(rows[s:s + self.batch_rows])
            out[s:s + len(chunk)] = np.asarray(self._dist_block(chunk))
        return out

    @staticmethod
    def _bucket(idx: np.ndarray) -> np.ndarray:
        """Pad index arrays to the next power of two (repeat index 0) so
        jit'd distance calls reuse compiled shapes instead of recompiling
        for every (candidates × cores) sub-matrix size."""
        n = len(idx)
        target = 1 << max(0, (n - 1)).bit_length()
        if target == n:
            return idx
        return np.concatenate([idx, np.zeros(target - n, idx.dtype)])

    def pair_distances(self, rows: np.ndarray, cols: np.ndarray) -> np.ndarray:
        """(len(rows), len(cols)) distance sub-matrix (for ε*-verification)."""
        rows = np.asarray(rows, dtype=np.int32)
        cols = np.asarray(cols, dtype=np.int32)
        nr, nc = len(rows), len(cols)
        self.distance_rows_computed += nr
        rp = jnp.asarray(self._bucket(rows))
        cp = jnp.asarray(self._bucket(cols))
        d = self.metric.tile(self.metric.take(self._state, rp),
                             self.metric.take(self._state, cp),
                             use_pallas=self.use_pallas)
        return np.asarray(d)[:nr, :nc]

    # ------------------------------------------------------ neighborhoods
    def _tile_bounds(self):
        """Host-side (start, end) row bounds of every sweep tile."""
        return [(s, min(s + self.batch_rows, self.n))
                for s in range(0, self.n, self.batch_rows)]

    def _rows(self, s: int, e: int):
        """Device state of the sweep tile's query rows [s, e)."""
        return self.metric.take(self._state, slice(s, e))

    def materialize(self, eps: float) -> Tuple[np.ndarray, CSRNeighborhoods]:
        """Weighted counts |N_ε| and CSR neighbor lists for every object.

        The sweep is ε-compacted on device (see the module docstring):
        only thresholded survivors — O(nnz) pair payload plus the bool hit
        plane (mask path) or per-row capacity slots (slot path) — ever
        cross to the host, instead of the dense (batch_rows × n) float
        plane.  Per-row neighbor lists come out sorted by object id and
        the CSR arrays are filled into a single preallocated buffer pair;
        the result is byte-identical to the dense reference
        (``repro.core.reference.reference_materialize``).
        """
        use_slots = self.emit == "slots" or (self.emit == "auto"
                                             and self.use_pallas)
        if use_slots:
            lens, ind_chunks, dist_chunks = self._sweep_slots(eps)
        else:
            lens, ind_chunks, dist_chunks = self._sweep_mask(eps)

        indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(lens, out=indptr[1:])
        nnz = int(indptr[-1])
        # preallocate once, fill chunk by chunk (chunks are freed as they
        # are consumed — no concatenate holding chunks + result at peak)
        indices = np.empty(nnz, dtype=np.int32)
        dists = np.empty(nnz, dtype=np.float32)
        off = 0
        for i in range(len(ind_chunks)):
            k = ind_chunks[i].size
            indices[off:off + k] = ind_chunks[i]
            dists[off:off + k] = dist_chunks[i]
            ind_chunks[i] = dist_chunks[i] = None
            off += k
        csr = CSRNeighborhoods(indptr=indptr, indices=indices, dists=dists,
                               eps=float(eps))
        if self.unit_weights:
            counts = lens.copy()
        else:
            # weighted counts over the surviving pairs only: O(nnz), exact
            # in float64 (weight sums < 2^53)
            counts = np.bincount(
                csr.row_ids(), weights=self.weights[indices].astype(np.float64),
                minlength=self.n).astype(np.int64)
        return counts, csr

    def _mask_extract(self, hit, payload, nc: int, flat_dtype):
        """One tile of the mask path: bool hit plane -> (per-row lens,
        sorted cols, in-flight distance gather, #survivors, host bytes).

        Shared by the full sweep and ``strip_materialize`` — the two are
        required to produce byte-identical entries for the incremental
        insert contract, so the extraction must be one piece of code.
        """
        mask = np.asarray(hit)
        flat = np.flatnonzero(mask)
        lens = np.diff(np.searchsorted(
            flat, np.arange(mask.shape[0] + 1, dtype=np.int64) * nc))
        pad = _pow2_pad(flat.size)
        fpad = np.zeros(pad, dtype=flat_dtype)
        fpad[:flat.size] = flat
        dv = self.metric.gather_pairs(payload, jnp.asarray(fpad))
        cols = (flat % nc).astype(np.int32)
        return lens, cols, dv, flat.size, mask.nbytes + fpad.nbytes + pad * 4

    def _sweep_mask(self, eps: float):
        """Compacted sweep, mask path: fused threshold plane + O(nnz)
        surviving-pair gather, two-deep pipelined (tile k+1's device work
        overlaps tile k's host extraction)."""
        n = self.n
        lens = np.zeros(n, dtype=np.int64)
        ind_chunks: list = []
        pending_gather: list = []
        host_bytes = 0
        thresh = self.metric.mask_threshold(eps)

        def dispatch(se):
            s, e = se
            return self.metric.mask_tile(self._rows(s, e), self._state,
                                         thresh)

        tiles = self._tile_bounds()
        pend = dispatch(tiles[0]) if tiles else None
        flat_dtype = np.int32 if self.batch_rows * n < 2 ** 31 else np.int64
        for i, (s, e) in enumerate(tiles):
            hit, payload = pend
            if i + 1 < len(tiles):
                pend = dispatch(tiles[i + 1])      # overlaps the host work
            self.distance_rows_computed += e - s
            tl, cols, dv, k, nbytes = self._mask_extract(
                hit, payload, n, flat_dtype)
            lens[s:e] = tl
            ind_chunks.append(cols)
            pending_gather.append((k, dv))
            host_bytes += nbytes
        dist_chunks = [np.asarray(dv)[:k] for k, dv in pending_gather]
        self.last_materialize = {
            "mode": "mask", "metric": self.metric.name,
            "tiles": len(tiles), "cap": None,
            "fallback_rows": 0, "host_bytes": host_bytes,
            "host_bytes_dense": self._dense_sweep_bytes(),
        }
        return lens, ind_chunks, dist_chunks

    def _sweep_slots(self, eps: float):
        """Compacted sweep, slot path: the fused emit kernels pack each
        row's survivors into ``cap`` slots on device; rows longer than the
        capacity fall back to a dense tile (byte-identical) and the
        capacity adapts upward for the rest of the sweep."""
        n = self.n
        lens = np.zeros(n, dtype=np.int64)
        ind_chunks: list = []
        dist_chunks: list = []
        host_bytes = 0
        fallback_rows = 0
        eps_dev = jnp.float32(eps)
        for s, e in self._tile_bounds():
            cap = self._slot_cap
            self.distance_rows_computed += e - s
            tl, tc, td = self.metric.eps_compact(
                self._rows(s, e), self._state, eps_dev, cap,
                use_pallas=self.use_pallas)
            tl = np.asarray(tl).astype(np.int64)
            tc, td = np.asarray(tc), np.asarray(td)
            host_bytes += tl.nbytes + tc.nbytes + td.nbytes
            lens[s:e] = tl
            over = tl > cap
            if over.any():
                # dense-tile fallback for the overflow rows only; bucket
                # the row list to pow2 so the jit'd distance call reuses
                # compiled shapes across tiles with different overflows
                fallback_rows += int(over.sum())
                rows = (s + np.flatnonzero(over)).astype(np.int32)
                d_over = np.asarray(self._dist_block(
                    jnp.asarray(self._bucket(rows))))[:len(rows)]
                host_bytes += d_over.nbytes
                oflat = np.flatnonzero(d_over <= np.float32(eps))
                ocols = (oflat % n).astype(np.int32)
                odists = d_over.ravel()[oflat]
                osplit = np.searchsorted(
                    oflat, np.arange(1, len(rows), dtype=np.int64) * n)
                # grow the capacity for the rest of the sweep
                while self._slot_cap < int(tl.max()):
                    self._slot_cap <<= 1
            # stitch slot rows and fallback rows back into row order
            # (overflow rows claim zero slots — their whole row comes
            # from the dense fallback)
            tile_nnz = int(tl.sum())
            t_indptr = np.zeros(e - s + 1, dtype=np.int64)
            np.cumsum(tl, out=t_indptr[1:])
            t_ind = np.empty(tile_nnz, dtype=np.int32)
            t_dist = np.empty(tile_nnz, dtype=np.float32)
            fill_slot_rows(t_ind, t_dist, t_indptr[:-1],
                           np.where(over, 0, tl), tc, td)
            if over.any():
                obase = np.repeat(t_indptr[:-1][over],
                                  np.diff(np.concatenate(
                                      ([0], osplit, [len(oflat)]))))
                odst = obase + np.arange(len(oflat)) - np.repeat(
                    np.concatenate(([0], osplit)),
                    np.diff(np.concatenate(([0], osplit, [len(oflat)]))))
                t_ind[odst] = ocols
                t_dist[odst] = odists
            ind_chunks.append(t_ind)
            dist_chunks.append(t_dist)
        self.last_materialize = {
            "mode": "slots", "metric": self.metric.name,
            "tiles": len(self._tile_bounds()),
            "cap": self._slot_cap, "fallback_rows": fallback_rows,
            "host_bytes": host_bytes,
            "host_bytes_dense": self._dense_sweep_bytes(),
        }
        return lens, ind_chunks, dist_chunks

    def strip_materialize(self, rows_state, eps: float, corpus=None,
                          batch_rows: Optional[int] = None
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """ε-compacted neighborhoods of arbitrary query rows vs ``corpus``
        (default: the full dataset state) — the (m, n) strip sweep behind
        incremental index maintenance.

        Same bit contract as the mask path of :meth:`materialize`: the
        per-pair distance bits of every registered metric depend only on
        that pair's rows (never on the tile extent or the other rows in
        the tile), so strip entries are byte-identical to the matching
        entries of a full sweep over the mutated dataset.

        Returns ``(lens, cols, dists)``: per-query-row survivor counts
        plus the flat row-major (col, dist) pairs, cols ascending within
        each row (the CSR ordering).
        """
        corpus = self._state if corpus is None else corpus
        nc = int(corpus[0].shape[0])
        nq = int(rows_state[0].shape[0])
        if batch_rows is None:
            # strips are narrow: tile by pair budget (~2^24) instead of
            # the cache-sized sweep default, so a single-row insert is a
            # couple of dispatches rather than n/batch_rows of them
            batch_rows = max(self.batch_rows, (1 << 24) // max(nc, 1))
        thresh = self.metric.mask_threshold(eps)
        lens = np.zeros(nq, dtype=np.int64)
        cols_chunks: list = []
        dist_chunks: list = []
        flat_dtype = np.int32 if batch_rows * nc < 2 ** 31 else np.int64
        for s in range(0, nq, batch_rows):
            e = min(s + batch_rows, nq)
            self.distance_rows_computed += e - s
            hit, payload = self.metric.mask_tile(
                self.metric.take(rows_state, slice(s, e)), corpus, thresh)
            tl, cols, dv, k, _ = self._mask_extract(
                hit, payload, nc, flat_dtype)
            lens[s:e] = tl
            cols_chunks.append(cols)
            dist_chunks.append(np.asarray(dv)[:k])
        cols = (np.concatenate(cols_chunks) if cols_chunks
                else np.zeros(0, dtype=np.int32))
        dists = (np.concatenate(dist_chunks) if dist_chunks
                 else np.zeros(0, dtype=np.float32))
        return lens, cols, dists

    # ------------------------------------------------------ row mutation
    def state_snapshot(self):
        """Cheap (reference-only) snapshot of the mutable dataset state —
        ``FinexIndex.insert``/``delete`` restore it if a delta fails
        midway, so the engine can never end up holding a different row
        set than the ordering it is attached to."""
        return (self._state, self.weights, self.n, self.unit_weights,
                self._w_dev, self._fingerprint)

    def state_restore(self, snap) -> None:
        (self._state, self.weights, self.n, self.unit_weights,
         self._w_dev, self._fingerprint) = snap

    def append_rows(self, data, weights: Optional[np.ndarray] = None) -> int:
        """Extend the dataset with new rows (incremental insert support).

        ``data`` is anything the metric canonicalizes; its canonical
        arrays must match the existing rows' trailing shape and dtype
        (for jaccard: pack new sets against the same universe). Returns
        the number of appended rows. Invalidate-and-recompute semantics
        for the fingerprint: the engine hashes the mutated dataset on
        next use.
        """
        canon_new = self.metric.canonicalize(data)
        canon_old = tuple(np.asarray(a) for a in self._state)
        if len(canon_new) != len(canon_old):
            raise ValueError(
                f"appended data canonicalizes to {len(canon_new)} arrays, "
                f"dataset has {len(canon_old)}")
        for a_old, a_new in zip(canon_old, canon_new):
            if a_old.shape[1:] != a_new.shape[1:] \
                    or a_old.dtype != a_new.dtype:
                raise ValueError(
                    "appended rows have incompatible canonical shape/dtype "
                    f"{a_new.shape[1:]}/{a_new.dtype} vs dataset "
                    f"{a_old.shape[1:]}/{a_old.dtype} (for jaccard, pack "
                    "new sets with the dataset's universe)")
        m = int(canon_new[0].shape[0])
        if weights is None:
            w_new = np.ones(m, dtype=np.int64)
        else:
            w_new = np.asarray(weights, dtype=np.int64)
            if w_new.shape != (m,):
                raise ValueError(
                    f"weights shape {w_new.shape} != ({m},)")
            if w_new.size and w_new.min() < 1:
                raise ValueError("duplicate weights must be >= 1")
        self._state = self.metric.device_state(tuple(
            np.concatenate([o, a]) for o, a in zip(canon_old, canon_new)))
        self.weights = np.concatenate([self.weights, w_new])
        self.n += m
        self.unit_weights = bool(np.all(self.weights == 1))
        self._w_dev = jnp.asarray(self.weights.astype(np.float32))
        self._fingerprint = None
        return m

    def keep_rows(self, keep: np.ndarray) -> None:
        """Restrict the dataset to ``keep`` (bool mask over rows) —
        incremental delete support. Surviving rows get compacted ids in
        the original order (``np.delete`` semantics)."""
        keep = np.asarray(keep, dtype=bool)
        if keep.shape != (self.n,):
            raise ValueError(f"keep mask shape {keep.shape} != ({self.n},)")
        idx = np.flatnonzero(keep)
        if idx.size == 0:
            raise ValueError("cannot delete every object")
        self._state = self.metric.device_state(tuple(
            np.asarray(a)[idx] for a in self._state))
        self.weights = self.weights[idx]
        self.n = int(idx.size)
        self.unit_weights = bool(np.all(self.weights == 1))
        self._w_dev = jnp.asarray(self.weights.astype(np.float32))
        self._fingerprint = None

    def _dense_sweep_bytes(self) -> int:
        """What the pre-compaction sweep moved to the host: a float32
        distance plane plus a bool mask per tile."""
        return self.n * self.n * 5

    def materialize_stats(self, eps: float, minpts: int
                          ) -> Tuple[np.ndarray, CSRNeighborhoods, np.ndarray]:
        """One-pass (counts, CSR, core distances) — the build-side product.

        The k-th-distance selection rides on the same compacted sweep's
        CSR via the segmented sort in :meth:`core_distances`; at fleet
        scale the device-resident ``kernels.kthdist`` bisection replaces
        it.
        """
        counts, csr = self.materialize(eps)
        C = self.core_distances(csr, counts, self.weights, minpts)
        return counts, csr, C

    def counts_only(self, eps: float) -> np.ndarray:
        """Weighted |N_ε(p)| for all p without materializing lists.

        Routed through the metric's fused ``eps_count`` kernel: the
        distance tile is reduced to per-row counts on device (in VMEM on
        TPU), so only O(rows) floats cross to the host per tile — no
        dense plane, no list storage.
        """
        counts = np.zeros(self.n, dtype=np.int64)
        eps_dev = jnp.float32(eps)
        for s, e in self._tile_bounds():
            self.distance_rows_computed += e - s
            c = self.metric.eps_count(self._rows(s, e), self._state, eps_dev,
                                      self._w_dev,
                                      use_pallas=self.use_pallas)
            counts[s:e] = np.asarray(c).astype(np.int64)
        return counts

    @staticmethod
    def core_distances(csr: CSRNeighborhoods, counts: np.ndarray,
                       weights: np.ndarray, minpts: int) -> np.ndarray:
        """M(p) for cores, inf otherwise (Definitions 3.6/3.7).

        With duplicate weights, M(p) is the smallest distance δ in p's sorted
        neighbor list at which the cumulative weight reaches MinPts.

        One segmented pass over the whole CSR, no per-object loop: a stable
        lexsort orders every row's neighbors by distance in place, a global
        cumulative weight turns the per-row "cumulative weight ≥ MinPts"
        threshold into ``searchsorted(cw, base + MinPts)`` (the global
        cumsum is strictly increasing, so the hit lands inside the row's
        own segment whenever the row is a core).
        """
        n = counts.shape[0]
        C = np.full(n, np.inf, dtype=np.float32)
        core = counts >= minpts
        if not core.any():
            return C
        seg = csr.row_ids()
        # single stable radix sort on a packed (row, dist) int64 key: the
        # distances are non-negative IEEE floats, whose bit patterns order
        # exactly like their values — ~3× cheaper than a 2-key lexsort
        key = (seg << np.int64(32)) | csr.dists.view(np.uint32)
        if np.all(weights == 1):
            # unit weights: the cumulative weight is just the within-row
            # rank, so the MinPts-th entry sits at a fixed offset — and no
            # permutation is needed, only sorted values (low 32 key bits)
            skey = np.sort(key)
            kth = skey[csr.indptr[:-1][core] + minpts - 1]
            C[core] = (kth & np.int64(0xFFFFFFFF)) \
                .astype(np.uint32).view(np.float32)
            return C
        order = np.argsort(key, kind="stable")    # == lexsort((dists, seg))
        sorted_d = csr.dists[order]
        cw = np.cumsum(weights[csr.indices[order]])
        base = np.where(csr.indptr[:-1] > 0, cw[csr.indptr[:-1] - 1], 0)
        hit = np.searchsorted(cw, base[core] + minpts, side="left")
        C[core] = sorted_d[hit]
        return C
