"""Set data → packed uint32 bitmaps.

The paper models process-mining events as sets of integer tokens and
clusters them under Jaccard distance. On a TPU the inverted-list/prefix
filter of the paper does not map (irregular traversal); instead sets become
dense packed bitmaps and |r ∩ s| becomes AND + popcount on the VPU.
"""
from __future__ import annotations

from typing import Iterable, Sequence, Tuple

import numpy as np


def pack_sets(sets: Sequence[Iterable[int]], universe: int | None = None
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Pack integer sets into (n, W) uint32 bitmaps + (n,) int32 sizes.

    ``universe``: exclusive upper bound on token ids; inferred if None.
    """
    materialized = [np.asarray(sorted(set(map(int, s))), dtype=np.int64)
                    for s in sets]
    if universe is None:
        universe = 1 + max((int(s[-1]) for s in materialized if s.size), default=0)
    W = max(1, (universe + 31) // 32)
    bits = np.zeros((len(materialized), W), dtype=np.uint32)
    sizes = np.zeros(len(materialized), dtype=np.int32)
    for i, s in enumerate(materialized):
        if s.size == 0:
            continue
        if s[-1] >= universe or s[0] < 0:
            raise ValueError(f"token out of range [0, {universe}) in set {i}")
        np.bitwise_or.at(bits[i], s // 32, (np.uint32(1) << (s % 32).astype(np.uint32)))
        sizes[i] = s.size
    return bits, sizes


def unpack_set(bits_row: np.ndarray) -> np.ndarray:
    """Inverse of pack_sets for one row — mostly for tests."""
    out = []
    for w, word in enumerate(bits_row.astype(np.uint64)):
        word = int(word)
        while word:
            b = word & -word
            out.append(32 * w + b.bit_length() - 1)
            word ^= b
    return np.asarray(out, dtype=np.int64)
