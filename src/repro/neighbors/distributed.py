"""Multi-pod sharded neighborhood computation (DESIGN.md §2).

The (n × n) distance plane is the paper's entire runtime cost at scale;
here it fans out over the production mesh with shard_map:

  * query rows   sharded over the DP axes ("pod", "data"),
  * corpus cols  sharded over "model",
  * each device sweeps its (rowblock × colblock) tile-by-tile (row chunks
    of ``row_chunk`` so the local distance tile stays ~0.5–1 GB),
  * per-row weighted counts and distance histograms are psum-ed along
    "model" — the only collective; traffic is O(n), never O(n²).

The host FINEX build (Algorithm 2/3) streams these statistics; the same
sweep with a CSR-emit step feeds the ordering at fleet scale. This
function is the ``--arch finex`` dry-run cell: it must lower + compile on
the 256-chip and 512-chip meshes like every LM cell.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 top-level API; 0.4.x keeps it in experimental
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.kernels import ref
from repro.sharding import dp_axes


def sharded_neighbor_stats(x: jax.Array, y: jax.Array, w: jax.Array,
                           eps: jax.Array, edges: jax.Array, mesh: Mesh,
                           row_chunk: int = 2048
                           ) -> Tuple[jax.Array, jax.Array]:
    """Weighted |N_ε| counts + distance histograms for all query rows.

    x: (nq, d) queries, rows sharded over DP axes.
    y: (nc, d) corpus, rows sharded over "model".
    w: (nc,) duplicate weights, sharded with y.
    Returns (counts (nq,), hist (nq, B)) sharded like x's rows.
    """
    dp = dp_axes(mesh)
    nbins = edges.shape[0] - 1

    def local(xb, yb, wb, eps_s, edges_s):
        nq_l = xb.shape[0]
        n_chunks = max(1, nq_l // row_chunk)
        xc = xb.reshape(n_chunks, -1, xb.shape[-1])

        def chunk_stats(xrow):
            d = ref.pairwise_euclidean(xrow, yb)
            cnt = jnp.where(d <= eps_s, wb[None, :], 0.0).sum(-1)
            hist = ref.tile_histogram(d, edges_s).astype(jnp.float32)
            return cnt, hist

        cnt, hist = jax.lax.map(chunk_stats, xc)
        cnt = cnt.reshape(nq_l)
        hist = hist.reshape(nq_l, nbins)
        # one psum pair along the corpus axis — O(nq) traffic
        cnt = jax.lax.psum(cnt, "model")
        hist = jax.lax.psum(hist, "model")
        return cnt, hist

    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None), P("model", None), P("model"), P(), P()),
        out_specs=(P(dp), P(dp, None)))
    return fn(x, y, w, eps, edges)


def finex_dryrun_lowerable(mesh: Mesh, n: int = 1 << 20, d: int = 64,
                           nbins: int = 32, row_chunk: int = 2048):
    """(fn, args_sds, in_shardings) for the paper-workload dry-run cell."""
    dp = dp_axes(mesh)
    x = jax.ShapeDtypeStruct((n, d), jnp.float32)
    y = jax.ShapeDtypeStruct((n, d), jnp.float32)
    w = jax.ShapeDtypeStruct((n,), jnp.float32)
    eps = jax.ShapeDtypeStruct((), jnp.float32)
    edges = jax.ShapeDtypeStruct((nbins + 1,), jnp.float32)
    shardings = (NamedSharding(mesh, P(dp, None)),
                 NamedSharding(mesh, P("model", None)),
                 NamedSharding(mesh, P("model")),
                 NamedSharding(mesh, P()),
                 NamedSharding(mesh, P()))

    def fn(x, y, w, eps, edges):
        return sharded_neighbor_stats(x, y, w, eps, edges, mesh,
                                      row_chunk=row_chunk)

    return fn, (x, y, w, eps, edges), shardings


def sharded_jaccard_counts(bits_q, sizes_q, bits_c, sizes_c, w, eps,
                           mesh: Mesh, row_chunk: int = 2048) -> jax.Array:
    """Weighted |N_ε| counts under Jaccard over the production mesh —
    the set-data (process mining) variant of the neighborhood plane."""
    dp = dp_axes(mesh)

    def local(bq, sq, bc, sc, wb, eps_s):
        n_chunks = max(1, bq.shape[0] // row_chunk)
        bqc = bq.reshape(n_chunks, -1, bq.shape[-1])
        sqc = sq.reshape(n_chunks, -1)

        def chunk(args):
            b, s = args
            d = ref.jaccard_distance(b, s, bc, sc)
            return jnp.where(d <= eps_s, wb[None, :], 0.0).sum(-1)

        cnt = jax.lax.map(chunk, (bqc, sqc)).reshape(bq.shape[0])
        return jax.lax.psum(cnt, "model")

    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None), P(dp), P("model", None), P("model"),
                  P("model"), P()),
        out_specs=P(dp))
    return fn(bits_q, sizes_q, bits_c, sizes_c, w, eps)


def finex_jaccard_dryrun_lowerable(mesh: Mesh, n: int = 1 << 20,
                                   words: int = 64, row_chunk: int = 2048):
    """Set-data FINEX plane: 1M packed 2048-token-universe bitmaps."""
    dp = dp_axes(mesh)
    bits = jax.ShapeDtypeStruct((n, words), jnp.uint32)
    sizes = jax.ShapeDtypeStruct((n,), jnp.int32)
    w = jax.ShapeDtypeStruct((n,), jnp.float32)
    eps = jax.ShapeDtypeStruct((), jnp.float32)
    shardings = (NamedSharding(mesh, P(dp, None)),
                 NamedSharding(mesh, P(dp)),
                 NamedSharding(mesh, P("model", None)),
                 NamedSharding(mesh, P("model")),
                 NamedSharding(mesh, P("model")),
                 NamedSharding(mesh, P()))

    def fn(bq, sq, bc, sc, w, eps):
        return sharded_jaccard_counts(bq, sq, bc, sc, w, eps, mesh,
                                      row_chunk=row_chunk)

    return fn, (bits, sizes, bits, sizes, w, eps), shardings
