"""Multi-pod sharded neighborhood computation (DESIGN.md §2).

The (n × n) distance plane is the paper's entire runtime cost at scale;
here it fans out over the production mesh with shard_map:

  * query rows   sharded over the DP axes ("pod", "data"),
  * corpus cols  sharded over "model",
  * each device sweeps its (rowblock × colblock) tile-by-tile (row chunks
    of ``row_chunk`` so the local distance tile stays ~0.5–1 GB),
  * per-row weighted counts and distance histograms are psum-ed along
    "model" — traffic O(n), never O(n²) (``sharded_neighbor_stats``),
  * the CSR-emit variant ``sharded_csr_emit`` compacts every shard's
    survivors into per-row capacity slots (``ref.eps_compact_tile``; the
    fused emit kernels on real TPUs) and all-gathers only those compacted
    pairs along "model" — O(n·cap) ≈ O(nnz) collective traffic.

The host FINEX build (Algorithm 2/3) streams the statistics, and
``sharded_csr_materialize`` assembles the gathered slot rows into the
exact CSR the single-device engine produces — the materialize step behind
``FinexIndex.build(..., mesh=...)``. These functions are the
``--arch finex`` / ``--arch finex-csr`` dry-run cells: they must lower +
compile on the 256-chip and 512-chip meshes like every LM cell.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 top-level API; 0.4.x keeps it in experimental
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.kernels import ref
from repro.metrics import MetricLike, get_metric
from repro.neighbors.engine import (CSRNeighborhoods, fill_slot_rows,
                                    screen_thresholds)
from repro.sharding import dp_axes


def _row_spec(a, axis_name):
    """PartitionSpec sharding axis 0 of ``a`` over ``axis_name`` —
    dataset-state arrays are row-aligned along axis 0 whatever their
    rank (vectors, packed bitmaps, size columns)."""
    return P(axis_name, *([None] * (a.ndim - 1)))


def _pad_rows(parts, n_pad):
    """Zero-pad every state array to ``n_pad`` rows (host side)."""
    out = []
    for a in parts:
        a = np.ascontiguousarray(np.asarray(a))
        padded = np.zeros((n_pad,) + a.shape[1:], dtype=a.dtype)
        padded[:a.shape[0]] = a
        out.append(padded)
    return tuple(out)


def sharded_neighbor_stats(x: jax.Array, y: jax.Array, w: jax.Array,
                           eps: jax.Array, edges: jax.Array, mesh: Mesh,
                           row_chunk: int = 2048
                           ) -> Tuple[jax.Array, jax.Array]:
    """Weighted |N_ε| counts + distance histograms for all query rows.

    x: (nq, d) queries, rows sharded over DP axes.
    y: (nc, d) corpus, rows sharded over "model".
    w: (nc,) duplicate weights, sharded with y.
    Returns (counts (nq,), hist (nq, B)) sharded like x's rows.
    """
    dp = dp_axes(mesh)
    nbins = edges.shape[0] - 1

    def local(xb, yb, wb, eps_s, edges_s):
        nq_l = xb.shape[0]
        n_chunks = max(1, nq_l // row_chunk)
        xc = xb.reshape(n_chunks, -1, xb.shape[-1])

        def chunk_stats(xrow):
            d = ref.pairwise_euclidean(xrow, yb)
            cnt = jnp.where(d <= eps_s, wb[None, :], 0.0).sum(-1)
            hist = ref.tile_histogram(d, edges_s).astype(jnp.float32)
            return cnt, hist

        cnt, hist = jax.lax.map(chunk_stats, xc)
        cnt = cnt.reshape(nq_l)
        hist = hist.reshape(nq_l, nbins)
        # one psum pair along the corpus axis — O(nq) traffic
        cnt = jax.lax.psum(cnt, "model")
        hist = jax.lax.psum(hist, "model")
        return cnt, hist

    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None), P("model", None), P("model"), P(), P()),
        out_specs=(P(dp), P(dp, None)))
    return fn(x, y, w, eps, edges)


def finex_dryrun_lowerable(mesh: Mesh, n: int = 1 << 20, d: int = 64,
                           nbins: int = 32, row_chunk: int = 2048):
    """(fn, args_sds, in_shardings) for the paper-workload dry-run cell."""
    dp = dp_axes(mesh)
    x = jax.ShapeDtypeStruct((n, d), jnp.float32)
    y = jax.ShapeDtypeStruct((n, d), jnp.float32)
    w = jax.ShapeDtypeStruct((n,), jnp.float32)
    eps = jax.ShapeDtypeStruct((), jnp.float32)
    edges = jax.ShapeDtypeStruct((nbins + 1,), jnp.float32)
    shardings = (NamedSharding(mesh, P(dp, None)),
                 NamedSharding(mesh, P("model", None)),
                 NamedSharding(mesh, P("model")),
                 NamedSharding(mesh, P()),
                 NamedSharding(mesh, P()))

    def fn(x, y, w, eps, edges):
        return sharded_neighbor_stats(x, y, w, eps, edges, mesh,
                                      row_chunk=row_chunk)

    return fn, (x, y, w, eps, edges), shardings


def sharded_csr_emit(q, c, eps: jax.Array, mesh: Mesh,
                     cap: int, row_chunk: int = 2048,
                     num_valid: int | None = None,
                     metric: MetricLike = "euclidean",
                     screen=None):
    """Sharded ε-compacted CSR emit: per-shard slots, gathered along "model".

    Each device sweeps its (rowblock × colblock) shard in ``row_chunk``
    tiles, compacts survivors into ``cap`` per-row slots with global
    column ids (``ref.eps_compact_tile``; the fused emit kernels on real
    TPUs), and all-gathers only the compacted slots along the corpus
    axis — O(nq·cap) ≈ O(nnz) collective traffic, never the O(nq·nc)
    plane.  The distance tile comes from ``metric.pairwise`` — the same
    traceable formula every registered metric already supplies — so the
    emit is metric-oblivious.

    q: query dataset state — one row-aligned array, or a tuple of them
       (e.g. (bits, sizes) for jaccard); rows sharded over the DP axes.
    c: corpus state, rows sharded over "model" (the corpus extent may be
       padded; ``num_valid`` masks the padding by global column id —
       padding *content* never matters, only the id mask).
    screen: optional projection-prune triple ``(sq, sc, s2t)`` — float32
       screen embeddings row-aligned with q and c plus the squared
       screen-space pair threshold (see ``engine.screen_thresholds``).
       Each (chunk × corpus-shard) tile then evaluates the device bound
       kernel (``ref.bound_min2_tile``) *first*: tiles whose min² screen
       distance exceeds the threshold skip the distance plane via
       ``lax.cond`` (the bound stays device-resident — only the scalar
       predicate is consumed), and surviving tiles emit with the
       provably-impossible pairs masked to inf.  The slots stay
       byte-identical to the unscreened emit (lower-bound contract).
    Returns (lens (M, nq) int32, cols (M, nq, cap) int32,
    dvals (M, nq, cap) float32) with M = the "model" axis size and rows
    sharded like q — shard m holding each row's survivors from corpus
    block m, ascending by column id, so concatenating the shard segments
    in m-order reproduces the single-device row order exactly.
    """
    m = get_metric(metric)
    dp = dp_axes(mesh)
    q_parts = q if isinstance(q, tuple) else (q,)
    c_parts = c if isinstance(c, tuple) else (c,)
    nq_parts = len(q_parts)
    nc_parts = len(c_parts)
    n_total = int(c_parts[0].shape[0]) if num_valid is None else int(num_valid)
    if screen is not None:
        # thread the screen embeddings through the same row-aligned
        # plumbing as the dataset state arrays
        sq, sc, s2t = screen
        s2t = jnp.float32(s2t)
        q_parts = q_parts + (jnp.asarray(sq, jnp.float32),)
        c_parts = c_parts + (jnp.asarray(sc, jnp.float32),)

    def local(eps_s, *parts):
        qb = parts[:len(q_parts)]
        cb = parts[len(q_parts):]
        cb_state, scb = (cb[:nc_parts], cb[-1]) if screen is not None \
            else (cb, None)
        nc_l = cb_state[0].shape[0]
        offset = jax.lax.axis_index("model") * nc_l
        rows = qb[0].shape[0]
        # pad the local rows up to whole chunks (padding rows sweep zero
        # state and are sliced off below) so any local extent tiles at
        # ~row_chunk granularity
        chunk_rows = min(row_chunk, rows)
        n_chunks = -(-rows // chunk_rows)
        pad = n_chunks * chunk_rows - rows
        if pad:
            qb = tuple(jnp.concatenate(
                [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)]) for a in qb)
        qc = tuple(a.reshape((n_chunks, chunk_rows) + a.shape[1:])
                   for a in qb)

        def chunk(qrow):
            if screen is None:
                d = m.pairwise(qrow, cb_state)
                return ref.eps_compact_tile(d, eps_s, cap,
                                            col_offset=offset,
                                            num_valid=n_total)
            qs, sq_row = qrow[:nq_parts], qrow[-1]
            # skip decision through the shared device bound kernel: the
            # tile's min² screen distance (stays device-resident — the
            # scalar compare feeds lax.cond directly, nothing crosses to
            # the host) against the slack-inflated pair threshold.
            # ``min(plane) <= s2t`` admits exactly when ``any(plane <=
            # s2t)`` does, so the emitted slots cannot change.
            tile_min2 = jnp.min(ref.bound_min2_tile(sq_row, scb))

            def emit(_):
                keep = ref.screen_sq_tile(sq_row, scb) <= s2t
                d = m.pairwise(qs, cb_state)
                return ref.eps_compact_tile(
                    jnp.where(keep, d, jnp.inf), eps_s, cap,
                    col_offset=offset, num_valid=n_total)

            def skip(_):
                # bound excluded the whole tile: the distance plane is
                # never computed; zero slots are what eps_compact_tile
                # emits for a hitless tile, so the gather stays identical
                return (jnp.zeros((chunk_rows,), jnp.int32),
                        jnp.zeros((chunk_rows, cap), jnp.int32),
                        jnp.zeros((chunk_rows, cap), jnp.float32))

            return jax.lax.cond(tile_min2 <= s2t, emit, skip, 0)

        lens, cols, dvals = jax.lax.map(chunk, qc)
        lens = lens.reshape(-1)[:rows]
        cols = cols.reshape(-1, cap)[:rows]
        dvals = dvals.reshape(-1, cap)[:rows]
        # the only collective: compacted slots, O(rows·cap) per device
        return (jax.lax.all_gather(lens, "model"),
                jax.lax.all_gather(cols, "model"),
                jax.lax.all_gather(dvals, "model"))

    # the outputs ARE replicated over "model" (they are all_gathers along
    # it), but the static replication checker cannot infer that through
    # lax.map + the compaction scatter, so it must be disabled
    # (check_rep= on jax 0.4/0.5, renamed check_vma= later)
    specs = dict(mesh=mesh,
                 in_specs=(P(),
                           *[_row_spec(a, dp) for a in q_parts],
                           *[_row_spec(a, "model") for a in c_parts]),
                 out_specs=(P(None, dp), P(None, dp, None),
                            P(None, dp, None)))
    try:
        fn = _shard_map(local, check_rep=False, **specs)
    except TypeError:
        fn = _shard_map(local, check_vma=False, **specs)
    return fn(eps, *q_parts, *c_parts)


def sharded_csr_materialize(data, eps: float, mesh: Mesh, cap: int = 1024,
                            row_chunk: int = 2048,
                            metric: MetricLike = "euclidean",
                            prune: str = "auto",
                            screen_k: int = 8) -> CSRNeighborhoods:
    """Multi-device materialize: sharded CSR-emit → host CSR assembly.

    Canonicalizes ``data`` through the metric, pads rows/corpus to the
    mesh extents, runs :func:`sharded_csr_emit`, and stitches the
    gathered per-shard slot rows into one CSR that is byte-identical to
    ``NeighborEngine.materialize`` on the same data — the sharded entry
    into ``FinexIndex.build(..., mesh=...)``, for every registered
    metric.

    When the metric declares a projection bound (``Metric.project``) and
    ``prune`` is not "off", the dataset is projected once on the host
    and the emit runs projection-pruned: shard tiles whose pair bound
    rules out every pair skip their distance plane entirely.  The CSR is
    byte-identical either way.

    ``cap`` bounds each row's survivors *per corpus shard*; the function
    refuses (rather than silently truncates) when a row overflows it.
    """
    m = get_metric(metric)
    canon = m.canonicalize(data)
    n = int(canon[0].shape[0])
    dp_total = int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))
    model = int(mesh.shape["model"])
    nq_pad = n + (-n) % dp_total
    nc_pad = n + (-n) % model
    xq = tuple(jnp.asarray(a) for a in _pad_rows(canon, nq_pad))
    yc = tuple(jnp.asarray(a) for a in _pad_rows(canon, nc_pad))
    screen = None
    if prune != "off":
        E = m.project(canon, screen_k)
        if E is not None:
            E = np.asarray(E, dtype=np.float64)
            E = E - (E.mean(axis=0, keepdims=True) if n else 0.0)
            m2 = float(np.max(np.sum(E * E, axis=1))) if n else 0.0
            _s_t, s2t = screen_thresholds(m, eps, 2.0 * np.sqrt(m2) + 1.0,
                                          m2)
            E32 = np.ascontiguousarray(E, dtype=np.float32)
            # padding embeddings are zeros: padded *queries* can only add
            # slots past row n (sliced off), padded *corpus* hits are
            # masked by num_valid inside the emit
            screen = (_pad_rows((E32,), nq_pad)[0],
                      _pad_rows((E32,), nc_pad)[0], s2t)
    with mesh:
        lens_g, cols_g, dvals_g = sharded_csr_emit(
            xq, yc, jnp.float32(eps), mesh,
            cap=cap, row_chunk=row_chunk, num_valid=n, metric=m,
            screen=screen)
    lens = np.asarray(lens_g)[:, :n].astype(np.int64)     # (M, n)
    if (lens > cap).any():
        raise ValueError(
            f"sharded CSR-emit capacity {cap} overflowed (longest per-shard "
            f"row has {int(lens.max())} neighbors); re-run with a larger "
            "cap= — the emit never silently truncates")
    cols = np.asarray(cols_g)[:, :n]
    dvals = np.asarray(dvals_g)[:, :n]
    row_total = lens.sum(axis=0)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(row_total, out=indptr[1:])
    nnz = int(indptr[-1])
    # destination of shard m's segment within row r: row base + the
    # lengths of the lower shards (ascending column blocks)
    shard_base = indptr[:-1][None, :] + (np.cumsum(lens, axis=0) - lens)
    indices = np.empty(nnz, dtype=np.int32)
    dists = np.empty(nnz, dtype=np.float32)
    fill_slot_rows(indices, dists, shard_base, lens, cols, dvals)
    return CSRNeighborhoods(indptr=indptr, indices=indices, dists=dists,
                            eps=float(eps))


def finex_csr_dryrun_lowerable(mesh: Mesh, n: int = 1 << 20, d: int = 64,
                               cap: int = 128, row_chunk: int = 2048):
    """CSR-emit dry-run cell: the paper workload's sharded materialize."""
    dp = dp_axes(mesh)
    x = jax.ShapeDtypeStruct((n, d), jnp.float32)
    y = jax.ShapeDtypeStruct((n, d), jnp.float32)
    eps = jax.ShapeDtypeStruct((), jnp.float32)
    shardings = (NamedSharding(mesh, P(dp, None)),
                 NamedSharding(mesh, P("model", None)),
                 NamedSharding(mesh, P()))

    def fn(x, y, eps):
        return sharded_csr_emit(x, y, eps, mesh, cap=cap,
                                row_chunk=row_chunk)

    return fn, (x, y, eps), shardings


def sharded_jaccard_counts(bits_q, sizes_q, bits_c, sizes_c, w, eps,
                           mesh: Mesh, row_chunk: int = 2048) -> jax.Array:
    """Weighted |N_ε| counts under Jaccard over the production mesh —
    the set-data (process mining) variant of the neighborhood plane."""
    dp = dp_axes(mesh)

    def local(bq, sq, bc, sc, wb, eps_s):
        n_chunks = max(1, bq.shape[0] // row_chunk)
        bqc = bq.reshape(n_chunks, -1, bq.shape[-1])
        sqc = sq.reshape(n_chunks, -1)

        def chunk(args):
            b, s = args
            d = ref.jaccard_distance(b, s, bc, sc)
            return jnp.where(d <= eps_s, wb[None, :], 0.0).sum(-1)

        cnt = jax.lax.map(chunk, (bqc, sqc)).reshape(bq.shape[0])
        return jax.lax.psum(cnt, "model")

    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None), P(dp), P("model", None), P("model"),
                  P("model"), P()),
        out_specs=P(dp))
    return fn(bits_q, sizes_q, bits_c, sizes_c, w, eps)


def finex_jaccard_dryrun_lowerable(mesh: Mesh, n: int = 1 << 20,
                                   words: int = 64, row_chunk: int = 2048):
    """Set-data FINEX plane: 1M packed 2048-token-universe bitmaps."""
    dp = dp_axes(mesh)
    bits = jax.ShapeDtypeStruct((n, words), jnp.uint32)
    sizes = jax.ShapeDtypeStruct((n,), jnp.int32)
    w = jax.ShapeDtypeStruct((n,), jnp.float32)
    eps = jax.ShapeDtypeStruct((), jnp.float32)
    shardings = (NamedSharding(mesh, P(dp, None)),
                 NamedSharding(mesh, P(dp)),
                 NamedSharding(mesh, P("model", None)),
                 NamedSharding(mesh, P("model")),
                 NamedSharding(mesh, P("model")),
                 NamedSharding(mesh, P()))

    def fn(bq, sq, bc, sc, w, eps):
        return sharded_jaccard_counts(bq, sq, bc, sc, w, eps, mesh,
                                      row_chunk=row_chunk)

    return fn, (bits, sizes, bits, sizes, w, eps), shardings
