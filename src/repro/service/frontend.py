"""``ServiceFrontend`` — concurrent intake for the clustering service.

The synchronous ``ClusterService.run`` loop answers a list of requests;
this module is what absorbs *traffic*: N client threads call
``submit(request)`` and get a ``concurrent.futures.Future`` back, a
bounded intake queue applies admission control (reject-with-backpressure
beyond ``max_queue``, per-index in-flight caps), and one dispatcher
thread drains the queue in windows, handing each index's window to a
worker pool.

Requests address indexes by **logical name**, not by dataset: mutations
change the dataset fingerprint, so data-addressed lookups would detach
from a mutated index mid-stream.  A ``BuildOp`` binds a name to the
index the ``IndexStore`` resolves for its (data, ε, MinPts) — builds
still dedupe store-wide by fingerprint — and every later op routes
through the name.

Window semantics (the coalescing contract):

  * Per window and per index, ops apply **builds → mutations → reads**;
    across windows, submission order.  The frontend serializes windows
    per index (a name is never in two workers at once), so per-name
    submission order is a total order over windows.
  * Adjacent same-op ``MutateRequest`` runs coalesce into ONE facade
    delta — K single-point inserts become one K-row batched splice (one
    strip sweep, one CSR splice, one component re-sweep), the win
    ``benchmarks/service_bench.py`` measures.  Delete ids are
    interpreted against the index state after the preceding coalesced
    batches of the same window, exactly as sequential application would.
  * All reads of a window run after its mutations as ONE
    ``SweepPlanner`` batch, and every response carries the index's
    monotone ``version`` — a client that saw its mutation acknowledged
    at version v never reads an older state afterwards.

Responses are byte-identical to replaying the *effective* per-index op
sequence through a bare facade sequentially (``record_ops=True`` records
that sequence for tests — ``tests/test_frontend.py`` pins the identity
across metrics under randomized 4-thread interleavings).

Lifecycle: ``shutdown(drain=True, timeout=...)`` refuses new submits,
flushes in-flight windows up to the drain deadline, then fails whatever
is still queued with ``AdmissionError``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro import obs
from repro.core.queries import ClusteringResult, normalize_settings
from repro.metrics import MetricLike
from repro.service.planner import Setting, SweepPlanner
from repro.service.store import IndexKey, IndexStore


class AdmissionError(RuntimeError):
    """Backpressure signal: the intake queue (or a per-index in-flight
    cap) is full, or the frontend is draining.  Clients retry later or
    shed load — the request was never enqueued."""


# ------------------------------------------------------------- requests
@dataclass
class BuildOp:
    """Bind ``index`` (a logical name) to the store's index for
    (data, ε, MinPts) — building it if it is neither resident nor
    spilled."""
    index: str
    data: Any
    eps: float
    minpts: int
    metric: MetricLike = "euclidean"
    weights: Optional[np.ndarray] = None


@dataclass
class ClusterOp:
    """One labeling of ``index``: the generating pair, or one
    ("eps"|"minpts", value) setting."""
    index: str
    setting: Optional[Setting] = None


@dataclass
class SweepOp:
    """K settings against ``index``, answered as one (K, n) matrix.
    Settings are typed (``Eps``/``MinPts``/``Hierarchy``) or bare
    ``(kind, value)`` pairs — see ``repro.core.queries``."""
    index: str
    settings: Sequence[Setting] = field(default_factory=list)


@dataclass
class HierarchyOp:
    """The all-scales verb: one stability-extracted labeling from
    ``index``'s condensed cluster tree (``FinexIndex.hierarchy``).  The
    tree is built once per index version and cached on the facade, so a
    warm serving index answers this with zero distance work."""
    index: str
    min_cluster_weight: Optional[int] = None


@dataclass
class MutateRequest:
    """Insert (``points``) or delete (``ids``) against ``index``.

    ``points`` must be batch-shaped for the index's metric (an (m, d)
    array for vector metrics; a packed-sets tuple for jaccard).  The
    dispatcher coalesces adjacent same-op mutations of one window into
    a single batched facade delta; riders of a coalesced batch share
    its report and post-mutation ``version``.
    """
    index: str
    op: str                                   # "insert" | "delete"
    points: Any = None
    ids: Optional[np.ndarray] = None
    weights: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.op not in ("insert", "delete"):
            raise ValueError(f"MutateRequest.op must be 'insert' or "
                             f"'delete', got {self.op!r}")
        if self.op == "insert" and self.points is None:
            raise ValueError("insert MutateRequest needs points")
        if self.op == "delete" and self.ids is None:
            raise ValueError("delete MutateRequest needs ids")


@dataclass
class StatsOp:
    """The Stats verb: resolves to the frontend's full stats dict."""


# ------------------------------------------------------------ responses
@dataclass
class BuildResult:
    index: str
    outcome: str                              # "hit" | "reload" | "build"
    key: IndexKey
    version: int
    n: int


# read responses are the unified ``ClusteringResult`` (an ndarray of
# labels carrying index name, version and query kind); the old dataclass
# name survives as an alias for one deprecation cycle so existing
# ``isinstance(res, SweepResult)`` / ``res.labels`` / ``res.index``
# call sites keep working unchanged
SweepResult = ClusteringResult


@dataclass
class MutateResult:
    index: str
    op: str
    count: int                    # this request's own rows/ids
    version: int                  # post-batch (shared by riders)
    riders: int                   # requests coalesced into the batch
    report: dict                  # the facade's delta report (shared)


class _Item:
    __slots__ = ("req", "future", "name", "seq", "t_submit")

    def __init__(self, req, future, name, seq):
        self.req = req
        self.future = future
        self.name = name
        self.seq = seq
        self.t_submit = time.perf_counter()


class _Entry:
    """One logical index binding: the facade object + its current store
    key (refreshed by ``rekey`` after every mutated window)."""
    __slots__ = ("index", "key")

    def __init__(self, index, key):
        self.index = index
        self.key = key


def _concat_points(parts: List[Any]) -> Any:
    if len(parts) == 1:
        return parts[0]
    if isinstance(parts[0], tuple):
        # multi-array canonical form (e.g. jaccard's (bits, sizes)):
        # concatenate componentwise along the object axis
        return tuple(np.concatenate([p[i] for p in parts], axis=0)
                     for i in range(len(parts[0])))
    return np.concatenate([np.asarray(p) for p in parts], axis=0)


def _rows_of(points: Any) -> int:
    if isinstance(points, tuple):
        return int(points[0].shape[0])
    return int(np.asarray(points).shape[0])


_DEFAULT_THRESHOLDS = {
    # latched ObsWarnings when the p95 of these drifts past the limit —
    # conservative defaults, override via the ``thresholds`` ctor arg
    "span.frontend.window": 5.0,
    "span.frontend.sweep": 5.0,
    "frontend.e2e_s": 10.0,
}


class ServiceFrontend:
    """Concurrent serving front-end over an ``IndexStore``.

    ``submit(op) -> Future``; see the module docstring for the window
    semantics.  ``workers`` sizes the group pool, ``window`` bounds how
    many queued ops one dispatch round may take, ``max_queue`` bounds
    the intake queue (admission control), ``max_inflight`` optionally
    caps unfinished ops per index name.  ``slack`` configures
    ``FinexIndex.enable_slack`` on every index the frontend binds (0 or
    None keeps packed splices).  ``record_ops=True`` keeps a per-name
    oplog of the effective (coalesced) operations for sequential-replay
    verification.
    """

    def __init__(self, store: Optional[IndexStore] = None, *,
                 workers: int = 2, window: int = 16, max_queue: int = 256,
                 max_inflight: Optional[int] = None,
                 slack: Optional[float] = 1.5,
                 capacity: int = 8, manager=None,
                 record_ops: bool = False,
                 thresholds: Optional[Dict[str, float]] = None,
                 autostart: bool = True):
        self.store = store if store is not None else IndexStore(
            capacity=capacity, manager=manager)
        self.workers = max(1, int(workers))
        self.window = max(1, int(window))
        self.max_queue = int(max_queue)
        self.max_inflight = (None if max_inflight is None
                             else int(max_inflight))
        self._slack = ({"slack": float(slack)}
                       if slack is not None and slack > 1.0 else None)
        self._cv = threading.Condition()
        self._queue: Deque[_Item] = deque()
        self._deferred: Deque[_Item] = deque()   # held back: name was busy
        self._busy: Set[str] = set()             # names inside a worker
        self._inflight: Dict[str, int] = {}      # name -> unfinished ops
        self._entries: Dict[str, _Entry] = {}
        self._seq = 0
        self._paused = False
        self._closed = False
        self._stop = False
        # ---- counters (mutated under self._cv) ----
        self.submitted = 0
        self.rejected = 0
        self.completed = 0
        self.failed = 0
        self.windows = 0
        self.batched_deltas = 0        # coalesced facade mutations applied
        self.coalesced_mutations = 0   # mutate ops that RODE a shared delta
        self.batched_sweeps = 0
        self.settings_answered = 0
        self.oplog: Optional[Dict[str, list]] = {} if record_ops else None
        for nm, limit in (thresholds if thresholds is not None
                          else _DEFAULT_THRESHOLDS).items():
            obs.set_threshold(nm, limit, "p95")
        self._pool = ThreadPoolExecutor(
            max_workers=self.workers, thread_name_prefix="finex-frontend")
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="finex-frontend-dispatch",
            daemon=True)
        if autostart:
            self._dispatcher.start()

    # ------------------------------------------------------------ intake
    def submit(self, req) -> Future:
        """Enqueue one op; raises ``AdmissionError`` instead of queueing
        unboundedly (backpressure is the client's signal to retry)."""
        name = getattr(req, "index", None)
        fut: Future = Future()
        with self._cv:
            if self._closed:
                self.rejected += 1
                if obs.enabled():
                    obs.count("frontend.rejected")
                raise AdmissionError(
                    "frontend is draining — no new submissions")
            if len(self._queue) + len(self._deferred) >= self.max_queue:
                self.rejected += 1
                if obs.enabled():
                    obs.count("frontend.rejected")
                    obs.count("frontend.rejected_queue_full")
                raise AdmissionError(
                    f"intake queue full ({self.max_queue} pending) — "
                    "retry with backoff")
            if (name is not None and self.max_inflight is not None
                    and self._inflight.get(name, 0) >= self.max_inflight):
                self.rejected += 1
                if obs.enabled():
                    obs.count("frontend.rejected")
                    obs.count("frontend.rejected_inflight")
                raise AdmissionError(
                    f"index {name!r} already has "
                    f"{self._inflight[name]} ops in flight "
                    f"(cap {self.max_inflight})")
            self._seq += 1
            item = _Item(req, fut, name, self._seq)
            self._queue.append(item)
            if name is not None:
                self._inflight[name] = self._inflight.get(name, 0) + 1
            self.submitted += 1
            depth = len(self._queue) + len(self._deferred)
            if obs.enabled():
                obs.count("frontend.submitted")
                obs.gauge("frontend.queue_depth", depth)
                obs.observe("frontend.queue_depth", depth)
            self._cv.notify_all()
        return fut

    # -------------------------------------------------------- dispatcher
    def start(self) -> None:
        """Start the dispatcher (no-op if ``autostart`` already did)."""
        if not self._dispatcher.is_alive():
            self._dispatcher.start()

    def pause(self) -> None:
        """Hold dispatching (submissions still enqueue) — lets tests and
        benchmarks stage a full window deterministically."""
        with self._cv:
            self._paused = True

    def resume(self) -> None:
        with self._cv:
            self._paused = False
            self._cv.notify_all()

    def _take_window_locked(self) -> List[_Item]:
        if self._paused:
            return []
        batch: List[_Item] = []
        skipped: List[_Item] = []
        blocked = set(self._busy)
        pending = list(self._deferred) + list(self._queue)
        self._deferred.clear()
        self._queue.clear()
        for it in pending:
            if (len(batch) >= self.window
                    or (it.name is not None and it.name in blocked)):
                skipped.append(it)
                if it.name is not None:
                    # later ops for a skipped name must skip too —
                    # per-name submission order is the contract
                    blocked.add(it.name)
                continue
            batch.append(it)
        self._deferred.extend(skipped)
        return batch

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                batch = self._take_window_locked()
                while not batch:
                    if self._stop:
                        return
                    self._cv.wait(0.1)
                    batch = self._take_window_locked()
                for it in batch:
                    if it.name is not None:
                        self._busy.add(it.name)
                self.windows += 1
                if obs.enabled():
                    obs.observe("frontend.window_size", len(batch))
            groups: Dict[str, List[_Item]] = {}
            stats_items: List[_Item] = []
            for it in batch:
                if it.name is None:
                    stats_items.append(it)
                else:
                    groups.setdefault(it.name, []).append(it)
            for name, items in groups.items():
                self._pool.submit(self._serve_group, name, items)
            for it in stats_items:
                # the Stats verb is cheap and lock-bounded: serve inline
                try:
                    self._resolve(it, self.stats())
                except BaseException as e:       # pragma: no cover
                    self._fail(it, e)

    # ------------------------------------------------------ group serving
    def _serve_group(self, name: str, items: List[_Item]) -> None:
        err: Optional[BaseException] = None
        try:
            with obs.span("frontend.window", index=name, size=len(items)):
                self._serve_group_impl(name, items)
        except BaseException as e:               # defensive: a bug here
            err = e                              # must not hang futures
        finally:
            for it in items:
                if not it.future.done():
                    self._fail(it, err if err is not None else
                               RuntimeError("request left unserved"))
            with self._cv:
                self._busy.discard(name)
                for it in items:
                    left = self._inflight.get(it.name, 0) - 1
                    if left > 0:
                        self._inflight[it.name] = left
                    else:
                        self._inflight.pop(it.name, None)
                self._cv.notify_all()

    def _serve_group_impl(self, name: str, items: List[_Item]) -> None:
        builds = [it for it in items if isinstance(it.req, BuildOp)]
        mutates = [it for it in items if isinstance(it.req, MutateRequest)]
        reads = [it for it in items
                 if isinstance(it.req, (SweepOp, ClusterOp, HierarchyOp))]
        for it in items:
            if not isinstance(it.req, (BuildOp, MutateRequest, SweepOp,
                                       ClusterOp, HierarchyOp)):
                self._fail(it, TypeError(
                    f"unsupported frontend request {type(it.req).__name__}"))
        entry = self._entries.get(name)
        for it in builds:
            entry = self._serve_build(name, it) or entry
        if mutates:
            entry = self._serve_mutations(name, entry, mutates)
        if reads:
            self._serve_reads(name, entry, reads)

    def _serve_build(self, name: str, it: _Item) -> Optional[_Entry]:
        r = it.req
        try:
            index, outcome = self.store.get_or_build(
                r.data, r.eps, r.minpts, metric=r.metric,
                weights=r.weights)
            if (self._slack is not None and index.engine is not None
                    and not index.slack_enabled):
                index.enable_slack(**self._slack)
            entry = _Entry(index, IndexKey.of_index(index))
        except BaseException as e:
            self._fail(it, e)
            return None
        self._entries[name] = entry
        if self.oplog is not None:
            self.oplog.setdefault(name, []).append(("build", r))
        self._resolve(it, BuildResult(
            index=name, outcome=outcome, key=entry.key,
            version=index.version, n=index.n))
        return entry

    def _serve_mutations(self, name: str, entry: Optional[_Entry],
                         mutates: List[_Item]) -> Optional[_Entry]:
        if entry is None:
            for it in mutates:
                self._fail(it, ValueError(
                    f"unknown index {name!r} — submit a BuildOp first"))
            return None
        # maximal adjacent same-op runs, in submission order
        runs: List[Tuple[str, List[_Item]]] = []
        for it in mutates:
            if runs and runs[-1][0] == it.req.op:
                runs[-1][1].append(it)
            else:
                runs.append((it.req.op, [it]))
        mutated = False
        for op, riders in runs:
            with obs.span("frontend.mutate", index=name, op=op,
                          riders=len(riders)):
                ok = (self._apply_insert_run(name, entry, riders)
                      if op == "insert"
                      else self._apply_delete_run(name, entry, riders))
            mutated = mutated or ok
        if mutated:
            # the mutation changed the dataset fingerprint: re-admit the
            # index under its post-mutation identity so store lookups
            # (and spills) stay exact
            entry.key = self.store.rekey(entry.index)
        return entry

    def _apply_insert_run(self, name, entry, riders) -> bool:
        parts = [it.req.points for it in riders]
        counts = [_rows_of(p) for p in parts]
        points = _concat_points(parts)
        wparts = [it.req.weights for it in riders]
        if any(w is not None for w in wparts):
            weights = np.concatenate([
                np.asarray(w, dtype=np.int64) if w is not None
                else np.ones(c, dtype=np.int64)
                for w, c in zip(wparts, counts)])
        else:
            weights = None
        try:
            report = entry.index.insert(points, weights=weights)
        except BaseException as e:
            for it in riders:
                self._fail(it, e)
            return False
        with self._cv:
            self.batched_deltas += 1
            self.coalesced_mutations += len(riders) - 1
        if obs.enabled() and len(riders) > 1:
            obs.count("frontend.coalesced_mutations", len(riders) - 1)
        if self.oplog is not None:
            self.oplog.setdefault(name, []).append(
                ("insert", points, weights, [it.req for it in riders]))
        for it, c in zip(riders, counts):
            self._resolve(it, MutateResult(
                index=name, op="insert", count=c,
                version=report["version"], riders=len(riders),
                report=dict(report)))
        return True

    def _apply_delete_run(self, name, entry, riders) -> bool:
        id_parts = [np.asarray(it.req.ids, dtype=np.int64).ravel()
                    for it in riders]
        ids = np.unique(np.concatenate(id_parts))
        try:
            report = entry.index.delete(ids)
        except BaseException as e:
            for it in riders:
                self._fail(it, e)
            return False
        with self._cv:
            self.batched_deltas += 1
            self.coalesced_mutations += len(riders) - 1
        if obs.enabled() and len(riders) > 1:
            obs.count("frontend.coalesced_mutations", len(riders) - 1)
        if self.oplog is not None:
            self.oplog.setdefault(name, []).append(
                ("delete", ids, None, [it.req for it in riders]))
        for it, part in zip(riders, id_parts):
            self._resolve(it, MutateResult(
                index=name, op="delete", count=int(part.size),
                version=report["version"], riders=len(riders),
                report=dict(report)))
        return True

    def _serve_reads(self, name: str, entry: Optional[_Entry],
                     reads: List[_Item]) -> None:
        if entry is None:
            for it in reads:
                self._fail(it, ValueError(
                    f"unknown index {name!r} — submit a BuildOp first"))
            return
        index = entry.index
        settings: List[Setting] = []
        spans: List[Tuple[_Item, int, int]] = []
        for it in reads:
            reqs = self._settings_of(index, it.req)
            spans.append((it, len(settings), len(settings) + len(reqs)))
            settings.extend(reqs)
        version = index.version
        try:
            with obs.span("frontend.sweep", index=name,
                          settings=len(settings)):
                labels = SweepPlanner(index).sweep(settings)
        except BaseException:
            # one invalid setting poisons the whole batch: re-serve each
            # request alone so the bad one fails and the rest answer
            for it, lo, hi in spans:
                sub = settings[lo:hi]
                try:
                    lab = SweepPlanner(index).sweep(sub)
                except BaseException as e:
                    self._fail(it, e)
                    continue
                if self.oplog is not None:
                    self.oplog.setdefault(name, []).append(
                        ("sweep", sub, [(it.req, 0, len(sub))]))
                self._finish_read(name, it, lab, 0, len(sub), version)
            return
        with self._cv:
            self.batched_sweeps += 1
            self.settings_answered += len(settings)
        if self.oplog is not None:
            self.oplog.setdefault(name, []).append(
                ("sweep", list(settings),
                 [(it.req, lo, hi) for it, lo, hi in spans]))
        for it, lo, hi in spans:
            self._finish_read(name, it, labels, lo, hi, version)

    def _finish_read(self, name, it, labels, lo, hi, version) -> None:
        # .copy(): results must not pin the whole window matrix
        req = it.req
        settings = None
        if isinstance(req, ClusterOp):
            out = np.asarray(labels)[lo].copy()
            if req.setting is None:
                kind, value = "generating", None
            else:
                kind, value = normalize_settings([req.setting])[0]
        elif isinstance(req, HierarchyOp):
            out = np.asarray(labels)[lo].copy()
            kind, value = "hierarchy", int(req.min_cluster_weight or 0)
        else:
            out = np.asarray(labels)[lo:hi].copy()
            kind, value = "sweep", None
            settings = normalize_settings(list(req.settings))
        self._resolve(it, ClusteringResult.wrap(
            out, kind=kind, value=value, version=version,
            settings=settings, index_name=name))

    @staticmethod
    def _settings_of(index, req) -> List[Setting]:
        if isinstance(req, SweepOp):
            return list(req.settings)
        if isinstance(req, HierarchyOp):
            return [("hierarchy", int(req.min_cluster_weight or 0))]
        # a generating-pair ClusterOp is the degenerate MinPts*-query
        # MinPts* = MinPts, so it coalesces like everything else
        return [req.setting if req.setting is not None
                else ("minpts", index.minpts)]

    # -------------------------------------------------------- resolution
    def _resolve(self, it: _Item, result) -> None:
        it.future.set_result(result)
        with self._cv:
            self.completed += 1
        if obs.enabled():
            obs.count("frontend.completed")
            obs.observe("frontend.e2e_s",
                        time.perf_counter() - it.t_submit)

    def _fail(self, it: _Item, exc: BaseException) -> None:
        if it.future.done():
            return
        it.future.set_exception(exc)
        with self._cv:
            self.failed += 1
        if obs.enabled():
            obs.count("frontend.failed")

    # --------------------------------------------------------- lifecycle
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until nothing is queued, deferred, busy or in flight.
        Returns False if ``timeout`` elapsed first."""
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        with self._cv:
            while (self._queue or self._deferred or self._busy
                   or self._inflight):
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    return False
                self._cv.wait(remaining if remaining is not None else 0.2)
            return True

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> bool:
        """Graceful stop: refuse new submits, flush in-flight windows
        (up to the drain deadline), fail whatever is left with
        ``AdmissionError``, stop the dispatcher and the pool.  Returns
        True iff every accepted request was served (nothing was failed
        unserved)."""
        with self._cv:
            self._closed = True
            self._paused = False            # a paused frontend must flush
            self._cv.notify_all()
        drained = self.drain(timeout) if drain else False
        with self._cv:
            self._stop = True
            leftovers = list(self._deferred) + list(self._queue)
            self._deferred.clear()
            self._queue.clear()
            self._cv.notify_all()
        for it in leftovers:
            self._fail(it, AdmissionError(
                "frontend shut down before serving this request"))
        if self._dispatcher.is_alive():
            self._dispatcher.join(timeout=5.0)
        self._pool.shutdown(wait=True)
        if obs.enabled():
            obs.count("frontend.shutdowns")
        return (drained if drain else not leftovers)

    def __enter__(self) -> "ServiceFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(drain=True)

    # ------------------------------------------------------------- stats
    def stats(self) -> Dict[str, object]:
        """The Stats verb payload: frontend counters + per-index
        bindings + store + the process obs snapshot (whose windows carry
        ``frontend.queue_depth`` / ``frontend.e2e_s`` p95s)."""
        with self._cv:
            front = {
                "workers": self.workers,
                "window": self.window,
                "max_queue": self.max_queue,
                "submitted": self.submitted,
                "rejected": self.rejected,
                "completed": self.completed,
                "failed": self.failed,
                "windows": self.windows,
                "batched_deltas": self.batched_deltas,
                "coalesced_mutations": self.coalesced_mutations,
                "batched_sweeps": self.batched_sweeps,
                "settings_answered": self.settings_answered,
                "queue_depth": len(self._queue) + len(self._deferred),
                "inflight": dict(self._inflight),
                "busy": len(self._busy),
            }
            entries = dict(self._entries)
        return {
            "frontend": front,
            "indexes": {
                nm: {"version": e.index.version, "n": e.index.n,
                     "eps": e.index.eps, "minpts": e.index.minpts,
                     "slack": e.index.slack_stats(),
                     "hierarchy": e.index.hierarchy_stats()}
                for nm, e in entries.items()},
            "store": self.store.stats(),
            "telemetry": obs.snapshot(),
        }
