"""``SweepPlanner`` — K parameter settings against one index, batched.

The paper's interactive workflow is "test various settings until a
satisfying clustering is found"; each probe is an ε*- or MinPts*-query.
Answering a grid one scalar facade call at a time repeats the
setting-independent work (Algorithm-1 scan inputs, the exact sparse
clustering, verification distance sub-matrices, the core-graph
traversal). The planner routes a mixed grid through the batched kernels
(``eps_star_batch`` / ``minpts_star_batch`` in ``repro.core.queries``)
that share all of it, and returns a (K, n) label matrix in request
order — row k byte-identical to the scalar query for settings[k].

    planner = SweepPlanner(index)
    labels = planner.sweep([("eps", 0.2), ("minpts", 60), ("eps", 0.3)])
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core.index import FinexIndex
from repro.core.queries import QueryStats, eps_star_batch, minpts_star_batch

# a sweep setting: ("eps", ε* ≤ ε) or ("minpts", MinPts* ≥ MinPts)
Setting = Tuple[str, float]


class SweepPlanner:
    """Batched query executor over one built ``FinexIndex``."""

    def __init__(self, index: FinexIndex):
        self.index = index

    def eps_grid(self, values: Sequence[float]) -> List[Setting]:
        return [("eps", float(v)) for v in values]

    def minpts_grid(self, values: Sequence[int]) -> List[Setting]:
        return [("minpts", int(v)) for v in values]

    def sweep(self, settings: Sequence[Setting],
              stats: Optional[QueryStats] = None) -> np.ndarray:
        """(K, n) exact labels for the K settings, in request order."""
        with obs.span("planner.sweep", k=len(settings),
                      n=self.index.n):
            return self._sweep_impl(settings, stats)

    def _sweep_impl(self, settings, stats=None) -> np.ndarray:
        # untraced body of :meth:`sweep`
        if stats is None:
            stats = self.index.query_stats
        eps_pos, eps_vals = [], []
        mp_pos, mp_vals = [], []
        for i, (kind, value) in enumerate(settings):
            if kind == "eps":
                eps_pos.append(i)
                eps_vals.append(float(value))
            elif kind == "minpts":
                mp_pos.append(i)
                mp_vals.append(int(value))
            else:
                raise ValueError(
                    f"unknown sweep setting kind {kind!r} at position {i} "
                    "(expected 'eps' or 'minpts')")
        if eps_vals and self.index.engine is None:
            raise RuntimeError(
                "ε*-sweeps need the distance engine for verification; "
                "load the index with its raw data (FinexIndex.load(..., "
                "data=...)) or sweep MinPts* settings only")
        out = np.empty((len(settings), self.index.n), dtype=np.int64)
        if eps_vals:
            out[eps_pos] = eps_star_batch(
                self.index.ordering, self.index.engine, eps_vals,
                stats=stats)
        if mp_vals:
            out[mp_pos] = minpts_star_batch(
                self.index.ordering, self.index.csr, mp_vals, stats=stats)
        return out
