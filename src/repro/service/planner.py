"""``SweepPlanner`` — K parameter settings against one index, batched.

The paper's interactive workflow is "test various settings until a
satisfying clustering is found"; each probe is an ε*- or MinPts*-query.
Answering a grid one scalar facade call at a time repeats the
setting-independent work (Algorithm-1 scan inputs, the exact sparse
clustering, verification distance sub-matrices, the core-graph
traversal). The planner routes a mixed grid through the batched kernels
(``eps_star_batch`` / ``minpts_star_batch`` in ``repro.core.queries``)
that share all of it, and returns a (K, n) label matrix in request
order — row k byte-identical to the scalar query for settings[k].

    planner = SweepPlanner(index)
    labels = planner.sweep([Eps(0.2), MinPts(60), ("eps", 0.3)])
    tree = planner.hierarchy()          # all (ε, MinPts) scales at once

Settings are the typed dataclasses from ``repro.core.queries`` (``Eps``
/ ``MinPts`` / ``Hierarchy``); bare ``("eps", v)`` tuples keep working
through ``normalize_settings``.  A ``Hierarchy`` row is the stability
extraction of the condensed cluster tree (built once per index version,
cached on the facade); the tree itself comes from :meth:`hierarchy`.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro import obs
from repro.core.hierarchy import ClusterHierarchy
from repro.core.index import FinexIndex
from repro.core.queries import (ClusteringResult, QueryStats, Setting,
                                eps_star_batch, minpts_star_batch,
                                normalize_settings)

__all__ = ["Setting", "SweepPlanner"]


class SweepPlanner:
    """Batched query executor over one built ``FinexIndex``."""

    def __init__(self, index: FinexIndex):
        self.index = index

    def eps_grid(self, values: Sequence[float]) -> List[Setting]:
        return [("eps", float(v)) for v in values]

    def minpts_grid(self, values: Sequence[int]) -> List[Setting]:
        return [("minpts", int(v)) for v in values]

    def hierarchy(self, min_cluster_weight: Optional[int] = None
                  ) -> ClusterHierarchy:
        """The index's condensed cluster tree (built/cached on the
        facade) — ``cut``/``cut_minpts`` slices answer any grid with
        zero distance computations."""
        return self.index.hierarchy(min_cluster_weight)

    def sweep(self, settings: Sequence[Setting],
              stats: Optional[QueryStats] = None) -> ClusteringResult:
        """(K, n) exact labels for the K settings, in request order.

        The result is a ``ClusteringResult`` (an ndarray carrying query
        kind, index version and the normalized settings) — row k is
        byte-identical to the scalar query for settings[k]; a
        ``("hierarchy", w)`` row is ``hierarchy(w or None).extract()``.
        """
        norm = normalize_settings(settings)
        with obs.span("planner.sweep", k=len(norm), n=self.index.n):
            labels = self._sweep_impl(norm, stats)
        return ClusteringResult.wrap(
            labels, kind="sweep", version=self.index.version,
            eps=self.index.eps, minpts=self.index.minpts, settings=norm)

    def _sweep_impl(self, settings, stats=None) -> np.ndarray:
        # untraced body of :meth:`sweep`; settings are normalized pairs
        if stats is None:
            stats = self.index.query_stats
        eps_pos, eps_vals = [], []
        mp_pos, mp_vals = [], []
        hier_pos, hier_vals = [], []
        for i, (kind, value) in enumerate(settings):
            if kind == "eps":
                eps_pos.append(i)
                eps_vals.append(float(value))
            elif kind == "minpts":
                mp_pos.append(i)
                mp_vals.append(int(value))
            else:        # normalize_settings admits exactly one more kind
                hier_pos.append(i)
                hier_vals.append(int(value))
        if eps_vals and self.index.engine is None:
            raise RuntimeError(
                "ε*-sweeps need the distance engine for verification; "
                "load the index with its raw data (FinexIndex.load(..., "
                "data=...)) or sweep MinPts* settings only")
        out = np.empty((len(settings), self.index.n), dtype=np.int64)
        if eps_vals:
            out[eps_pos] = eps_star_batch(
                self.index.ordering, self.index.engine, eps_vals,
                stats=stats)
        if mp_vals:
            out[mp_pos] = minpts_star_batch(
                self.index.ordering, self.index.csr, mp_vals, stats=stats)
        for i, w in zip(hier_pos, hier_vals):
            out[i] = self.index.hierarchy(w or None).extract()
        return out
