"""Interactive clustering service over ``FinexIndex`` (serving subsystem).

Three layers, composable or standalone:
  * ``IndexStore``     — LRU registry of built indexes keyed by dataset
                         fingerprint + generating (ε, MinPts), with disk
                         spill/reload through ``CheckpointManager``
  * ``SweepPlanner``   — K mixed ε*/MinPts* settings answered in batched
                         vectorized passes: one (K, n) label matrix
  * ``ClusterService`` — slot-batched request loop (build / cluster /
                         sweep / stats), coalescing same-index requests
  * ``ServiceFrontend`` — concurrent intake: ``submit(op) -> Future``,
                         bounded queue + admission control, windowed
                         dispatcher coalescing per-index mutations into
                         batched deltas, graceful drain/shutdown
"""
from repro.core.queries import (ClusteringResult, Eps, Hierarchy, MinPts,
                                normalize_settings)
from repro.service.store import IndexKey, IndexStore
from repro.service.planner import Setting, SweepPlanner
from repro.service.engine import (BuildRequest, ClusterRequest,
                                  ClusterService, ServiceRequest,
                                  StatsRequest, SweepRequest)
from repro.service.frontend import (AdmissionError, BuildOp, BuildResult,
                                    ClusterOp, HierarchyOp, MutateRequest,
                                    MutateResult, ServiceFrontend, StatsOp,
                                    SweepOp, SweepResult)

__all__ = [
    "IndexKey", "IndexStore",
    "Setting", "SweepPlanner",
    "Eps", "MinPts", "Hierarchy", "normalize_settings",
    "ClusteringResult",
    "BuildRequest", "ClusterRequest", "ClusterService", "ServiceRequest",
    "StatsRequest", "SweepRequest",
    "AdmissionError", "BuildOp", "BuildResult", "ClusterOp",
    "HierarchyOp", "MutateRequest", "MutateResult", "ServiceFrontend",
    "StatsOp", "SweepOp", "SweepResult",
]
