"""Interactive clustering service over ``FinexIndex`` (serving subsystem).

Three layers, composable or standalone:
  * ``IndexStore``     — LRU registry of built indexes keyed by dataset
                         fingerprint + generating (ε, MinPts), with disk
                         spill/reload through ``CheckpointManager``
  * ``SweepPlanner``   — K mixed ε*/MinPts* settings answered in batched
                         vectorized passes: one (K, n) label matrix
  * ``ClusterService`` — slot-batched request loop (build / cluster /
                         sweep / stats), coalescing same-index requests
"""
from repro.service.store import IndexKey, IndexStore
from repro.service.planner import Setting, SweepPlanner
from repro.service.engine import (BuildRequest, ClusterRequest,
                                  ClusterService, ServiceRequest,
                                  StatsRequest, SweepRequest)

__all__ = [
    "IndexKey", "IndexStore",
    "Setting", "SweepPlanner",
    "BuildRequest", "ClusterRequest", "ClusterService", "ServiceRequest",
    "StatsRequest", "SweepRequest",
]
