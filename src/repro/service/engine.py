"""``ClusterService`` — slot-batched clustering request loop.

The clustering analog of ``repro.serve.engine.ServeEngine``: a fixed
number of request slots drains a queue, and requests that land in the
same slot window against the same index are *coalesced* — all of their
parameter settings are answered by one ``SweepPlanner`` batch instead of
one query each. Index residency is delegated to the ``IndexStore``, so a
request against a warm index costs zero distance computations beyond
ε*-verification.

Request kinds (dataclasses, mirroring the serve Request pattern):
  * ``BuildRequest``   — ensure the index for (data, ε, MinPts) exists
  * ``ClusterRequest`` — one labeling: the generating pair, or a single
                         setting
  * ``SweepRequest``   — K settings, answered as one (K, n) matrix
  * ``StatsRequest``   — service + store counters snapshot

Settings are the typed dataclasses from ``repro.core.queries`` (``Eps``
/ ``MinPts`` / ``Hierarchy``) or bare ``(kind, value)`` pairs — the
planner normalizes both, so existing tuple callers are untouched; a
``Hierarchy`` setting answers with the condensed-tree stability
extraction (cached per index version on the facade).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Union

import numpy as np

from repro import obs
from repro.metrics import MetricLike
from repro.service.planner import Setting, SweepPlanner
from repro.service.store import IndexKey, IndexStore


@dataclass
class BuildRequest:
    data: Any
    eps: float
    minpts: int
    metric: MetricLike = "euclidean"
    weights: Optional[np.ndarray] = None
    # filled by the service
    key: Optional[IndexKey] = None
    outcome: str = ""                    # "hit" | "reload" | "build"
    done: bool = False


@dataclass
class ClusterRequest:
    data: Any
    eps: float
    minpts: int
    setting: Optional[Setting] = None    # None -> generating-pair labels
    metric: MetricLike = "euclidean"
    weights: Optional[np.ndarray] = None
    # filled by the service
    labels: Optional[np.ndarray] = None  # (n,)
    outcome: str = ""
    done: bool = False


@dataclass
class SweepRequest:
    data: Any
    eps: float
    minpts: int
    settings: Sequence[Setting] = field(default_factory=list)
    metric: MetricLike = "euclidean"
    weights: Optional[np.ndarray] = None
    # filled by the service
    labels: Optional[np.ndarray] = None  # (K, n), request order
    outcome: str = ""
    done: bool = False


@dataclass
class StatsRequest:
    result: Optional[Dict[str, object]] = None
    done: bool = False


ServiceRequest = Union[BuildRequest, ClusterRequest, SweepRequest,
                       StatsRequest]


class ClusterService:
    """Fixed-slot batched clustering engine over an ``IndexStore``."""

    def __init__(self, store: Optional[IndexStore] = None,
                 slots: int = 8, capacity: int = 4, manager=None,
                 stats_every: int = 0, stats_log=print):
        self.store = store if store is not None else IndexStore(
            capacity=capacity, manager=manager)
        self.slots = slots
        self.requests_served = 0
        self.settings_answered = 0
        self.batched_sweeps = 0        # planner batches actually executed
        self.coalesced_settings = 0    # settings that rode a shared batch
        # periodic stats line: every N served requests, one
        # ``stats_log(...)`` call summarizing the counters (0 = off)
        self.stats_every = int(stats_every)
        self.stats_log = stats_log
        self._next_stats_at = self.stats_every or None

    # ------------------------------------------------------------- loop
    def run(self, requests: Sequence[ServiceRequest]
            ) -> Sequence[ServiceRequest]:
        """Serve all requests to completion (slot window = batch)."""
        queue = list(requests)
        with obs.span("service.run", requests=len(queue)):
            while queue:
                if obs.enabled():
                    obs.gauge("service.queue_depth", len(queue))
                    obs.observe("service.queue_depth", len(queue))
                active = queue[:self.slots]
                queue = queue[len(active):]
                with obs.span("service.window", size=len(active)):
                    self._serve_window(active)
                self._maybe_log_stats()
        return requests

    def _maybe_log_stats(self) -> None:
        """Emit the periodic stats line once per ``stats_every`` served
        requests (crossing possibly several boundaries in one window)."""
        if not self.stats_every or self.stats_log is None:
            return
        if self.requests_served >= self._next_stats_at:
            while self._next_stats_at <= self.requests_served:
                self._next_stats_at += self.stats_every
            s = self.stats()
            st = s["store"]
            self.stats_log(
                f"[cluster-service] served={s['requests_served']} "
                f"settings={s['settings_answered']} "
                f"sweeps={s['batched_sweeps']} "
                f"coalesced={s['coalesced_settings']} "
                f"store hits={st['hits']} builds={st['builds']} "
                f"reloads={st['reloads']} spills={st['spills']}")

    def _serve_window(self, active: List[ServiceRequest]) -> None:
        # resolve indexes first: builds happen once per key per window
        groups: Dict[IndexKey, list] = {}
        stats_reqs: List[StatsRequest] = []
        for r in active:
            if isinstance(r, StatsRequest):
                stats_reqs.append(r)     # answered after the window's work
                continue
            index, outcome = self.store.get_or_build(
                r.data, r.eps, r.minpts, metric=r.metric, weights=r.weights)
            r.outcome = outcome
            if isinstance(r, BuildRequest):
                r.key = IndexKey.of_index(index)
                r.done = True
                self.requests_served += 1
                continue
            groups.setdefault(IndexKey.of_index(index),
                              [index, []])[1].append(r)

        # coalesce: one planner batch per index per window
        for index, members in groups.values():
            settings: List[Setting] = []
            spans = []
            for r in members:
                reqs = self._settings_of(index, r)
                spans.append((r, len(settings), len(settings) + len(reqs)))
                settings.extend(reqs)
            labels = SweepPlanner(index).sweep(settings)
            self.batched_sweeps += 1
            self.settings_answered += len(settings)
            if len(members) > 1:
                self.coalesced_settings += len(settings)
            for r, lo, hi in spans:
                # .copy(): results must not pin the whole window matrix
                # (np.asarray: request dataclasses keep plain label
                # arrays; the typed ClusteringResult is the planner's
                # and frontend's return surface)
                labs = np.asarray(labels)
                r.labels = (labs[lo].copy()
                            if isinstance(r, ClusterRequest)
                            else labs[lo:hi].copy())
                r.done = True
                self.requests_served += 1

        for r in stats_reqs:
            r.result = self.stats()
            r.done = True
            self.requests_served += 1

    @staticmethod
    def _settings_of(index, r) -> List[Setting]:
        if isinstance(r, SweepRequest):
            return list(r.settings)
        # a generating-pair ClusterRequest is the degenerate MinPts*-query
        # MinPts* = MinPts (fast path: identical to index.clustering()
        # labels with noise at -1), so it coalesces like everything else
        return [r.setting if r.setting is not None
                else ("minpts", index.minpts)]

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, object]:
        return {
            "requests_served": self.requests_served,
            "settings_answered": self.settings_answered,
            "batched_sweeps": self.batched_sweeps,
            "coalesced_settings": self.coalesced_settings,
            "store": self.store.stats(),
            # the process-wide observability snapshot (documented schema:
            # repro.obs.telemetry) — this is the service's Stats verb
            # payload, so a StatsRequest doubles as a /stats endpoint
            "telemetry": obs.snapshot(),
        }
