"""``IndexStore`` — the serving-side registry of built FINEX indexes.

A built index is the expensive artifact of this system (device tile sweep
+ host ordering sweep); every query against it is cheap. The store keeps
the hot indexes resident under an LRU bound, keyed by dataset fingerprint
plus generating (ε, MinPts), and spills evicted indexes to disk through
``CheckpointManager.save_index`` so they reload instead of rebuilding.

    store = IndexStore(capacity=4, manager=CheckpointManager("idx_cache"))
    index, outcome = store.get_or_build(x, eps=0.5, minpts=10)  # "build"
    index, outcome = store.get_or_build(x, eps=0.5, minpts=10)  # "hit"
    # ... capacity overflow spills LRU victims; a later get_or_build of a
    # spilled key is a "reload": npz read + engine re-attach (from the
    # dataset the caller just presented — the store retains no data), no
    # distances recomputed

A warm hit costs zero distance computations: the resident index answers
``clustering``/``minpts_star`` without touching the engine at all, and
ε*-queries only ever compute verification sub-matrices. A bare ``get``
reloads spilled indexes engine-less (MinPts*-queries and the linear scan
still work); use ``get_or_build`` with the dataset to re-attach.
"""
from __future__ import annotations

import threading
import weakref
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro import obs
from repro.core.index import FinexIndex
from repro.metrics import MetricLike, get_metric
from repro.neighbors.engine import dataset_fingerprint


@dataclass(frozen=True)
class IndexKey:
    """Identity of a built index: what data, at which generating pair.

    The metric is part of the identity through the fingerprint head
    (registry name + params), so the same bytes under different distance
    semantics key different indexes."""
    fingerprint: str
    eps: float
    minpts: int

    @classmethod
    def make(cls, data, eps: float, minpts: int,
             metric: MetricLike = "euclidean",
             weights: Optional[np.ndarray] = None) -> "IndexKey":
        # ε is canonicalized to the float32 distance domain, matching the
        # device tile sweep — 0.5 and np.float32(0.5) are the same index
        return cls(dataset_fingerprint(data, metric, weights=weights),
                   float(np.float32(eps)), int(minpts))

    @classmethod
    def of_index(cls, index: FinexIndex) -> "IndexKey":
        if index.fingerprint() is None:
            raise ValueError(
                "index carries no dataset fingerprint (archive predates "
                "fingerprinting) — rebuild or re-save it before storing")
        return cls(index.fingerprint(), float(np.float32(index.eps)),
                   index.minpts)


class IndexStore:
    """LRU-bounded index registry with disk spill through a checkpoint
    manager. ``capacity`` counts resident indexes; pass ``manager=None``
    to drop evicted indexes instead of spilling them.

    Thread-safe: every structural operation holds one RLock, and
    ``get_or_build`` is single-flight per key — concurrent requests for
    the same missing key elect one builder (the rest wait and return the
    built index as a "hit"), so a key is never double-built and a
    mid-construction index is never visible.

    Durable: with a manager attached, the spill map is mirrored to an
    atomically-published JSON catalog (``<manager.dir>/INDEX_CATALOG
    .json``) on every change and reloaded on construction — a new store
    (or process) answers previously-spilled keys as ``"reload"`` instead
    of rebuilding. ``forget`` removes entries decrementally (catalog and
    step artifacts included)."""

    CATALOG = "INDEX_CATALOG"

    def __init__(self, capacity: int = 4, manager=None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.manager = manager
        self._resident: "OrderedDict[IndexKey, FinexIndex]" = OrderedDict()
        self._spilled: Dict[IndexKey, int] = {}      # key -> manager step
        # (id(array), metric spec) -> (weakref, fingerprint): skips the
        # full-dataset hash when the same array object is presented again
        # under the same metric (every request in a service window hits
        # this path); one entry per metric per array, each dying with the
        # array through its own weakref finalizer
        self._fp_cache: Dict[int, tuple] = {}
        self._lock = threading.RLock()
        # single-flight gates: key -> Event held by the elected builder
        self._building: Dict[IndexKey, threading.Event] = {}
        self.hits = 0
        self.reloads = 0
        self.builds = 0
        self.spills = 0
        self.drops = 0
        self.stale_drops = 0       # refused-stale-spill subset of drops
        self.rekeys = 0
        self.build_waits = 0       # threads that waited on another's build
        if manager is not None:
            self._load_catalog()

    # ------------------------------------------------------------ lookup
    def __len__(self) -> int:
        with self._lock:
            return len(self._resident)

    def __contains__(self, key: IndexKey) -> bool:
        with self._lock:
            return key in self._resident or key in self._spilled

    def get(self, key: IndexKey) -> Optional[FinexIndex]:
        """Resident index for ``key``, reloading from spill if needed.
        Reloads are engine-less here (the store retains no datasets) —
        use :meth:`get_or_build` with the dataset to re-attach."""
        with self._lock:
            idx = self._resident.get(key)
            if idx is not None:
                self._resident.move_to_end(key)
                self.hits += 1
                return idx
            step = self._spilled.get(key)
        if step is None:
            return None
        return self._reload(key, step, data=None)

    def _reload(self, key: IndexKey, step: int, data) -> FinexIndex:
        # npz IO runs outside the lock; admission re-takes it
        with obs.span("store.reload", eps=key.eps, minpts=key.minpts):
            idx = self.manager.restore_index(step, data=data)
        with self._lock:
            self.reloads += 1
            self._admit(key, idx)
        return idx

    def get_or_build(self, data, eps: float, minpts: int, *,
                     metric: MetricLike = "euclidean",
                     weights: Optional[np.ndarray] = None,
                     **build_kw) -> Tuple[FinexIndex, str]:
        """Fetch or build the index for (data, ε, MinPts).

        Returns (index, outcome) with outcome one of "hit" (resident,
        zero distance computations), "reload" (spilled npz re-read) or
        "build" (full materialize + ordering sweep).
        """
        with obs.span("store.get_or_build", eps=float(eps),
                      minpts=int(minpts)) as sp:
            index, outcome = self._get_or_build_impl(
                data, eps, minpts, metric=metric, weights=weights,
                **build_kw)
            sp.annot(outcome=outcome)
            if obs.enabled():
                obs.count(f"store.{outcome}s")
                if outcome != "hit":
                    obs.count("store.misses")
        return index, outcome

    def _get_or_build_impl(self, data, eps, minpts, *,
                           metric="euclidean", weights=None,
                           **build_kw):
        # untraced body of :meth:`get_or_build`
        key = IndexKey(self._fingerprint_of(data, metric, weights),
                       float(np.float32(eps)), int(minpts))
        while True:
            with self._lock:
                idx = self._resident.get(key)
                if idx is not None:
                    self._resident.move_to_end(key)
                    self.hits += 1
                    return idx, "hit"
                gate = self._building.get(key)
                if gate is None:
                    # this thread is the elected builder for the key
                    self._building[key] = gate = threading.Event()
                    step = self._spilled.get(key)
                    break
                self.build_waits += 1
            # another thread holds the gate: wait for its admission,
            # then loop — normally the key is now resident ("hit"); if
            # eviction pressure already pushed it back out (or the build
            # failed), this thread becomes the next builder
            gate.wait()
        try:
            if step is not None:
                # the caller's dataset re-attaches the engine; the key
                # proves it is the dataset the spilled index was built over
                return self._reload(key, step, data=data), "reload"
            idx = FinexIndex.build(data, eps=eps, minpts=minpts,
                                   metric=metric, weights=weights,
                                   **build_kw)
            with self._lock:
                self.builds += 1
                self._admit(key, idx)
            return idx, "build"
        finally:
            with self._lock:
                self._building.pop(key, None)
            gate.set()

    def put(self, index: FinexIndex) -> IndexKey:
        """Register an externally built index (keyed by its fingerprint)."""
        key = IndexKey.of_index(index)
        with self._lock:
            self._admit(key, index)
        return key

    def rekey(self, index: FinexIndex) -> IndexKey:
        """Re-register a mutated index under its post-mutation identity.

        ``FinexIndex.insert``/``delete`` change the dataset fingerprint,
        so a resident entry would otherwise keep serving the mutated
        index under the *old* dataset's key — a ``get_or_build`` for the
        original data would return wrong clusterings. Call this after
        mutating a stored index: every resident entry holding this index
        object is invalidated and the index is re-admitted under its new
        fingerprint (spilled snapshots of the old state stay on disk —
        they are still exact for the old dataset). ``SweepPlanner``s
        re-read the ordering per sweep, so a re-keyed index keeps
        answering exactly. Returns the new key.
        """
        key = IndexKey.of_index(index)
        with self._lock:
            stale = [k for k, v in self._resident.items() if v is index]
            for k in stale:
                del self._resident[k]
            self.rekeys += 1
            self._admit(key, index)
        return key

    def forget(self, key: IndexKey, *, delete_spill: bool = True) -> bool:
        """Decrementally drop ``key``: resident entry, spill-catalog
        entry and (by default) the spilled step artifacts themselves.
        Returns True if the key was known in either tier."""
        with self._lock:
            was_resident = self._resident.pop(key, None) is not None
            step = self._spilled.pop(key, None)
            if step is not None and self.manager is not None:
                if delete_spill:
                    self.manager.delete_step(step)
                self._save_catalog()
        return was_resident or step is not None

    def _fingerprint_of(self, data, metric: MetricLike, weights) -> str:
        """``dataset_fingerprint``, memoized by (array identity, metric)
        for the common serving shape: one plain unweighted array
        presented on every request. Weighted or multi-array-tuple
        datasets always rehash — a cache keyed on one piece of a
        composite identity can go stale through id reuse and silently
        serve the wrong index. The metric's identity token is part of
        the cache key: the same array under two registered metrics has
        two fingerprints."""
        if weights is not None or isinstance(data, tuple):
            return dataset_fingerprint(data, metric, weights=weights)
        key = (id(data), get_metric(metric).spec)
        with self._lock:
            ent = self._fp_cache.get(key)
            if ent is not None and ent[0]() is data:
                return ent[1]
        fp = dataset_fingerprint(data, metric)      # hash outside the lock
        with self._lock:
            try:
                self._fp_cache[key] = (weakref.ref(
                    data, lambda _, k=key: self._fp_cache.pop(k, None)),
                    fp)
            except TypeError:  # not weakref-able: recompute next time
                pass
        return fp

    # ---------------------------------------------------------- eviction
    def _admit(self, key: IndexKey, index: FinexIndex) -> None:
        self._resident[key] = index
        self._resident.move_to_end(key)
        while len(self._resident) > self.capacity:
            victim_key, victim = self._resident.popitem(last=False)
            self._evict(victim_key, victim)

    def _evict(self, key: IndexKey, index: FinexIndex) -> None:
        # caller holds the lock (only _admit evicts)
        if self.manager is None:
            self._count_drop("capacity")
            return
        fp = index.fingerprint()
        if fp is not None and IndexKey.of_index(index) != key:
            # the index was mutated after admission and never rekey()'d:
            # spilling the post-mutation state under the pre-mutation key
            # would poison every future lookup of the original dataset
            # (the reload's fingerprint check would fail forever instead
            # of rebuilding) — drop it; the caller still holds the object
            # and can rekey() it back in
            self._count_drop("stale")
            return
        if key not in self._spilled:
            # allocate the step from the manager's live listing: the step
            # namespace is shared with training checkpoints, so a number
            # reserved at construction time could since have been taken
            step = max(self.manager.all_steps(), default=-1) + 1
            with obs.span("store.spill", eps=key.eps,
                          minpts=key.minpts):
                self.manager.save_index(step, index)
            self._spilled[key] = step
            self.spills += 1
            if obs.enabled():
                obs.count("store.spills")
            self._save_catalog()
        # else: an identical snapshot is already durable — nothing to write

    def _count_drop(self, kind: str) -> None:
        """Every drop increments ``drops``; a refused stale spill ALSO
        increments ``stale_drops`` — it is an operator-actionable signal
        (someone mutated a stored index without ``rekey``-ing it), so it
        surfaces distinctly in obs counters and the Stats verb instead
        of hiding inside the capacity-drop tally."""
        self.drops += 1
        if kind == "stale":
            self.stale_drops += 1
        if obs.enabled():
            obs.count("store.drops")
            if kind == "stale":
                obs.count("store.stale_drops")

    # ------------------------------------------------------ spill catalog
    def _load_catalog(self) -> None:
        """Rehydrate the spill map from the manager's catalog document.
        Entries whose step artifacts are gone (or are not index
        snapshots) are skipped — the catalog is a cache of durable
        state, never an authority over it."""
        payload = self.manager.load_catalog(self.CATALOG)
        if not payload:
            return
        for ent in payload.get("entries", ()):
            try:
                key = IndexKey(str(ent["fingerprint"]), float(ent["eps"]),
                               int(ent["minpts"]))
                step = int(ent["step"])
            except (KeyError, TypeError, ValueError):
                continue
            if self.manager._step_kind(step) == "finex_index":
                self._spilled[key] = step

    def _save_catalog(self) -> None:
        # caller holds the lock
        if self.manager is None:
            return
        self.manager.save_catalog(self.CATALOG, {
            "version": 1,
            "entries": [
                {"fingerprint": k.fingerprint, "eps": k.eps,
                 "minpts": k.minpts, "step": step}
                for k, step in self._spilled.items()],
        })

    # ------------------------------------------------------------- stats
    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {
                "capacity": self.capacity,
                "resident": len(self._resident),
                "spilled": len(self._spilled),
                "hits": self.hits,
                "reloads": self.reloads,
                "builds": self.builds,
                "spills": self.spills,
                "drops": self.drops,
                "stale_drops": self.stale_drops,
                "rekeys": self.rekeys,
                "build_waits": self.build_waits,
                "catalog": self.manager is not None,
            }
