"""Chameleon-34B  [arXiv:2405.09818] — early-fusion VLM.

The VQ image tokenizer is a frontend stub: image patches arrive as token
ids in the shared 65536 vocab (early fusion), so the backbone is a plain
dense decoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=65536, head_dim=128,
    notes="early fusion; VQ image tokens share the text vocab")
