"""Hymba-1.5B  [arXiv:2411.13676] — parallel attention + mamba heads.

Per DESIGN.md: all attention is sliding-window (1024) with the SSM path
carrying global context (the published model keeps 3 full-attn layers;
we deviate so long_500k is honestly sub-quadratic). ssm_expand=1 so the
25 SSM heads run parallel to the 25 attention heads at matched width.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab=32001, head_dim=64, swa_window=1024,
    ssm_state=16, ssm_expand=1, ssm_headdim=64, ssm_chunk=128,
    notes="parallel SWA-attn + mamba heads; long_500k capable")
