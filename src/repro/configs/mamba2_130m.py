"""Mamba2-130M  [arXiv:2405.21060] — SSD, attention-free.

O(1)-state decode makes this one of the two long_500k-capable archs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, tie_embeddings=True,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=128,
    notes="SSD (state-space duality); pure SSM blocks, no FFN sublayer")
