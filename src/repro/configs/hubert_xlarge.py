"""HuBERT-XLarge  [arXiv:2106.07447] — encoder-only audio backbone.

The CNN waveform frontend is a stub: input_specs() feeds precomputed
frame embeddings (B, T, 1280). Vocab 504 = masked-prediction cluster
targets. No decode shapes (encoder-only), 2-matrix GELU FFN, no RoPE.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab=504, head_dim=80, causal=False, mlp_glu=False,
    embed_inputs=False,
    notes="encoder-only; frame-embedding frontend stub; GELU FFN")
