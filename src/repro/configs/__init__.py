"""Architecture registry: ``--arch <id>`` resolves here."""
from repro.configs.base import (ModelConfig, RunConfig, ShapeConfig, SHAPES,
                                TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen2_moe
from repro.configs.llama4_maverick_400b_a17b import CONFIG as _llama4
from repro.configs.minicpm_2b import CONFIG as _minicpm
from repro.configs.stablelm_1_6b import CONFIG as _stablelm
from repro.configs.deepseek_7b import CONFIG as _deepseek
from repro.configs.qwen2_72b import CONFIG as _qwen72
from repro.configs.mamba2_130m import CONFIG as _mamba2
from repro.configs.chameleon_34b import CONFIG as _chameleon
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.hubert_xlarge import CONFIG as _hubert

ARCHS = {c.name: c for c in [
    _qwen2_moe, _llama4, _minicpm, _stablelm, _deepseek, _qwen72,
    _mamba2, _chameleon, _hymba, _hubert,
]}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "get_arch", "ModelConfig", "RunConfig", "ShapeConfig",
           "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K"]
