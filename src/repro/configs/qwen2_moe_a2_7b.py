"""Qwen1.5-MoE-A2.7B  [hf:Qwen/Qwen1.5-MoE-A2.7B].

60 routed experts (top-4) + 4 shared experts merged into one 5632-wide
SwiGLU with a sigmoid gate. 60 does not divide the 16-way model axis, so
this config uses expert-TP (expert hidden dims sharded: 1408/16 = 88).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=151936, head_dim=128, qkv_bias=True, rope_theta=1_000_000.0,
    n_experts=60, top_k=4, moe_dff=1408, shared_dff=5632, moe_every=1,
    expert_parallel=False,
    notes="4 shared + 60 routed top-4; qkv bias; expert-TP (60 % 16 != 0)")
