"""Llama-4 Maverick 400B-A17B  [hf:meta-llama/Llama-4-*; unverified].

128 routed experts top-1 + 1 shared expert, MoE every other layer
(interleave step 2), dense layers use d_ff 16384. Early fusion: image
tokens share the 202048 vocab (frontend stub). EP: 128/16 = 8 experts
per model shard.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=16384,
    vocab=202048, head_dim=128, rope_theta=500_000.0,
    n_experts=128, top_k=1, moe_dff=8192, shared_dff=8192, moe_every=2,
    expert_parallel=True,
    notes="MoE every 2nd layer; 128e top-1 + shared; early-fusion vocab")
