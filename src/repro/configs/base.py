"""Config system: model / shape / run configs.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; input-shape regimes are ``ShapeConfig``s shared across
architectures. ``RunConfig`` binds (model × shape × mesh × execution knobs)
and is what the launcher, dry-run and benchmarks consume.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Literal, Optional

VOCAB_PAD = 2048          # pad vocab so TP shards stay MXU-aligned


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "vlm", "hybrid", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    causal: bool = True              # False → encoder-only (hubert)
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    # --- MoE ---
    n_experts: int = 0               # routed experts (0 → dense)
    top_k: int = 0
    moe_dff: int = 0                 # per-routed-expert hidden dim
    shared_dff: int = 0              # merged shared-experts hidden dim
    moe_every: int = 1               # layer i is MoE iff (i+1) % moe_every == 0
    capacity_factor: float = 1.25
    expert_parallel: bool = True     # EP (experts sharded) vs expert-TP
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    ssm_groups: int = 1
    # --- hybrid / attention flavor ---
    swa_window: int = 0              # >0 → sliding-window attention
    mlp_glu: bool = True             # SwiGLU (False → 2-matrix GELU FFN)
    # --- modality frontend ---
    embed_inputs: bool = True        # False → inputs are precomputed
    #                                  frame/patch embeddings (audio/vlm stub)
    notes: str = ""

    # ------------------------------------------------------------ derived
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab // VOCAB_PAD) * VOCAB_PAD

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def ssm_dinner(self) -> int:
        return self.ssm_expand * self.d_model if self.has_ssm else 0

    @property
    def ssm_heads(self) -> int:
        return self.ssm_dinner // self.ssm_headdim if self.has_ssm else 0

    @property
    def scan_group(self) -> int:
        """Layers per scan step (MoE interleave forms one group)."""
        return self.moe_every if self.is_moe else 1

    def param_count(self) -> int:
        """Analytic parameter count (unpadded vocab) for 6ND rooflines."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.qkv_bias:
            per_attn += self.n_heads * hd + 2 * self.n_kv_heads * hd
        per_dense_mlp = (3 if self.mlp_glu else 2) * d * self.d_ff
        per_moe = (self.n_experts * 3 * d * self.moe_dff
                   + 3 * d * self.shared_dff + d * self.n_experts)
        dinner = self.ssm_dinner
        per_ssm = (d * (2 * dinner + 2 * self.ssm_groups * self.ssm_state
                        + self.ssm_heads)
                   + dinner * d + 4 * (dinner + 2 * self.ssm_groups * self.ssm_state)
                   + 3 * self.ssm_heads) if self.has_ssm else 0
        for i in range(self.n_layers):
            total += 2 * d                       # norms
            if self.has_attention:
                total += per_attn
            if self.has_ssm:
                total += per_ssm
            if self.is_moe and (i + 1) % self.moe_every == 0:
                total += per_moe
            elif self.family != "ssm":
                total += per_dense_mlp
        return total

    def active_param_count(self) -> int:
        """Active (per-token) params — 6·N_active·D for MoE rooflines."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        inactive = ((self.n_experts - self.top_k) * 3 * d * self.moe_dff
                    * (self.n_layers // self.moe_every))
        return self.param_count() - inactive

    def reduced(self, **over) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2 * self.scan_group),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256,
            vocab=512,
            head_dim=32,
        )
        if self.is_moe:
            kw.update(n_experts=4, top_k=min(self.top_k, 2), moe_dff=64,
                      shared_dff=128 if self.shared_dff else 0)
        if self.has_ssm:
            kw.update(ssm_state=16, ssm_headdim=32, ssm_chunk=32)
        if self.swa_window:
            kw.update(swa_window=16)
        kw.update(over)
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    multi_pod: bool = False
    microbatch: int = 0              # 0 → auto (see launch.dryrun)
    remat: bool = True
    remat_blocks: int = 0            # √-remat: nested scan, only block
    #                                  inputs saved (0 → auto by act size)
    fsdp_over_pod: bool = False      # extend FSDP across the pod axis
    #                                  (400B-class models on multi-pod)
    sequence_parallel: bool = False  # shard long-seq activations on 'model'
    attn_chunk: int = 1024           # q-chunk for chunked attention
    full_attn_max_seq: int = 8192    # above this, chunked attention
    grad_compression: bool = False   # int8 DP gradient compression
    accum_mode: str = "loss"         # "loss": grad of scanned loss (single
    #                                  grad buffer + one DP reduction/step)
    #                                  "grads": per-micro grad + explicit
    #                                  accumulator (§Perf baseline variant)
    flash_attention: bool = False    # account attention dots as VMEM-fused
    #                                  (Pallas flash kernels on real TPU)
    dtype: str = "bfloat16"

    def skip_reason(self) -> Optional[str]:
        """Mandated shape skips (DESIGN.md §Arch-applicability)."""
        m, s = self.model, self.shape
        if s.kind == "decode" and not m.causal:
            return "encoder-only architecture has no decode step"
        full_attn = m.has_attention and m.swa_window == 0
        if s.seq_len > 100_000 and full_attn:
            return "long_500k needs sub-quadratic attention (full-attention arch)"
        return None
