"""MiniCPM-2B  [arXiv:2404.06395]. Tied embeddings; trains with the WSD
(warmup-stable-decay) schedule from the paper (repro.train.optimizer)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab=122753, head_dim=64, tie_embeddings=True,
    notes="llama-like; WSD schedule; 36 heads pad unevenly -> fused-dim TP")
