"""Mamba2 SSD (state-space duality) mixer — chunked scan + decode step.

Implements the SSD algorithm of arXiv:2405.21060: within chunks of Q
tokens the recurrence is computed as masked matmuls (MXU work), across
chunks a small (B, H, P, S) state is carried by a sequential scan — the
structure that makes SSM training MXU-bound instead of scan-bound, and
decode O(1) in sequence length (which is why mamba2/hymba are the two
long_500k-capable architectures, DESIGN.md §3).

Shapes: B batch, T time, H ssm heads, P headdim, S ssm state, G groups
(B/C shared across H/G heads), Q chunk.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rmsnorm


class SSMParams(NamedTuple):
    ssm_in: jax.Array      # (d, 2*din + 2*G*S + H)
    ssm_conv: jax.Array    # (K, din + 2*G*S) depthwise causal conv
    ssm_alog: jax.Array    # (H,) log of -A
    ssm_dtbias: jax.Array  # (H,)
    ssm_d: jax.Array       # (H,) skip coefficient
    ssm_gnorm: jax.Array   # (din,) gated-RMSNorm weight
    ssm_out: jax.Array     # (din, d)


CONV_K = 4


def _split_in(h: jax.Array, cfg: ModelConfig):
    din = cfg.ssm_dinner
    gs = cfg.ssm_groups * cfg.ssm_state
    z, xbc, dt = jnp.split(h, [din, 2 * din + 2 * gs], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, T, CH) with kernel (K, CH)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for k in range(K):
        out = out + pad[:, k:k + xbc.shape[1]] * w[k]
    return jax.nn.silu(out)


def ssd_forward(x_in: jax.Array, p: SSMParams, cfg: ModelConfig) -> jax.Array:
    """(B, T, d) → (B, T, d) through the SSD mixer (training/prefill)."""
    Bsz, T, _ = x_in.shape
    H, P, S, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_groups
    Q = min(cfg.ssm_chunk, T)
    assert T % Q == 0, (T, Q)
    NC = T // Q

    h = x_in @ p.ssm_in
    z, xbc, dt = _split_in(h, cfg)
    xbc = _causal_conv(xbc, p.ssm_conv)
    din = cfg.ssm_dinner
    x, Bm, Cm = jnp.split(xbc, [din, din + G * S], axis=-1)
    x = x.reshape(Bsz, T, H, P)
    Bm = Bm.reshape(Bsz, T, G, S)
    Cm = Cm.reshape(Bsz, T, G, S)

    A = -jnp.exp(p.ssm_alog.astype(jnp.float32))                  # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.ssm_dtbias)   # (B,T,H)
    dA = dt * A                                                   # (B,T,H) ≤ 0

    # chunk views
    xc = x.reshape(Bsz, NC, Q, H, P).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, NC, Q, G, S).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, NC, Q, G, S).astype(jnp.float32)
    dtc = dt.reshape(Bsz, NC, Q, H)
    dAc = dA.reshape(Bsz, NC, Q, H)
    cs = jnp.cumsum(dAc, axis=2)                                  # (B,NC,Q,H)

    rep = H // G
    Bh = jnp.repeat(Bc, rep, axis=3)                              # (B,NC,Q,H,S)
    Ch = jnp.repeat(Cc, rep, axis=3)

    # ---- intra-chunk: masked (Q × Q) attention-like matmuls ----
    # L[i,j] = exp(cs_i - cs_j) for i ≥ j. The mask must be applied to
    # the EXPONENT: for i < j the difference is positive and can overflow
    # to inf, and where(mask, inf, 0) poisons the backward pass with NaNs.
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]            # (B,NC,i,j,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    Ldec = jnp.exp(jnp.where(tri[None, None, :, :, None], diff, -1e30))
    CB = jnp.einsum("bnihs,bnjhs->bnijh", Ch, Bh)                 # (B,NC,i,j,H)
    W = CB * Ldec * dtc[:, :, None, :, :]                         # weight j→i
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", W, xc)

    # ---- chunk summary states ----
    seg = jnp.exp(cs[:, :, -1:, :] - cs)                          # (B,NC,Q,H)
    Sc = jnp.einsum("bnjh,bnjhs,bnjhp->bnhps",
                    seg * dtc, Bh, xc)                            # (B,NC,H,P,S)
    chunk_decay = jnp.exp(cs[:, :, -1, :])                        # (B,NC,H)

    # ---- inter-chunk recurrence (sequential over NC) ----
    def step(state, inp):
        sc, dec = inp                                              # per chunk
        new = state * dec[:, :, None, None] + sc
        return new, state                                          # emit prev

    init = jnp.zeros((Bsz, H, P, S), jnp.float32)
    _, prev_states = jax.lax.scan(
        step, init, (jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                 # (B,NC,H,P,S)

    y_inter = jnp.einsum("bnihs,bnhps->bnihp",
                         Ch * jnp.exp(cs)[..., None], prev_states)

    y = (y_intra + y_inter).reshape(Bsz, T, H, P)
    y = y + p.ssm_d[None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(Bsz, T, din)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)), p.ssm_gnorm, cfg.norm_eps)
    return (y @ p.ssm_out).astype(x_in.dtype)


class SSMCache(NamedTuple):
    state: jax.Array       # (B, H, P, S) float32
    conv: jax.Array        # (B, K-1, CH) last conv inputs


def init_ssm_cache(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> SSMCache:
    H, P, S = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    ch = cfg.ssm_dinner + 2 * cfg.ssm_groups * cfg.ssm_state
    return SSMCache(state=jnp.zeros((batch, H, P, S), jnp.float32),
                    conv=jnp.zeros((batch, CONV_K - 1, ch), dtype))


def ssd_decode(x_in: jax.Array, cache: SSMCache, p: SSMParams,
               cfg: ModelConfig) -> Tuple[jax.Array, SSMCache]:
    """One-token SSD step. x_in: (B, 1, d) → ((B, 1, d), new cache)."""
    Bsz = x_in.shape[0]
    H, P, S, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_groups
    din = cfg.ssm_dinner

    h = x_in[:, 0] @ p.ssm_in                                     # (B, Z)
    z, xbc, dt = _split_in(h, cfg)
    # conv ring buffer: K-1 previous inputs + current
    buf = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)  # (B, K, CH)
    conv = jax.nn.silu(jnp.einsum("bkc,kc->bc", buf, p.ssm_conv))
    new_conv = buf[:, 1:]

    x, Bm, Cm = jnp.split(conv, [din, din + G * S], axis=-1)
    x = x.reshape(Bsz, H, P).astype(jnp.float32)
    Bm = Bm.reshape(Bsz, G, S).astype(jnp.float32)
    Cm = Cm.reshape(Bsz, G, S).astype(jnp.float32)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)                              # (B,H,S)
    Ch = jnp.repeat(Cm, rep, axis=1)

    A = -jnp.exp(p.ssm_alog.astype(jnp.float32))
    dt1 = jax.nn.softplus(dt.astype(jnp.float32) + p.ssm_dtbias)  # (B,H)
    decay = jnp.exp(dt1 * A)                                      # (B,H)

    state = (cache.state * decay[:, :, None, None]
             + jnp.einsum("bh,bhp,bhs->bhps", dt1, x, Bh))
    y = jnp.einsum("bhps,bhs->bhp", state, Ch) + p.ssm_d[None, :, None] * x
    y = y.reshape(Bsz, din)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)), p.ssm_gnorm, cfg.norm_eps)
    out = (y @ p.ssm_out).astype(x_in.dtype)[:, None]
    return out, SSMCache(state=state, conv=new_conv)
