"""Routed mixture-of-experts with sort-based fixed-capacity dispatch.

Design (DESIGN.md §4): tokens are routed top-k, flattened to (T·k)
assignments, stably sorted by expert id, and truncated at a fixed
per-expert capacity C = ⌈k·T·cf/E⌉. The gathered (E, C, d) expert batches
run through a batched SwiGLU einsum and are scatter-added back with their
gate weights. Dropped tokens (beyond capacity) fall through to the
residual path, standard practice for fixed-capacity MoE.

Parallelism: under EP the expert axis E is sharded on "model" (llama4:
128/16 = 8 experts per device; the gather/scatter over data-sharded tokens
lowers to the expected all-to-all/all-gather pattern). qwen2-moe's 60
experts don't divide the axis, so it uses expert-TP: E replicated, expert
hidden dims sharded on "model" (60 × 1408/16 = 88 per device) — the
framework's answer to "the paper's technique must not dictate awkward
shardings" (configs pick per-arch).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


class MoEParams(NamedTuple):
    router: jax.Array            # (d, E)
    experts_gate_up: jax.Array   # (E, d, 2*ff)
    experts_down: jax.Array      # (E, ff, d)
    # merged shared experts (qwen2-moe), zero-size arrays when unused
    shared_gate_up: jax.Array    # (d, 2*sff) or (d, 0)
    shared_down: jax.Array       # (sff, d)  or (0, d)
    shared_gate: jax.Array       # (d,) sigmoid gate (or (0,))


def capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(cfg.top_k * tokens * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)


def moe_ffn(x: jax.Array, p: MoEParams, cfg: ModelConfig) -> jax.Array:
    """(Tl, d) local tokens → (Tl, d). Routing is per data shard."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(T, cfg)

    logits = (x.astype(jnp.float32) @ p.router.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, exp_ids = jax.lax.top_k(probs, k)                        # (T, k)

    flat_exp = exp_ids.reshape(-1)                                   # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    flat_w = gate_w.reshape(-1)

    order = jnp.argsort(flat_exp, stable=True)
    s_exp = flat_exp[order]
    s_tok = flat_tok[order]
    s_w = flat_w[order]

    # rank of each assignment within its expert
    counts = jnp.bincount(s_exp, length=E)                           # (E,)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * k) - starts[s_exp]
    keep = rank < C

    # dispatch indices (E, C): token id per slot, T (=OOB) for empty slots
    slot = s_exp * C + rank
    disp = jnp.full((E * C,), T, jnp.int32)
    disp = disp.at[jnp.where(keep, slot, E * C - 1)].set(
        jnp.where(keep, s_tok, T).astype(jnp.int32), mode="drop")
    disp = disp.reshape(E, C)

    xe = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)[disp]
    h = jnp.einsum("ecd,edf->ecf", xe, p.experts_gate_up)
    g, u = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, p.experts_down)               # (E,C,d)

    # combine: scatter-add gated expert outputs back to token slots
    out = jnp.zeros((T + 1, d), jnp.float32)
    flat_ye = ye.reshape(E * C, d).astype(jnp.float32)
    w_slot = jnp.zeros((E * C,), jnp.float32).at[
        jnp.where(keep, slot, E * C - 1)].set(
        jnp.where(keep, s_w, 0.0), mode="drop")
    out = out.at[disp.reshape(-1)].add(flat_ye * w_slot[:, None],
                                       mode="drop")
    out = out[:T]

    if p.shared_gate_up.shape[-1] > 0:
        hs = x @ p.shared_gate_up
        g, u = jnp.split(hs, 2, axis=-1)
        ys = (jax.nn.silu(g) * u) @ p.shared_down
        sgate = jax.nn.sigmoid(x.astype(jnp.float32) @ p.shared_gate[:, None])
        out = out + ys.astype(jnp.float32) * sgate

    return out.astype(x.dtype)


def moe_ffn_batched(x: jax.Array, p: MoEParams, cfg: ModelConfig,
                    mesh=None, dp=None) -> jax.Array:
    """(B, T, d) → (B, T, d), routing per sequence, batch-dim native.

    Equivalent to vmap(moe_ffn) but with every large intermediate carrying
    an explicit sharding constraint — under a multi-pod mesh, GSPMD left
    to its own devices replicates the (B, E, C, d) dispatch tensors across
    the pod axis (observed: 3.6× temp memory on the 2x16x16 mesh).
    """
    import jax.sharding as js

    def cst(a, *spec):
        if mesh is None:
            return a
        return jax.lax.with_sharding_constraint(
            a, js.NamedSharding(mesh, js.PartitionSpec(*spec)))

    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(T, cfg)
    e_ax = "model" if cfg.expert_parallel else None     # EP shards experts
    f_ax = None if cfg.expert_parallel else "model"     # TP shards hidden

    logits = x.astype(jnp.float32) @ p.router.astype(jnp.float32)  # (B,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, exp_ids = jax.lax.top_k(probs, k)                      # (B,T,k)

    flat_exp = exp_ids.reshape(B, T * k)
    flat_tok = jnp.broadcast_to(jnp.repeat(jnp.arange(T), k)[None],
                                (B, T * k))
    flat_w = gate_w.reshape(B, T * k)

    order = jnp.argsort(flat_exp, axis=-1, stable=True)
    s_exp = jnp.take_along_axis(flat_exp, order, axis=-1)
    s_tok = jnp.take_along_axis(flat_tok, order, axis=-1)
    s_w = jnp.take_along_axis(flat_w, order, axis=-1)

    # rank within each expert run (batched: cummax of run-start positions)
    pos = jnp.broadcast_to(jnp.arange(T * k)[None], (B, T * k))
    is_new = jnp.concatenate(
        [jnp.ones((B, 1), bool), s_exp[:, 1:] != s_exp[:, :-1]], axis=1)
    start_pos = jax.lax.cummax(jnp.where(is_new, pos, 0), axis=1)
    rank = pos - start_pos
    keep = rank < C
    slot = jnp.where(keep, s_exp * C + rank, E * C - 1)

    bidx = jnp.arange(B)[:, None]
    disp = jnp.full((B, E * C), T, jnp.int32)
    disp = disp.at[bidx, slot].set(jnp.where(keep, s_tok, T).astype(jnp.int32),
                                   mode="drop")

    xpad = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    xe = xpad[bidx, disp].reshape(B, E, C, d)
    # xe stays batch-sharded: the gather is then dp-local; the expert
    # einsum below moves it to expert-sharding (a small all-to-all)
    xe = cst(xe, dp, None, None, None)
    h = jnp.einsum("becd,edf->becf", xe, p.experts_gate_up)
    h = cst(h, dp, e_ax, None, f_ax)
    g, u = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("becf,efd->becd", h, p.experts_down)
    ye = cst(ye, dp, e_ax, None, None)

    # combine by GATHER, not scatter-add: each (token, j) assignment reads
    # its expert-output slot back through the inverse sort permutation.
    # (A scatter-add into (B, T, d) makes GSPMD replicate the full f32
    # output across the mesh — observed 20 GiB temps on the 32k cells.)
    inv = jnp.argsort(order, axis=-1)
    rank_flat = jnp.take_along_axis(rank, inv, axis=-1)        # (B, T*k)
    keep_flat = rank_flat < C
    slot_flat = jnp.where(keep_flat, flat_exp * C + rank_flat, E * C)
    # re-shard expert outputs to batch-sharded (bf16) BEFORE the gather:
    # this is the EP combine all-gather along "model"; gathering from an
    # expert-sharded operand instead makes GSPMD replicate f32 partials
    # of the full (B, T, d) output and all-reduce them (20 GiB temps).
    ye_bt = cst(ye.astype(x.dtype).reshape(B, E * C, d), dp, None, None)
    ye_pad = jnp.concatenate(
        [ye_bt, jnp.zeros((B, 1, d), ye_bt.dtype)], axis=1)
    y_tok = ye_pad[bidx, slot_flat]                            # (B, T*k, d)
    y_tok = cst(y_tok, dp, None, None)
    out = jnp.einsum("btkd,btk->btd",
                     y_tok.reshape(B, T, k, d).astype(jnp.float32),
                     gate_w)
    out = cst(out, dp, None, None)

    if p.shared_gate_up.shape[-1] > 0:
        hs = x @ p.shared_gate_up
        hs = cst(hs, dp, None, "model")
        g, u = jnp.split(hs, 2, axis=-1)
        ys = (jax.nn.silu(g) * u) @ p.shared_down
        sgate = jax.nn.sigmoid(
            x.astype(jnp.float32) @ p.shared_gate[:, None])
        out = out + ys.astype(jnp.float32) * sgate
    return out.astype(x.dtype)


def aux_load_balance_loss(x: jax.Array, router: jax.Array,
                          cfg: ModelConfig) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (mean over tokens)."""
    logits = x.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, ids = jax.lax.top_k(probs, cfg.top_k)
    frac = jnp.mean(jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32),
                    axis=(0, 1))
    imp = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac * imp)
