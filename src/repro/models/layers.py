"""Shared neural layers: RMSNorm, RoPE, attention flavors, gated MLP.

Attention comes in four execution paths, all mathematically the same
softmax attention but with different memory behavior:

  * ``full``     — plain masked einsum; used for T ≤ full_attn_max_seq.
  * ``chunked``  — lax.scan over query chunks against the full K/V; the
                   (B,H,qc,S) logits block is the only O(S) temp. Exact,
                   inference-only path for 32k prefill (no O(T²) buffer).
  * ``swa``      — sliding-window mask (window w); chunked variant slices
                   a (w + qc) K/V band per chunk → O(T·w) total.
  * ``decode``   — single-token query against a (possibly ring-buffer)
                   cache; with the cache sequence-sharded on "model", XLA
                   SPMD turns the softmax/v-contraction reductions into
                   the flash-decode partial-softmax + psum pattern.

On real TPU hardware the swa/chunked paths are replaced by the Pallas
flash kernels (kernels/flash_swa.py); the XLA paths here are the portable
oracle and what the CPU dry-run lowers (mosaic cannot target CPU).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp



def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * scale).astype(x.dtype) * w


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, T, H, hd); positions: (B, T) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, T, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, KV, hd) → (B, S, H, hd) by repeating KV groups."""
    kv = k.shape[2]
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=2)


def _softmax_f32(logits: jax.Array, axis: int = -1) -> jax.Array:
    m = jax.lax.stop_gradient(jnp.max(logits, axis=axis, keepdims=True))
    e = jnp.exp(logits - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def attention_full(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool, window: int = 0) -> jax.Array:
    """(B,T,H,hd) × (B,S,KV,hd)² → (B,T,H,hd); full (T,S) logits."""
    B, T, H, hd = q.shape
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    scale = hd ** -0.5
    logits = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    S = k.shape[1]
    ti = jnp.arange(T)[:, None] + (S - T)      # queries are the last T slots
    si = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= si <= ti
    if window > 0:
        mask &= si > ti - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = _softmax_f32(logits)
    out = jnp.einsum("bhts,bshd->bthd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      chunk: int, causal: bool, window: int = 0) -> jax.Array:
    """Query-chunked exact attention for long-sequence prefill.

    With a window, only the (window + chunk) K/V band of each chunk is
    touched — O(T·w) flops/memory; otherwise each chunk sees the full
    prefix (O(T²) flops but O(T·chunk) memory).
    """
    B, T, H, hd = q.shape
    assert T % chunk == 0, (T, chunk)
    assert causal or window == 0, \
        "windowed non-causal attention is not supported (no arch uses it)"
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    scale = hd ** -0.5
    nchunks = T // chunk

    if window > 0:
        pad = window  # front-pad so every chunk slices a full band
        kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))

    def body(_, ci):
        qs = jax.lax.dynamic_slice_in_dim(q, ci * chunk, chunk, axis=1)
        if window > 0:
            band = window + chunk
            ks = jax.lax.dynamic_slice_in_dim(kp, ci * chunk, band, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vp, ci * chunk, band, axis=1)
            ti = jnp.arange(chunk)[:, None] + window          # abs pos in band
            si = jnp.arange(band)[None, :]
            valid = si + ci * chunk >= window                  # not front pad
            mask = valid & (si <= ti) & (si > ti - window) if causal else \
                valid & (jnp.abs(si - ti) < window)
        else:
            ks, vs = k, v
            ti = ci * chunk + jnp.arange(chunk)[:, None]
            si = jnp.arange(T)[None, :]
            mask = (si <= ti) if causal else jnp.ones((chunk, T), bool)
        logits = jnp.einsum("bthd,bshd->bhts", qs.astype(jnp.float32),
                            ks.astype(jnp.float32)) * scale
        logits = jnp.where(mask[None, None], logits, -1e30)
        probs = _softmax_f32(logits)
        out = jnp.einsum("bhts,bshd->bthd", probs, vs.astype(jnp.float32))
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(body, None, jnp.arange(nchunks))
    # (nchunks, B, chunk, H, hd) → (B, T, H, hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, T, H, hd)


def attention_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: int = 0,
                     mesh=None, seq_spec=None) -> jax.Array:
    """One-token attention against the cache (flash-decode pattern).

    q: (B, 1, H, hd); caches: (B, S, KV, hd); pos: () next-token index.
    For SWA the cache is a ring buffer of size window and every slot that
    has ever been written is valid.

    The cache stays sharded on its SEQUENCE axis ("model"): the logits are
    explicitly constrained seq-sharded so each device scores only its own
    cache chunk, and the softmax max/sum + value contraction lower to the
    flash-decode partial-reduce + psum. (Without the constraint GSPMD
    reshards the whole cache to head-sharding every step — a full-cache
    collective per layer per token.)
    """
    B, _, H, hd = q.shape
    S = k_cache.shape[1]
    k = _repeat_kv(k_cache, H)
    v = _repeat_kv(v_cache, H)
    scale = hd ** -0.5
    logits = jnp.einsum("bohd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale          # (B, H, S)
    if mesh is not None and seq_spec is not None:
        logits = jax.lax.with_sharding_constraint(
            logits, jax.sharding.NamedSharding(mesh, seq_spec))
    si = jnp.arange(S)[None, None, :]
    if window > 0:
        valid = si < jnp.minimum(pos + 1, window)               # ring buffer
    else:
        valid = si <= pos
    logits = jnp.where(valid, logits, -1e30)
    probs = _softmax_f32(logits)
    out = jnp.einsum("bhs,bshd->bhd", probs, v.astype(jnp.float32))
    return out[:, None].astype(q.dtype)


def gated_mlp(x: jax.Array, w_gate_up: jax.Array, w_down: jax.Array,
              glu: bool = True) -> jax.Array:
    """SwiGLU (glu=True) or 2-matrix GELU FFN (glu=False, e.g. HuBERT)."""
    h = x @ w_gate_up
    if glu:
        g, u = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(h)
    return h @ w_down
