from repro.models.transformer import (init_params, param_shapes, forward,
                                      decode_step, init_cache, cache_specs)

__all__ = ["init_params", "param_shapes", "forward", "decode_step",
           "init_cache", "cache_specs"]
