"""Unified model: dense / MoE / SSD / hybrid / encoder families.

One parameter layout, one forward, one decode step — the family switches
live in the per-sublayer mixer. Layers are *scanned* in groups
(``cfg.scan_group`` layers per group; llama4's dense/MoE interleave makes
a 2-layer group) so HLO size is independent of depth, which keeps the
40-cell dry-run compilable and gives remat a natural per-group boundary.

Parameters are a flat {path: array} dict; ``repro.sharding.param_spec``
maps paths to PartitionSpecs. Stacked group dims lead every layer param.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.models import layers as L
from repro.models import mamba2, moe
from repro.sharding import act_spec, constrain, dp_axes


def _mesh_dp(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes[a] for a in dp_axes(mesh)]))


# --------------------------------------------------------------- shapes
def _sublayer_shapes(cfg: ModelConfig, is_moe_layer: bool) -> Dict[str, tuple]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    out: Dict[str, tuple] = {"norm_attn": (d,), "norm_mlp": (d,)}
    if cfg.has_attention:
        out["wqkv"] = (d, (H + 2 * KV) * hd)
        if cfg.qkv_bias:
            out["bqkv"] = ((H + 2 * KV) * hd,)
        out["wo"] = (H * hd, d)
    if cfg.has_ssm:
        gs = cfg.ssm_groups * cfg.ssm_state
        din = cfg.ssm_dinner
        out.update(ssm_in=(d, 2 * din + 2 * gs + cfg.ssm_heads),
                   ssm_conv=(mamba2.CONV_K, din + 2 * gs),
                   ssm_alog=(cfg.ssm_heads,), ssm_dtbias=(cfg.ssm_heads,),
                   ssm_d=(cfg.ssm_heads,), ssm_gnorm=(din,),
                   ssm_out=(din, d))
    if is_moe_layer:
        out.update(router=(d, cfg.n_experts),
                   experts_gate_up=(cfg.n_experts, d, 2 * cfg.moe_dff),
                   experts_down=(cfg.n_experts, cfg.moe_dff, d))
        if cfg.shared_dff:
            out.update(shared_gate_up=(d, 2 * cfg.shared_dff),
                       shared_down=(cfg.shared_dff, d), shared_gate=(d,))
    elif cfg.family == "ssm":
        out.pop("norm_mlp")          # pure SSM block: no FFN sublayer
    else:
        ff = cfg.d_ff
        out.update(w_gate_up=(d, 2 * ff if cfg.mlp_glu else ff),
                   w_down=(ff, d))
    return out


def param_shapes(cfg: ModelConfig, dtype=jnp.float32
                 ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Flat {path: ShapeDtypeStruct}. Group dim G leads layer params."""
    G = cfg.n_layers // cfg.scan_group
    shapes: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.embed_inputs:
        shapes["embed"] = jax.ShapeDtypeStruct((cfg.padded_vocab, cfg.d_model),
                                               dtype)
    if not cfg.tie_embeddings:
        shapes["lm_head"] = jax.ShapeDtypeStruct(
            (cfg.d_model, cfg.padded_vocab), dtype)
    shapes["final_norm"] = jax.ShapeDtypeStruct((cfg.d_model,), dtype)
    for j in range(cfg.scan_group):
        is_moe_layer = cfg.is_moe and (j + 1) % cfg.moe_every == 0
        for name, shp in _sublayer_shapes(cfg, is_moe_layer).items():
            shapes[f"layers/s{j}/{name}"] = jax.ShapeDtypeStruct(
                (G,) + shp, dtype)
    return shapes


def init_params(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32
                ) -> Dict[str, jax.Array]:
    shapes = param_shapes(cfg, dtype)
    params = {}
    for i, (path, sds) in enumerate(sorted(shapes.items())):
        k = jax.random.fold_in(key, i)
        leaf = path.split("/")[-1]
        if leaf.startswith("norm") or leaf == "ssm_gnorm":
            params[path] = jnp.ones(sds.shape, sds.dtype)
        elif leaf in ("ssm_dtbias",):
            params[path] = jnp.zeros(sds.shape, sds.dtype)
        elif leaf == "ssm_alog":
            params[path] = jnp.log(jax.random.uniform(
                k, sds.shape, jnp.float32, 1.0, 16.0)).astype(sds.dtype)
        elif leaf == "ssm_d":
            params[path] = jnp.ones(sds.shape, sds.dtype)
        elif leaf.startswith("b"):
            params[path] = jnp.zeros(sds.shape, sds.dtype)
        else:
            fan_in = sds.shape[-2] if len(sds.shape) >= 2 else sds.shape[-1]
            std = min(0.02, fan_in ** -0.5)
            params[path] = (jax.random.normal(k, sds.shape, jnp.float32)
                            * std).astype(sds.dtype)
    return params


# --------------------------------------------------------------- blocks
def _attn(x, pp, cfg: ModelConfig, rc: RunConfig, positions, mesh,
          cache=None, pos=None):
    """Attention sublayer (no residual). Returns (out, new_kv or None)."""
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    B, T, _ = x.shape
    qkv = x @ pp["wqkv"].astype(x.dtype)
    if cfg.qkv_bias:
        qkv = qkv + pp["bqkv"].astype(x.dtype)
    q, k, v = jnp.split(qkv, [H * hd, (H + KV) * hd], axis=-1)
    q = q.reshape(B, T, H, hd)
    k = k.reshape(B, T, KV, hd)
    v = v.reshape(B, T, KV, hd)
    if cfg.causal:            # encoder (hubert) uses no RoPE (conv-pos stub)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    is_decode = cache is not None
    if mesh is not None and not is_decode:
        hs = P(dp_axes(mesh), None, "model", None)
        q = constrain(q, mesh, hs)
        k = constrain(k, mesh, P(dp_axes(mesh), None, None, None))
        v = constrain(v, mesh, P(dp_axes(mesh), None, None, None))

    new_kv = None
    if is_decode:                               # decode: T == 1
        kc, vc = cache
        S = kc.shape[1]
        slot = pos % S if cfg.swa_window > 0 else pos
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot,
                                                 axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot,
                                                 axis=1)
        # flash-decode: score per cache-seq shard, psum via the softmax/V
        # reductions — the cache never leaves its sequence sharding
        seq_spec = None
        if mesh is not None and cfg.swa_window == 0:
            bdp = dp_axes(mesh) if B % _mesh_dp(mesh) == 0 else None
            seq_spec = P(bdp, None, "model")
        o = L.attention_decode(q, kc, vc, pos, window=cfg.swa_window,
                               mesh=mesh, seq_spec=seq_spec)
        new_kv = (kc, vc)
    elif T <= rc.full_attn_max_seq:
        o = L.attention_full(q, k, v, causal=cfg.causal,
                             window=cfg.swa_window)
    else:
        o = L.attention_chunked(q, k, v, chunk=rc.attn_chunk,
                                causal=cfg.causal, window=cfg.swa_window)
    o = o.reshape(B, T, H * hd)
    return o @ pp["wo"].astype(x.dtype), new_kv


def _ssm_params(pp, x_dtype) -> mamba2.SSMParams:
    return mamba2.SSMParams(
        ssm_in=pp["ssm_in"].astype(x_dtype),
        ssm_conv=pp["ssm_conv"].astype(x_dtype),
        ssm_alog=pp["ssm_alog"], ssm_dtbias=pp["ssm_dtbias"],
        ssm_d=pp["ssm_d"], ssm_gnorm=pp["ssm_gnorm"],
        ssm_out=pp["ssm_out"].astype(x_dtype))


def _moe_params(pp, x_dtype, cfg) -> moe.MoEParams:
    if "shared_gate_up" in pp:
        sgu = pp["shared_gate_up"].astype(x_dtype)
        sdn = pp["shared_down"].astype(x_dtype)
        sgt = pp["shared_gate"]
    else:
        sgu = jnp.zeros((cfg.d_model, 0), x_dtype)
        sdn = jnp.zeros((0, cfg.d_model), x_dtype)
        sgt = jnp.zeros((cfg.d_model,), jnp.float32)
    return moe.MoEParams(
        router=pp["router"],
        experts_gate_up=pp["experts_gate_up"].astype(x_dtype),
        experts_down=pp["experts_down"].astype(x_dtype),
        shared_gate_up=sgu, shared_down=sdn, shared_gate=sgt)


def _sublayer(x, pp, j, cfg: ModelConfig, rc: RunConfig, positions, mesh,
              cache=None, pos=None):
    """One layer: mixer + FFN with pre-norms. Returns (x, new_cache)."""
    is_moe_layer = cfg.is_moe and (j + 1) % cfg.moe_every == 0
    new_cache = {}
    xn = L.rmsnorm(x, pp["norm_attn"].astype(x.dtype), cfg.norm_eps)

    mix = jnp.zeros_like(x)
    if cfg.has_attention:
        kv = (cache["k"], cache["v"]) if cache is not None else None
        a_out, new_kv = _attn(xn, pp, cfg, rc, positions, mesh, kv, pos)
        mix = mix + a_out
        if new_kv is not None:
            new_cache["k"], new_cache["v"] = new_kv
    if cfg.has_ssm:
        sp = _ssm_params(pp, x.dtype)
        if cache is not None:
            sc = mamba2.SSMCache(state=cache["ssm_state"],
                                 conv=cache["ssm_conv"])
            s_out, sc2 = mamba2.ssd_decode(xn, sc, sp, cfg)
            new_cache["ssm_state"], new_cache["ssm_conv"] = sc2.state, sc2.conv
        else:
            s_out = mamba2.ssd_forward(xn, sp, cfg)
        mix = mix + s_out
    if cfg.family == "hybrid":        # parallel attn + mamba heads (hymba)
        mix = mix * 0.5
    x = x + mix
    if mesh is not None:
        x = constrain(x, mesh, act_spec(mesh, seq_sharded=rc.sequence_parallel))

    if "norm_mlp" in pp:              # pure-SSM blocks have no FFN
        xn2 = L.rmsnorm(x, pp["norm_mlp"].astype(x.dtype), cfg.norm_eps)
        if is_moe_layer:
            mp = _moe_params(pp, x.dtype, cfg)
            B, T, d = xn2.shape
            if T == 1:                # decode: route the whole batch at once
                f_out = moe.moe_ffn(xn2.reshape(B, d), mp, cfg).reshape(B, 1, d)
            else:                     # train/prefill: route per sequence
                dp = dp_axes(mesh) if mesh is not None else None
                f_out = moe.moe_ffn_batched(xn2, mp, cfg, mesh, dp)
        else:
            f_out = L.gated_mlp(xn2, pp["w_gate_up"].astype(x.dtype),
                                pp["w_down"].astype(x.dtype), cfg.mlp_glu)
        x = x + f_out
        if mesh is not None:
            x = constrain(x, mesh,
                          act_spec(mesh, seq_sharded=rc.sequence_parallel))
    return x, new_cache


def _group_params(params: Dict[str, jax.Array], cfg: ModelConfig):
    """Split flat params into (stacked layer xs, non-layer dict)."""
    xs: Dict[str, jax.Array] = {}
    rest: Dict[str, jax.Array] = {}
    for k, v in params.items():
        (xs if k.startswith("layers/") else rest)[k] = v
    return xs, rest


# --------------------------------------------------------------- forward
def forward(params: Dict[str, jax.Array], inputs: jax.Array,
            cfg: ModelConfig, rc: RunConfig, mesh: Optional[Mesh] = None,
            positions: Optional[jax.Array] = None,
            last_only: bool = False) -> jax.Array:
    """Full-sequence forward → logits (B, T, padded_vocab).

    ``inputs``: int32 token ids (B, T) when cfg.embed_inputs, else float
    frame/patch embeddings (B, T, d_model) from the modality frontend stub.
    ``last_only``: serving prefill — slice to the final position *before*
    the LM head so the (B, T, V) logits tensor is never materialized.
    """
    compute_dtype = jnp.bfloat16 if rc.dtype == "bfloat16" else jnp.float32
    if cfg.embed_inputs:
        x = jnp.take(params["embed"], inputs, axis=0).astype(compute_dtype)
    else:
        x = inputs.astype(compute_dtype)
    B, T = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    if mesh is not None:
        x = constrain(x, mesh, act_spec(mesh, seq_sharded=rc.sequence_parallel))

    xs, rest = _group_params(params, cfg)

    def group_body(x, gp):
        for j in range(cfg.scan_group):
            pp = {k.split("/")[-1]: v for k, v in gp.items()
                  if k.startswith(f"layers/s{j}/")}
            x, _ = _sublayer(x, pp, j, cfg, rc, positions, mesh)
        return x, None

    G = cfg.n_layers // cfg.scan_group
    training = rc.remat and rc.shape.kind == "train"
    body = group_body
    if training:
        body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable)

    K = rc.remat_blocks
    if training and K > 1 and G % K == 0:
        # √-remat: nested scan saving only G/K block inputs; the K inner
        # group inputs rematerialize transiently during backward. Cuts the
        # saved-activation chain from G to G/K + K at one extra forward.
        xs_blocked = jax.tree.map(
            lambda a: a.reshape((G // K, K) + a.shape[1:]), xs)

        def block_body(x, block_params):
            x, _ = jax.lax.scan(body, x, block_params)
            return x, None

        outer = jax.checkpoint(
            block_body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(outer, x, xs_blocked)
    else:
        x, _ = jax.lax.scan(body, x, xs)

    if last_only:
        x = x[:, -1:, :]
    x = L.rmsnorm(x, rest["final_norm"].astype(x.dtype), cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ rest["embed"].astype(x.dtype).T
    else:
        logits = x @ rest["lm_head"].astype(x.dtype)
    if mesh is not None:
        logits = constrain(logits, mesh, P(dp_axes(mesh), None, "model"))
    return logits


# ----------------------------------------------------------------- cache
def cache_shapes(cfg: ModelConfig, batch: int, max_seq: int,
                 dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Flat cache ShapeDtypeStructs, stacked over scan groups."""
    G = cfg.n_layers // cfg.scan_group
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    S = min(max_seq, cfg.swa_window) if cfg.swa_window > 0 else max_seq
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    for j in range(cfg.scan_group):
        pre = f"layers/s{j}/"
        if cfg.has_attention:
            out[pre + "k"] = jax.ShapeDtypeStruct((G, batch, S, KV, hd), dtype)
            out[pre + "v"] = jax.ShapeDtypeStruct((G, batch, S, KV, hd), dtype)
        if cfg.has_ssm:
            H, Pd, St = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
            ch = cfg.ssm_dinner + 2 * cfg.ssm_groups * cfg.ssm_state
            out[pre + "ssm_state"] = jax.ShapeDtypeStruct(
                (G, batch, H, Pd, St), jnp.float32)
            out[pre + "ssm_conv"] = jax.ShapeDtypeStruct(
                (G, batch, mamba2.CONV_K - 1, ch), dtype)
    return out


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    return {k: jnp.zeros(s.shape, s.dtype)
            for k, s in cache_shapes(cfg, batch, max_seq, dtype).items()}


def cache_specs(cfg: ModelConfig, mesh: Mesh) -> Dict[str, P]:
    """PartitionSpecs per cache entry (see sharding.kvcache_spec)."""
    dp = dp_axes(mesh)
    out = {}
    for j in range(cfg.scan_group):
        pre = f"layers/s{j}/"
        if cfg.has_attention:
            # (G, B, S, KV, hd): batch over DP, cache seq over model —
            # flash-decode; SWA ring buffers are small → seq unsharded
            seq_ax = None if cfg.swa_window > 0 else "model"
            out[pre + "k"] = P(None, dp, seq_ax, None, None)
            out[pre + "v"] = P(None, dp, seq_ax, None, None)
        if cfg.has_ssm:
            out[pre + "ssm_state"] = P(None, dp, None, None, "model")
            out[pre + "ssm_conv"] = P(None, dp, None, "model")
    return out


def decode_step(params: Dict[str, jax.Array], cache: Dict[str, jax.Array],
                tokens: jax.Array, pos: jax.Array, cfg: ModelConfig,
                rc: RunConfig, mesh: Optional[Mesh] = None
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step: (B, 1) tokens + cache @ pos → (logits, new cache)."""
    compute_dtype = jnp.bfloat16 if rc.dtype == "bfloat16" else jnp.float32
    if cfg.embed_inputs:
        x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    else:
        x = tokens.astype(compute_dtype)
    B = x.shape[0]
    positions = jnp.broadcast_to(pos.astype(jnp.int32), (B, 1))

    xs, rest = _group_params(params, cfg)
    G = cfg.n_layers // cfg.scan_group

    # The cache rides in the scan CARRY and is updated with indexed
    # dynamic updates — a single (donated) buffer end to end. Passing it
    # as scan xs/ys instead makes XLA double-buffer the full cache
    # (input stack + output stack), which alone blows the HBM budget for
    # the 32k decode cells.
    def group_body(carry, slices):
        x, cache_c = carry
        gp, g = slices
        for j in range(cfg.scan_group):
            pp = {k.split("/")[-1]: v for k, v in gp.items()
                  if k.startswith(f"layers/s{j}/")}
            cc = {k.split("/")[-1]:
                  jax.lax.dynamic_index_in_dim(v, g, 0, keepdims=False)
                  for k, v in cache_c.items()
                  if k.startswith(f"layers/s{j}/")}
            x, nc = _sublayer(x, pp, j, cfg, rc, positions, mesh,
                              cache=cc if cc else None, pos=pos)
            for k, v in nc.items():
                full = f"layers/s{j}/{k}"
                cache_c = dict(cache_c)
                cache_c[full] = jax.lax.dynamic_update_index_in_dim(
                    cache_c[full], v.astype(cache_c[full].dtype), g, 0)
        return (x, cache_c), None

    (x, new_cache), _ = jax.lax.scan(group_body, (x, cache),
                                     (xs, jnp.arange(G)))
    x = L.rmsnorm(x, rest["final_norm"].astype(x.dtype), cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ rest["embed"].astype(x.dtype).T
    else:
        logits = x @ rest["lm_head"].astype(x.dtype)
    return logits, new_cache
