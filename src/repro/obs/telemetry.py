"""Typed counter/gauge registry + span rollups + early-warning thresholds.

One process-wide ``Telemetry`` instance (``telemetry`` below, reachable
as ``repro.obs.telemetry``) aggregates everything the tracer and the
instrumented call sites report:

  * counters   — monotonically increasing ints (``count("store.hits")``)
  * gauges     — last-value scalars (``gauge("service.queue_depth", 3)``)
  * windows    — rolling series with median/p95 (``observe(name, v)``);
                 every span's wall time is auto-fed into the
                 ``span.<name>`` window, so latency percentiles come for
                 free wherever spans are wired
  * spans      — per-name rollup {count, total_s, self_s, device_s}
  * thresholds — early-warning limits on window statistics; a breach
                 fires ``warnings.warn(ObsWarning)`` once and stays
                 latched until the statistic recovers below the limit

Every mutating entry point checks ``trace.ENABLED`` (the subsystem's one
module-level flag) and returns immediately when tracing is off, so the
disabled-mode cost at a call site is one attribute load and one branch.

``snapshot()`` is the documented read API — the same dict is returned by
the ``ClusterService`` Stats verb (``stats()["telemetry"]``) and by
``FinexIndex.stats()["telemetry"]``::

    {
        "enabled": bool,
        "counters": {name: int},
        "gauges": {name: float},
        "windows": {name: {count, window, last, mean, median, p95,
                           max, min}},
        "spans": {name: {count, total_s, self_s, device_s}},
        "thresholds": {name: {limit, stat, window, breached, breaches,
                              value}},
    }
"""

from __future__ import annotations

import threading
import warnings

from repro.obs import trace
from repro.obs.rolling import RollingWindow


class ObsWarning(UserWarning):
    """Raised (via ``warnings.warn``) when a telemetry threshold is
    breached."""


class _Threshold:
    __slots__ = ("limit", "stat", "breached", "breaches")

    def __init__(self, limit, stat):
        self.limit = limit
        self.stat = stat
        self.breached = False
        self.breaches = 0


class Telemetry:
    """Process-wide registry; all methods are thread-safe."""

    def __init__(self, window_size=256):
        self.window_size = window_size
        self._lock = threading.Lock()
        self._counters = {}
        self._gauges = {}
        self._windows = {}
        self._spans = {}
        self._thresholds = {}

    # -- write side -----------------------------------------------------

    def count(self, name, delta=1):
        if not trace.ENABLED:
            return
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + delta

    def gauge(self, name, value):
        if not trace.ENABLED:
            return
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name, value):
        """Push one observation into the ``name`` rolling window and
        re-check any threshold registered on it."""
        if not trace.ENABLED:
            return
        with self._lock:
            window = self._windows.get(name)
            if window is None:
                window = self._windows[name] = RollingWindow(self.window_size)
            window.push(value)
            warn_msg = self._check_threshold(name)
        if warn_msg is not None:
            warnings.warn(warn_msg, ObsWarning, stacklevel=2)

    def record_span(self, span):
        """Called by ``trace.Span.__exit__``; rolls the span into the
        per-name aggregate and its latency window."""
        if not trace.ENABLED:
            return
        with self._lock:
            agg = self._spans.get(span.name)
            if agg is None:
                agg = self._spans[span.name] = {
                    "count": 0,
                    "total_s": 0.0,
                    "self_s": 0.0,
                    "device_s": 0.0,
                }
            agg["count"] += 1
            agg["total_s"] += span.wall_s
            agg["self_s"] += span.self_s
            agg["device_s"] += span.device_s
        self.observe(f"span.{span.name}", span.wall_s)

    # -- thresholds -----------------------------------------------------

    def set_threshold(self, name, limit, stat="median"):
        """Early-warning limit on window ``name``: whenever
        ``stat(window) > limit`` the first breach warns (``ObsWarning``)
        and latches; the latch resets once the statistic recovers, so a
        sustained breach warns once, not once per observation.

        Registration does not create the window — the window appears in
        the registry only once ``observe(name, ...)`` feeds it, so idle
        thresholds leave ``snapshot()["windows"]`` untouched."""
        with self._lock:
            self._thresholds[name] = _Threshold(float(limit), stat)

    def _check_threshold(self, name):
        # caller holds self._lock; returns a warning message or None
        th = self._thresholds.get(name)
        if th is None:
            return None
        window = self._windows.get(name)
        if window is None:
            return None
        value = window.stat(th.stat)
        if value is None:
            return None
        if value > th.limit:
            if not th.breached:
                th.breached = True
                th.breaches += 1
                return (
                    f"telemetry threshold breached: {name} {th.stat}="
                    f"{value:.6g} > limit {th.limit:.6g}"
                )
        else:
            th.breached = False
        return None

    # -- read side ------------------------------------------------------

    def snapshot(self):
        """The documented telemetry snapshot (see module docstring)."""
        with self._lock:
            return {
                "enabled": trace.ENABLED,
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "windows": {n: w.summary() for n, w in self._windows.items()},
                "spans": {n: dict(agg) for n, agg in self._spans.items()},
                "thresholds": {
                    name: {
                        "limit": th.limit,
                        "stat": th.stat,
                        "window": len(w) if w is not None else 0,
                        "breached": th.breached,
                        "breaches": th.breaches,
                        "value": w.stat(th.stat) if w is not None else None,
                    }
                    for name, th in self._thresholds.items()
                    for w in (self._windows.get(name),)
                },
            }

    def reset(self):
        """Drop all aggregates (thresholds keep their limits but lose
        their windows' contents and breach latches)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._spans.clear()
            self._windows.clear()
            for th in self._thresholds.values():
                th.breached = False
                th.breaches = 0


telemetry = Telemetry()
