"""Fixed-size rolling windows with order-statistic summaries.

A ``RollingWindow`` keeps the last ``size`` observations of one series
(a span's wall time, a queue depth, a candidate fraction, ...) and
answers median / p95 / arbitrary quantiles over that window with
numpy-style linear interpolation — without importing numpy, so the
window math stays dependency-free and usable from the serving loop.
"""

from __future__ import annotations

import math
from collections import deque


def quantile(values, q):
    """Linear-interpolation quantile of ``values`` (numpy default
    method). ``q`` in [0, 1]. Raises ``ValueError`` on empty input."""
    if not values:
        raise ValueError("quantile of empty sequence")
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile q must be in [0, 1], got {q!r}")
    data = sorted(values)
    pos = q * (len(data) - 1)
    lo = math.floor(pos)
    hi = math.ceil(pos)
    if lo == hi:
        return float(data[lo])
    frac = pos - lo
    return float(data[lo]) * (1.0 - frac) + float(data[hi]) * frac


class RollingWindow:
    """Last-``size`` observations of one scalar series."""

    __slots__ = ("size", "_buf", "count", "total")

    def __init__(self, size=256):
        if size < 1:
            raise ValueError(f"window size must be >= 1, got {size!r}")
        self.size = size
        self._buf = deque(maxlen=size)
        # lifetime (not window-limited) count / sum, for rate math
        self.count = 0
        self.total = 0.0

    def push(self, value):
        value = float(value)
        self._buf.append(value)
        self.count += 1
        self.total += value

    def __len__(self):
        return len(self._buf)

    def values(self):
        return list(self._buf)

    def last(self):
        return self._buf[-1] if self._buf else None

    def median(self):
        return quantile(self._buf, 0.5) if self._buf else None

    def p95(self):
        return quantile(self._buf, 0.95) if self._buf else None

    def stat(self, name):
        """Named statistic over the current window: ``last`` | ``mean``
        | ``median`` | ``p95`` | ``max`` | ``min``."""
        if not self._buf:
            return None
        if name == "last":
            return self._buf[-1]
        if name == "mean":
            return sum(self._buf) / len(self._buf)
        if name == "median":
            return self.median()
        if name == "p95":
            return self.p95()
        if name == "max":
            return max(self._buf)
        if name == "min":
            return min(self._buf)
        raise ValueError(f"unknown window statistic {name!r}")

    def summary(self):
        """Snapshot dict for ``Telemetry.snapshot()``."""
        if not self._buf:
            return {"count": self.count, "window": 0}
        return {
            "count": self.count,
            "window": len(self._buf),
            "last": self._buf[-1],
            "mean": sum(self._buf) / len(self._buf),
            "median": self.median(),
            "p95": self.p95(),
            "max": max(self._buf),
            "min": min(self._buf),
        }
