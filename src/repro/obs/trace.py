"""Structured span tracer — zero overhead when disabled.

One module-level flag (``ENABLED``) guards the entire observability
subsystem: with it off, ``span()`` returns a shared no-op object and the
telemetry registry drops every update, so instrumented hot paths pay one
boolean check per call site and nothing else (byte-identical outputs
either way — spans never touch the computation, they only time it).

Enabled, ``span("materialize", n=..., metric=...)`` context managers
record wall time (``time.perf_counter``), nest through a thread-local
stack (children subtract from the parent's self-time), can attribute
device wait explicitly via ``Span.fence(x)`` (a ``jax.block_until_ready``
whose duration lands in ``device_s``), and on exit feed both the
in-process rollup (``repro.obs.telemetry``) and, when a sink is
configured, a JSONL export — one JSON object per line with enough
``id``/``parent``/``depth`` structure to reconstruct the span tree
offline (``scripts/trace_report.py``).

Activation:
  * ``REPRO_TRACE=/path/to/trace.jsonl`` in the environment enables
    tracing at import time with a JSONL sink at that path.
  * ``trace.configure(sink=..., enabled=True)`` / ``trace.enable()`` /
    ``trace.disable()`` at runtime; ``sink`` accepts a path or any
    file-like object with ``write``.
"""

from __future__ import annotations

import atexit
import itertools
import json
import os
import threading
import time

# THE flag. Every obs entry point (span creation, counter/gauge/window
# updates) checks this one module-level boolean and no-ops when False.
ENABLED = False

_UNSET = object()
_SINK = None
_SINK_OWNED = False
_LOCK = threading.Lock()
_TLS = threading.local()
_NEXT_ID = itertools.count(1)
_TELEMETRY = None


def _get_telemetry():
    # imported lazily: telemetry imports this module for the flag
    global _TELEMETRY
    if _TELEMETRY is None:
        from repro.obs.telemetry import telemetry

        _TELEMETRY = telemetry
    return _TELEMETRY


def _jsonable(obj):
    """JSON fallback for span attributes: numpy scalars -> Python
    scalars, anything else -> repr (a trace line must never fail to
    serialize mid-request)."""
    item = getattr(obj, "item", None)
    if callable(item):
        try:
            return item()
        except (TypeError, ValueError):
            pass
    return repr(obj)


class _NullSpan:
    """The shared disabled-mode span: every method is a no-op."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def annot(self, **attrs):
        return self

    def fence(self, value):
        return value


_NULL_SPAN = _NullSpan()


class Span:
    """One timed region. Use through ``span(...)``, not directly."""

    __slots__ = (
        "name",
        "attrs",
        "span_id",
        "parent_id",
        "depth",
        "t0",
        "wall_s",
        "child_s",
        "device_s",
    )

    def __init__(self, name, attrs):
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        stack = getattr(_TLS, "stack", None)
        if stack is None:
            stack = _TLS.stack = []
        self.span_id = next(_NEXT_ID)
        self.parent_id = stack[-1].span_id if stack else None
        self.depth = len(stack)
        self.wall_s = 0.0
        self.child_s = 0.0
        self.device_s = 0.0
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def annot(self, **attrs):
        """Attach result-side attributes (nnz, bytes, mode, ...) to the
        span record."""
        self.attrs.update(attrs)
        return self

    def fence(self, value):
        """Block until ``value``'s device computation is done and charge
        the wait to this span's ``device_s``. Returns ``value``
        unchanged, so call sites can wrap expressions in place."""
        import jax

        t0 = time.perf_counter()
        jax.block_until_ready(value)
        self.device_s += time.perf_counter() - t0
        return value

    def __exit__(self, exc_type, exc, tb):
        self.wall_s = time.perf_counter() - self.t0
        stack = _TLS.stack
        if stack and stack[-1] is self:
            stack.pop()
        if stack:
            stack[-1].child_s += self.wall_s
        _get_telemetry().record_span(self)
        if _SINK is not None:
            _emit(self)
        return False

    @property
    def self_s(self):
        return max(self.wall_s - self.child_s, 0.0)


def _emit(span):
    rec = {
        "name": span.name,
        "id": span.span_id,
        "parent": span.parent_id,
        "depth": span.depth,
        "thread": threading.get_ident(),
        "ts": span.t0,
        "wall_s": span.wall_s,
        "self_s": span.self_s,
        "device_s": span.device_s,
        "attrs": span.attrs,
    }
    line = json.dumps(rec, default=_jsonable)
    with _LOCK:
        if _SINK is not None:
            _SINK.write(line + "\n")


def span(name, **attrs):
    """Start a traced region: ``with span("materialize", n=n) as sp:``.

    Disabled mode returns the shared no-op span (one flag check, zero
    allocation). Keyword arguments become the span's attributes; add
    result-side attributes later with ``sp.annot(...)``.
    """
    if not ENABLED:
        return _NULL_SPAN
    return Span(name, attrs)


def configure(sink=_UNSET, enabled=None):
    """Reconfigure the tracer.

    ``sink``: a path (opened for write, owned and closed by the tracer),
    a file-like object (borrowed), or ``None`` to detach the current
    sink. Omit to leave the sink unchanged. ``enabled``: set the module
    flag; omit to leave it unchanged.
    """
    global _SINK, _SINK_OWNED, ENABLED
    if sink is not _UNSET:
        with _LOCK:
            if _SINK is not None and _SINK_OWNED:
                _SINK.close()
            if sink is None:
                _SINK, _SINK_OWNED = None, False
            elif isinstance(sink, (str, os.PathLike)):
                _SINK, _SINK_OWNED = open(sink, "w"), True
            else:
                _SINK, _SINK_OWNED = sink, False
    if enabled is not None:
        ENABLED = bool(enabled)


def enable(sink=_UNSET):
    """Turn tracing on (optionally wiring a sink in the same call)."""
    configure(sink=sink, enabled=True)


def disable():
    """Turn tracing off and flush any sink (the sink stays attached)."""
    configure(enabled=False)
    flush()


def enabled():
    return ENABLED


def flush():
    with _LOCK:
        if _SINK is not None:
            _SINK.flush()


@atexit.register
def _close_sink():
    global _SINK, _SINK_OWNED
    with _LOCK:
        if _SINK is not None:
            _SINK.flush()
            if _SINK_OWNED:
                _SINK.close()
            _SINK, _SINK_OWNED = None, False


_env_sink = os.environ.get("REPRO_TRACE")
if _env_sink:
    enable(sink=_env_sink)
