"""Observability layer: span tracing + counters/gauges + rolling stats.

Zero-overhead when disabled: the whole subsystem is guarded by ONE
module-level flag (``repro.obs.trace.ENABLED``). Instrumented call
sites throughout the engine / core / service layers call ``obs.span``,
``obs.count``, ``obs.gauge`` and ``obs.observe``; with the flag off each
of those is a single boolean check and a no-op, and every computation's
output is byte-identical either way.

Quickstart::

    from repro import obs

    obs.enable(sink="trace.jsonl")       # or REPRO_TRACE=trace.jsonl env
    idx = FinexIndex.build(data, eps=0.4, minpts=8)
    idx.stats()["telemetry"]             # counters/windows/span rollups
    obs.snapshot()                       # same schema, process-wide
    obs.disable()                        # flushes the JSONL sink

then ``python scripts/trace_report.py trace.jsonl`` for a top-N
self-time table and per-phase rollup.
"""

from repro.obs import trace
from repro.obs.rolling import RollingWindow, quantile
from repro.obs.telemetry import ObsWarning, Telemetry, telemetry
from repro.obs.trace import (
    Span,
    configure,
    disable,
    enable,
    enabled,
    flush,
    span,
)


def count(name, delta=1):
    """Increment counter ``name`` (no-op while tracing is disabled)."""
    telemetry.count(name, delta)


def gauge(name, value):
    """Set gauge ``name`` to ``value`` (no-op while disabled)."""
    telemetry.gauge(name, value)


def observe(name, value):
    """Push ``value`` into rolling window ``name`` (no-op while
    disabled); fires the window's threshold warning on breach."""
    telemetry.observe(name, value)


def set_threshold(name, limit, stat="median"):
    """Register an early-warning limit on window ``name``."""
    telemetry.set_threshold(name, limit, stat)


def snapshot():
    """The process-wide telemetry snapshot (documented schema in
    ``repro.obs.telemetry``)."""
    return telemetry.snapshot()


def reset():
    """Clear all counters/gauges/windows/span rollups."""
    telemetry.reset()


__all__ = [
    "ObsWarning",
    "RollingWindow",
    "Span",
    "Telemetry",
    "configure",
    "count",
    "disable",
    "enable",
    "enabled",
    "flush",
    "gauge",
    "observe",
    "quantile",
    "reset",
    "set_threshold",
    "snapshot",
    "span",
    "telemetry",
    "trace",
]
