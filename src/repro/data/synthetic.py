"""Synthetic dataset generators statistically matched to the paper's data.

The paper evaluates on 12 real datasets: heavy-tailed duplicated *set* data
from process mining (Celonis event logs, ENRON, ...) under Jaccard, and
standardized multi-dimensional *vector* data (HOUSEHOLD, GAS-SENSOR, ...)
under Euclidean. Those datasets are license-gated; these generators
reproduce the properties the paper's claims depend on: clusters at multiple
densities, border/noise mass, duplicate skew for sets, standardized
variables for vectors (DESIGN.md §7.4).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def gaussian_mixture(n: int, d: int = 8, k: int = 6, noise_frac: float = 0.1,
                     spread_range: Tuple[float, float] = (0.05, 0.4),
                     seed: int = 0) -> np.ndarray:
    """Standardized Gaussian blobs of *mixed densities* + uniform noise.

    Mixed per-cluster spreads create the multi-density structure of Fig. 1:
    no single (ε, MinPts) captures all clusters, which is what makes
    parameter exploration (the paper's motivation) meaningful.
    """
    rng = np.random.default_rng(seed)
    n_noise = int(n * noise_frac)
    n_clustered = n - n_noise
    sizes = rng.multinomial(n_clustered, np.ones(k) / k)
    centers = rng.uniform(-1.0, 1.0, size=(k, d))
    spreads = rng.uniform(*spread_range, size=k)
    parts = [rng.normal(centers[i], spreads[i], size=(sizes[i], d))
             for i in range(k)]
    parts.append(rng.uniform(-1.5, 1.5, size=(n_noise, d)))
    x = np.concatenate(parts).astype(np.float32)
    rng.shuffle(x)
    # standardize to zero mean / unit variance, as the paper does (§6)
    x = (x - x.mean(0)) / (x.std(0) + 1e-9)
    return x


def heavy_tail_sets(n: int, universe: int = 512, mean_size: int = 12,
                    k: int = 8, dup_factor: float = 3.0, seed: int = 0
                    ) -> Tuple[List[set], np.ndarray]:
    """Process-mining-style set data with a heavy duplicate tail.

    Each cluster is built around a template set of transition tokens (the
    paper's (event→event) tuples); members mutate a few tokens. Returned as
    (unique_sets, duplicate_weights) — deduplicated exactly like the
    paper's §6 pipeline, with weights = duplicate counts.
    """
    rng = np.random.default_rng(seed)
    raw: List[frozenset] = []
    template_sizes = rng.poisson(mean_size, size=k) + 3
    templates = [frozenset(rng.choice(universe, size=s, replace=False))
                 for s in template_sizes]
    # heavy-tail cluster popularity (process variants follow Zipf)
    pop = (1.0 / np.arange(1, k + 1)) ** 1.2
    pop /= pop.sum()
    for _ in range(n):
        t = templates[rng.choice(k, p=pop)]
        s = set(t)
        n_mut = rng.geometric(1.0 / (1.0 + dup_factor)) - 1
        for _ in range(n_mut):
            if rng.random() < 0.5 and len(s) > 2:
                s.discard(int(rng.choice(sorted(s))))
            else:
                s.add(int(rng.integers(universe)))
        raw.append(frozenset(s))
    uniq: dict[frozenset, int] = {}
    for s in raw:
        uniq[s] = uniq.get(s, 0) + 1
    sets = [set(s) for s in uniq]
    weights = np.asarray(list(uniq.values()), dtype=np.int64)
    return sets, weights


def two_scale_blobs(n: int, seed: int = 0) -> np.ndarray:
    """The Figure-1 scenario: one sparse cluster + two dense ones nearby.

    Used by the docs/examples to show that no single ε captures all three,
    while one FINEX build at the sparse ε serves both clusterings.
    """
    rng = np.random.default_rng(seed)
    n1 = n // 2
    n2 = n - n1
    sparse = rng.normal((0.0, 2.0), 0.45, size=(n1, 2))
    dense_a = rng.normal((2.0, -0.5), 0.12, size=(n2 // 2, 2))
    dense_b = rng.normal((2.9, -0.5), 0.12, size=(n2 - n2 // 2, 2))
    x = np.concatenate([sparse, dense_a, dense_b]).astype(np.float32)
    rng.shuffle(x)
    return x
