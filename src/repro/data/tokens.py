"""Deterministic, shardable, resumable synthetic token pipeline.

Every (step, dp_shard) pair maps to an independent PRNG stream, so:
  * restarts resume mid-run bit-exactly from just the step counter
    (fault tolerance needs no data-state checkpointing),
  * elastic re-sharding (different DP size after restart) re-partitions
    the same global stream deterministically,
  * no host is a straggler source: generation is local and O(batch).

The stream is a Zipf-ish Markov token chain — enough structure that a
~100M model's loss visibly drops in a few hundred steps (examples/).
"""
from __future__ import annotations

from typing import Dict, Iterator

import jax
import numpy as np

from repro.configs.base import ModelConfig


class TokenStream:
    def __init__(self, cfg: ModelConfig, seq_len: int, global_batch: int,
                 seed: int = 17, dp_rank: int = 0, dp_size: int = 1):
        assert global_batch % dp_size == 0
        self.cfg = cfg
        self.seq = seq_len
        self.local_batch = global_batch // dp_size
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.base = jax.random.PRNGKey(seed)
        # fixed random "grammar": per-state successor table
        g = np.random.default_rng(seed)
        self.n_states = 64
        self.succ = g.integers(0, cfg.vocab, size=(self.n_states, 8))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The batch for a given global step (pure function of step)."""
        rng = np.random.default_rng(
            (step * self.dp_size + self.dp_rank) * 2654435761 % 2**63)
        B, T = self.local_batch, self.seq
        state = rng.integers(0, self.n_states, size=B)
        toks = np.empty((B, T + 1), np.int32)
        for t in range(T + 1):
            choice = rng.integers(0, 8, size=B)
            toks[:, t] = self.succ[state, choice]
            state = (state * 31 + choice) % self.n_states
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if not self.cfg.embed_inputs:      # frontend stub: embeddings
            emb_rng = np.random.default_rng(step * 977 + self.dp_rank)
            batch["embeds"] = emb_rng.normal(
                0, 1, size=(B, T, self.cfg.d_model)).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
