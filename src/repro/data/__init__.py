from repro.data.synthetic import (gaussian_mixture, heavy_tail_sets,
                                  two_scale_blobs)

__all__ = ["gaussian_mixture", "heavy_tail_sets", "two_scale_blobs"]
