"""FINEX-powered training-data curation (the paper ↔ LM-stack bridge).

Documents are modeled as *sets of token n-grams* — exactly the paper's
process-mining set modeling (a trace becomes the set of its transitions) —
and clustered under Jaccard distance. Near-duplicate clusters are
downsampled to ``keep_per_cluster`` representatives; noise (the unique
long tail) is kept in full.

The point of using FINEX rather than one-shot DBSCAN: dedup aggressiveness
is a *hyperparameter*. With the index built once at a permissive
(ε, MinPts), every tighter setting — ε* ≤ ε or MinPts* ≥ MinPts — is an
exact re-clustering in a fraction of the cost (``CurationReport.retune``),
so the data pipeline can sweep dedup levels interactively, which is the
paper's headline capability applied to LM training data.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import List, Optional, Sequence

import numpy as np

from repro.core import FinexIndex
from repro.neighbors.bitset import pack_sets


def docs_to_ngram_sets(docs: Sequence[Sequence[int]], ngram: int = 2,
                       universe: int = 1 << 16) -> List[set]:
    """Token sequences → sets of hashed n-grams (the set modeling)."""
    out = []
    for doc in docs:
        s = set()
        toks = list(doc)
        for i in range(len(toks) - ngram + 1):
            h = 0
            for t in toks[i:i + ngram]:
                h = (h * 1000003 + int(t)) & 0x7FFFFFFF
            s.add(h % universe)
        out.append(s or {0})
    return out


@dataclass
class CurationReport:
    index: FinexIndex
    labels: np.ndarray
    kept_indices: np.ndarray
    keep_per_cluster: int

    @property
    def n_clusters(self) -> int:
        return int(self.labels.max()) + 1 if (self.labels >= 0).any() else 0

    @property
    def n_noise(self) -> int:
        return int((self.labels < 0).sum())

    def retune(self, eps_star: Optional[float] = None,
               minpts_star: Optional[int] = None) -> "CurationReport":
        """Exact re-clustering at new parameters — NO index rebuild."""
        if eps_star is not None and minpts_star is not None:
            raise ValueError("tune one parameter per query (paper §5)")
        if eps_star is not None:
            labels = self.index.eps_star(eps_star)
        elif minpts_star is not None:
            labels = self.index.minpts_star(minpts_star)
        else:
            labels = self.index.clustering()
        kept = _select_survivors(labels, self.keep_per_cluster)
        return replace(self, labels=labels, kept_indices=kept)


def _select_survivors(labels: np.ndarray, keep: int) -> np.ndarray:
    kept = []
    seen: dict[int, int] = {}
    for i, l in enumerate(labels):
        if l < 0:
            kept.append(i)                    # noise = unique docs: keep
        elif seen.get(int(l), 0) < keep:
            kept.append(i)
            seen[int(l)] = seen.get(int(l), 0) + 1
    return np.asarray(kept, dtype=np.int64)


def curate_corpus(docs: Sequence[Sequence[int]], eps: float = 0.3,
                  minpts: int = 8, ngram: int = 2,
                  keep_per_cluster: int = 2) -> CurationReport:
    """Build the FINEX index over the corpus and apply dedup once."""
    sets = docs_to_ngram_sets(docs, ngram=ngram)
    bits, sizes = pack_sets(sets)
    index = FinexIndex.build((bits, sizes), eps=eps, minpts=minpts,
                             metric="jaccard")
    labels = index.clustering()               # exact (Cor. 5.5)
    kept = _select_survivors(labels, keep_per_cluster)
    return CurationReport(index=index, labels=labels, kept_indices=kept,
                          keep_per_cluster=keep_per_cluster)
