"""FINEX — the paper's contribution: exact, flexible density-based
clustering behind a linear-space index (Thiel et al., SIGMOD 2023).

``FinexIndex`` is the facade most callers want: build once, query many
times. The functional layer underneath (finex_build, eps_star_query, …)
stays exported for benchmarks and tests that need the pieces."""
from repro.core.ordering import ClusterOrdering, FinexOrdering
from repro.core.build import finex_build, optics_build
from repro.core.extract import query_clustering, query_clustering_batch
from repro.core.queries import (ClusteringResult, Eps, Hierarchy, MinPts,
                                QueryStats, Setting, eps_star_batch,
                                eps_star_query, minpts_star_batch,
                                minpts_star_query, normalize_settings)
from repro.core.hierarchy import (ClusterHierarchy, CondensedTree,
                                  build_hierarchy, eps_cut_labels)
from repro.core.index import FinexIndex
from repro.core.dbscan import dbscan, dbscan_from_csr, filtered_counts
from repro.core.equivalence import (assert_equivalent_exact, border_recall,
                                    canonical_core_partition)

__all__ = [
    "ClusterOrdering", "FinexOrdering", "FinexIndex",
    "finex_build", "optics_build",
    "query_clustering", "query_clustering_batch",
    "eps_star_query", "minpts_star_query",
    "eps_star_batch", "minpts_star_batch", "QueryStats",
    "Eps", "MinPts", "Hierarchy", "Setting", "normalize_settings",
    "ClusteringResult",
    "ClusterHierarchy", "CondensedTree", "build_hierarchy",
    "eps_cut_labels",
    "dbscan", "dbscan_from_csr", "filtered_counts",
    "assert_equivalent_exact", "border_recall", "canonical_core_partition",
]
