"""Incremental index maintenance primitives — exact insert/delete deltas.

FINEX's serving story needs the index to survive dataset churn without
paying the O(n²) distance sweep again: ``FinexIndex.insert`` and
``FinexIndex.delete`` update the CSR, the weighted counts, the core
distances and the ordering *byte-identically* to a fresh build over the
mutated dataset, while computing only the new rows' distance strips and
re-sweeping only the affected components.  This module holds the
array-level primitives; the orchestration lives on the facade
(``repro.core.index``).

Why component-local repair is exact: the build sweep (Algorithms 2/3)
processes the dataset as a sequence of outer-loop "runs" (flood fills
from the smallest unprocessed id).  A run only ever reaches objects
connected to its trigger through *core-incidence* edges — pairs {c, x}
with c core and x in N_eps(c) — and the case-3 re-insertions that move a
border object into a later run also travel along core-incidence edges.
So the sweep never crosses a connected component of the core-incidence
graph: each component's run subsequences (and its R, F values) are a
function of the component's own rows alone, and the global order is all
runs merged by trigger id (the outer loop always starts the run with the
smallest unprocessed id, so triggers sort the runs).  Monotone id
relabeling — what a deletion does to the survivors — preserves every
comparison the sweep makes (ascending outer loop, id-sorted neighbor
rows, positional tie-breaking), so clean components keep their old
subsequences verbatim and only components containing a changed row, plus
components a new edge binds to them, need re-sweeping.  This is
IncrementalDBSCAN's affected-neighborhood argument (Ester et al., 1998)
carried over to the FINEX ordering.

Exactness assumes the metric's ``pairwise`` is per-pair independent (the
value of d(x, y) never depends on the other rows in the tile) and
bit-symmetric (d(x, y) == d(y, x) bitwise).  Every built-in metric
satisfies both; a registered metric that violates them should mutate via
the (always exact) full-rebuild path instead.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np
from scipy import sparse
from scipy.sparse.csgraph import connected_components

from repro import obs
from repro.neighbors.engine import CSRNeighborhoods


def _traced(name):
    """Wrap a delta primitive in an obs span (a no-op branch while
    tracing is disabled — the primitives run once per mutation, never
    per pair)."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with obs.span(name):
                return fn(*args, **kwargs)

        return wrapped

    return deco


@_traced("delta.core_components")
def core_components(
    csr: CSRNeighborhoods,
    core: np.ndarray,
    rows: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Connected-component labels of the core-incidence graph.

    Edges are {row, col} for every CSR entry of a *core* row; non-core
    rows contribute no edges of their own (their membership comes from
    the symmetric entry on the core's side).  With ``rows`` given, the
    graph is restricted to that id subset (which must be closed under
    core-incidence edges — true for any union of components) and labels
    come back in the subset's local numbering.
    """
    starts_all = csr.row_bounds()[0]
    if rows is None:
        n = starts_all.shape[0]
        core_rows = np.flatnonzero(core)
        gidx, lens_core = _row_gather_index(csr, core_rows)
        cols = csr.indices[gidx]
        counts = np.zeros(n, dtype=np.int64)
        counts[core_rows] = lens_core
    else:
        n = rows.size
        core_pos = np.flatnonzero(core)
        gidx, lens_core = _row_gather_index(csr, rows[core_pos])
        loc = np.full(starts_all.shape[0], -1, dtype=np.int64)
        loc[rows] = np.arange(n, dtype=np.int64)
        cols = loc[csr.indices[gidx]]
        if cols.size and cols.min() < 0:
            raise ValueError(
                "row subset is not closed under core-incidence edges "
                "(is the metric's pairwise bit-symmetric?)"
            )
        counts = np.zeros(n, dtype=np.int64)
        counts[core_pos] = lens_core
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    graph = sparse.csr_matrix(
        (np.ones(cols.size, dtype=np.uint8), np.asarray(cols, np.int64), indptr),
        shape=(n, n),
    )
    _, labels = connected_components(graph, directed=True, connection="weak")
    return labels.astype(np.int64)


def _row_gather_index(
    csr: CSRNeighborhoods, rows: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Flat gather index selecting the given rows' CSR segments.

    Three O(sub-nnz) passes (repeat of the per-row source/destination
    offset delta, one arange, one add) — the hot primitive under every
    subset operation on the delta path.  Goes through ``row_bounds()``,
    so it reads packed and slack-padded layouts alike.
    """
    starts, ends = csr.row_bounds()
    lens = (ends - starts)[rows]
    total = int(lens.sum())
    dst = np.zeros(rows.size, dtype=np.int64)
    np.cumsum(lens[:-1], out=dst[1:])
    gidx = np.repeat(starts[rows] - dst, lens)
    gidx += np.arange(total, dtype=np.int64)
    return gidx, lens


def subset_csr(csr: CSRNeighborhoods, rows: np.ndarray) -> CSRNeighborhoods:
    """Row subset of a CSR; column ids stay in the full id space."""
    gidx, lens = _row_gather_index(csr, rows)
    indptr = np.zeros(rows.size + 1, dtype=np.int64)
    np.cumsum(lens, out=indptr[1:])
    return CSRNeighborhoods(
        indptr=indptr,
        indices=csr.indices[gidx],
        dists=csr.dists[gidx],
        eps=csr.eps,
    )


def subset_core_distances(
    csr: CSRNeighborhoods,
    rows: np.ndarray,
    counts_rows: np.ndarray,
    weights: np.ndarray,
    minpts: int,
) -> np.ndarray:
    """Core distances for a row subset — same per-row bits as a full
    ``NeighborEngine.core_distances`` pass (the segmented selection is
    row-local, so restricting the rows cannot change any row's result).
    """
    from repro.neighbors.engine import NeighborEngine

    sub = subset_csr(csr, rows)
    return NeighborEngine.core_distances(sub, counts_rows, weights, minpts)


def merge_insert_components(
    comp_old: np.ndarray,
    aff_labels: np.ndarray,
    aff_old: np.ndarray,
    is_core: np.ndarray,
    n_old: int,
    m: int,
    rows_a: np.ndarray,
    cols_a: np.ndarray,
    newly_core_rows: np.ndarray,
    csr_new: CSRNeighborhoods,
) -> np.ndarray:
    """Post-insert component labels for the affected region — contracted.

    An insertion can only *merge* components, and every new
    core-incidence edge is incident to a new row or to a newly-core old
    row.  So instead of re-traversing the affected subgraph, union-find
    runs over a contracted graph whose nodes are the affected old labels
    plus the m new rows, with edges:

      * (new row p, label of x) for x an old ε-neighbor of p, when p or
        x is core (the strip-A pairs, transposed view included);
      * (new row p, new row q) for ε-adjacent new pairs, either core;
      * (label of c, label of y) for every newly-core old row c and
        y in N_eps(c) — the only way an old-old edge can be new.

    Returns 0-based labels aligned with ``concat(aff_old, new ids)``.
    """
    k = aff_labels.size
    nnodes = k + m
    edges = []
    old_sel = cols_a < n_old
    x = cols_a[old_sel].astype(np.int64)
    p = rows_a[old_sel]
    live = is_core[n_old + p] | is_core[x]
    edges.append(
        np.stack(
            [k + p[live], np.searchsorted(aff_labels, comp_old[x[live]])]
        )
    )
    nn = ~old_sel
    q = cols_a[nn].astype(np.int64) - n_old
    pn = rows_a[nn]
    live = is_core[n_old + pn] | is_core[n_old + q]
    edges.append(np.stack([k + pn[live], k + q[live]]))
    if newly_core_rows.size:
        gidx, lens = _row_gather_index(csr_new, newly_core_rows)
        y = csr_new.indices[gidx].astype(np.int64)
        c_rep = np.repeat(newly_core_rows, lens)
        sel = y < n_old
        lc = np.searchsorted(aff_labels, comp_old[c_rep[sel]])
        ly = np.searchsorted(aff_labels, comp_old[y[sel]])
        edges.append(np.stack([lc, ly]))
    e = np.concatenate(edges, axis=1)
    packed = np.unique(
        np.minimum(e[0], e[1]) * nnodes + np.maximum(e[0], e[1])
    )
    parent = np.arange(nnodes, dtype=np.int64)

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for code in packed.tolist():
        a, b = find(code // nnodes), find(code % nnodes)
        if a != b:
            parent[b] = a
    roots = np.array([find(i) for i in range(nnodes)], dtype=np.int64)
    _, labels_out = np.unique(roots, return_inverse=True)
    row_nodes = np.searchsorted(aff_labels, comp_old[aff_old])
    return np.concatenate([labels_out[row_nodes], labels_out[k:]])


@_traced("delta.splice_insert")
def splice_insert(
    csr: CSRNeighborhoods,
    add_lens: np.ndarray,
    add_cols: np.ndarray,
    add_dists: np.ndarray,
    new_lens: np.ndarray,
    new_cols: np.ndarray,
    new_dists: np.ndarray,
) -> CSRNeighborhoods:
    """CSR after appending m new objects to an n-object dataset.

    ``add_*`` carry each *old* row's new-column survivors (flat,
    row-major, cols already in the global id space — all >= n, so they
    append at the row tails and every row stays id-sorted); ``new_*``
    carry the m new rows whole.  The row-offset rebuild is one cumsum
    plus one contiguous block copy per touched old row — no Python
    per-entry work and no O(nnz) gather/scatter permutation.
    """
    n_old = csr.indptr.shape[0] - 1
    old_lens = np.diff(csr.indptr)
    lens = np.concatenate([old_lens + add_lens, new_lens])
    indptr = np.zeros(lens.shape[0] + 1, dtype=np.int64)
    np.cumsum(lens, out=indptr[1:])
    nnz = int(indptr[-1])
    indices = np.empty(nnz, dtype=np.int32)
    dists = np.empty(nnz, dtype=np.float32)
    touched = np.flatnonzero(add_lens)
    # rows between consecutive touched rows shift by one constant offset:
    # copy them as contiguous blocks (touched row k's own old entries
    # belong to block k — its appended tail starts the next offset)
    lo = np.concatenate(([0], touched + 1))
    hi = np.concatenate((touched + 1, [n_old]))
    src_lo = csr.indptr[lo]
    src_hi = csr.indptr[hi]
    dst_lo = indptr[lo]
    for s, e, d in zip(src_lo.tolist(), src_hi.tolist(), dst_lo.tolist()):
        indices[d : d + (e - s)] = csr.indices[s:e]
        dists[d : d + (e - s)] = csr.dists[s:e]
    if touched.size:
        seg_lens = add_lens[touched]
        app_base = indptr[touched] + old_lens[touched]
        starts = np.zeros(touched.size, dtype=np.int64)
        np.cumsum(seg_lens[:-1], out=starts[1:])
        offs = np.arange(add_cols.size, dtype=np.int64)
        dst = np.repeat(app_base - starts, seg_lens) + offs
        indices[dst] = add_cols
        dists[dst] = add_dists
    tail = indptr[n_old]
    indices[tail:] = new_cols
    dists[tail:] = new_dists
    return CSRNeighborhoods(
        indptr=indptr, indices=indices, dists=dists, eps=csr.eps
    )


class SlackCSR:
    """Slack-backed CSR: capacity-padded rows so insert batches splice
    in place instead of reallocating the whole O(nnz) array pair.

    Layout: row ``i`` occupies ``indices[capptr[i] : capptr[i]+lens[i]]``
    inside a physical buffer whose per-row capacity is
    ``capptr[i+1]-capptr[i]`` (>= lens[i]); the spare tail of each row
    plus one arena past ``capptr[-1]`` absorb future splices.  Every
    row-addressed consumer (the ordering sweep, ``_row_gather_index``,
    ``core_components``) reads it through :meth:`row_bounds`, so the
    logical content is exactly the packed CSR :meth:`packed` returns —
    same entries, same per-row order, same bits.

    ``append_batch`` is the whole point: when the incoming splice fits
    the existing slack it writes only O(adds) entries in place
    (``in_place_splices``); otherwise it falls back to one packed
    ``splice_insert`` plus a re-padding pass (``relayouts``, O(nnz) —
    the cost the slack exists to amortize).  Deletes always repack (the
    compacting id remap is O(nnz) regardless), so the facade re-pads on
    the next insert.

    Mutation rollback: :meth:`splice_snapshot` captures the logical
    extent (lens + capptr) in O(n); restoring it un-publishes any
    in-place tail writes, because entries beyond ``lens`` are garbage by
    contract.
    """

    def __init__(self, capptr: np.ndarray, lens: np.ndarray,
                 indices: np.ndarray, dists: np.ndarray, eps: float,
                 slack: float, min_row_slack: int,
                 stats: Optional[dict] = None):
        self.capptr = capptr          # (n+1,) int64 physical row offsets
        self.lens = lens              # (n,) int64 logical row lengths
        self.indices = indices        # physical int32 buffer (cap,)
        self.dists = dists            # physical float32 buffer (cap,)
        self.eps = eps
        self.slack = float(slack)
        self.min_row_slack = int(min_row_slack)
        # shared across relayouts so the facade's counters survive the
        # object swap a relayout performs
        self.stats = stats if stats is not None else {
            "in_place_splices": 0, "relayouts": 0}
        self._packed: Optional[CSRNeighborhoods] = None

    # ------------------------------------------------------ construction
    @classmethod
    def from_csr(cls, csr: CSRNeighborhoods, slack: float = 1.5,
                 min_row_slack: int = 8,
                 stats: Optional[dict] = None) -> "SlackCSR":
        """Re-pad a packed CSR: each row gets ``max(ceil(len*(slack-1)),
        min_row_slack)`` spare slots, plus a tail arena for future rows."""
        lens = np.diff(csr.indptr).astype(np.int64)
        caps = lens + cls._row_slack(lens, slack, min_row_slack)
        n = lens.shape[0]
        capptr = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(caps, out=capptr[1:])
        tail = max(int(csr.indptr[-1] * (slack - 1.0)), 8 * min_row_slack)
        cap = int(capptr[-1]) + tail
        indices = np.empty(cap, dtype=np.int32)
        dists = np.empty(cap, dtype=np.float32)
        dst = np.repeat(capptr[:-1] - csr.indptr[:-1], lens)
        dst += np.arange(int(csr.indptr[-1]), dtype=np.int64)
        indices[dst] = csr.indices
        dists[dst] = csr.dists
        return cls(capptr, lens, indices, dists, csr.eps, slack,
                   min_row_slack, stats=stats)

    @staticmethod
    def _row_slack(lens: np.ndarray, slack: float,
                   min_row_slack: int) -> np.ndarray:
        extra = np.ceil(lens * (slack - 1.0)).astype(np.int64)
        return np.maximum(extra, min_row_slack)

    # --------------------------------------------------- CSR access shim
    def row_bounds(self) -> Tuple[np.ndarray, np.ndarray]:
        starts = self.capptr[:-1]
        return starts, starts + self.lens

    @property
    def nnz(self) -> int:
        return int(self.lens.sum())

    @property
    def capacity(self) -> int:
        return int(self.indices.shape[0])

    def packed(self) -> CSRNeighborhoods:
        """The canonical packed view — cached until the next splice.
        One O(nnz) gather; every query-side consumer (MinPts* batches,
        serialization, spill) goes through this, so a read window after
        a burst of mutations packs exactly once."""
        if self._packed is None:
            n = self.lens.shape[0]
            gidx, lens = _row_gather_index(self, np.arange(n, dtype=np.int64))
            indptr = np.zeros(n + 1, dtype=np.int64)
            np.cumsum(lens, out=indptr[1:])
            self._packed = CSRNeighborhoods(
                indptr=indptr, indices=self.indices[gidx],
                dists=self.dists[gidx], eps=self.eps)
        return self._packed

    # ------------------------------------------------------------ splice
    @_traced("delta.slack_splice")
    def append_batch(self, add_lens: np.ndarray, add_cols: np.ndarray,
                     add_dists: np.ndarray, new_lens: np.ndarray,
                     new_cols: np.ndarray, new_dists: np.ndarray
                     ) -> "SlackCSR":
        """Splice an insert batch (same arguments as ``splice_insert``).

        Returns the post-splice SlackCSR: ``self`` (mutated in place)
        when everything fits the slack, a freshly laid-out object after
        a relayout.  Either way the logical content equals
        ``splice_insert(self.packed(), ...)`` bit for bit — old rows
        append at their tails in the same (row, new-id) order, new rows
        land whole.
        """
        m = new_lens.shape[0]
        new_lens = new_lens.astype(np.int64)
        row_caps = np.diff(self.capptr)
        newcaps = new_lens + self._row_slack(
            new_lens, self.slack, self.min_row_slack)
        need_tail = int(newcaps.sum())
        fits = (bool(np.all(self.lens + add_lens <= row_caps))
                and int(self.capptr[-1]) + need_tail <= self.capacity)
        if not fits:
            merged = splice_insert(self.packed(), add_lens, add_cols,
                                   add_dists, new_lens, new_cols, new_dists)
            self.stats["relayouts"] += 1
            if obs.enabled():
                obs.count("delta.slack.relayouts")
            return SlackCSR.from_csr(merged, self.slack,
                                     self.min_row_slack, stats=self.stats)
        touched = np.flatnonzero(add_lens)
        if touched.size:
            seg = add_lens[touched]
            starts = np.zeros(touched.size, dtype=np.int64)
            np.cumsum(seg[:-1], out=starts[1:])
            dst = np.repeat(
                self.capptr[:-1][touched] + self.lens[touched] - starts,
                seg)
            dst += np.arange(add_cols.size, dtype=np.int64)
            self.indices[dst] = add_cols
            self.dists[dst] = add_dists
        # new rows claim arena segments past capptr[-1]
        nstarts = np.zeros(m, dtype=np.int64)
        np.cumsum(newcaps[:-1], out=nstarts[1:])
        nstarts += self.capptr[-1]
        if int(new_lens.sum()):
            ndst = np.zeros(m, dtype=np.int64)
            np.cumsum(new_lens[:-1], out=ndst[1:])
            gdst = np.repeat(nstarts - ndst, new_lens)
            gdst += np.arange(int(new_lens.sum()), dtype=np.int64)
            self.indices[gdst] = new_cols
            self.dists[gdst] = new_dists
        self.capptr = np.concatenate(
            [self.capptr, self.capptr[-1] + np.cumsum(newcaps)])
        self.lens = np.concatenate(
            [self.lens + add_lens.astype(np.int64), new_lens])
        self._packed = None
        self.stats["in_place_splices"] += 1
        if obs.enabled():
            obs.count("delta.slack.in_place_splices")
        return self

    # ---------------------------------------------------------- rollback
    def splice_snapshot(self) -> tuple:
        """O(n) logical-extent capture for mutation rollback (the facade
        pairs it with ``NeighborEngine.state_snapshot``)."""
        return (self.capptr.copy(), self.lens.copy(), self._packed)

    def splice_restore(self, snap: tuple) -> None:
        self.capptr, self.lens, self._packed = snap


@_traced("delta.splice_delete")
def splice_delete(
    csr: CSRNeighborhoods,
    keep: np.ndarray,
    weights: np.ndarray,
) -> Tuple[CSRNeighborhoods, np.ndarray, np.ndarray]:
    """CSR restricted to the kept rows/columns, ids remapped compactly.

    Returns ``(csr_new, removed_weight, min_removed)``, the latter two
    per-*kept*-row: the total duplicate weight of that row's deleted
    neighbors (exactly what its |N_eps| count loses) and the smallest
    deleted distance (inf where nothing was lost — the core-distance
    repair only recomputes rows whose loss reaches down to the old C).
    No distance is ever recomputed — the surviving pairs keep the bits
    the original sweep produced.
    """
    idmap = np.cumsum(keep, dtype=np.int64) - 1
    lens = np.diff(csr.indptr)
    keep_row = np.repeat(keep, lens)     # bool segment flags — the int64
    keep_col = keep[csr.indices]         # row-id array is never built
    sel = keep_row & keep_col
    indices = idmap[csr.indices[sel]].astype(np.int32)
    dists = csr.dists[sel]
    # per-row tallies by prefix-sum differencing at the old row
    # boundaries (one O(nnz) cumsum each, reused buffer), instead of
    # bincount scans keyed by materialized row ids; empty rows fall out
    # as zero-width windows for free
    cs = np.empty(csr.indices.size + 1, dtype=np.int64)
    cs[0] = 0
    np.cumsum(sel, out=cs[1:])
    kept_lens = (cs[csr.indptr[1:]] - cs[csr.indptr[:-1]])[keep]
    indptr = np.zeros(kept_lens.shape[0] + 1, dtype=np.int64)
    np.cumsum(kept_lens, out=indptr[1:])
    removed = keep_row & ~keep_col
    np.cumsum(removed, out=cs[1:])
    rem_counts = (cs[csr.indptr[1:]] - cs[csr.indptr[:-1]])[keep]
    removed_w = np.zeros(rem_counts.shape[0], dtype=np.int64)
    min_removed = np.full(rem_counts.shape[0], np.inf, dtype=np.float32)
    # segment by STRUCTURAL removal counts: every row that lost an entry
    # owns a reduceat window, whatever the entry's weight — segmenting by
    # removed weight would misalign all later windows if a weight were
    # ever 0. The same windows serve both the lost-weight sums and the
    # smallest-lost-distance mins.
    lost = np.flatnonzero(rem_counts)
    if lost.size:
        starts = np.zeros(lost.size, dtype=np.int64)
        np.cumsum(rem_counts[lost][:-1], out=starts[1:])
        d_rem = csr.dists[removed]
        removed_w[lost] = np.add.reduceat(
            weights[csr.indices[removed]], starts)
        min_removed[lost] = np.minimum.reduceat(d_rem, starts)
    csr_new = CSRNeighborhoods(
        indptr=indptr, indices=indices, dists=dists, eps=csr.eps
    )
    return csr_new, removed_w, min_removed


@_traced("delta.stitch")
def stitch(
    n: int,
    clean: np.ndarray,
    old_pos: np.ndarray,
    old_run_id: np.ndarray,
    old_triggers: np.ndarray,
    sweep: dict,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Merge clean components' old run subsequences with a re-sweep.

    ``clean`` flags the objects whose old (remapped) run data is kept;
    ``sweep`` is the ``finex_sweep`` result over everything else.  Runs
    are merged by trigger id — exactly the order the full outer loop
    would start them in — and renumbered; within a run, clean objects
    keep their old relative order (``old_pos``) and re-swept objects
    their emission order.  Returns ``(order, run_id, run_triggers)``.

    A run is kept iff its *trigger* is clean (a trigger always belongs
    to its run's component).  Membership cannot stand in for that test:
    a run may be empty in the final order — its trigger re-emitted into
    a later run — yet it still holds a slot in the trigger-ordered
    numbering a fresh build would produce.  Deleted triggers arrive
    remapped to -1 and are dropped (their components are affected by
    construction).
    """
    valid = old_triggers >= 0
    clean_runs = np.flatnonzero(valid & clean[old_triggers])
    trig_clean = old_triggers[clean_runs]
    all_trigs = np.concatenate([trig_clean, sweep["run_triggers"]])
    by_trig = np.argsort(all_trigs)
    rank = np.empty(all_trigs.size, dtype=np.int64)
    rank[by_trig] = np.arange(all_trigs.size, dtype=np.int64)
    run_key = np.empty(n, dtype=np.int64)
    within = np.empty(n, dtype=np.int64)
    if clean_runs.size:
        lookup = np.full(int(clean_runs.max()) + 1, -1, dtype=np.int64)
        lookup[clean_runs] = rank[: clean_runs.size]
        run_key[clean] = lookup[old_run_id[clean]]
        within[clean] = old_pos[clean]
    sweep_order = sweep["order"]
    if sweep_order.size:
        new_rank = rank[clean_runs.size :]
        run_key[sweep_order] = new_rank[sweep["run_id"][sweep_order]]
        within[sweep_order] = np.arange(sweep_order.size, dtype=np.int64)
    order = np.lexsort((within, run_key))
    return order, run_key, all_trigs[by_trig]
