"""Loop-based reference implementations of the neighborhood/build/query
hot paths (the pre-vectorization code paths, verbatim).

The production paths in ``repro.neighbors.engine``, ``repro.core.build``
and ``repro.core.queries`` are fully vectorized (tile-level 2-D nonzero,
segmented lexsort core distances, bulk queue updates, union-find core
components, masked-argmax verification). These reference versions keep
the original per-object / per-neighbor Python loops so that

  * ``tests/test_vectorized_equivalence.py`` can assert the vectorized
    paths produce *byte-identical* arrays (labels, orderings, C/R/N/F,
    CSR contents) on randomized datasets, and
  * ``benchmarks/index_bench.py`` can report the end-to-end speedup of
    the vectorized pipeline against the loop baseline.

They are correctness oracles, not production code — do not call them
from library modules.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Optional, Tuple

import numpy as np

from repro.core.ordering import ClusterOrdering, FinexOrdering
from repro.neighbors.engine import CSRNeighborhoods, NeighborEngine


# --------------------------------------------------------------- engine
def reference_materialize(engine: NeighborEngine, eps: float
                          ) -> Tuple[np.ndarray, CSRNeighborhoods]:
    """Per-row CSR assembly (original ``NeighborEngine.materialize``)."""
    import jax.numpy as jnp
    n = engine.n
    counts = np.zeros(n, dtype=np.int64)
    ind_chunks, dist_chunks, lens = [], [], np.zeros(n, dtype=np.int64)
    for s in range(0, n, engine.batch_rows):
        rows = np.arange(s, min(s + engine.batch_rows, n), dtype=np.int32)
        engine.distance_rows_computed += len(rows)
        d = np.asarray(engine._dist_block(jnp.asarray(rows)))
        mask = d <= eps
        counts[rows] = mask @ engine.weights
        for bi, r in enumerate(rows):
            nb = np.nonzero(mask[bi])[0]
            ind_chunks.append(nb.astype(np.int32))
            dist_chunks.append(d[bi, nb])
            lens[r] = nb.size
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(lens, out=indptr[1:])
    csr = CSRNeighborhoods(indptr=indptr,
                           indices=np.concatenate(ind_chunks),
                           dists=np.concatenate(dist_chunks),
                           eps=float(eps))
    return counts, csr


def reference_core_distances(csr: CSRNeighborhoods, counts: np.ndarray,
                             weights: np.ndarray, minpts: int) -> np.ndarray:
    """Per-object argsort loop (original ``core_distances``)."""
    n = counts.shape[0]
    C = np.full(n, np.inf, dtype=np.float32)
    for p in range(n):
        if counts[p] < minpts:
            continue
        idx, d = csr.indices[csr.indptr[p]:csr.indptr[p + 1]], \
            csr.dists[csr.indptr[p]:csr.indptr[p + 1]]
        order = np.argsort(d, kind="stable")
        cw = np.cumsum(weights[idx[order]])
        C[p] = d[order][np.searchsorted(cw, minpts)]
    return C


# ---------------------------------------------------------------- build
class _SeedStablePQ:
    """Min-heap keyed by (priority, insertion-seq) with lazy deletion."""

    def __init__(self):
        self._heap: list = []
        self._seq = itertools.count()
        self._best: dict = {}

    def __len__(self) -> int:
        return len(self._best)

    def __contains__(self, obj: int) -> bool:
        return obj in self._best

    def insert(self, obj: int, priority: float) -> None:
        self._best[obj] = priority
        heapq.heappush(self._heap, (priority, next(self._seq), obj))

    decrease = insert

    def pop(self) -> Tuple[int, float]:
        while True:
            priority, _, obj = heapq.heappop(self._heap)
            if self._best.get(obj) == priority:
                del self._best[obj]
                return obj, priority


def _reference_prepare(engine: NeighborEngine, eps: float, minpts: int,
                       csr: Optional[CSRNeighborhoods] = None):
    if csr is None:
        counts, csr = reference_materialize(engine, eps)
    else:
        counts = np.zeros(engine.n, dtype=np.int64)
        for p in range(engine.n):
            idx = csr.indices[csr.indptr[p]:csr.indptr[p + 1]]
            counts[p] = engine.weights[idx].sum()
    C = reference_core_distances(csr, counts, engine.weights, minpts)
    return counts, csr, C


def reference_finex_build(engine: NeighborEngine, eps: float, minpts: int,
                          csr: Optional[CSRNeighborhoods] = None
                          ) -> Tuple[FinexOrdering, CSRNeighborhoods]:
    """Per-neighbor zip-loop queue updates (original ``finex_build``)."""
    n = engine.n
    counts, csr, C = _reference_prepare(engine, eps, minpts, csr)

    R = np.full(n, np.inf, dtype=np.float64)
    N = counts.astype(np.int64)
    F = np.arange(n, dtype=np.int64)
    visible_N = np.zeros(n, dtype=np.int64)
    processed = np.zeros(n, dtype=bool)
    slot = np.full(n, -1, dtype=np.int64)
    order_list: list = []
    is_core = np.isfinite(C)

    pq = _SeedStablePQ()

    def q_update(c: int) -> None:
        s, e = csr.indptr[c], csr.indptr[c + 1]
        nbrs = csr.indices[s:e]
        dists = csr.dists[s:e]
        Cc = C[c]
        for q, d in zip(nbrs, dists):
            rdist = Cc if Cc >= d else float(d)
            if not processed[q] and q not in pq:
                R[q] = rdist
                pq.insert(int(q), rdist)
            elif q in pq:
                if rdist < R[q]:
                    R[q] = rdist
                    pq.decrease(int(q), rdist)
            else:
                if not is_core[q] and rdist < R[q]:
                    processed[q] = False
                    order_list[slot[q]] = -1
                    slot[q] = -1
                    R[q] = rdist
                    pq.insert(int(q), rdist)
            if visible_N[c] > visible_N[F[q]]:
                F[q] = c

    def append(o: int) -> None:
        processed[o] = True
        slot[o] = len(order_list)
        order_list.append(o)
        visible_N[o] = N[o]

    for o in range(n):
        if processed[o]:
            continue
        append(o)
        if is_core[o]:
            q_update(o)
            while len(pq):
                p, _ = pq.pop()
                append(p)
                if is_core[p]:
                    q_update(p)

    order = np.asarray([x for x in order_list if x >= 0], dtype=np.int64)
    assert order.shape[0] == n
    pos = np.empty(n, dtype=np.int64)
    pos[order] = np.arange(n)
    idx = FinexOrdering(eps=float(eps), minpts=int(minpts), order=order,
                        pos=pos, C=C.astype(np.float64), R=R, N=N, F=F)
    return idx, csr


def reference_optics_build(engine: NeighborEngine, eps: float, minpts: int,
                           csr: Optional[CSRNeighborhoods] = None
                           ) -> Tuple[ClusterOrdering, CSRNeighborhoods]:
    """Original OPTICS sweep with per-neighbor loops."""
    n = engine.n
    counts, csr, C = _reference_prepare(engine, eps, minpts, csr)

    R = np.full(n, np.inf, dtype=np.float64)
    processed = np.zeros(n, dtype=bool)
    order_list: list = []
    is_core = np.isfinite(C)
    pq = _SeedStablePQ()

    def q_update(c: int) -> None:
        s, e = csr.indptr[c], csr.indptr[c + 1]
        Cc = C[c]
        for q, d in zip(csr.indices[s:e], csr.dists[s:e]):
            rdist = Cc if Cc >= d else float(d)
            if not processed[q] and q not in pq:
                R[q] = rdist
                pq.insert(int(q), rdist)
            elif q in pq and rdist < R[q]:
                R[q] = rdist
                pq.decrease(int(q), rdist)

    for o in range(n):
        if processed[o]:
            continue
        processed[o] = True
        order_list.append(o)
        if is_core[o]:
            q_update(o)
            while len(pq):
                p, _ = pq.pop()
                processed[p] = True
                order_list.append(p)
                if is_core[p]:
                    q_update(p)

    order = np.asarray(order_list, dtype=np.int64)
    pos = np.empty(n, dtype=np.int64)
    pos[order] = np.arange(n)
    return ClusterOrdering(eps=float(eps), minpts=int(minpts), order=order,
                           pos=pos, C=C.astype(np.float64), R=R), csr


# -------------------------------------------------------------- queries
def _reference_core_clustering(cores: np.ndarray, csr: CSRNeighborhoods,
                               labels_out: np.ndarray, next_label: int) -> int:
    """Python-set BFS (original ``_compute_core_clustering``)."""
    remaining = set(int(c) for c in cores)
    for seed in cores:
        seed = int(seed)
        if seed not in remaining:
            continue
        stack = [seed]
        remaining.discard(seed)
        labels_out[seed] = next_label
        while stack:
            x = stack.pop()
            s, e = csr.indptr[x], csr.indptr[x + 1]
            for q in csr.indices[s:e]:
                q = int(q)
                if q in remaining:
                    remaining.discard(q)
                    labels_out[q] = next_label
                    stack.append(q)
        next_label += 1
    return next_label


def reference_minpts_star_query(index: FinexOrdering, csr: CSRNeighborhoods,
                                minpts_star: int) -> np.ndarray:
    """Original MinPts*-query with the per-sparse-cluster BFS loop."""
    from repro.core.extract import query_clustering
    if minpts_star < index.minpts:
        raise ValueError("MinPts* must be >= generating MinPts")
    n = index.n
    sparse = query_clustering(index, index.eps)
    labels = np.full(n, -1, dtype=np.int64)
    cores_star = (index.N >= minpts_star)
    demoted = (index.N >= index.minpts) & (index.N < minpts_star)
    if not np.any(demoted):
        labels[:] = np.where(sparse >= 0, sparse, -1)
        return labels
    next_label = 0
    nsparse = int(sparse.max()) + 1 if np.any(sparse >= 0) else 0
    for k in range(nsparse):
        members = np.nonzero(sparse == k)[0]
        kcores = members[cores_star[members]]
        if kcores.size:
            next_label = _reference_core_clustering(kcores, csr, labels,
                                                    next_label)
    border = (sparse >= 0) & (~cores_star)
    fin = index.F[border]
    ok = cores_star[fin]
    border_ids = np.nonzero(border)[0]
    labels[border_ids[ok]] = labels[fin[ok]]
    return labels


def reference_eps_star_query(index: FinexOrdering, engine: NeighborEngine,
                             eps_star: float,
                             verify_batch: int = 4096) -> np.ndarray:
    """Original ε*-query with the per-candidate first-hit loop."""
    from repro.core.extract import query_clustering

    def cluster_spans_loop(o, labels):
        m = int(labels.max()) + 1 if labels.size and labels.max() >= 0 else 0
        first = np.full(m, np.iinfo(np.int64).max, dtype=np.int64)
        last = np.full(m, -1, dtype=np.int64)
        pos = o.pos
        for obj in range(o.n):
            lab = labels[obj]
            if lab >= 0:
                p = pos[obj]
                if p < first[lab]:
                    first[lab] = p
                if p > last[lab]:
                    last[lab] = p
        return first, last

    eps_star = float(np.float32(eps_star))
    eps_gen = float(np.float32(index.eps))
    labels = query_clustering(index, eps_star)
    if eps_star >= eps_gen:
        return labels

    cand_mask = (labels < 0) & (index.C > eps_star) & (index.C <= eps_gen)
    candidates = np.nonzero(cand_mask)[0]
    if len(candidates) == 0:
        return labels

    sparse = query_clustering(index, index.eps)
    first, _ = cluster_spans_loop(index, labels)
    m = first.shape[0]

    core_star = index.C <= eps_star
    cores_by_S: dict = {}
    for obj in np.nonzero(core_star)[0]:
        lab = labels[obj]
        if lab >= 0:
            cores_by_S.setdefault(int(lab), []).append(int(obj))

    sparse_of_S = np.full(m, -1, dtype=np.int64)
    for i, cores in cores_by_S.items():
        sparse_of_S[i] = sparse[cores[0]]

    order_pos = index.pos
    by_sparse: dict = {}
    for o in candidates:
        k = int(sparse[o])
        if k >= 0:
            by_sparse.setdefault(k, []).append(int(o))

    for k, cands in by_sparse.items():
        sids = [i for i in range(m)
                if sparse_of_S[i] == k and i in cores_by_S]
        if not sids:
            continue
        core_ids = np.concatenate([np.asarray(cores_by_S[i], np.int64)
                                   for i in sids])
        core_cluster = np.concatenate([np.full(len(cores_by_S[i]), i,
                                               np.int64) for i in sids])
        cand_arr = np.asarray(cands, np.int64)
        unassigned = np.ones(len(cand_arr), bool)
        for s in range(0, len(core_ids), verify_batch):
            blk = slice(s, s + verify_batch)
            d = engine.pair_distances(cand_arr[unassigned], core_ids[blk])
            hit = d <= eps_star
            for ci, o in enumerate(cand_arr[unassigned]):
                ok = hit[ci] & (first[core_cluster[blk]] > order_pos[o])
                js = np.nonzero(ok)[0]
                if js.size:
                    labels[o] = core_cluster[blk][js[0]]
            unassigned = labels[cand_arr] < 0
            if not unassigned.any():
                break
    return labels


def reference_sweep_labels(index: FinexOrdering, engine: NeighborEngine,
                           csr: CSRNeighborhoods, settings) -> np.ndarray:
    """Loop reference for the batched parameter sweep: one scalar
    reference query per setting, stacked into the (K, n) label matrix the
    batched kernels (``eps_star_batch``/``minpts_star_batch``) produce in
    shared passes. ``settings`` is a sequence of ("eps", v) / ("minpts", v)
    pairs."""
    rows = []
    for kind, value in settings:
        if kind == "eps":
            rows.append(reference_eps_star_query(index, engine, value))
        elif kind == "minpts":
            rows.append(reference_minpts_star_query(index, csr, int(value)))
        else:
            raise ValueError(f"unknown sweep setting kind {kind!r}")
    if not rows:
        return np.empty((0, index.n), dtype=np.int64)
    return np.stack(rows)


# ------------------------------------------------------------- hierarchy
def reference_hierarchy(index: FinexOrdering, csr: CSRNeighborhoods,
                        weights: np.ndarray,
                        min_cluster_weight: Optional[int] = None) -> dict:
    """Loop oracle for ``repro.core.hierarchy.build_hierarchy``.

    No spanning tree, no union-find: mutual-reachability components are
    recomputed from scratch with a set-based BFS at every evaluation
    level (the merge levels of each tracked cluster), and the
    condensation / stability / excess-of-mass selection rules are the
    paper-facing definitions written as plain loops.  Returns a dict of
    per-cluster lists plus the per-object condensed-node attribution and
    the extracted flat labels, in *some* cluster order — the production
    tree is compared against it up to the canonical (birth, size,
    min-member) keying, never by raw cluster id.
    """
    n = index.n
    eps_gen = float(np.float32(index.eps))
    W = int(min_cluster_weight if min_cluster_weight is not None
            else index.minpts)
    C = index.C
    w = np.asarray(weights, dtype=np.int64)
    cores = [p for p in range(n) if np.isfinite(C[p])]
    core_set = set(cores)

    # every mutual-reachability pair, straight off the CSR rows
    adj: dict = {p: [] for p in cores}
    all_m = []
    for p in cores:
        s, e = csr.indptr[p], csr.indptr[p + 1]
        for q, d in zip(csr.indices[s:e], csr.dists[s:e]):
            q = int(q)
            if p < q and q in core_set:
                m = max(float(d), float(C[p]), float(C[q]))
                adj[p].append((q, m))
                adj[q].append((p, m))
                all_m.append(m)

    def comps_below(members, h):
        """Components of {p: C[p] < h} under edges m < h (set BFS)."""
        act = {p for p in members if C[p] < h}
        out = []
        while act:
            seed = act.pop()
            comp, stack = {seed}, [seed]
            while stack:
                x = stack.pop()
                for q, m in adj[x]:
                    if q in act and m < h:
                        act.discard(q)
                        comp.add(q)
                        stack.append(q)
            out.append(comp)
        return out

    parent, birth, death, size, attr = [], [], [], [], {}
    stack = []
    for comp in comps_below(cores, np.inf):      # top-level components
        parent.append(-1)
        birth.append(eps_gen)
        death.append(np.nan)
        size.append(int(sum(w[p] for p in comp)))
        stack.append((comp, len(parent) - 1))
    while stack:
        S, c = stack.pop()
        if len(S) == 1:                           # a lone surviving core
            (p,) = S
            attr[p] = c
            death[c] = float(C[p])
            continue
        # next evaluation level: the largest level (member C or internal
        # edge m) at which the cluster's structure actually changes — a
        # cycle edge's m is not an event, so test instead of trusting max
        levels = sorted({float(C[p]) for p in S}
                        | {m for p in S for q, m in adj[p] if q in S},
                        reverse=True)
        h = next(e for e in levels if comps_below(S, e) != [S])
        for p in S:
            if C[p] == h:                        # falls with this merge
                attr[p] = c
        comps = comps_below(S, h)
        big = [comp for comp in comps if sum(w[p] for p in comp) >= W]
        if len(big) >= 2:                                # a real split
            death[c] = float(h)
            for comp in comps:
                if comp in big:
                    parent.append(c)
                    birth.append(float(h))
                    death.append(np.nan)
                    size.append(int(sum(w[p] for p in comp)))
                    stack.append((comp, len(parent) - 1))
                else:
                    for p in comp:
                        attr[p] = c
        elif len(big) == 1:                          # cluster continues
            for comp in comps:
                if comp is big[0]:
                    stack.append((comp, c))
                else:
                    for p in comp:
                        attr[p] = c
        else:                                        # cluster dissolves
            death[c] = float(h)
            for comp in comps:
                for p in comp:
                    attr[p] = c

    nc = len(parent)
    pos_lv = [float(C[p]) for p in cores] + all_m + [eps_gen]
    pos_lv = [v for v in pos_lv if v > 0]
    floor = min(pos_lv) * 0.5 if pos_lv else 1.0

    def lam(e):
        return 1.0 / max(e, floor)

    stability = [0.0] * nc
    for p, c in attr.items():
        stability[c] += float(w[p]) * (lam(float(C[p])) - lam(birth[c]))

    children: dict = {}
    for c in range(nc):
        if parent[c] >= 0:
            children.setdefault(parent[c], []).append(c)
    selected = [True] * nc
    s_hat = [0.0] * nc
    for c in range(nc - 1, -1, -1):          # children have larger ids
        cs = sum(s_hat[x] for x in children.get(c, []))
        if children.get(c) and cs > stability[c]:
            selected[c] = False
            s_hat[c] = cs
        else:
            s_hat[c] = stability[c]
    for c in range(nc):                      # parents have smaller ids
        if any(selected[a] for a in _ancestors(parent, c)):
            selected[c] = False

    labels = np.full(n, -1, dtype=np.int64)
    chosen: dict = {}
    for p, c in attr.items():
        a = c
        while a >= 0 and not selected[a]:
            a = parent[a]
        if a >= 0:
            chosen.setdefault(a, []).append(p)
    for lbl, a in enumerate(sorted(chosen, key=lambda a: min(chosen[a]))):
        for p in chosen[a]:
            labels[p] = lbl
    return {"parent": parent, "birth": birth, "death": death,
            "size": size, "stability": stability, "selected": selected,
            "attr": attr, "labels": labels, "floor": floor}


def _ancestors(parent, c):
    out = []
    p = parent[c]
    while p >= 0:
        out.append(p)
        p = parent[p]
    return out
