"""Exact ε*- and MinPts*-queries over a FINEX-ordering (§5.3, §5.4).

These are the paper's headline feature: after one build at the generating
(ε, MinPts), any (ε* ≤ ε, MinPts) or (ε, MinPts* ≥ MinPts) clustering is
*exact* (Definition 3.5) at a fraction of DBSCAN-from-scratch cost.

ε*-query (Theorem 5.6):   Alg. 1 scan → candidate former-cores
  (noise-labeled, ε* < C ≤ ε, processed before S_i's first object, same
  sparse cluster) → verified by a *batched device* distance computation
  against only the ε*-cores of the candidate's sparse cluster, with
  first-hit semantics. This inherits both of the paper's §5.3 savings:
  (i) distances only against cluster cores, not D; (ii) early termination.

MinPts*-query (§5.4):      exact sparse clustering filters noise →
  Alg. 4 BFS over preserved cores (with the paper's fast path when no core
  loses status) → border objects placed through their finder reference
  F[o] with *zero* neighborhood computations.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.extract import cluster_spans, query_clustering
from repro.core.ordering import FinexOrdering
from repro.neighbors.engine import CSRNeighborhoods, NeighborEngine


@dataclass
class QueryStats:
    """Instrumentation mirroring the paper's efficiency arguments."""
    candidates: int = 0
    verification_pairs: int = 0       # candidate×core distances computed
    neighborhoods_computed: int = 0   # full-row neighborhood computations
    fast_path: bool = False


def eps_star_query(index: FinexOrdering, engine: NeighborEngine,
                   eps_star: float, stats: Optional[QueryStats] = None,
                   verify_batch: int = 4096) -> np.ndarray:
    """Exact clustering w.r.t. (ε*, MinPts), ε* ≤ ε  (Theorem 5.6)."""
    if stats is None:
        stats = QueryStats()
    eps_star = float(np.float32(eps_star))        # float32 distance domain
    eps_gen = float(np.float32(index.eps))
    labels = query_clustering(index, eps_star)
    if eps_star >= eps_gen:           # Corollary 5.5: scan is already exact
        return labels

    # -- candidates: former-cores labeled noise (cond. 1) ----------------
    cand_mask = (labels < 0) & (index.C > eps_star) & (index.C <= eps_gen)
    candidates = np.nonzero(cand_mask)[0]
    stats.candidates = len(candidates)
    if len(candidates) == 0:
        return labels

    # -- sparse exact clustering w.r.t. (ε, MinPts) for cond. 3 ----------
    sparse = query_clustering(index, index.eps)

    first, _ = cluster_spans(index, labels)
    m = first.shape[0]

    # ε*-cores per approximate cluster (these are already in S: Thm 5.2c)
    core_star = index.C <= eps_star
    cores_by_S: dict[int, list[int]] = {}
    for obj in np.nonzero(core_star)[0]:
        l = labels[obj]
        if l >= 0:
            cores_by_S.setdefault(int(l), []).append(int(obj))

    # sparse cluster of each S_i (Prop. 3.9: unique). Read it off an
    # ε*-core: cores are unambiguous in the exact sparse partition, while
    # a border member of S_i may be *assigned* to a different sparse
    # cluster it also touches.
    sparse_of_S = np.full(m, -1, dtype=np.int64)
    for i, cores in cores_by_S.items():
        sparse_of_S[i] = sparse[cores[0]]

    # Batched verification, grouped by sparse cluster: one device call per
    # (candidate-group × core-set) computes the whole sub-matrix. The
    # paper's per-candidate early exit (§5.3 discussion, point ii) suits a
    # CPU; on an accelerator one batched tile beats thousands of tiny
    # early-exit probes — same exactness, counted pairs are higher but
    # wall time is far lower (benchmarked in Fig 6/7 harness).
    order_pos = index.pos
    by_sparse: dict[int, list[int]] = {}
    for o in candidates:
        k = int(sparse[o])
        if k >= 0:
            by_sparse.setdefault(k, []).append(int(o))

    for k, cands in by_sparse.items():
        sids = [i for i in range(m)
                if sparse_of_S[i] == k and i in cores_by_S]
        if not sids:
            continue
        core_ids = np.concatenate([np.asarray(cores_by_S[i], np.int64)
                                   for i in sids])
        core_cluster = np.concatenate([np.full(len(cores_by_S[i]), i,
                                               np.int64) for i in sids])
        cand_arr = np.asarray(cands, np.int64)
        unassigned = np.ones(len(cand_arr), bool)
        for s in range(0, len(core_ids), verify_batch):
            blk = slice(s, s + verify_batch)
            d = engine.pair_distances(cand_arr[unassigned], core_ids[blk])
            stats.verification_pairs += d.size
            hit = d <= eps_star
            for ci, o in enumerate(cand_arr[unassigned]):
                ok = hit[ci] & (first[core_cluster[blk]] > order_pos[o])
                js = np.nonzero(ok)[0]
                if js.size:
                    labels[o] = core_cluster[blk][js[0]]
            unassigned = labels[cand_arr] < 0
            if not unassigned.any():       # cond. 4: everyone placed
                break
    return labels


def _compute_core_clustering(cores: np.ndarray, csr: CSRNeighborhoods,
                             eps: float, labels_out: np.ndarray,
                             next_label: int, stats: QueryStats) -> int:
    """Algorithm 4: connected components of cores under the ε-graph.

    ``cores`` must be sorted; neighborhoods come from the generating-ε CSR
    restricted to the core set (the paper's ``N_ε(x) ∩ Cores``).
    """
    in_cores = np.zeros(labels_out.shape[0], dtype=bool)
    in_cores[cores] = True
    remaining = set(int(c) for c in cores)
    for seed in cores:
        seed = int(seed)
        if seed not in remaining:
            continue
        # new component
        stack = [seed]
        remaining.discard(seed)
        labels_out[seed] = next_label
        while stack:
            x = stack.pop()
            s, e = csr.indptr[x], csr.indptr[x + 1]
            stats.neighborhoods_computed += 1
            for q in csr.indices[s:e]:
                q = int(q)
                if q in remaining:
                    remaining.discard(q)
                    labels_out[q] = next_label
                    stack.append(q)
        next_label += 1
    return next_label


def minpts_star_query(index: FinexOrdering, csr: CSRNeighborhoods,
                      minpts_star: int, stats: Optional[QueryStats] = None
                      ) -> np.ndarray:
    """Exact clustering w.r.t. (ε, MinPts*), MinPts* ≥ MinPts  (§5.4)."""
    if stats is None:
        stats = QueryStats()
    if minpts_star < index.minpts:
        raise ValueError("MinPts* must be >= generating MinPts")

    n = index.n
    # step 1: exact sparse clustering; discard its noise (Prop. 5.7)
    sparse = query_clustering(index, index.eps)
    labels = np.full(n, -1, dtype=np.int64)

    cores_star = (index.N >= minpts_star)          # o.N ≥ MinPts*  — no
    # neighborhood computation needed to decide core status (§5.4)

    # fast path: no object straddles [MinPts, MinPts*) ⇒ every sparse core
    # keeps core status ⇒ components are the sparse clusters themselves.
    demoted = (index.N >= index.minpts) & (index.N < minpts_star)
    if not np.any(demoted):
        stats.fast_path = True
        labels[:] = np.where(sparse >= 0, sparse, -1)
        return labels

    # step 2: Algorithm 4 within each sparse cluster
    next_label = 0
    nsparse = int(sparse.max()) + 1 if np.any(sparse >= 0) else 0
    for k in range(nsparse):
        members = np.nonzero(sparse == k)[0]
        kcores = members[cores_star[members]]
        if kcores.size:
            next_label = _compute_core_clustering(
                kcores, csr, index.eps, labels, next_label, stats)

    # step 3: borders via finder references — F[o] is the densest core
    # reaching o, so o is a border iff N[F[o]] ≥ MinPts* (no distances!)
    border = (sparse >= 0) & (~cores_star)
    fin = index.F[border]
    ok = cores_star[fin]
    border_ids = np.nonzero(border)[0]
    labels[border_ids[ok]] = labels[fin[ok]]
    return labels
