"""Exact ε*- and MinPts*-queries over a FINEX-ordering (§5.3, §5.4).

These are the paper's headline feature: after one build at the generating
(ε, MinPts), any (ε* ≤ ε, MinPts) or (ε, MinPts* ≥ MinPts) clustering is
*exact* (Definition 3.5) at a fraction of DBSCAN-from-scratch cost.

ε*-query (Theorem 5.6):   Alg. 1 scan → candidate former-cores
  (noise-labeled, ε* < C ≤ ε, processed before S_i's first object, same
  sparse cluster) → verified by a *batched device* distance computation
  against only the ε*-cores of the candidate's sparse cluster; the
  first-hit selection over each verification sub-matrix is a single
  masked argmax, not a per-candidate scan. This inherits both of the
  paper's §5.3 savings: (i) distances only against cluster cores, not D;
  (ii) early termination (block-level).

MinPts*-query (§5.4):      exact sparse clustering filters noise →
  Alg. 4 as *one* union-find/connected-components pass over the
  core-restricted CSR (with the paper's fast path when no core loses
  status) → border objects placed through their finder reference F[o]
  with *zero* neighborhood computations.

The loop-based originals live in ``repro.core.reference``;
``tests/test_vectorized_equivalence.py`` pins byte-identical labels.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import ClassVar, List, Optional, Sequence, Tuple, Union

import numpy as np
from scipy.sparse import csr_matrix
from scipy.sparse.csgraph import connected_components

from repro import obs
from repro.core.extract import (cluster_spans, query_clustering,
                                query_clustering_batch)
from repro.core.ordering import FinexOrdering
from repro.neighbors.engine import CSRNeighborhoods, NeighborEngine


@dataclass
class QueryStats:
    """Instrumentation mirroring the paper's efficiency arguments."""
    candidates: int = 0
    verification_pairs: int = 0       # candidate×core distances computed
    screened_pairs: int = 0           # pairs the projection screen skipped
    neighborhoods_computed: int = 0   # full-row neighborhood computations
    fast_path: bool = False


def eps_star_query(index: FinexOrdering, engine: NeighborEngine,
                   eps_star: float, stats: Optional[QueryStats] = None,
                   verify_batch: int = 4096) -> np.ndarray:
    """Exact clustering w.r.t. (ε*, MinPts), ε* ≤ ε  (Theorem 5.6)."""
    if stats is None:
        stats = QueryStats()
    eps_star = float(np.float32(eps_star))        # float32 distance domain
    eps_gen = float(np.float32(index.eps))
    labels = query_clustering(index, eps_star)
    if eps_star >= eps_gen:           # Corollary 5.5: scan is already exact
        return labels

    # -- candidates: former-cores labeled noise (cond. 1) ----------------
    cand_mask = (labels < 0) & (index.C > eps_star) & (index.C <= eps_gen)
    candidates = np.nonzero(cand_mask)[0]
    stats.candidates += len(candidates)   # cumulative, like the pair count
    if len(candidates) == 0:
        return labels

    # -- sparse exact clustering w.r.t. (ε, MinPts) for cond. 3 ----------
    sparse = query_clustering(index, index.eps)

    first, _ = cluster_spans(index, labels)
    m = first.shape[0]

    # ε*-cores per approximate cluster (these are already in S: Thm 5.2c),
    # ordered by (cluster, object id) so per-cluster core blocks are the
    # ascending-id lists the first-hit semantics below rely on
    core_star_ids = np.nonzero((index.C <= eps_star) & (labels >= 0))[0]
    core_lab = labels[core_star_ids]
    by_lab = np.argsort(core_lab, kind="stable")
    sorted_cores = core_star_ids[by_lab]
    sorted_lab = core_lab[by_lab]

    # sparse cluster of each S_i (Prop. 3.9: unique). Read it off an
    # ε*-core: cores are unambiguous in the exact sparse partition, while
    # a border member of S_i may be *assigned* to a different sparse
    # cluster it also touches. Reverse assignment keeps the first
    # (smallest-id) core per cluster.
    sparse_of_S = np.full(m, -1, dtype=np.int64)
    sparse_of_S[sorted_lab[::-1]] = sparse[sorted_cores[::-1]]
    core_group = sparse_of_S[sorted_lab]          # sparse cluster per core

    # Batched verification, grouped by sparse cluster: one device call per
    # (candidate-group × core-set) computes the whole sub-matrix. The
    # paper's per-candidate early exit (§5.3 discussion, point ii) suits a
    # CPU; on an accelerator one batched tile beats thousands of tiny
    # early-exit probes — same exactness, counted pairs are higher but
    # wall time is far lower (benchmarked in Fig 6/7 harness).
    order_pos = index.pos
    cand_sparse = sparse[candidates]
    for k in np.unique(cand_sparse[cand_sparse >= 0]):
        sel = core_group == k
        if not sel.any():
            continue
        core_ids = sorted_cores[sel]
        core_cluster = sorted_lab[sel]
        cand_arr = candidates[cand_sparse == k]
        unassigned = np.ones(len(cand_arr), bool)
        for s in range(0, len(core_ids), verify_batch):
            blk = slice(s, s + verify_batch)
            sub = cand_arr[unassigned]
            cols_blk = core_ids[blk]
            clus_blk = core_cluster[blk]
            # projection screen over the verification sub-matrix: a core
            # column no candidate admits provably holds no hit (the
            # screen bound exceeds ε* ⇒ the true distance does), so it
            # drops from the block before any distance is computed.
            # Surviving columns keep their relative (cluster, id) order,
            # so the masked-argmax first hit is unchanged.
            admit = engine.screen_admit(sub, cols_blk, eps_star)
            if admit is not None:
                kpos = np.flatnonzero(admit.any(axis=0))
                stats.screened_pairs += \
                    int(sub.size) * (len(cols_blk) - kpos.size)
                if kpos.size == 0:
                    continue
                cols_blk, clus_blk = cols_blk[kpos], clus_blk[kpos]
            d = engine.pair_distances(sub, cols_blk)
            stats.verification_pairs += d.size
            # first hit per candidate row: masked argmax over the block
            ok = (d <= eps_star) & \
                (first[clus_blk][None, :] > order_pos[sub][:, None])
            got = ok.any(axis=1)
            hit = np.argmax(ok, axis=1)
            labels[sub[got]] = clus_blk[hit[got]]
            unassigned = labels[cand_arr] < 0
            if not unassigned.any():       # cond. 4: everyone placed
                break
    return labels


def _compute_core_clustering(cores: np.ndarray, csr: CSRNeighborhoods,
                             sparse: np.ndarray, labels_out: np.ndarray,
                             stats: QueryStats) -> int:
    """Algorithm 4, vectorized: components of cores under the ε-graph.

    ``cores`` must be sorted; adjacency is the generating-ε CSR restricted
    to the core set (the paper's ``N_ε(x) ∩ Cores``), evaluated as one
    union-find (connected-components) pass over the induced subgraph.
    Component labels replicate the sequential per-sparse-cluster BFS
    numbering: clusters in sparse-id order, components within a cluster in
    smallest-core-id order. (Components never straddle sparse clusters —
    two ε-reachable generating cores are density-connected.)
    Returns the number of labels assigned.
    """
    n = labels_out.shape[0]
    if cores.size == 0:
        return 0
    in_cores = np.zeros(n, dtype=bool)
    in_cores[cores] = True
    seg = csr.row_ids()
    keep = in_cores[seg] & in_cores[csr.indices]
    # assemble the induced subgraph directly in CSR form (rows of `keep`
    # are already sorted), skipping scipy's COO→CSR conversion pass;
    # int32 indices while they fit (scipy's native dtype), int64 beyond
    sub_rows64 = seg[keep]
    idx_dtype = (np.int32 if sub_rows64.size <= np.iinfo(np.int32).max
                 else np.int64)
    remap = np.full(n, -1, dtype=idx_dtype)
    remap[cores] = np.arange(cores.size, dtype=idx_dtype)
    sub_rows = remap[sub_rows64]
    sub_indptr = np.zeros(cores.size + 1, dtype=idx_dtype)
    np.cumsum(np.bincount(sub_rows, minlength=cores.size),
              out=sub_indptr[1:], dtype=idx_dtype)
    g = csr_matrix((np.ones(sub_rows.size, dtype=np.int8),
                    remap[csr.indices[keep]], sub_indptr),
                   shape=(cores.size, cores.size))
    ncomp, comp = connected_components(g, directed=False)
    stats.neighborhoods_computed += int(cores.size)
    # representative of each component = its first (smallest-id) core
    _, first_pos = np.unique(comp, return_index=True)
    rank = np.lexsort((cores[first_pos], sparse[cores[first_pos]]))
    label_of = np.empty(ncomp, dtype=np.int64)
    label_of[rank] = np.arange(ncomp)
    labels_out[cores] = label_of[comp]
    return ncomp


def minpts_star_query(index: FinexOrdering, csr: CSRNeighborhoods,
                      minpts_star: int, stats: Optional[QueryStats] = None
                      ) -> np.ndarray:
    """Exact clustering w.r.t. (ε, MinPts*), MinPts* ≥ MinPts  (§5.4)."""
    if stats is None:
        stats = QueryStats()
    if minpts_star < index.minpts:
        raise ValueError("MinPts* must be >= generating MinPts")

    n = index.n
    # step 1: exact sparse clustering; discard its noise (Prop. 5.7)
    sparse = query_clustering(index, index.eps)
    labels = np.full(n, -1, dtype=np.int64)

    cores_star = (index.N >= minpts_star)          # o.N ≥ MinPts*  — no
    # neighborhood computation needed to decide core status (§5.4)

    # fast path: no object straddles [MinPts, MinPts*) ⇒ every sparse core
    # keeps core status ⇒ components are the sparse clusters themselves.
    demoted = (index.N >= index.minpts) & (index.N < minpts_star)
    if not np.any(demoted):
        stats.fast_path = True
        labels[:] = np.where(sparse >= 0, sparse, -1)
        return labels

    # step 2: Algorithm 4 over all preserved cores at once (a core is
    # never sparse noise, so the sparse filter is implicit)
    kcores = np.nonzero(cores_star & (sparse >= 0))[0]
    _compute_core_clustering(kcores, csr, sparse, labels, stats)

    # step 3: borders via finder references — F[o] is the densest core
    # reaching o, so o is a border iff N[F[o]] ≥ MinPts* (no distances!)
    border = (sparse >= 0) & (~cores_star)
    fin = index.F[border]
    ok = cores_star[fin]
    border_ids = np.nonzero(border)[0]
    labels[border_ids[ok]] = labels[fin[ok]]
    return labels


# ----------------------------------------------------- batched sweep kernels
# The serving hot path (repro.service.SweepPlanner) answers K parameter
# settings against one index. Answering them one scalar query at a time
# repeats work that is setting-independent: the Algorithm-1 scan inputs,
# the exact sparse clustering, the verification distance sub-matrices
# (ε*-queries) and the core-graph traversal (MinPts*-queries). The two
# kernels below share all four. Row k of each result is byte-identical to
# the corresponding scalar query (pinned by tests/test_service.py against
# ``reference_sweep_labels`` and the facade).


def _gather_csr_rows(csr: CSRNeighborhoods, rows: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Concatenated (source, neighbor) pairs of the given CSR rows.
    Neighbor ids keep the CSR's native dtype (they only index arrays)."""
    starts = csr.indptr[rows]
    lens = csr.indptr[rows + 1] - starts
    total = int(lens.sum())
    if total == 0:
        return (np.empty(0, dtype=np.int64),
                np.empty(0, dtype=csr.indices.dtype))
    # flat CSR positions: row-start offset + within-row rank
    seg_base = np.cumsum(lens) - lens
    pos = np.repeat(starts - seg_base, lens) + np.arange(total)
    return np.repeat(rows, lens), csr.indices[pos]


def eps_star_batch(index: FinexOrdering, engine: NeighborEngine,
                   eps_stars, stats: Optional[QueryStats] = None,
                   verify_batch: int = 4096) -> np.ndarray:
    """K exact ε*-queries as one batched pass: (K, n) labels.

    Shared across settings: the (K, n) Algorithm-1 scan, the exact sparse
    clustering, and — the expensive part — the verification distances.
    Candidates and ε*-cores live in setting-independent sparse clusters
    (an ε*-core's sparse cluster is its own sparse label, Prop. 3.9), so
    one (union-candidates × union-cores) distance sub-matrix per sparse
    cluster serves every setting; each setting then reduces its slice with
    the same masked-argmax first-hit as the scalar query.
    ``stats.candidates`` accumulates per-setting (mirroring K scalar
    calls); ``stats.verification_pairs`` counts pairs actually computed,
    i.e. after cross-setting sharing.
    """
    if stats is None:
        stats = QueryStats()
    with obs.span("queries.eps_star_batch", n=index.n,
                  k=int(np.atleast_1d(eps_stars).size)) as sp:
        labels = _eps_star_batch_impl(index, engine, eps_stars, stats,
                                      verify_batch)
        sp.annot(candidates=stats.candidates,
                 verification_pairs=stats.verification_pairs,
                 screened_pairs=stats.screened_pairs)
        if obs.enabled():
            obs.count("queries.eps_star_batches")
            obs.count("queries.verification_pairs",
                      stats.verification_pairs)
            obs.count("queries.screened_pairs", stats.screened_pairs)
    return labels


def _eps_star_batch_impl(index, engine, eps_stars, stats,
                         verify_batch=4096):
    # untraced body of :func:`eps_star_batch`
    es = np.asarray([float(np.float32(e)) for e in np.atleast_1d(eps_stars)],
                    dtype=np.float64)
    eps_gen = float(np.float32(index.eps))
    labels = query_clustering_batch(index, es)
    if es.size == 0:
        return labels
    C = index.C
    cand_masks = ((labels < 0) & (C[None, :] > es[:, None])
                  & (C[None, :] <= eps_gen))
    cand_masks[es >= eps_gen] = False     # Corollary 5.5: scan already exact
    stats.candidates += int(cand_masks.sum())
    live = np.nonzero(cand_masks.any(axis=1))[0]
    if live.size == 0:
        return labels

    sparse = query_clustering(index, index.eps)           # shared, once
    firsts = {k: cluster_spans(index, labels[k])[0] for k in live}
    # union ε*-core set over the live settings (cores of S_i are already
    # in S_i: Thm 5.2c, so membership is labels[k] >= 0)
    core_union = ((C[None, :] <= es[live, None])
                  & (labels[live] >= 0)).any(axis=0)
    order_pos = index.pos
    # per-candidate column budget: a candidate of setting k only ever
    # needs distances to that setting's cores, i.e. C ≤ es[k]; the
    # largest ε* listing the object as a candidate bounds all of them
    max_es = np.where(cand_masks[live], es[live, None], -np.inf).max(axis=0)

    cand_ids_all = np.nonzero(cand_masks[live].any(axis=0))[0]
    cand_groups = sparse[cand_ids_all]
    for g in np.unique(cand_groups[cand_groups >= 0]):
        cand_g = cand_ids_all[cand_groups == g]           # ascending ids
        core_g = np.nonzero(core_union & (sparse == g))[0]
        if core_g.size == 0:
            continue
        # shared sub-matrix per sparse cluster, computed as a staircase:
        # columns ordered by (C, id) make every setting's core set a
        # prefix (an ε*-core is exactly C ≤ ε*), so each candidate row is
        # computed once, against exactly the columns its settings can use
        col_order = np.lexsort((core_g, C[core_g]))
        core_gc = core_g[col_order]
        Cgc = C[core_gc]
        budgets = max_es[cand_g]
        D = np.full((cand_g.size, core_gc.size), np.inf, dtype=np.float32)
        for b in np.unique(budgets):
            rows_b = np.nonzero(budgets == b)[0]
            ncols = int(np.searchsorted(Cgc, b, side="right"))
            if ncols == 0:
                continue
            for s in range(0, ncols, verify_batch):
                e = min(s + verify_batch, ncols)
                cols_blk = core_gc[s:e]
                # screen the staircase block at the row budget b: a pair
                # not admitted at b is not admitted at any setting these
                # rows serve (es[k] <= b), so its D entry may stay inf —
                # every setting's ``sub <= es[k]`` test then rejects it
                # exactly as the computed distance would have
                admit = engine.screen_admit(cand_g[rows_b], cols_blk, b)
                if admit is not None:
                    kpos = np.flatnonzero(admit.any(axis=0))
                    stats.screened_pairs += \
                        rows_b.size * (cols_blk.size - kpos.size)
                    if kpos.size == 0:
                        continue
                    stats.verification_pairs += rows_b.size * kpos.size
                    D[rows_b[:, None], (s + kpos)[None, :]] = \
                        engine.pair_distances(cand_g[rows_b],
                                              cols_blk[kpos])
                else:
                    stats.verification_pairs += rows_b.size * (e - s)
                    D[rows_b, s:e] = engine.pair_distances(
                        cand_g[rows_b], cols_blk)
        for k in live:
            ck = cand_g[cand_masks[k][cand_g]]
            if ck.size == 0:
                continue
            csel = (Cgc <= es[k]) & (labels[k][core_gc] >= 0)
            if not csel.any():
                continue
            cpos = np.nonzero(csel)[0]
            ids = core_gc[cpos]
            clab = labels[k][ids]
            by_lab = np.lexsort((ids, clab))           # (cluster, id) order
            cpos, clab = cpos[by_lab], clab[by_lab]
            sub = D[np.searchsorted(cand_g, ck)[:, None], cpos[None, :]]
            ok = (sub <= es[k]) & \
                (firsts[k][clab][None, :] > order_pos[ck][:, None])
            got = ok.any(axis=1)
            hit = np.argmax(ok, axis=1)
            labels[k, ck[got]] = clab[hit[got]]
    return labels


def minpts_star_batch(index: FinexOrdering, csr: CSRNeighborhoods,
                      minpts_stars, stats: Optional[QueryStats] = None
                      ) -> np.ndarray:
    """K exact MinPts*-queries as one incremental pass: (K, n) labels.

    Core sets are nested — lowering MinPts* only ever *adds* cores — and
    connected components are incremental under node additions. Settings
    are processed once each (unique values, descending): each step
    activates the newly-cored objects, scans only *their* CSR rows against
    the active set, and merges into the running component structure via a
    condensed graph (previous components contracted to super-nodes). Every
    CSR entry is therefore touched at most once across the whole sweep,
    instead of once per setting as K scalar queries would.

    Component numbering replicates the scalar query exactly: clusters in
    (sparse id, smallest-core-id) rank order. ``stats.fast_path`` is set
    only when every setting hits the no-demotion fast path;
    ``stats.neighborhoods_computed`` counts unique activations.
    """
    if stats is None:
        stats = QueryStats()
    with obs.span("queries.minpts_star_batch", n=index.n,
                  k=int(np.atleast_1d(minpts_stars).size)) as sp:
        out = _minpts_star_batch_impl(index, csr, minpts_stars, stats)
        sp.annot(fast_path=stats.fast_path)
        if obs.enabled():
            obs.count("queries.minpts_star_batches")
    return out


def _minpts_star_batch_impl(index, csr, minpts_stars, stats):
    # untraced body of :func:`minpts_star_batch`
    ms = [int(m) for m in np.atleast_1d(minpts_stars)]
    if any(m < index.minpts for m in ms):
        raise ValueError("MinPts* must be >= generating MinPts")
    n = index.n
    out = np.empty((len(ms), n), dtype=np.int64)
    if not ms:
        return out
    sparse = query_clustering(index, index.eps)           # shared, once
    N, F = index.N, index.F

    # fast path per setting: nothing straddles [MinPts, MinPts*) ⇒ the
    # components are the sparse clusters themselves (§5.4)
    straddles = {m: bool(np.any((N >= index.minpts) & (N < m)))
                 for m in set(ms)}
    slow = sorted((m for m in set(ms) if straddles[m]), reverse=True)

    snapshots = {}
    if slow:
        # int32 component/slot ids throughout: scipy's native index dtype,
        # so the per-step graph assembly never round-trips through int64
        comp_of = np.full(n, -1, dtype=np.int32)   # node -> component id
        comp_min = np.empty(0, dtype=np.int64)     # comp -> smallest core
        comp_sparse = np.empty(0, dtype=np.int64)  # comp -> sparse cluster
        active = np.zeros(n, dtype=bool)
        active_ids = np.empty(0, dtype=np.int64)
        for m in slow:                      # descending: core sets grow
            cores_m = np.nonzero((N >= m) & (sparse >= 0))[0]
            fresh = cores_m[~active[cores_m]]
            ncomp_prev = comp_min.size
            if fresh.size:
                active[fresh] = True
                src, nb = _gather_csr_rows(csr, fresh)
                keep = active[nb]
                src, nb = src[keep], nb[keep]
                slot = np.full(n, -1, dtype=np.int32)
                slot[fresh] = np.arange(fresh.size, dtype=np.int32)
                u = np.int32(ncomp_prev) + slot[src]
                v = np.where(comp_of[nb] >= 0, comp_of[nb],
                             np.int32(ncomp_prev) + slot[nb])
                m_nodes = ncomp_prev + fresh.size
                g = csr_matrix((np.ones(u.size, dtype=np.int8), (u, v)),
                               shape=(m_nodes, m_nodes))
                ncomp, cc = connected_components(g, directed=False)
                nm = np.full(ncomp, n, dtype=np.int64)
                np.minimum.at(nm, cc[:ncomp_prev], comp_min)
                np.minimum.at(nm, cc[ncomp_prev:], fresh)
                nsp = np.empty(ncomp, dtype=np.int64)
                nsp[cc[ncomp_prev:]] = sparse[fresh]
                nsp[cc[:ncomp_prev]] = comp_sparse
                cc = cc.astype(np.int32, copy=False)
                comp_of[active_ids] = cc[comp_of[active_ids]]
                comp_of[fresh] = cc[ncomp_prev + np.arange(fresh.size)]
                active_ids = np.concatenate([active_ids, fresh])
                comp_min, comp_sparse = nm, nsp
                stats.neighborhoods_computed += int(fresh.size)
            row = np.full(n, -1, dtype=np.int64)
            ncomp = comp_min.size
            if ncomp:
                rank = np.lexsort((comp_min, comp_sparse))
                label_of = np.empty(ncomp, dtype=np.int64)
                label_of[rank] = np.arange(ncomp)
                row[active_ids] = label_of[comp_of[active_ids]]
            # borders via finder references, zero distances (§5.4)
            cores_star = N >= m
            border = (sparse >= 0) & (~cores_star)
            fin = F[border]
            okb = cores_star[fin]
            border_ids = np.nonzero(border)[0]
            row[border_ids[okb]] = row[fin[okb]]
            snapshots[m] = row
    else:
        stats.fast_path = True

    fast_row = None
    for i, m in enumerate(ms):
        if straddles[m]:
            out[i] = snapshots[m]
        else:
            if fast_row is None:
                fast_row = np.where(sparse >= 0, sparse, -1)
            out[i] = fast_row
    return out


# ------------------------------------------------------ typed query settings
# The query surface grew up on bare ("eps", v) tuples; the typed settings
# below are the canonical spelling going forward (they survive adding new
# query kinds — see ``Hierarchy`` — where positional tuples would force
# every dispatcher to grow another string case). ``normalize_settings`` is
# the single normalization shim: every consumer (``SweepPlanner.sweep``,
# ``SweepOp``, ``SweepRequest``, the serve CLI) routes through it, so
# tuple-based callers keep working unchanged.


@dataclass(frozen=True)
class Eps:
    """An exact ε*-query setting (ε* ≤ generating ε) — Theorem 5.6."""
    value: float
    kind: ClassVar[str] = "eps"


@dataclass(frozen=True)
class MinPts:
    """An exact MinPts*-query setting (MinPts* ≥ generating MinPts) —
    §5.4, zero distance computations."""
    value: int
    kind: ClassVar[str] = "minpts"


@dataclass(frozen=True)
class Hierarchy:
    """A stability-extraction setting: the labels row is the flat
    clustering ``FinexIndex.hierarchy(min_cluster_weight).extract()``
    selects from the condensed cluster tree (``repro.core.hierarchy``).
    ``min_cluster_weight=None`` condenses at the generating MinPts."""
    min_cluster_weight: Optional[int] = None
    kind: ClassVar[str] = "hierarchy"

    @property
    def value(self) -> int:
        # tuple-normal form carries 0 for "default" so the shim stays a
        # plain (kind, number) pair
        return int(self.min_cluster_weight or 0)


Setting = Union[Eps, MinPts, Hierarchy, Tuple[str, float]]

_SETTING_KINDS = ("eps", "minpts", "hierarchy")


def normalize_settings(settings: Sequence[Setting]
                       ) -> List[Tuple[str, float]]:
    """Canonicalize a mixed typed/tuple settings sequence.

    Returns plain ("eps"|"minpts"|"hierarchy", value) pairs — the wire
    format every batched kernel and oplog already speaks. Bare 2-tuples
    pass through (validated), so no existing caller breaks.
    """
    out: List[Tuple[str, float]] = []
    for i, s in enumerate(settings):
        if isinstance(s, (Eps, MinPts, Hierarchy)):
            out.append((s.kind, s.value))
            continue
        try:
            kind, value = s
        except (TypeError, ValueError):
            raise TypeError(
                f"sweep setting at position {i} must be Eps/MinPts/"
                f"Hierarchy or a (kind, value) pair, got {s!r}") from None
        if kind not in _SETTING_KINDS:
            raise ValueError(
                f"unknown sweep setting kind {kind!r} at position {i} "
                "(expected 'eps', 'minpts' or 'hierarchy')")
        out.append((kind, value))
    return out


# ------------------------------------------------------- unified result type
class ClusteringResult(np.ndarray):
    """Labels + provenance — the one response type every query surface
    returns (facade queries, planner sweeps, frontend futures).

    An ``np.ndarray`` subclass: it IS the label array ((n,) for scalar
    queries, (K, n) for sweeps), so every existing caller that indexes,
    compares or reduces the old bare ndarray keeps working byte-for-byte.
    The provenance travels as attributes:

      * ``kind``    — "eps" | "minpts" | "generating" | "stability" |
                      "sweep"
      * ``value``   — the query parameter (None for generating/sweep)
      * ``version`` — the index's mutation counter when answered
      * ``eps`` / ``minpts`` — the generating pair
      * ``elapsed_s`` — wall time of the answering call
      * ``settings``  — normalized settings list (sweep results)
      * ``index_name`` — logical name (frontend results)

    Deprecation cycle: ``.labels`` and ``.index`` mirror the retired
    ``SweepResult`` response object's attribute names.
    """

    _meta = ("kind", "value", "version", "eps", "minpts", "elapsed_s",
             "settings", "index_name")

    @classmethod
    def wrap(cls, labels: np.ndarray, *, kind: str, value=None,
             version: int = 0, eps=None, minpts=None, elapsed_s=None,
             settings=None, index_name=None) -> "ClusteringResult":
        obj = np.asarray(labels).view(cls)
        obj.kind = kind
        obj.value = value
        obj.version = int(version)
        obj.eps = eps
        obj.minpts = minpts
        obj.elapsed_s = elapsed_s
        obj.settings = settings
        obj.index_name = index_name
        return obj

    def __array_finalize__(self, obj):
        if obj is None:
            return
        for f in self._meta:
            setattr(self, f, getattr(obj, f, None))

    # --- one-deprecation-cycle aliases (the old SweepResult shape) ---
    @property
    def labels(self) -> np.ndarray:
        """The bare label array (plain ndarray view)."""
        return self.view(np.ndarray)

    @property
    def index(self):
        """Logical index name this result was served for (frontend)."""
        return self.index_name
