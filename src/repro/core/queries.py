"""Exact ε*- and MinPts*-queries over a FINEX-ordering (§5.3, §5.4).

These are the paper's headline feature: after one build at the generating
(ε, MinPts), any (ε* ≤ ε, MinPts) or (ε, MinPts* ≥ MinPts) clustering is
*exact* (Definition 3.5) at a fraction of DBSCAN-from-scratch cost.

ε*-query (Theorem 5.6):   Alg. 1 scan → candidate former-cores
  (noise-labeled, ε* < C ≤ ε, processed before S_i's first object, same
  sparse cluster) → verified by a *batched device* distance computation
  against only the ε*-cores of the candidate's sparse cluster; the
  first-hit selection over each verification sub-matrix is a single
  masked argmax, not a per-candidate scan. This inherits both of the
  paper's §5.3 savings: (i) distances only against cluster cores, not D;
  (ii) early termination (block-level).

MinPts*-query (§5.4):      exact sparse clustering filters noise →
  Alg. 4 as *one* union-find/connected-components pass over the
  core-restricted CSR (with the paper's fast path when no core loses
  status) → border objects placed through their finder reference F[o]
  with *zero* neighborhood computations.

The loop-based originals live in ``repro.core.reference``;
``tests/test_vectorized_equivalence.py`` pins byte-identical labels.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np
from scipy.sparse import csr_matrix
from scipy.sparse.csgraph import connected_components

from repro.core.extract import cluster_spans, query_clustering
from repro.core.ordering import FinexOrdering
from repro.neighbors.engine import CSRNeighborhoods, NeighborEngine


@dataclass
class QueryStats:
    """Instrumentation mirroring the paper's efficiency arguments."""
    candidates: int = 0
    verification_pairs: int = 0       # candidate×core distances computed
    neighborhoods_computed: int = 0   # full-row neighborhood computations
    fast_path: bool = False


def eps_star_query(index: FinexOrdering, engine: NeighborEngine,
                   eps_star: float, stats: Optional[QueryStats] = None,
                   verify_batch: int = 4096) -> np.ndarray:
    """Exact clustering w.r.t. (ε*, MinPts), ε* ≤ ε  (Theorem 5.6)."""
    if stats is None:
        stats = QueryStats()
    eps_star = float(np.float32(eps_star))        # float32 distance domain
    eps_gen = float(np.float32(index.eps))
    labels = query_clustering(index, eps_star)
    if eps_star >= eps_gen:           # Corollary 5.5: scan is already exact
        return labels

    # -- candidates: former-cores labeled noise (cond. 1) ----------------
    cand_mask = (labels < 0) & (index.C > eps_star) & (index.C <= eps_gen)
    candidates = np.nonzero(cand_mask)[0]
    stats.candidates += len(candidates)   # cumulative, like the pair count
    if len(candidates) == 0:
        return labels

    # -- sparse exact clustering w.r.t. (ε, MinPts) for cond. 3 ----------
    sparse = query_clustering(index, index.eps)

    first, _ = cluster_spans(index, labels)
    m = first.shape[0]

    # ε*-cores per approximate cluster (these are already in S: Thm 5.2c),
    # ordered by (cluster, object id) so per-cluster core blocks are the
    # ascending-id lists the first-hit semantics below rely on
    core_star_ids = np.nonzero((index.C <= eps_star) & (labels >= 0))[0]
    core_lab = labels[core_star_ids]
    by_lab = np.argsort(core_lab, kind="stable")
    sorted_cores = core_star_ids[by_lab]
    sorted_lab = core_lab[by_lab]

    # sparse cluster of each S_i (Prop. 3.9: unique). Read it off an
    # ε*-core: cores are unambiguous in the exact sparse partition, while
    # a border member of S_i may be *assigned* to a different sparse
    # cluster it also touches. Reverse assignment keeps the first
    # (smallest-id) core per cluster.
    sparse_of_S = np.full(m, -1, dtype=np.int64)
    sparse_of_S[sorted_lab[::-1]] = sparse[sorted_cores[::-1]]
    core_group = sparse_of_S[sorted_lab]          # sparse cluster per core

    # Batched verification, grouped by sparse cluster: one device call per
    # (candidate-group × core-set) computes the whole sub-matrix. The
    # paper's per-candidate early exit (§5.3 discussion, point ii) suits a
    # CPU; on an accelerator one batched tile beats thousands of tiny
    # early-exit probes — same exactness, counted pairs are higher but
    # wall time is far lower (benchmarked in Fig 6/7 harness).
    order_pos = index.pos
    cand_sparse = sparse[candidates]
    for k in np.unique(cand_sparse[cand_sparse >= 0]):
        sel = core_group == k
        if not sel.any():
            continue
        core_ids = sorted_cores[sel]
        core_cluster = sorted_lab[sel]
        cand_arr = candidates[cand_sparse == k]
        unassigned = np.ones(len(cand_arr), bool)
        for s in range(0, len(core_ids), verify_batch):
            blk = slice(s, s + verify_batch)
            sub = cand_arr[unassigned]
            d = engine.pair_distances(sub, core_ids[blk])
            stats.verification_pairs += d.size
            # first hit per candidate row: masked argmax over the block
            ok = (d <= eps_star) & \
                (first[core_cluster[blk]][None, :] > order_pos[sub][:, None])
            got = ok.any(axis=1)
            hit = np.argmax(ok, axis=1)
            labels[sub[got]] = core_cluster[blk][hit[got]]
            unassigned = labels[cand_arr] < 0
            if not unassigned.any():       # cond. 4: everyone placed
                break
    return labels


def _compute_core_clustering(cores: np.ndarray, csr: CSRNeighborhoods,
                             sparse: np.ndarray, labels_out: np.ndarray,
                             stats: QueryStats) -> int:
    """Algorithm 4, vectorized: components of cores under the ε-graph.

    ``cores`` must be sorted; adjacency is the generating-ε CSR restricted
    to the core set (the paper's ``N_ε(x) ∩ Cores``), evaluated as one
    union-find (connected-components) pass over the induced subgraph.
    Component labels replicate the sequential per-sparse-cluster BFS
    numbering: clusters in sparse-id order, components within a cluster in
    smallest-core-id order. (Components never straddle sparse clusters —
    two ε-reachable generating cores are density-connected.)
    Returns the number of labels assigned.
    """
    n = labels_out.shape[0]
    if cores.size == 0:
        return 0
    in_cores = np.zeros(n, dtype=bool)
    in_cores[cores] = True
    seg = csr.row_ids()
    keep = in_cores[seg] & in_cores[csr.indices]
    # assemble the induced subgraph directly in CSR form (rows of `keep`
    # are already sorted), skipping scipy's COO→CSR conversion pass;
    # int32 indices while they fit (scipy's native dtype), int64 beyond
    sub_rows64 = seg[keep]
    idx_dtype = (np.int32 if sub_rows64.size <= np.iinfo(np.int32).max
                 else np.int64)
    remap = np.full(n, -1, dtype=idx_dtype)
    remap[cores] = np.arange(cores.size, dtype=idx_dtype)
    sub_rows = remap[sub_rows64]
    sub_indptr = np.zeros(cores.size + 1, dtype=idx_dtype)
    np.cumsum(np.bincount(sub_rows, minlength=cores.size),
              out=sub_indptr[1:], dtype=idx_dtype)
    g = csr_matrix((np.ones(sub_rows.size, dtype=np.int8),
                    remap[csr.indices[keep]], sub_indptr),
                   shape=(cores.size, cores.size))
    ncomp, comp = connected_components(g, directed=False)
    stats.neighborhoods_computed += int(cores.size)
    # representative of each component = its first (smallest-id) core
    _, first_pos = np.unique(comp, return_index=True)
    rank = np.lexsort((cores[first_pos], sparse[cores[first_pos]]))
    label_of = np.empty(ncomp, dtype=np.int64)
    label_of[rank] = np.arange(ncomp)
    labels_out[cores] = label_of[comp]
    return ncomp


def minpts_star_query(index: FinexOrdering, csr: CSRNeighborhoods,
                      minpts_star: int, stats: Optional[QueryStats] = None
                      ) -> np.ndarray:
    """Exact clustering w.r.t. (ε, MinPts*), MinPts* ≥ MinPts  (§5.4)."""
    if stats is None:
        stats = QueryStats()
    if minpts_star < index.minpts:
        raise ValueError("MinPts* must be >= generating MinPts")

    n = index.n
    # step 1: exact sparse clustering; discard its noise (Prop. 5.7)
    sparse = query_clustering(index, index.eps)
    labels = np.full(n, -1, dtype=np.int64)

    cores_star = (index.N >= minpts_star)          # o.N ≥ MinPts*  — no
    # neighborhood computation needed to decide core status (§5.4)

    # fast path: no object straddles [MinPts, MinPts*) ⇒ every sparse core
    # keeps core status ⇒ components are the sparse clusters themselves.
    demoted = (index.N >= index.minpts) & (index.N < minpts_star)
    if not np.any(demoted):
        stats.fast_path = True
        labels[:] = np.where(sparse >= 0, sparse, -1)
        return labels

    # step 2: Algorithm 4 over all preserved cores at once (a core is
    # never sparse noise, so the sparse filter is implicit)
    kcores = np.nonzero(cores_star & (sparse >= 0))[0]
    _compute_core_clustering(kcores, csr, sparse, labels, stats)

    # step 3: borders via finder references — F[o] is the densest core
    # reaching o, so o is a border iff N[F[o]] ≥ MinPts* (no distances!)
    border = (sparse >= 0) & (~cores_star)
    fin = index.F[border]
    ok = cores_star[fin]
    border_ids = np.nonzero(border)[0]
    labels[border_ids[ok]] = labels[fin[ok]]
    return labels
