"""``FinexIndex`` — the one-build / many-queries facade.

Everything the paper promises behind a single object: construct once at a
permissive generating (ε, MinPts) — the expensive device tile sweep plus
the host ordering sweep — then answer any (ε* ≤ ε, MinPts) or
(ε, MinPts* ≥ MinPts) clustering *exactly* (Definition 3.5) without
touching the raw data again (ε*-queries still batch a small verification
sub-matrix through the engine; MinPts*-queries need zero distances).

    from repro.core import FinexIndex

    index = FinexIndex.build(x, eps=0.5, minpts=10)      # once
    a = index.clustering()                               # (ε, MinPts)
    b = index.eps_star(0.2)                              # (0.2, MinPts)
    c = index.minpts_star(60)                            # (ε, 60)
    h = index.hierarchy()                                # ALL scales
    h.cut(0.2); h.cut_minpts(60); h.extract()            # zero distances
    index.save("index.npz"); FinexIndex.load("index.npz", data=x)

    Queries return ``repro.core.queries.ClusteringResult`` — an ndarray
    of labels carrying the query kind, index version and timing, so it
    drops into every existing label-array call site unchanged.

The facade is the integration surface for the rest of the repo: the
quickstart example, the paper-table benchmarks, the data-curation
pipeline and the checkpoint manager all go through it, so later scaling
PRs (sharded materialize, serving, caching) only have one seam to cut.
"""
from __future__ import annotations

import json
import time
import warnings
from typing import Dict, Optional

import numpy as np

from repro import obs
from repro.core.build import finex_build, finex_sweep
from repro.core.delta import (SlackCSR, core_components,
                              merge_insert_components, splice_delete,
                              splice_insert, stitch, subset_core_distances,
                              subset_csr)
from repro.core.extract import query_clustering
from repro.core.hierarchy import ClusterHierarchy, build_hierarchy
from repro.core.ordering import FinexOrdering
from repro.core.queries import (ClusteringResult, QueryStats,
                                eps_star_query, minpts_star_query)
from repro.metrics import Metric, MetricLike, get_metric, registered_metrics
from repro.neighbors.engine import CSRNeighborhoods, NeighborEngine

# the flat-array serialization contract of to_arrays()/from_arrays():
# every key must be present for reconstruction, so a truncated or
# foreign npz fails loudly up front instead of KeyError-ing mid-rebuild
REQUIRED_ARRAY_KEYS = (
    "eps", "minpts", "order", "pos", "C", "R", "N", "F",
    "csr_indptr", "csr_indices", "csr_dists", "weights", "metric",
)


class FinexIndex:
    """A built FINEX-ordering bundled with its CSR and distance engine."""

    def __init__(self, ordering: FinexOrdering, csr: CSRNeighborhoods,
                 engine: Optional[NeighborEngine] = None,
                 metric: MetricLike = "euclidean",
                 weights: Optional[np.ndarray] = None,
                 fingerprint: Optional[str] = None,
                 version: int = 0, delta_log: Optional[list] = None,
                 comp: Optional[np.ndarray] = None,
                 run_id: Optional[np.ndarray] = None,
                 run_triggers: Optional[np.ndarray] = None):
        self.ordering = ordering
        self.csr = csr
        self.engine = engine
        # slack mode (see repro.core.delta.SlackCSR): None = packed
        # splices; a config dict re-pads the CSR on the next insert so
        # consecutive insert batches splice in place. Counters are
        # facade-held so they survive relayout object swaps.
        self._slack: Optional[dict] = None
        self._slack_stats = {"in_place_splices": 0, "relayouts": 0}
        # --- incremental-maintenance state (see repro.core.delta) ---
        # version: monotonically bumped per mutation; delta_log: one
        # report dict per applied insert/delete (the npz round-trips
        # both). comp/run_id/run_triggers are the sweep decomposition
        # that lets deltas re-sweep only affected components; indexes
        # loaded from archives that predate them (None) still mutate
        # exactly, through the full-resweep fallback.
        self.version = int(version)
        self.delta_log: list = list(delta_log) if delta_log else []
        self._comp = comp
        self._run_id = run_id
        self._run_triggers = run_triggers
        # the resolved Metric instance travels with the index even when no
        # engine is attached, so the npz round-trip can persist its
        # registry name + params and engine re-attach resolves identically
        self._metric_obj: Metric = (engine.metric if engine is not None
                                    else get_metric(metric))
        # duplicate weights live on the index itself so an engine-less
        # (lean-loaded) index round-trips them instead of dropping to ones
        if engine is not None:
            self.weights = engine.weights
        elif weights is not None:
            self.weights = np.asarray(weights, dtype=np.int64)
        else:
            self.weights = np.ones(ordering.n, dtype=np.int64)
        # dataset identity travels with the index (and through npz
        # round-trips) so load(data=...) can refuse the wrong dataset;
        # with an engine attached it is derived lazily (hashing the whole
        # dataset is not free) and the engine's identity always wins
        self._data_fingerprint = fingerprint
        self.query_stats = QueryStats()     # cumulative, resettable
        # the condensed cluster tree (repro.core.hierarchy): built lazily
        # on first hierarchy() call, invalidated by mutations — the same
        # build-once-pays-nothing pattern as the component labels
        self._hier: Optional[ClusterHierarchy] = None

    @property
    def metric(self) -> str:
        """Registry name of this index's metric (what manifests, npz
        archives and ``stats()`` record)."""
        return self._metric_obj.name

    @property
    def metric_obj(self) -> Metric:
        """The resolved ``repro.metrics.Metric`` instance."""
        return self._metric_obj

    # ------------------------------------------------------ construction
    @classmethod
    def build(cls, data, eps: float, minpts: int, *,
              metric: MetricLike = "euclidean",
              weights: Optional[np.ndarray] = None,
              batch_rows: int = 256, use_pallas: bool = False,
              mesh=None, shard_cap: int = 1024, shard_row_chunk: int = 2048
              ) -> "FinexIndex":
        """Materialize neighborhoods on device and run the ordering sweep.

        ``data``: whatever ``metric`` canonicalizes — an (n, d) float
        array for the vector metrics, the (bits, sizes) pair from
        ``bitset.pack_sets`` for jaccard.  ``metric`` is a registry name
        or a ``repro.metrics.Metric`` instance.

        ``mesh``: a ``jax.sharding.Mesh`` routes the materialize step
        through the sharded ε-compacted CSR-emit
        (``neighbors.distributed.sharded_csr_materialize``) — every
        device sweeps its (rowblock × colblock) shard and only compacted
        pairs are gathered; the resulting CSR (and therefore the index)
        is byte-identical to the single-device build, for every
        registered metric.  ``shard_cap`` bounds per-row survivors per
        corpus shard (the emit refuses to truncate), ``shard_row_chunk``
        sizes each device's local tiles.
        """
        engine = NeighborEngine(data, metric=metric, weights=weights,
                                batch_rows=batch_rows, use_pallas=use_pallas)
        csr = None
        if mesh is not None:
            from repro.neighbors.distributed import sharded_csr_materialize
            csr = sharded_csr_materialize(data, eps, mesh, cap=shard_cap,
                                          row_chunk=shard_row_chunk,
                                          metric=engine.metric)
        return cls.from_engine(engine, eps, minpts, csr=csr)

    @classmethod
    def from_engine(cls, engine: NeighborEngine, eps: float, minpts: int,
                    csr: Optional[CSRNeighborhoods] = None) -> "FinexIndex":
        run_meta: dict = {}
        ordering, csr = finex_build(engine, eps, minpts, csr=csr,
                                    run_meta=run_meta)
        # component labels (the delta-update seam) are computed lazily on
        # the first mutation — build-once indexes never pay the O(nnz)
        # union-find; the run decomposition falls out of the sweep free
        return cls(ordering, csr, engine,
                   run_id=run_meta["run_id"],
                   run_triggers=run_meta["run_triggers"])

    # ----------------------------------------------------------- queries
    @property
    def eps(self) -> float:
        return self.ordering.eps

    @property
    def minpts(self) -> int:
        return self.ordering.minpts

    @property
    def n(self) -> int:
        return self.ordering.n

    @property
    def csr(self) -> CSRNeighborhoods:
        """The canonical packed CSR view — what queries, archives and
        spills consume. Under slack mode the raw storage is a
        ``SlackCSR`` and this packs lazily (one O(nnz) gather, cached
        until the next splice — a read window after a burst of coalesced
        mutations packs exactly once)."""
        raw = self._csr
        return raw.packed() if isinstance(raw, SlackCSR) else raw

    @csr.setter
    def csr(self, value) -> None:
        self._csr = value

    # --------------------------------------------------- slack splicing
    def enable_slack(self, slack: float = 1.5,
                     min_row_slack: int = 8) -> None:
        """Switch insert splices to slack-backed CSR arrays.

        Rows are over-allocated by ``slack`` (capacity ≈ len·slack, at
        least ``min_row_slack`` spare slots each) so consecutive insert
        batches splice in place — O(adds) instead of the packed path's
        O(nnz) reallocation per splice.  Re-padding happens lazily on
        the next insert; queries are unaffected (they read the packed
        view, cached per mutation generation). Exactness is unchanged —
        the packed view is byte-identical to the packed-splice result.
        """
        if slack < 1.0:
            raise ValueError(f"slack factor must be >= 1.0, got {slack:g}")
        self._slack = {"slack": float(slack),
                       "min_row_slack": int(min_row_slack)}

    def disable_slack(self) -> None:
        """Back to packed splices; the raw storage repacks immediately."""
        if isinstance(self._csr, SlackCSR):
            self._csr = self._csr.packed()
        self._slack = None

    @property
    def slack_enabled(self) -> bool:
        return self._slack is not None

    def slack_stats(self) -> dict:
        """Splice-amortization counters: how many insert splices landed
        in place vs forced an O(nnz) relayout."""
        raw = self._csr
        out = {"enabled": self._slack is not None,
               "in_place_splices": self._slack_stats["in_place_splices"],
               "relayouts": self._slack_stats["relayouts"]}
        if isinstance(raw, SlackCSR):
            out["capacity"] = raw.capacity
            out["nnz"] = raw.nnz
        return out

    def _wrap(self, labels: np.ndarray, kind: str, value,
              t0: float) -> ClusteringResult:
        return ClusteringResult.wrap(
            labels, kind=kind, value=value, version=self.version,
            eps=self.eps, minpts=self.minpts,
            elapsed_s=time.perf_counter() - t0)

    def clustering(self) -> ClusteringResult:
        """Exact labels at the generating (ε, MinPts) — Corollary 5.5."""
        t0 = time.perf_counter()
        labels = query_clustering(self.ordering, self.ordering.eps)
        return self._wrap(labels, "generating", None, t0)

    def eps_star(self, eps_star: float,
                 stats: Optional[QueryStats] = None) -> ClusteringResult:
        """Exact labels at (ε* ≤ ε, MinPts) — Theorem 5.6."""
        if self.engine is None:
            raise RuntimeError(
                "ε*-queries need the distance engine for verification; "
                "load the index with its raw data (FinexIndex.load(..., "
                "data=...)) or use minpts_star/clustering")
        t0 = time.perf_counter()
        with obs.span("index.eps_star", eps_star=float(eps_star),
                      n=self.n):
            labels = eps_star_query(self.ordering, self.engine, eps_star,
                                    stats=stats if stats is not None
                                    else self.query_stats)
        return self._wrap(labels, "eps", float(eps_star), t0)

    def minpts_star(self, minpts_star: int,
                    stats: Optional[QueryStats] = None) -> ClusteringResult:
        """Exact labels at (ε, MinPts* ≥ MinPts) — §5.4, zero distances."""
        t0 = time.perf_counter()
        with obs.span("index.minpts_star", minpts_star=int(minpts_star),
                      n=self.n):
            labels = minpts_star_query(self.ordering, self.csr,
                                       minpts_star,
                                       stats=stats if stats is not None
                                       else self.query_stats)
        return self._wrap(labels, "minpts", int(minpts_star), t0)

    # --------------------------------------------------------- hierarchy
    def hierarchy(self, min_cluster_weight: Optional[int] = None
                  ) -> ClusterHierarchy:
        """The condensed cluster tree over ALL (ε ≤ ε_gen, MinPts) scales.

        Built once from the ordering + CSR with zero new distance work
        (``repro.core.hierarchy``), cached until the next insert/delete,
        and rebuilt lazily after one — the same pattern as the component
        labels, so build-once indexes pay nothing until they ask.
        ``min_cluster_weight`` sets the condensation threshold (default:
        the generating MinPts); asking at a different threshold rebuilds.
        """
        W = int(min_cluster_weight if min_cluster_weight is not None
                else self.minpts)
        h = self._hier
        if h is None or h.min_cluster_weight != W:
            h = build_hierarchy(self.ordering, self.csr, self.weights,
                                W, version=self.version)
            self._hier = h
        return h

    def hierarchy_stats(self) -> dict:
        """Cache state of the condensed tree (what ``/stats`` surfaces):
        ``built`` is False until ``hierarchy()`` runs, and flips back on
        every mutation (the tree is invalidated, not eagerly rebuilt)."""
        if self._hier is None:
            return {"built": False}
        return {"built": True, **self._hier.stats()}

    # ---------------------------------------------- incremental updates
    def insert(self, points, *, weights: Optional[np.ndarray] = None,
               rebuild_threshold: float = 0.5) -> dict:
        """Append new objects and repair the index — an exact delta.

        The result is byte-identical to ``FinexIndex.build`` over the
        concatenated dataset (new objects take ids n..n+m-1), for every
        registered metric: only the new rows' (m, n+m) and (n, m)
        distance strips are computed (``NeighborEngine
        .strip_materialize``, same bit contract as the full sweep), the
        CSR is spliced in place, core distances are recomputed only for
        rows whose ε-neighborhood changed, and the ordering is repaired
        by re-sweeping only the affected core-incidence components
        (``repro.core.delta``).  When the affected set exceeds
        ``rebuild_threshold`` (as a fraction of the *post-mutation*
        object count) the ordering falls back —
        loudly — to a full re-sweep over the spliced CSR, which is still
        exact and still free of any O(n²) distance work.

        ``points`` is whatever the index's metric canonicalizes (for
        jaccard: sets packed against the dataset's universe). Returns
        the report dict, which is also appended to ``delta_log`` (no-op
        mutations return a ``mode="noop"`` report and are not logged).
        Exactness of the delta path additionally assumes the metric's
        ``pairwise`` is per-pair independent and bit-symmetric (true for
        every built-in; see ``repro.core.delta``) — on any failure the
        engine state is rolled back and the index left untouched.
        """
        if self.engine is None:
            raise RuntimeError(
                "index mutations need the distance engine; load the "
                "index with its raw data (FinexIndex.load(..., data=...))")
        eng = self.engine
        metric = self._metric_obj
        canon_new = metric.canonicalize(points)
        m = int(canon_new[0].shape[0])
        if m == 0:
            return self._noop_report("insert")
        n_old = self.n
        was_core = np.isfinite(self.ordering.C)
        if self._slack is not None and not isinstance(self._csr, SlackCSR):
            # lazy re-pad (first insert after enable_slack / a delete):
            # pure layout change, the logical content is untouched
            self._csr = SlackCSR.from_csr(self.csr,
                                          stats=self._slack_stats,
                                          **self._slack)
        # atomicity: the index's own fields are only assigned at the very
        # end of _apply_mutation, so restoring the engine on any failure
        # (bad weights, a non-bit-symmetric user metric tripping the
        # component-closure check, ...) leaves the whole index untouched.
        # Slack mode splices in place, so its logical extent is captured
        # too (O(n)) — restoring it un-publishes any tail writes.
        snap = eng.state_snapshot()
        csr_snap = (self._csr.splice_snapshot()
                    if isinstance(self._csr, SlackCSR) else None)
        with obs.span("index.insert", count=m, n=n_old,
                      metric=self.metric) as sp:
            try:
                report = self._insert_impl(canon_new, weights, m, n_old,
                                           was_core, rebuild_threshold)
            except BaseException:
                eng.state_restore(snap)
                if csr_snap is not None:
                    self._csr.splice_restore(csr_snap)
                raise
            sp.annot(mode=report["mode"],
                     affected=report["affected"])
            if obs.enabled():
                obs.count("delta.inserts")
                obs.count(f"delta.mode.{report['mode']}")
                obs.observe("delta.affected_frac",
                            report["affected_frac"])
            return report

    def _insert_impl(self, canon_new, weights, m: int, n_old: int,
                     was_core: np.ndarray,
                     rebuild_threshold: float) -> dict:
        eng = self.engine
        metric = self._metric_obj
        # component labels describe the PRE-insert graph: compute them
        # before the splice — slack mode appends into the live buffers,
        # so reading them afterwards would see the post-insert rows
        track_runs = (self._run_id is not None
                      and self._run_triggers is not None)
        comp = self._ensure_comp() if track_runs else None
        # append_rows re-canonicalizes the tuple; canonicalize is
        # documented idempotent (repro.metrics.Metric.canonicalize), so
        # this second pass is a no-copy identity
        eng.append_rows(canon_new, weights=weights)
        n_new = n_old + m
        new_ids = np.arange(n_old, n_new, dtype=np.int64)
        # ONE compacted (m, n+m) strip: the new rows against everything,
        # in exactly the full sweep's orientation and corpus extent
        new_state = metric.take(eng._state, slice(n_old, n_new))
        lens_a, cols_a, dists_a = eng.strip_materialize(new_state, self.eps)
        # the old rows' gained entries come from the SAME strip,
        # transposed: pairwise is bit-symmetric and the strip shares the
        # full sweep's corpus extent, so d(p, i) carries exactly the bits
        # a full build would write at (i, p) — a separate narrow-corpus
        # (n, m) sweep could not promise that (XLA lowers skinny matmuls
        # through different reduction orders)
        rows_a = np.repeat(np.arange(m, dtype=np.int64), lens_a)
        sel = cols_a < n_old
        old_i = cols_a[sel].astype(np.int64)
        by_row = np.argsort(old_i, kind="stable")   # keeps new-id order
        add_lens = np.bincount(old_i, minlength=n_old)
        add_cols = (rows_a[sel][by_row] + n_old).astype(np.int32)
        add_dists = dists_a[sel][by_row]
        if isinstance(self._csr, SlackCSR):
            csr_new = self._csr.append_batch(add_lens, add_cols, add_dists,
                                             lens_a, cols_a, dists_a)
        else:
            csr_new = splice_insert(self.csr, add_lens, add_cols, add_dists,
                                    lens_a, cols_a, dists_a)
        w = eng.weights
        counts = np.empty(n_new, dtype=np.int64)
        add_w = np.bincount(
            old_i, weights=w[rows_a[sel] + n_old].astype(np.float64),
            minlength=n_old).astype(np.int64)
        counts[:n_old] = self.ordering.N + add_w
        counts[n_old:] = np.bincount(
            rows_a, weights=w[cols_a].astype(np.float64),
            minlength=m).astype(np.int64)
        touched_old = np.flatnonzero(add_lens)
        C32 = np.empty(n_new, dtype=np.float32)
        C32[:n_old] = self.ordering.C.astype(np.float32)
        # core distances: a row's C moves only if an added neighbor lands
        # strictly below it (weight added at or beyond the staircase hit
        # leaves the selected value untouched; non-core rows have C=inf,
        # so any gain qualifies them) — recompute just those rows
        if touched_old.size:
            starts = np.zeros(touched_old.size, dtype=np.int64)
            np.cumsum(add_lens[touched_old][:-1], out=starts[1:])
            min_add = np.minimum.reduceat(add_dists, starts)
            moved = touched_old[min_add < C32[touched_old]]
        else:
            moved = touched_old
        recompute = np.concatenate([moved, new_ids])
        C32[recompute] = subset_core_distances(
            csr_new, recompute, counts[recompute], w, self.minpts)
        affected = None
        base = None
        comp_affected = None
        frac = None
        if track_runs:
            is_core = np.isfinite(C32)
            # affected = components of the dirty rows, plus every
            # component a newly-core row's edges now bind to them (new
            # edges are all incident to dirty rows, so one step closes)
            newly_core = touched_old[is_core[touched_old]
                                     & ~was_core[touched_old]]
            reach = subset_csr(csr_new, newly_core).indices
            reach = reach[reach < n_old]
            labels = np.unique(np.concatenate(
                [comp[touched_old], comp[reach]]))
            aff_mask = np.isin(comp, labels)
            aff_old = np.flatnonzero(aff_mask)
            affected = np.concatenate([aff_old, new_ids])
            # inserts only merge components, so the affected region's new
            # labels come from a contracted union-find over (affected old
            # labels + new rows) — no subgraph re-traversal
            comp_affected = merge_insert_components(
                comp, labels, aff_old, is_core, n_old, m,
                rows_a, cols_a, newly_core, csr_new)
            # the fallback decision is component-granular: re-sweep cost
            # scales with how many sweep components are dirtied, not how
            # many rows they happen to contain (one giant cluster would
            # otherwise push row-fraction past any threshold on a
            # handful of inserts)
            frac = labels.size / max(np.unique(comp).size, 1)
            base = {
                "pos": np.concatenate(
                    [self.ordering.pos, np.zeros(m, dtype=np.int64)]),
                "R": np.concatenate(
                    [self.ordering.R, np.full(m, np.inf)]),
                "F": np.concatenate([self.ordering.F, new_ids]),
                "run_id": np.concatenate(
                    [self._run_id, np.full(m, -1, dtype=np.int64)]),
                "triggers": self._run_triggers,
                "comp": np.concatenate(
                    [comp, np.zeros(m, dtype=np.int64)]),
            }
        return self._apply_mutation("insert", m, csr_new, counts, C32,
                                    affected, base, rebuild_threshold,
                                    comp_affected=comp_affected,
                                    frac=frac)

    def delete(self, ids, *, rebuild_threshold: float = 0.5) -> dict:
        """Remove objects by id and repair the index — an exact delta.

        Byte-identical to ``FinexIndex.build`` over the dataset with
        those rows removed (``np.delete`` id semantics: survivors are
        renumbered compactly, order preserved).  Deletion computes *no*
        distances at all: surviving CSR entries keep their original
        bits, counts/core distances are recomputed only for rows that
        lost a neighbor, and only the affected core-incidence components
        are re-swept (cluster splits included). See :meth:`insert` for
        the ``rebuild_threshold`` fallback.
        """
        if self.engine is None:
            raise RuntimeError(
                "index mutations need the distance engine; load the "
                "index with its raw data (FinexIndex.load(..., data=...))")
        ids = np.unique(np.asarray(ids, dtype=np.int64))
        if ids.size == 0:
            return self._noop_report("delete")
        if ids[0] < 0 or ids[-1] >= self.n:
            raise IndexError(
                f"delete ids must lie in [0, {self.n}), got range "
                f"[{ids[0]}, {ids[-1]}]")
        if ids.size >= self.n:
            raise ValueError("cannot delete every object in the index")
        snap = self.engine.state_snapshot()
        with obs.span("index.delete", count=int(ids.size), n=self.n,
                      metric=self.metric) as sp:
            try:
                report = self._delete_impl(ids, rebuild_threshold)
            except BaseException:
                self.engine.state_restore(snap)
                raise
            sp.annot(mode=report["mode"],
                     affected=report["affected"])
            if obs.enabled():
                obs.count("delta.deletes")
                obs.count(f"delta.mode.{report['mode']}")
                obs.observe("delta.affected_frac",
                            report["affected_frac"])
            return report

    def _delete_impl(self, ids: np.ndarray,
                     rebuild_threshold: float) -> dict:
        n_old = self.n
        keep = np.ones(n_old, dtype=bool)
        keep[ids] = False
        csr_new, removed_w, min_removed = splice_delete(
            self.csr, keep, self.engine.weights)
        self.engine.keep_rows(keep)
        idmap = np.cumsum(keep, dtype=np.int64) - 1
        counts = self.ordering.N[keep] - removed_w
        C32 = self.ordering.C.astype(np.float32)[keep]
        # structurally-changed rows (an entry vanished), not weight-based:
        # the ordering sweep reads row contents, so a row losing even a
        # zero-weight neighbor is dirty
        touched = np.flatnonzero(np.isfinite(min_removed))
        # a row's C moves only if a loss reaches down to it: removals
        # strictly beyond the staircase hit never shift the selected
        # value, and non-core rows (C=inf, counts only shrink) stay
        # non-core — recompute just the rows where min lost dist <= C
        moved = np.flatnonzero(np.isfinite(C32) & (min_removed <= C32))
        C32[moved] = subset_core_distances(
            csr_new, moved, counts[moved], self.engine.weights,
            self.minpts)
        affected = None
        base = None
        frac = None
        if self._run_id is not None and self._run_triggers is not None:
            comp = self._ensure_comp()
            # edge removal never merges components, so the affected set
            # is exactly the components holding a deleted or touched row
            comp_kept = comp[keep]
            labels = np.unique(np.concatenate(
                [comp[ids], comp_kept[touched]]))
            affected = np.flatnonzero(np.isin(comp_kept, labels))
            # component-granular fallback fraction (see _insert_impl):
            # deleting 1% of the rows of one large cluster dirties one
            # component, not "most of the dataset"
            frac = labels.size / max(np.unique(comp_kept).size, 1)
            base = {
                "pos": self.ordering.pos[keep],
                "R": self.ordering.R[keep],
                "F": idmap[self.ordering.F[keep]],
                "run_id": self._run_id[keep],
                # triggers of dropped (affected/deleted) runs are never
                # read by the stitch; map survivors, poison the rest
                "triggers": np.where(keep[self._run_triggers],
                                     idmap[self._run_triggers], -1),
                "comp": comp_kept,
            }
        return self._apply_mutation("delete", int(ids.size), csr_new,
                                    counts, C32, affected, base,
                                    rebuild_threshold, frac=frac)

    def _ensure_comp(self) -> Optional[np.ndarray]:
        """Core-incidence component labels, computed on first use (one
        O(nnz) weak-connectivity pass — deferred so build-once indexes
        never pay it).  Inserts maintain the labels incrementally (their
        contracted union-find relabel is cheap); deletes and resweep
        fallbacks invalidate instead, and the next mutation recomputes
        here lazily."""
        if self._comp is None:
            # raw storage: core_components is row_bounds-addressed, so
            # slack layouts need no packing pass here
            self._comp = core_components(
                self._csr, np.isfinite(self.ordering.C))
        return self._comp

    def _noop_report(self, op: str) -> dict:
        """Empty mutation: full report shape (callers index into it),
        version unchanged, nothing appended to the delta log."""
        return {"op": op, "count": 0, "n": int(self.n), "mode": "noop",
                "affected": 0, "affected_frac": 0.0,
                "version": self.version}

    def _apply_mutation(self, op: str, moved: int, csr_new, counts, C32,
                        affected, base, rebuild_threshold: float,
                        comp_affected=None, frac=None) -> dict:
        """Shared tail of insert/delete: ordering repair + bookkeeping.

        ``frac`` is the *component*-granular affected fraction computed
        by the caller (dirty sweep components / total components) — the
        quantity the re-sweep cost actually scales with.  ``None``
        (callers without run metadata) forces the full-resweep fallback.
        """
        n_new = counts.shape[0]
        eps, minpts = self.ordering.eps, self.ordering.minpts
        is_core = np.isfinite(C32)
        if frac is None:
            frac = (affected.size / n_new) if affected is not None else 1.0
        fallback = affected is None or frac > rebuild_threshold
        if fallback:
            if affected is None:
                reason = ("index carries no run metadata (archive "
                          "predates incremental maintenance)")
            else:
                reason = (f"affected component fraction {frac:.2f} "
                          f"exceeds rebuild_threshold "
                          f"{rebuild_threshold:g}")
            warnings.warn(
                f"FinexIndex.{op}: {reason}; falling back to a full "
                "ordering re-sweep over the spliced CSR (still exact, "
                "still no O(n^2) distance recomputation)")
            sweep = finex_sweep(counts, csr_new, C32)
            order = sweep["order"]
            run_id, triggers = sweep["run_id"], sweep["run_triggers"]
            R, F = sweep["R"], sweep["F"]
            comp = None          # recomputed lazily by _ensure_comp
        else:
            sweep = finex_sweep(counts, csr_new, C32, active=affected)
            clean = np.ones(n_new, dtype=bool)
            clean[affected] = False
            order, run_id, triggers = stitch(
                n_new, clean, base["pos"], base["run_id"],
                base["triggers"], sweep)
            R = base["R"].copy()
            R[affected] = sweep["R"][affected]
            F = base["F"].copy()
            F[affected] = sweep["F"][affected]
            if comp_affected is None:
                # deletions can split a component, which takes a subgraph
                # re-traversal to re-label — and "affected components" is
                # component-granular, so a scatter of deletes across every
                # cluster makes that traversal a near-full O(nnz) pass.
                # The labels are only read by the NEXT mutation's affected
                # computation, so defer: _ensure_comp recomputes them
                # lazily, exactly like the build path defers the initial
                # labeling (inserts stay eager — their contracted
                # union-find relabel is O(affected), merges only)
                comp = None
            else:
                comp = base["comp"].copy()
                comp[affected] = (int(comp.max()) + 1) + comp_affected
        pos = np.empty(n_new, dtype=np.int64)
        pos[order] = np.arange(n_new)
        self.ordering = FinexOrdering(
            eps=eps, minpts=minpts, order=order, pos=pos,
            C=C32.astype(np.float64), R=R, N=counts.astype(np.int64), F=F)
        self.csr = csr_new
        self.weights = self.engine.weights
        self._comp, self._run_id, self._run_triggers = comp, run_id, triggers
        self._hier = None       # condensed tree rebuilt lazily on next ask
        self._data_fingerprint = None    # the engine's (rehashed) wins
        self.version += 1
        report = {"op": op, "count": int(moved), "n": int(n_new),
                  "mode": "resweep" if fallback else "delta",
                  "affected": (int(affected.size) if affected is not None
                               else int(n_new)),
                  "affected_frac": round(float(frac), 4),
                  "version": self.version}
        self.delta_log.append(report)
        return dict(report)

    def fingerprint(self) -> Optional[str]:
        """Dataset identity (metric + shape + dtype + content hash) of the
        data this index was built over; ``None`` only for engine-less
        indexes loaded from archives written before fingerprints were
        recorded. Computed on first use (and cached on the engine)."""
        if self.engine is not None:
            return self.engine.fingerprint()
        return self._data_fingerprint

    # ------------------------------------------------------------- stats
    def stats(self) -> Dict[str, object]:
        cores = int(np.isfinite(self.ordering.C).sum())
        # prune rates of the engine's most recent FULL sweep — mutations
        # run strip sweeps, but those report separately (``strip``
        # below), so post-insert pruning stats keep describing the build
        # sweep. Absent for engine-less indexes / unscreened sweeps.
        pruning = None
        strip = None
        if self.engine is not None:
            full = (self.engine.last_full_materialize
                    or self.engine.last_materialize or {})
            pruning = full.get("pruning")
            strip = self.engine.last_strip or None
        return {
            "n": self.n,
            "eps": self.eps,
            "minpts": self.minpts,
            "metric": self.metric,
            "cores": cores,
            # raw-storage nnz: identical for packed and slack layouts,
            # and reading it here never forces a pack
            "csr_nnz": self._csr.nnz,
            "slack": self.slack_stats(),
            "max_neighborhood": int(self.ordering.N.max()) if self.n else 0,
            "distance_rows_computed":
                self.engine.distance_rows_computed
                if self.engine is not None else None,
            "query_candidates": self.query_stats.candidates,
            "query_verification_pairs": self.query_stats.verification_pairs,
            "query_screened_pairs": self.query_stats.screened_pairs,
            "pruning": pruning,
            "strip": strip,
            "hierarchy": self.hierarchy_stats(),
            "version": self.version,
            "mutations": len(self.delta_log),
            # the process-wide observability snapshot (documented schema:
            # repro.obs.telemetry) — {"enabled": False, ...} empties
            # while tracing is off
            "telemetry": obs.snapshot(),
        }

    # ----------------------------------------------------------- persist
    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Flat array dict — the npz/checkpoint serialization format."""
        o = self.ordering
        return {
            "eps": np.float64(o.eps), "minpts": np.int64(o.minpts),
            "order": o.order, "pos": o.pos, "C": o.C, "R": o.R,
            "N": o.N, "F": o.F,
            "csr_indptr": self.csr.indptr, "csr_indices": self.csr.indices,
            "csr_dists": self.csr.dists,
            "weights": self.weights,
            # the metric round-trips as registry name + JSON params;
            # load resolves it back through the registry, so archives
            # written under a user-registered metric reload exactly
            "metric": np.str_(self.metric),
            "metric_params": np.str_(
                json.dumps(self._metric_obj.params, sort_keys=True)),
            "fingerprint": np.str_(self.fingerprint() or ""),
            # incremental-maintenance state: the mutation counter and the
            # delta log always travel; the sweep decomposition arrays are
            # included when present so a reloaded index keeps taking the
            # fast component-local delta path (absent -> full-resweep
            # fallback, still exact)
            "version": np.int64(self.version),
            "delta_log": np.str_(json.dumps(self.delta_log)),
            **({"run_id": self._run_id,
                "run_triggers": self._run_triggers}
               if self._run_id is not None
               and self._run_triggers is not None else {}),
            # comp is lazy: only present once a mutation (or load of a
            # mutated archive) has materialized it
            **({"comp": self._comp} if self._comp is not None else {}),
            # the condensed tree rides along once built (optional keys:
            # archives without them reload fine and rebuild lazily)
            **(self._hier.to_arrays() if self._hier is not None else {}),
        }

    @classmethod
    def from_arrays(cls, z, data=None, *, batch_rows: int = 256,
                    use_pallas: bool = False,
                    fingerprint_mismatch: str = "error") -> "FinexIndex":
        if fingerprint_mismatch not in ("error", "warn"):
            raise ValueError(
                "fingerprint_mismatch must be 'error' or 'warn', got "
                f"{fingerprint_mismatch!r}")
        missing = sorted(k for k in REQUIRED_ARRAY_KEYS if k not in z)
        if missing:
            raise ValueError(
                f"FINEX index archive is missing required arrays {missing} "
                f"(expected {sorted(REQUIRED_ARRAY_KEYS)}); was this npz "
                "written by FinexIndex.save / CheckpointManager.save_index?")
        eps = float(z["eps"])
        ordering = FinexOrdering(
            eps=eps, minpts=int(z["minpts"]), order=np.asarray(z["order"]),
            pos=np.asarray(z["pos"]), C=np.asarray(z["C"]),
            R=np.asarray(z["R"]), N=np.asarray(z["N"]), F=np.asarray(z["F"]))
        csr = CSRNeighborhoods(indptr=np.asarray(z["csr_indptr"]),
                               indices=np.asarray(z["csr_indices"]),
                               dists=np.asarray(z["csr_dists"]), eps=eps)
        metric_name = str(z["metric"])
        params_raw = str(z["metric_params"]) if "metric_params" in z else ""
        metric_params = json.loads(params_raw) if params_raw else {}
        try:
            # resolve through the registry up front: an archive carrying
            # an unknown (or typo'd) metric name must fail HERE, naming
            # the registered alternatives — not blow up later inside the
            # engine or return wrong clusterings
            metric = get_metric(metric_name, **metric_params)
        except ValueError as e:
            raise ValueError(
                f"index archive was built under metric {metric_name!r}, "
                "which is not in the metric registry (registered: "
                f"{list(registered_metrics())}); register_metric() it "
                "before loading") from e
        weights = np.asarray(z["weights"])
        stored_fp = str(z["fingerprint"]) if "fingerprint" in z else ""
        engine = None
        if data is not None:
            engine = NeighborEngine(data, metric=metric, weights=weights,
                                    batch_rows=batch_rows,
                                    use_pallas=use_pallas)
            if engine.n != ordering.n:
                raise ValueError(
                    f"dataset has {engine.n} objects but the stored index "
                    f"was built over {ordering.n} — re-attach the exact "
                    "dataset the index was built on")
            if stored_fp and engine.fingerprint() != stored_fp:
                msg = (
                    "dataset fingerprint mismatch: the stored index was "
                    f"built over {stored_fp} but the supplied data is "
                    f"{engine.fingerprint()} — queries against the wrong "
                    "engine return wrong clusterings")
                if fingerprint_mismatch == "error":
                    raise ValueError(
                        msg + " (pass fingerprint_mismatch='warn' to "
                              "attach anyway)")
                warnings.warn(msg)
        def _opt(key):
            return np.asarray(z[key]) if key in z else None

        delta_raw = str(z["delta_log"]) if "delta_log" in z else ""
        idx = cls(ordering, csr, engine, metric=metric, weights=weights,
                  fingerprint=stored_fp or None,
                  version=int(z["version"]) if "version" in z else 0,
                  delta_log=json.loads(delta_raw) if delta_raw else [],
                  comp=_opt("comp"), run_id=_opt("run_id"),
                  run_triggers=_opt("run_triggers"))
        # a persisted condensed tree re-attaches warm (None when the
        # archive predates hierarchies or was saved before one was built)
        idx._hier = ClusterHierarchy.from_arrays(
            z, ordering, idx.csr, idx.weights, version=idx.version)
        return idx

    def save(self, path: str) -> None:
        """Serialize ordering + CSR + weights as one compressed npz."""
        np.savez_compressed(path, **self.to_arrays())

    @classmethod
    def load(cls, path: str, data=None, **kw) -> "FinexIndex":
        """Load an index; pass ``data`` to re-attach a distance engine
        (required for ε*-queries — MinPts*-queries work without it)."""
        with np.load(path) as z:
            return cls.from_arrays(dict(z.items()), data=data, **kw)
