"""``FinexIndex`` — the one-build / many-queries facade.

Everything the paper promises behind a single object: construct once at a
permissive generating (ε, MinPts) — the expensive device tile sweep plus
the host ordering sweep — then answer any (ε* ≤ ε, MinPts) or
(ε, MinPts* ≥ MinPts) clustering *exactly* (Definition 3.5) without
touching the raw data again (ε*-queries still batch a small verification
sub-matrix through the engine; MinPts*-queries need zero distances).

    from repro.core import FinexIndex

    index = FinexIndex.build(x, eps=0.5, minpts=10)      # once
    a = index.clustering()                               # (ε, MinPts)
    b = index.eps_star(0.2)                              # (0.2, MinPts)
    c = index.minpts_star(60)                            # (ε, 60)
    index.save("index.npz"); FinexIndex.load("index.npz", data=x)

The facade is the integration surface for the rest of the repo: the
quickstart example, the paper-table benchmarks, the data-curation
pipeline and the checkpoint manager all go through it, so later scaling
PRs (sharded materialize, serving, caching) only have one seam to cut.
"""
from __future__ import annotations

import json
import warnings
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.build import finex_build
from repro.core.extract import query_clustering
from repro.core.ordering import FinexOrdering
from repro.core.queries import QueryStats, eps_star_query, minpts_star_query
from repro.metrics import Metric, MetricLike, get_metric, registered_metrics
from repro.neighbors.engine import CSRNeighborhoods, NeighborEngine

# the flat-array serialization contract of to_arrays()/from_arrays():
# every key must be present for reconstruction, so a truncated or
# foreign npz fails loudly up front instead of KeyError-ing mid-rebuild
REQUIRED_ARRAY_KEYS = (
    "eps", "minpts", "order", "pos", "C", "R", "N", "F",
    "csr_indptr", "csr_indices", "csr_dists", "weights", "metric",
)


class FinexIndex:
    """A built FINEX-ordering bundled with its CSR and distance engine."""

    def __init__(self, ordering: FinexOrdering, csr: CSRNeighborhoods,
                 engine: Optional[NeighborEngine] = None,
                 metric: MetricLike = "euclidean",
                 weights: Optional[np.ndarray] = None,
                 fingerprint: Optional[str] = None):
        self.ordering = ordering
        self.csr = csr
        self.engine = engine
        # the resolved Metric instance travels with the index even when no
        # engine is attached, so the npz round-trip can persist its
        # registry name + params and engine re-attach resolves identically
        self._metric_obj: Metric = (engine.metric if engine is not None
                                    else get_metric(metric))
        # duplicate weights live on the index itself so an engine-less
        # (lean-loaded) index round-trips them instead of dropping to ones
        if engine is not None:
            self.weights = engine.weights
        elif weights is not None:
            self.weights = np.asarray(weights, dtype=np.int64)
        else:
            self.weights = np.ones(ordering.n, dtype=np.int64)
        # dataset identity travels with the index (and through npz
        # round-trips) so load(data=...) can refuse the wrong dataset;
        # with an engine attached it is derived lazily (hashing the whole
        # dataset is not free) and the engine's identity always wins
        self._data_fingerprint = fingerprint
        self.query_stats = QueryStats()     # cumulative, resettable

    @property
    def metric(self) -> str:
        """Registry name of this index's metric (what manifests, npz
        archives and ``stats()`` record)."""
        return self._metric_obj.name

    @property
    def metric_obj(self) -> Metric:
        """The resolved ``repro.metrics.Metric`` instance."""
        return self._metric_obj

    # ------------------------------------------------------ construction
    @classmethod
    def build(cls, data, eps: float, minpts: int, *,
              metric: MetricLike = "euclidean",
              weights: Optional[np.ndarray] = None,
              batch_rows: int = 256, use_pallas: bool = False,
              mesh=None, shard_cap: int = 1024, shard_row_chunk: int = 2048
              ) -> "FinexIndex":
        """Materialize neighborhoods on device and run the ordering sweep.

        ``data``: whatever ``metric`` canonicalizes — an (n, d) float
        array for the vector metrics, the (bits, sizes) pair from
        ``bitset.pack_sets`` for jaccard.  ``metric`` is a registry name
        or a ``repro.metrics.Metric`` instance.

        ``mesh``: a ``jax.sharding.Mesh`` routes the materialize step
        through the sharded ε-compacted CSR-emit
        (``neighbors.distributed.sharded_csr_materialize``) — every
        device sweeps its (rowblock × colblock) shard and only compacted
        pairs are gathered; the resulting CSR (and therefore the index)
        is byte-identical to the single-device build, for every
        registered metric.  ``shard_cap`` bounds per-row survivors per
        corpus shard (the emit refuses to truncate), ``shard_row_chunk``
        sizes each device's local tiles.
        """
        engine = NeighborEngine(data, metric=metric, weights=weights,
                                batch_rows=batch_rows, use_pallas=use_pallas)
        csr = None
        if mesh is not None:
            from repro.neighbors.distributed import sharded_csr_materialize
            csr = sharded_csr_materialize(data, eps, mesh, cap=shard_cap,
                                          row_chunk=shard_row_chunk,
                                          metric=engine.metric)
        return cls.from_engine(engine, eps, minpts, csr=csr)

    @classmethod
    def from_engine(cls, engine: NeighborEngine, eps: float, minpts: int,
                    csr: Optional[CSRNeighborhoods] = None) -> "FinexIndex":
        ordering, csr = finex_build(engine, eps, minpts, csr=csr)
        return cls(ordering, csr, engine)

    # ----------------------------------------------------------- queries
    @property
    def eps(self) -> float:
        return self.ordering.eps

    @property
    def minpts(self) -> int:
        return self.ordering.minpts

    @property
    def n(self) -> int:
        return self.ordering.n

    def clustering(self) -> np.ndarray:
        """Exact labels at the generating (ε, MinPts) — Corollary 5.5."""
        return query_clustering(self.ordering, self.ordering.eps)

    def eps_star(self, eps_star: float,
                 stats: Optional[QueryStats] = None) -> np.ndarray:
        """Exact labels at (ε* ≤ ε, MinPts) — Theorem 5.6."""
        if self.engine is None:
            raise RuntimeError(
                "ε*-queries need the distance engine for verification; "
                "load the index with its raw data (FinexIndex.load(..., "
                "data=...)) or use minpts_star/clustering")
        return eps_star_query(self.ordering, self.engine, eps_star,
                              stats=stats if stats is not None
                              else self.query_stats)

    def minpts_star(self, minpts_star: int,
                    stats: Optional[QueryStats] = None) -> np.ndarray:
        """Exact labels at (ε, MinPts* ≥ MinPts) — §5.4, zero distances."""
        return minpts_star_query(self.ordering, self.csr, minpts_star,
                                 stats=stats if stats is not None
                                 else self.query_stats)

    def fingerprint(self) -> Optional[str]:
        """Dataset identity (metric + shape + dtype + content hash) of the
        data this index was built over; ``None`` only for engine-less
        indexes loaded from archives written before fingerprints were
        recorded. Computed on first use (and cached on the engine)."""
        if self.engine is not None:
            return self.engine.fingerprint()
        return self._data_fingerprint

    # ------------------------------------------------------------- stats
    def stats(self) -> Dict[str, object]:
        cores = int(np.isfinite(self.ordering.C).sum())
        return {
            "n": self.n,
            "eps": self.eps,
            "minpts": self.minpts,
            "metric": self.metric,
            "cores": cores,
            "csr_nnz": self.csr.nnz,
            "max_neighborhood": int(self.ordering.N.max()) if self.n else 0,
            "distance_rows_computed":
                self.engine.distance_rows_computed
                if self.engine is not None else None,
            "query_candidates": self.query_stats.candidates,
            "query_verification_pairs": self.query_stats.verification_pairs,
        }

    # ----------------------------------------------------------- persist
    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Flat array dict — the npz/checkpoint serialization format."""
        o = self.ordering
        return {
            "eps": np.float64(o.eps), "minpts": np.int64(o.minpts),
            "order": o.order, "pos": o.pos, "C": o.C, "R": o.R,
            "N": o.N, "F": o.F,
            "csr_indptr": self.csr.indptr, "csr_indices": self.csr.indices,
            "csr_dists": self.csr.dists,
            "weights": self.weights,
            # the metric round-trips as registry name + JSON params;
            # load resolves it back through the registry, so archives
            # written under a user-registered metric reload exactly
            "metric": np.str_(self.metric),
            "metric_params": np.str_(
                json.dumps(self._metric_obj.params, sort_keys=True)),
            "fingerprint": np.str_(self.fingerprint() or ""),
        }

    @classmethod
    def from_arrays(cls, z, data=None, *, batch_rows: int = 256,
                    use_pallas: bool = False,
                    fingerprint_mismatch: str = "error") -> "FinexIndex":
        if fingerprint_mismatch not in ("error", "warn"):
            raise ValueError(
                "fingerprint_mismatch must be 'error' or 'warn', got "
                f"{fingerprint_mismatch!r}")
        missing = sorted(k for k in REQUIRED_ARRAY_KEYS if k not in z)
        if missing:
            raise ValueError(
                f"FINEX index archive is missing required arrays {missing} "
                f"(expected {sorted(REQUIRED_ARRAY_KEYS)}); was this npz "
                "written by FinexIndex.save / CheckpointManager.save_index?")
        eps = float(z["eps"])
        ordering = FinexOrdering(
            eps=eps, minpts=int(z["minpts"]), order=np.asarray(z["order"]),
            pos=np.asarray(z["pos"]), C=np.asarray(z["C"]),
            R=np.asarray(z["R"]), N=np.asarray(z["N"]), F=np.asarray(z["F"]))
        csr = CSRNeighborhoods(indptr=np.asarray(z["csr_indptr"]),
                               indices=np.asarray(z["csr_indices"]),
                               dists=np.asarray(z["csr_dists"]), eps=eps)
        metric_name = str(z["metric"])
        params_raw = str(z["metric_params"]) if "metric_params" in z else ""
        metric_params = json.loads(params_raw) if params_raw else {}
        try:
            # resolve through the registry up front: an archive carrying
            # an unknown (or typo'd) metric name must fail HERE, naming
            # the registered alternatives — not blow up later inside the
            # engine or return wrong clusterings
            metric = get_metric(metric_name, **metric_params)
        except ValueError as e:
            raise ValueError(
                f"index archive was built under metric {metric_name!r}, "
                "which is not in the metric registry (registered: "
                f"{list(registered_metrics())}); register_metric() it "
                "before loading") from e
        weights = np.asarray(z["weights"])
        stored_fp = str(z["fingerprint"]) if "fingerprint" in z else ""
        engine = None
        if data is not None:
            engine = NeighborEngine(data, metric=metric, weights=weights,
                                    batch_rows=batch_rows,
                                    use_pallas=use_pallas)
            if engine.n != ordering.n:
                raise ValueError(
                    f"dataset has {engine.n} objects but the stored index "
                    f"was built over {ordering.n} — re-attach the exact "
                    "dataset the index was built on")
            if stored_fp and engine.fingerprint() != stored_fp:
                msg = (
                    "dataset fingerprint mismatch: the stored index was "
                    f"built over {stored_fp} but the supplied data is "
                    f"{engine.fingerprint()} — queries against the wrong "
                    "engine return wrong clusterings")
                if fingerprint_mismatch == "error":
                    raise ValueError(
                        msg + " (pass fingerprint_mismatch='warn' to "
                              "attach anyway)")
                warnings.warn(msg)
        return cls(ordering, csr, engine, metric=metric, weights=weights,
                   fingerprint=stored_fp or None)

    def save(self, path: str) -> None:
        """Serialize ordering + CSR + weights as one compressed npz."""
        np.savez_compressed(path, **self.to_arrays())

    @classmethod
    def load(cls, path: str, data=None, **kw) -> "FinexIndex":
        """Load an index; pass ``data`` to re-attach a distance engine
        (required for ε*-queries — MinPts*-queries work without it)."""
        with np.load(path) as z:
            return cls.from_arrays(dict(z.items()), data=data, **kw)
