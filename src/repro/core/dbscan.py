"""Exact DBSCAN — the from-scratch baseline and correctness oracle.

Produces an exact clustering per Definition 3.5: every density-connected
component is one cluster; ambiguous border objects go to the cluster that
discovers them first. Deterministic (objects scanned in id order).

``dbscan_from_csr`` re-clusters at any ε* ≤ csr.eps / MinPts* by filtering
the materialized neighborhoods — this is what the benchmark's "DBSCAN from
scratch" baseline uses, charged with the same neighborhood-computation cost
model as the index builds (the engine instruments distance-row counts).
"""
from __future__ import annotations

from collections import deque
from typing import Optional, Tuple

import numpy as np

from repro.neighbors.engine import CSRNeighborhoods, NeighborEngine


def filtered_counts(csr: CSRNeighborhoods, weights: np.ndarray,
                    eps_star: float) -> np.ndarray:
    """Weighted |N_ε*| per object from a generating-ε CSR."""
    n = csr.indptr.shape[0] - 1
    keep = csr.dists <= np.float32(eps_star)
    counts = np.zeros(n, dtype=np.int64)
    w = weights[csr.indices]
    np.add.at(counts, np.repeat(np.arange(n), np.diff(csr.indptr)),
              np.where(keep, w, 0))
    return counts


def dbscan_from_csr(csr: CSRNeighborhoods, weights: np.ndarray,
                    eps_star: float, minpts: int) -> np.ndarray:
    """Exact DBSCAN labels at (ε* ≤ csr.eps, MinPts) from materialized CSR."""
    eps_star = float(np.float32(eps_star))
    if eps_star > float(np.float32(csr.eps)) + 1e-12:
        raise ValueError("eps* exceeds the materialized radius")
    n = csr.indptr.shape[0] - 1
    counts = filtered_counts(csr, weights, eps_star)
    core = counts >= minpts
    labels = np.full(n, -1, dtype=np.int64)
    cid = 0
    for o in range(n):
        if not core[o] or labels[o] >= 0:
            continue
        labels[o] = cid
        queue = deque([o])
        while queue:
            c = queue.popleft()
            s, e = csr.indptr[c], csr.indptr[c + 1]
            nbrs = csr.indices[s:e]
            good = csr.dists[s:e] <= np.float32(eps_star)
            for q in nbrs[good]:
                if labels[q] < 0:
                    labels[q] = cid
                    if core[q]:
                        queue.append(q)
        cid += 1
    return labels


def dbscan(engine: NeighborEngine, eps: float, minpts: int,
           csr: Optional[CSRNeighborhoods] = None
           ) -> Tuple[np.ndarray, CSRNeighborhoods]:
    """DBSCAN from scratch: materialize neighborhoods at ε, then cluster."""
    if csr is None:
        _, csr = engine.materialize(eps)
    return dbscan_from_csr(csr, engine.weights, eps, minpts), csr
