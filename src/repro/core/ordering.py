"""The FINEX-ordering data structure (Definition 5.1).

A permutation of the dataset where every object x carries the quintuple
(P, C, R, N, F):

  P — permutation number (position in processing order)
  C — core distance w.r.t. the generating (ε, MinPts)        (Def. 3.7)
  R — reachability distance; *globally minimized over all of D for
      non-core objects* (the key delta vs. OPTICS)            (Def. 5.1)
  N — ε-neighborhood size |N_ε(x)| (weighted by duplicates)
  F — finder reference: the densest core that reaches x       (§5.4)

Stored as a struct-of-arrays over object ids — linear space, trivially
serializable, and the Alg.-1 linear scan vectorizes over it.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class ClusterOrdering:
    """OPTICS-ordering (Def. 4.1): the (P, C, R) subset of FINEX."""
    eps: float
    minpts: int
    order: np.ndarray                 # (n,) object ids in processing order
    pos: np.ndarray                   # (n,) P attribute: pos[obj] = rank
    C: np.ndarray                     # (n,) core distance, inf for non-core
    R: np.ndarray                     # (n,) reachability distance

    @property
    def n(self) -> int:
        return int(self.order.shape[0])

    def validate(self) -> None:
        n = self.n
        assert self.order.shape == (n,) and self.pos.shape == (n,)
        assert np.array_equal(np.sort(self.order), np.arange(n)), \
            "order must be a permutation"
        assert np.array_equal(self.pos[self.order], np.arange(n)), \
            "pos must invert order"
        assert np.all((self.C[self.C != np.inf] <= self.eps + 1e-6)), \
            "finite core distances must be <= generating eps"


@dataclass
class FinexOrdering(ClusterOrdering):
    """Full FINEX index: adds neighborhood sizes and finder references."""
    N: np.ndarray = field(default=None)   # (n,) weighted |N_ε(x)|
    F: np.ndarray = field(default=None)   # (n,) finder reference object id

    def validate(self) -> None:
        super().validate()
        n = self.n
        assert self.N.shape == (n,) and self.F.shape == (n,)
        core = np.isfinite(self.C)
        # F is a self-reference exactly for objects no core reaches;
        # noise w.r.t. (ε, MinPts) always self-references (Def. 5.1).
        assert np.all((self.F >= 0) & (self.F < n))
        # every non-self finder must be a core object
        nonself = self.F != np.arange(n)
        assert np.all(core[self.F[nonself]]), "finder refs must be cores"

    def save(self, path: str) -> None:
        np.savez_compressed(path, eps=self.eps, minpts=self.minpts,
                            order=self.order, pos=self.pos, C=self.C,
                            R=self.R, N=self.N, F=self.F)

    @classmethod
    def load(cls, path: str) -> "FinexOrdering":
        z = np.load(path)
        return cls(eps=float(z["eps"]), minpts=int(z["minpts"]),
                   order=z["order"], pos=z["pos"], C=z["C"], R=z["R"],
                   N=z["N"], F=z["F"])
