"""AnyDBC-style baseline (simplified reimplementation of Mai et al.).

The paper's strongest exact competitor [16, 17] processes neighborhoods
*lazily*: batches of objects are range-queried, primitive clusters are
merged, and objects whose status is already determined are never queried.
This reimplementation keeps the two signature mechanisms —

  * anytime batched processing (α objects per round), and
  * triangle-inequality pruning: for an unqueried u and any queried row
    around c, |N_ε(u)| ≤ Σ_w weights[|d(w,c) − d(u,c)| ≤ ε]; if even the
    tightest such bound is < MinPts, u is certainly non-core and needs no
    range query (this is why AnyDBC needs a *metric*, which the paper
    calls out as its flexibility limitation vs FINEX §2) —

while dropping the full cluster-graph machinery of the original. Like the
original it produces an EXACT clustering (every potential core is queried,
so all core-core edges are found; checked against the DBSCAN oracle in
tests). Its cost metric — engine.distance_rows_computed — reproduces the
paper's observation that pruning works on vector data (~48% in Fig. 7)
but largely fails under Jaccard (~0.4% in Fig. 6), where the bounds are
too loose.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.neighbors.engine import NeighborEngine


class _UnionFind:
    def __init__(self, n: int):
        self.parent = np.arange(n)

    def find(self, x: int) -> int:
        root = x
        while self.parent[root] != root:
            root = self.parent[root]
        while self.parent[x] != root:
            self.parent[x], x = root, self.parent[x]
        return root

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


def anydbc(engine: NeighborEngine, eps: float, minpts: int,
           alpha: int = 64, seed: int = 0,
           ) -> Tuple[np.ndarray, dict]:
    """Exact clustering labels + stats (queries issued / pruned)."""
    n = engine.n
    eps = float(np.float32(eps))
    rng = np.random.default_rng(seed)
    w = engine.weights.astype(np.float64)

    queried = np.zeros(n, bool)
    noncore_certain = np.zeros(n, bool)
    is_core = np.zeros(n, bool)
    toucher = np.full(n, -1, np.int64)       # first core whose ball covers
    count_ub = np.full(n, np.inf)
    uf = _UnionFind(n)
    queries = 0

    def tighten_bounds(center_row: np.ndarray) -> None:
        """Triangle-inequality upper bounds from one queried row."""
        order = np.argsort(center_row, kind="stable")
        sorted_d = center_row[order]
        cum_w = np.concatenate([[0.0], np.cumsum(w[order])])
        hi = np.searchsorted(sorted_d, center_row + eps, side="right")
        lo = np.searchsorted(sorted_d, center_row - eps, side="left")
        ub = cum_w[hi] - cum_w[lo]
        np.minimum(count_ub, ub, out=count_ub)

    while True:
        # query only POTENTIAL cores (upper bound ≥ MinPts). Every true
        # core has count_ub ≥ its true count ≥ MinPts, so all cores get
        # queried, every border is eventually covered by its core, and
        # certainly-non-core objects are never range-queried at all —
        # that is AnyDBC's pruning payoff.
        unresolved = ~queried & (count_ub >= minpts)
        cand = np.nonzero(unresolved)[0]
        if cand.size == 0:
            break
        batch = rng.choice(cand, size=min(alpha, cand.size), replace=False)
        rows = engine.distances_from(batch)
        queries += len(batch)
        for bi, u in enumerate(batch):
            row = rows[bi]
            queried[u] = True
            members = np.nonzero(row <= eps)[0]
            cnt = w[members].sum()
            if cnt >= minpts:
                is_core[u] = True
                for v in members:
                    if is_core[v] and queried[v]:
                        uf.union(int(u), int(v))
                    if toucher[v] < 0:
                        toucher[v] = u
            else:
                noncore_certain[u] = True
            tighten_bounds(row)

    # labels: components over queried cores; borders via first toucher
    labels = np.full(n, -1, np.int64)
    reps: dict[int, int] = {}
    next_label = 0
    for c in np.nonzero(is_core)[0]:
        r = uf.find(int(c))
        if r not in reps:
            reps[r] = next_label
            next_label += 1
        labels[c] = reps[r]
    border = (~is_core) & (toucher >= 0)
    labels[np.nonzero(border)[0]] = labels[toucher[border]]

    stats = {"queries": queries, "pruned": int(n - queries),
             "pruned_frac": 1.0 - queries / n}
    return labels, stats


def anyfinex_minpts_star(index, csr, engine: NeighborEngine,
                         minpts_star: int, alpha: int = 256, seed: int = 0
                         ) -> Tuple[np.ndarray, dict]:
    """AnyFINEX (paper §6.3): FINEX's noise filter + N attribute combined
    with AnyDBC-style on-demand connectivity search.

    Steps (mirroring the paper's proof-of-concept):
      1. exact sparse clustering from the FINEX-ordering filters noise,
      2. core status w.r.t. MinPts* comes FREE from the N attribute
         (no bound computation, no query — FINEX's §5.4 trick),
      3. density-connected components among the preserved cores are found
         by on-demand range queries over cores only (the AnyDBC part),
      4. borders attach through finder references (no queries).

    Returns (labels, stats) with stats["queries"] = range queries issued —
    ≤ the number of MinPts*-cores, vs. AnyDBC-alone which must also probe
    every potential core among non-members.
    """
    from repro.core.extract import query_clustering

    n = engine.n
    sparse = query_clustering(index, index.eps)
    cores_star = np.asarray(index.N >= minpts_star) & (sparse >= 0)
    core_ids = np.nonzero(cores_star)[0]
    labels = np.full(n, -1, np.int64)
    uf = _UnionFind(n)
    eps = float(np.float32(index.eps))
    queries = 0

    rng = np.random.default_rng(seed)
    order = rng.permutation(core_ids)
    queried = np.zeros(n, bool)
    for s in range(0, len(order), alpha):
        batch = order[s:s + alpha]
        batch = batch[~queried[batch]]
        if batch.size == 0:
            continue
        rows = engine.distances_from(batch)
        queries += len(batch)
        for bi, u in enumerate(batch):
            queried[u] = True
            nbrs = np.nonzero((rows[bi] <= eps) & cores_star)[0]
            for v in nbrs:
                uf.union(int(u), int(v))

    reps: dict[int, int] = {}
    nxt = 0
    for c in core_ids:
        r = uf.find(int(c))
        if r not in reps:
            reps[r] = nxt
            nxt += 1
        labels[c] = reps[r]
    # borders via finder reference (densest reaching core)
    border = (sparse >= 0) & (~cores_star)
    fin = np.asarray(index.F)[border]
    ok = cores_star[fin]
    bids = np.nonzero(border)[0]
    labels[bids[ok]] = labels[fin[ok]]
    return labels, {"queries": queries,
                    "cores": int(core_ids.size),
                    "noise_filtered": int((sparse < 0).sum())}
