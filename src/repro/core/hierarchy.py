"""Hierarchy as a query: the condensed cluster tree over one FINEX index.

The ordering quintuple (order, C, R) plus the generating-ε CSR already
encode the *complete* density hierarchy: the exact DBSCAN core components
at every ε ≤ ε_gen are the connected components of the mutual-reachability
graph  m(p, q) = max(C[p], C[q], d(p, q))  thresholded at ε — and every
pair with d ≤ ε_gen sits in the CSR with its exact float32 distance, so
the whole dendrogram is computable with ZERO new distance work.  This
module turns that observation into an HDBSCAN*-style condensed cluster
tree (birth/death ε, sizes, parents, stabilities — FISHDBC in PAPERS.md
is the flexible/incremental precedent; here it is *exact*):

  * ``build_hierarchy``      — minimum spanning forest of the mutual-
    reachability graph (vectorized edge extraction + one tight union-find
    merge pass over the ≤ n_cores−1 MST edges, grouped level-exactly so
    discrete-metric ties condense canonically), then a level-granular
    condensation at a minimum cluster weight (default: the generating
    MinPts) and the excess-of-mass stability selection.
  * ``ClusterHierarchy.cut(ε)``        — label-identical to
    ``FinexIndex.eps_star(ε)``: the ε*-query of Theorem 5.6 replayed with
    CSR-sourced pair distances (a pair absent from the CSR has
    d > ε_gen ≥ ε*, exactly an ∞ entry), so verification costs zero
    distance computations.
  * ``ClusterHierarchy.cut_minpts(m)`` — label-identical to
    ``FinexIndex.minpts_star(m)`` (delegates to the §5.4 kernel, which
    is already distance-free).
  * ``ClusterHierarchy.extract()``     — the stability-selected flat
    clustering (cores only; non-cores are noise, as in HDBSCAN*).

The loop oracle lives in ``repro.core.reference.reference_hierarchy``;
``tests/test_hierarchy.py`` pins cut-equivalence per registered metric,
the condensed tree against a brute-force all-level grid, and the
zero-distance claim via the engine/obs counters.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np
from scipy.sparse import csr_matrix
from scipy.sparse.csgraph import minimum_spanning_tree

from repro import obs
from repro.core.extract import cluster_spans, query_clustering
from repro.core.ordering import FinexOrdering
from repro.core.queries import ClusteringResult, minpts_star_query
from repro.neighbors.engine import CSRNeighborhoods

# npz keys the hierarchy round-trips through ``FinexIndex.to_arrays``
# (all optional: archives written before this feature load fine and
# rebuild the tree lazily)
HIERARCHY_ARRAY_KEYS = (
    "hier_parent", "hier_birth", "hier_death", "hier_size",
    "hier_stability", "hier_selected", "hier_leaf_cond", "hier_minw",
)


@dataclass(frozen=True)
class CondensedTree:
    """The condensed cluster tree as flat arrays (one row per cluster).

    ``parent`` is -1 for roots; ``birth``/``death`` are the ε values at
    which the cluster separated from its parent / split or vanished;
    ``size`` is the total member weight at birth; ``stability`` the
    excess-of-mass integral Σ w·(λ_out − λ_birth) with λ = 1/ε;
    ``selected`` marks the stability-optimal flat clustering.
    """
    parent: np.ndarray        # (c,) int64
    birth: np.ndarray         # (c,) float64
    death: np.ndarray         # (c,) float64
    size: np.ndarray          # (c,) int64
    stability: np.ndarray     # (c,) float64
    selected: np.ndarray      # (c,) bool


def _mutual_reach_edges(ordering: FinexOrdering, csr: CSRNeighborhoods
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Unique (i < j) mutual-reachability edges between generating cores.

    Every qualifying pair is in the CSR (d ≤ ε_gen), so this is a pure
    gather; m = max(C_i, C_j, d) is exact in float64 over the float32
    distance domain.
    """
    C = ordering.C
    i = csr.row_ids()
    j = csr.indices.astype(np.int64, copy=False)
    keep = (i < j) & np.isfinite(C[i]) & np.isfinite(C[j])
    i, j = i[keep], j[keep]
    d = csr.dists[keep].astype(np.float64)
    m = np.maximum(d, np.maximum(C[i], C[j]))
    return i, j, m


def _mst_edges(k: int, ri: np.ndarray, rj: np.ndarray, m: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Minimum spanning forest over core-local node ids 0..k-1.

    m = 0 is real (duplicate objects with C = 0) but scipy's sparse MST
    drops explicit zeros, so zero weights are biased to half the
    smallest positive m before the pass and mapped back after.  The
    bias is a monotone relabeling (0 < tiny < every positive m, zero
    ties stay ties), so the forest's per-level connectivity — all
    single linkage needs — is unchanged, and every surviving weight
    round-trips exactly: positive m values pass through untouched, and
    a returned weight equal to ``tiny`` can only be a mapped zero.
    Avoiding a global edge sort here matters: it was the build's
    dominant cost at bench scale.  MST tie-breaking among equal weights
    is arbitrary but irrelevant — the level-contracted merge forest and
    the condensation are canonical under ties (pinned against the loop
    oracle on discrete metrics in tests/test_hierarchy.py).
    """
    if m.size == 0:
        return (np.empty(0, np.int64), np.empty(0, np.int64),
                np.empty(0, np.float64))
    pos = m[m > 0]
    tiny = 0.5 * float(pos.min()) if pos.size else 1.0
    g = csr_matrix((np.maximum(m, tiny), (ri, rj)), shape=(k, k))
    t = minimum_spanning_tree(g).tocoo()
    mw = np.where(t.data == tiny, 0.0, t.data)
    return (t.row.astype(np.int64), t.col.astype(np.int64), mw)


def _merge_forest(k: int, leaf_height: np.ndarray, ea: np.ndarray,
                  eb: np.ndarray, ew: np.ndarray):
    """Level-contracted single-linkage forest from the MST edge list.

    Returns (heights, children, roots): tree nodes 0..k-1 are the core
    leaves (height = the core's birth level C); internal nodes are
    appended per merge *level* — equal-weight edges landing in one
    component share one multiway node, so discrete-metric ties produce
    the canonical level-granular tree, independent of edge order.  The
    union-find pass is the build's one sequential seam: O(#MST edges)
    with path halving, every array around it vectorized.
    """
    order = np.lexsort((eb, ea, ew))
    ea, eb, ew = ea[order], eb[order], ew[order]
    uf = np.arange(k, dtype=np.int64)
    node_of = np.arange(k, dtype=np.int64)
    heights = list(leaf_height)
    children: Dict[int, list] = {}
    alive = []

    def find(x: int) -> int:
        while uf[x] != x:
            uf[x] = uf[uf[x]]
            x = uf[x]
        return x

    nxt = k
    for a, b, w in zip(ea, eb, ew):
        ra, rb = find(int(a)), find(int(b))
        na, nb = int(node_of[ra]), int(node_of[rb])
        a_open = na >= k and heights[na] == w
        b_open = nb >= k and heights[nb] == w
        if a_open and b_open:            # two same-level nodes: absorb
            children[na].extend(children[nb])
            children[nb] = None
            alive[nb - k] = False
            target = na
        elif a_open:
            children[na].append(nb)
            target = na
        elif b_open:
            children[nb].append(na)
            target = nb
        else:
            children[nxt] = [na, nb]
            heights.append(w)
            alive.append(True)
            target = nxt
            nxt += 1
        uf[ra] = rb
        node_of[find(rb)] = target
    roots = sorted({int(node_of[find(x)]) for x in range(k)})
    return np.asarray(heights, dtype=np.float64), children, roots, alive


def _lam(e, floor: float):
    """λ(ε) = 1/ε over the discrete level domain, with ε clamped to half
    the smallest positive level so ε = 0 (exact duplicates) stays finite
    and deterministic."""
    return 1.0 / np.maximum(e, floor)


def build_hierarchy(ordering: FinexOrdering, csr: CSRNeighborhoods,
                    weights: np.ndarray,
                    min_cluster_weight: Optional[int] = None,
                    version: int = 0) -> "ClusterHierarchy":
    """Condensed cluster tree + stability selection, zero distance work."""
    with obs.span("hierarchy.build", n=ordering.n) as sp:
        t0 = time.perf_counter()
        h = _build_impl(ordering, csr, weights, min_cluster_weight,
                        version)
        h.build_seconds = time.perf_counter() - t0
        sp.annot(cores=int(h.cores.size), clusters=int(h.parent.size),
                 selected=int(h.selected.sum()))
        if obs.enabled():
            obs.count("hierarchy.builds")
            obs.observe("hierarchy.build_s", h.build_seconds)
    return h


def _build_impl(ordering, csr, weights, min_cluster_weight, version):
    # untraced body of :func:`build_hierarchy`
    n = ordering.n
    eps_gen = float(np.float32(ordering.eps))
    W = int(min_cluster_weight if min_cluster_weight is not None
            else ordering.minpts)
    C = ordering.C
    cores = np.flatnonzero(np.isfinite(C))
    k = cores.size
    leaf_cond = np.full(n, -1, dtype=np.int64)
    empty = ClusterHierarchy(
        ordering=ordering, csr=csr, weights=weights,
        min_cluster_weight=W, cores=cores,
        leaf_cond=leaf_cond,
        parent=np.empty(0, np.int64), birth=np.empty(0, np.float64),
        death=np.empty(0, np.float64), size=np.empty(0, np.int64),
        stability=np.empty(0, np.float64),
        selected=np.empty(0, bool), version=version)
    if k == 0:
        return empty

    remap = np.full(n, -1, dtype=np.int64)
    remap[cores] = np.arange(k)
    i, j, m = _mutual_reach_edges(ordering, csr)
    ea, eb, ew = _mst_edges(k, remap[i], remap[j], m)
    Cl = C[cores]                                   # leaf birth levels
    w_leaf = np.asarray(weights, dtype=np.int64)[cores]
    heights, children, roots, alive = _merge_forest(k, Cl, ea, eb, ew)

    # subtree weights: children always carry smaller node ids, so one
    # ascending pass suffices
    wt = np.zeros(heights.size, dtype=np.int64)
    wt[:k] = w_leaf
    for nid in range(k, heights.size):
        ch = children.get(nid)
        if ch is not None:
            wt[nid] = sum(int(wt[c]) for c in ch)

    # λ floor: half the smallest positive level (see _lam) — over ALL
    # mutual-reachability values, not just MST survivors, so the floor
    # is a property of the graph (what the loop reference recomputes)
    # rather than of which tie-broken spanning tree scipy returned
    pos_lv = np.concatenate([Cl, m, [eps_gen]])
    pos_lv = pos_lv[pos_lv > 0]
    floor = float(pos_lv.min()) * 0.5 if pos_lv.size else 1.0

    # ---- level-granular condensation (top-down stack walk) ----
    parent, birth, death, size = [], [], [], []
    leaf_local = np.full(k, -1, dtype=np.int64)
    stack = []
    for r in roots:
        parent.append(-1)
        birth.append(eps_gen)
        death.append(np.nan)
        size.append(int(wt[r]))
        stack.append((r, len(parent) - 1, False))
    while stack:
        t, c, frozen = stack.pop()
        if t < k:                                    # a core leaf
            leaf_local[t] = c
            if not frozen:                  # the cluster's last survivor
                death[c] = float(Cl[t])
            continue
        h = heights[t]
        ch = children[t]
        if frozen:
            for x in ch:
                stack.append((x, c, True))
            continue
        surv = []
        for x in ch:
            if x < k and Cl[x] == h:         # deactivates with this level
                leaf_local[x] = c
            else:
                surv.append(x)
        big = [x for x in surv if wt[x] >= W]
        if len(big) >= 2:                            # a real split
            death[c] = float(h)
            for x in surv:
                if wt[x] >= W:
                    parent.append(c)
                    birth.append(float(h))
                    death.append(np.nan)
                    size.append(int(wt[x]))
                    stack.append((x, len(parent) - 1, False))
                else:
                    stack.append((x, c, True))
        elif len(big) == 1:                          # cluster continues
            for x in surv:
                stack.append((x, c, wt[x] < W))
        else:                                        # cluster dissolves
            death[c] = float(h)
            for x in surv:
                stack.append((x, c, True))

    parent = np.asarray(parent, dtype=np.int64)
    birth = np.asarray(birth, dtype=np.float64)
    death = np.asarray(death, dtype=np.float64)
    size = np.asarray(size, dtype=np.int64)
    nc = parent.size

    # ---- stability: Σ w·(λ_out − λ_birth), members fall at own C ----
    stab = (np.bincount(leaf_local, weights=w_leaf * _lam(Cl, floor),
                        minlength=nc)
            - np.bincount(leaf_local, weights=w_leaf.astype(np.float64),
                          minlength=nc) * _lam(birth, floor))

    # ---- excess-of-mass selection ----
    child_sum = np.zeros(nc, dtype=np.float64)
    has_child = np.zeros(nc, dtype=bool)
    has_child[parent[parent >= 0]] = True
    s_hat = np.empty(nc, dtype=np.float64)
    selected = np.ones(nc, dtype=bool)
    for c in range(nc - 1, -1, -1):      # children have larger ids
        if has_child[c] and child_sum[c] > stab[c]:
            selected[c] = False
            s_hat[c] = child_sum[c]
        else:
            s_hat[c] = stab[c]
        if parent[c] >= 0:
            child_sum[parent[c]] += s_hat[c]
    anc = np.zeros(nc, dtype=bool)       # any ancestor already selected?
    for c in range(nc):                  # parents have smaller ids
        p = parent[c]
        if p >= 0:
            anc[c] = anc[p] or selected[p]
            if anc[c]:
                selected[c] = False

    leaf_cond[cores] = leaf_local
    return ClusterHierarchy(
        ordering=ordering, csr=csr, weights=weights,
        min_cluster_weight=W, cores=cores, leaf_cond=leaf_cond,
        parent=parent, birth=birth, death=death, size=size,
        stability=stab, selected=selected, version=version)


def eps_cut_labels(ordering: FinexOrdering, csr: CSRNeighborhoods,
                   eps_star: float) -> np.ndarray:
    """The ε*-query of Theorem 5.6, replayed from the CSR — label-
    identical to ``eps_star_query`` with ZERO distance computations.

    Every pair with d ≤ ε_gen is in the CSR carrying its exact float32
    distance; a pair absent from a candidate's row has d > ε_gen ≥ ε*,
    which every ``d ≤ ε*`` test rejects exactly as a computed distance
    would.  The per-candidate first hit in (cluster, id) core order is
    one global min-rank reduction instead of the scalar query's blocked
    masked-argmax — same argument order, same labels.
    """
    eps_star = float(np.float32(eps_star))
    eps_gen = float(np.float32(ordering.eps))
    labels = query_clustering(ordering, eps_star)
    if eps_star >= eps_gen:
        return labels
    C = ordering.C
    cand_mask = (labels < 0) & (C > eps_star) & (C <= eps_gen)
    candidates = np.nonzero(cand_mask)[0]
    if candidates.size == 0:
        return labels
    sparse = query_clustering(ordering, ordering.eps)
    first, _ = cluster_spans(ordering, labels)
    core_star_ids = np.nonzero((C <= eps_star) & (labels >= 0))[0]
    if core_star_ids.size == 0:
        return labels
    core_lab = labels[core_star_ids]
    by_lab = np.argsort(core_lab, kind="stable")
    sorted_cores = core_star_ids[by_lab]
    sorted_lab = core_lab[by_lab]
    m = first.shape[0]
    sparse_of_S = np.full(m, -1, dtype=np.int64)
    sparse_of_S[sorted_lab[::-1]] = sparse[sorted_cores[::-1]]
    core_group = sparse_of_S[sorted_lab]
    rank_of = np.full(ordering.n, -1, dtype=np.int64)
    rank_of[sorted_cores] = np.arange(sorted_cores.size)

    # candidates' CSR rows: (candidate, neighbor, d) triples, gathered
    starts = csr.indptr[candidates].astype(np.int64)
    lens = (csr.indptr[candidates + 1] - csr.indptr[candidates]
            ).astype(np.int64)
    total = int(lens.sum())
    if total == 0:
        return labels
    seg_base = np.cumsum(lens) - lens
    pos = np.repeat(starts - seg_base, lens) + np.arange(total)
    slot = np.repeat(np.arange(candidates.size), lens)
    nb = csr.indices[pos].astype(np.int64)
    d = csr.dists[pos]

    r = rank_of[nb]
    keep = (r >= 0) & (d <= eps_star)
    slot, r = slot[keep], r[keep]
    # Thm 5.6 conds 2+3: candidate and core share a sparse cluster, and
    # the core's cluster started before the candidate was processed
    keep = ((sparse[candidates[slot]] == core_group[r])
            & (first[sorted_lab[r]] > ordering.pos[candidates[slot]]))
    slot, r = slot[keep], r[keep]
    sentinel = np.int64(sorted_cores.size)
    best = np.full(candidates.size, sentinel, dtype=np.int64)
    np.minimum.at(best, slot, r)
    got = best < sentinel
    labels[candidates[got]] = sorted_lab[best[got]]
    return labels


class ClusterHierarchy:
    """One index's full density hierarchy: condensed tree + exact cuts.

    Immutable snapshot semantics: mutations replace the facade's
    ordering/CSR objects, so a handle taken before an insert/delete
    keeps answering for the state it was built from, while the facade's
    lazy cache rebuilds on next access.
    """

    def __init__(self, *, ordering, csr, weights, min_cluster_weight,
                 cores, leaf_cond, parent, birth, death, size, stability,
                 selected, version=0):
        self.ordering = ordering
        self.csr = csr
        self.weights = weights
        self.min_cluster_weight = int(min_cluster_weight)
        self.cores = cores
        self.leaf_cond = leaf_cond
        self.parent = parent
        self.birth = birth
        self.death = death
        self.size = size
        self.stability = stability
        self.selected = selected
        self.version = int(version)
        self.build_seconds: Optional[float] = None

    @property
    def n(self) -> int:
        return self.ordering.n

    @property
    def n_clusters(self) -> int:
        return int(self.parent.size)

    @property
    def n_selected(self) -> int:
        return int(self.selected.sum())

    def condensed(self) -> CondensedTree:
        return CondensedTree(parent=self.parent, birth=self.birth,
                             death=self.death, size=self.size,
                             stability=self.stability,
                             selected=self.selected)

    # ----------------------------------------------------------- slices
    def cut(self, eps_star: float) -> ClusteringResult:
        """Exact labels at (ε* ≤ ε_gen, MinPts) — identical to
        ``FinexIndex.eps_star`` with zero distance computations."""
        with obs.span("hierarchy.cut", eps_star=float(eps_star),
                      n=self.n):
            t0 = time.perf_counter()
            labels = eps_cut_labels(self.ordering, self.csr, eps_star)
            if obs.enabled():
                obs.count("hierarchy.cuts")
        return ClusteringResult.wrap(
            labels, kind="eps", value=float(eps_star),
            version=self.version, eps=self.ordering.eps,
            minpts=self.ordering.minpts,
            elapsed_s=time.perf_counter() - t0)

    def cut_minpts(self, minpts_star: int) -> ClusteringResult:
        """Exact labels at (ε_gen, MinPts* ≥ MinPts) — identical to
        ``FinexIndex.minpts_star`` (the §5.4 kernel is already
        distance-free)."""
        with obs.span("hierarchy.cut_minpts",
                      minpts_star=int(minpts_star), n=self.n):
            t0 = time.perf_counter()
            labels = minpts_star_query(self.ordering, self.csr,
                                       int(minpts_star))
            if obs.enabled():
                obs.count("hierarchy.cuts")
        return ClusteringResult.wrap(
            labels, kind="minpts", value=int(minpts_star),
            version=self.version, eps=self.ordering.eps,
            minpts=self.ordering.minpts,
            elapsed_s=time.perf_counter() - t0)

    def extract(self) -> ClusteringResult:
        """The stability-selected flat clustering (excess of mass).

        Cores of selected clusters get labels numbered by smallest
        member id; everything else (including non-cores) is noise."""
        with obs.span("hierarchy.extract", n=self.n):
            t0 = time.perf_counter()
            labels = self._extract_labels()
        return ClusteringResult.wrap(
            labels, kind="stability", value=self.min_cluster_weight,
            version=self.version, eps=self.ordering.eps,
            minpts=self.ordering.minpts,
            elapsed_s=time.perf_counter() - t0)

    def _extract_labels(self) -> np.ndarray:
        n, nc = self.n, self.n_clusters
        labels = np.full(n, -1, dtype=np.int64)
        if nc == 0:
            return labels
        sel_of = np.full(nc, -1, dtype=np.int64)
        for c in range(nc):              # parents have smaller ids
            if self.selected[c]:
                sel_of[c] = c
            elif self.parent[c] >= 0:
                sel_of[c] = sel_of[self.parent[c]]
        local = self.leaf_cond[self.cores]
        cluster = sel_of[local]
        mask = cluster >= 0
        if not mask.any():
            return labels
        # deterministic numbering: clusters by smallest member object id
        mins = np.full(nc, n, dtype=np.int64)
        np.minimum.at(mins, cluster[mask], self.cores[mask])
        present = np.flatnonzero(mins < n)
        label_of = np.full(nc, -1, dtype=np.int64)
        label_of[present[np.argsort(mins[present])]] = \
            np.arange(present.size)
        labels[self.cores[mask]] = label_of[cluster[mask]]
        return labels

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, object]:
        return {
            "cores": int(self.cores.size),
            "clusters": self.n_clusters,
            "selected": self.n_selected,
            "min_cluster_weight": self.min_cluster_weight,
            "version": self.version,
            "build_s": self.build_seconds,
        }

    # ---------------------------------------------------------- persist
    def to_arrays(self) -> Dict[str, np.ndarray]:
        """The optional npz keys ``FinexIndex.to_arrays`` merges in."""
        return {
            "hier_parent": self.parent, "hier_birth": self.birth,
            "hier_death": self.death, "hier_size": self.size,
            "hier_stability": self.stability,
            "hier_selected": self.selected,
            "hier_leaf_cond": self.leaf_cond,
            "hier_minw": np.int64(self.min_cluster_weight),
        }

    @classmethod
    def from_arrays(cls, z, ordering: FinexOrdering,
                    csr: CSRNeighborhoods, weights: np.ndarray,
                    version: int = 0) -> Optional["ClusterHierarchy"]:
        """Rebuild from an archive dict; None if the keys are absent."""
        if any(k not in z for k in HIERARCHY_ARRAY_KEYS):
            return None
        leaf_cond = np.asarray(z["hier_leaf_cond"])
        return cls(
            ordering=ordering, csr=csr, weights=weights,
            min_cluster_weight=int(z["hier_minw"]),
            cores=np.flatnonzero(leaf_cond >= 0), leaf_cond=leaf_cond,
            parent=np.asarray(z["hier_parent"]),
            birth=np.asarray(z["hier_birth"]),
            death=np.asarray(z["hier_death"]),
            size=np.asarray(z["hier_size"]),
            stability=np.asarray(z["hier_stability"]),
            selected=np.asarray(z["hier_selected"]), version=version)
