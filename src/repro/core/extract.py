"""Algorithm 1 — QueryClustering(O, ε*) — as a vectorized linear scan.

The paper's loop walks the ordering once: an object with R > ε* either
starts a new cluster (if C ≤ ε*) or is noise; an object with R ≤ ε* joins
the current cluster. Over the struct-of-arrays ordering this is a cumsum
over cluster-start markers — O(n) with no Python-level loop, which is the
"linear-time clustering" of §5.2 in vectorized form.

Applied to a FINEX-ordering this yields:
  * the *exact* clustering for ε* = ε (Corollary 5.5),
  * an approximate clustering strictly at-least-as-accurate as OPTICS for
    ε* < ε (Theorems 5.2–5.4).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.ordering import ClusterOrdering


def query_clustering(o: ClusterOrdering, eps_star: float) -> np.ndarray:
    """Labels per object id: cluster ids 0..m-1, or -1 for noise.

    Cluster ids are assigned in ordering appearance order, so they are
    deterministic for a given ordering.

    Thresholds are canonicalized to float32 — the distance domain of the
    device tile sweep — so that d ≤ ε* means the same thing here as it does
    in the CSR filter and the fused count kernels (ties at the threshold
    are common for discrete metrics like Jaccard).
    """
    eps_star = float(np.float32(eps_star))
    if eps_star > float(np.float32(o.eps)) + 1e-12:
        raise ValueError(f"eps*={eps_star} exceeds generating eps={o.eps}")
    Rq = o.R[o.order]
    Cq = o.C[o.order]
    breaks = Rq > eps_star
    starts = breaks & (Cq <= eps_star)
    member = ~breaks | starts
    labels_in_order = np.cumsum(starts) - 1
    labels_in_order = np.where(member & (labels_in_order >= 0),
                               labels_in_order, -1)
    # R ≤ ε* before any cluster start would join an empty cluster; the
    # orderings produced by Algorithms 2/3 cannot do this (the minimizing
    # core precedes — see Thm 5.3 proof), so flag it loudly if it happens.
    assert not np.any((~breaks) & (np.cumsum(starts) == 0)), \
        "object reachable at eps* before any cluster start: corrupt ordering"
    labels = np.empty(o.n, dtype=np.int64)
    labels[o.order] = labels_in_order
    return labels


def cluster_spans(o: ClusterOrdering, labels: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-cluster (first, last) positions in the ordering.

    Approximate clusters are contiguous runs in the ordering (Def. 4.2);
    the ε*-query candidate test "processed before the first object of S_i"
    (Thm 5.6 cond. 2) reads the ``first`` array.
    """
    m = int(labels.max()) + 1 if labels.size and labels.max() >= 0 else 0
    first = np.full(m, np.iinfo(np.int64).max, dtype=np.int64)
    last = np.full(m, -1, dtype=np.int64)
    member = labels >= 0
    np.minimum.at(first, labels[member], o.pos[member])
    np.maximum.at(last, labels[member], o.pos[member])
    return first, last
