"""Algorithm 1 — QueryClustering(O, ε*) — as a vectorized linear scan.

The paper's loop walks the ordering once: an object with R > ε* either
starts a new cluster (if C ≤ ε*) or is noise; an object with R ≤ ε* joins
the current cluster. Over the struct-of-arrays ordering this is a cumsum
over cluster-start markers — O(n) with no Python-level loop, which is the
"linear-time clustering" of §5.2 in vectorized form.

Applied to a FINEX-ordering this yields:
  * the *exact* clustering for ε* = ε (Corollary 5.5),
  * an approximate clustering strictly at-least-as-accurate as OPTICS for
    ε* < ε (Theorems 5.2–5.4).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.ordering import ClusterOrdering


def query_clustering(o: ClusterOrdering, eps_star: float) -> np.ndarray:
    """Labels per object id: cluster ids 0..m-1, or -1 for noise.

    Cluster ids are assigned in ordering appearance order, so they are
    deterministic for a given ordering.

    Thresholds are canonicalized to float32 — the distance domain of the
    device tile sweep — so that d ≤ ε* means the same thing here as it does
    in the CSR filter and the fused count kernels (ties at the threshold
    are common for discrete metrics like Jaccard).

    The K=1 slice of :func:`query_clustering_batch` — one implementation
    of the scan, so the "row k is byte-identical" contract holds by
    construction.
    """
    return query_clustering_batch(o, [eps_star])[0]


def query_clustering_batch(o: ClusterOrdering, eps_stars) -> np.ndarray:
    """Algorithm 1 over K thresholds at once: (K, n) label matrix.

    One segmented extraction instead of K sequential scans: the per-object
    (R, C) rows are read once and broadcast against the threshold column,
    so the cumsum/labeling pass is a single 2-D kernel. Row k is
    byte-identical to ``query_clustering(o, eps_stars[k])``.
    """
    es = np.asarray([float(np.float32(e)) for e in np.atleast_1d(eps_stars)],
                    dtype=np.float64)
    if es.size == 0:
        return np.empty((0, o.n), dtype=np.int64)
    eps_gen = float(np.float32(o.eps))
    if es.max() > eps_gen + 1e-12:
        raise ValueError(
            f"eps*={es.max()} exceeds generating eps={o.eps}")
    Rq = o.R[o.order][None, :]
    Cq = o.C[o.order][None, :]
    e = es[:, None]
    breaks = Rq > e
    starts = breaks & (Cq <= e)
    member = ~breaks | starts
    cum = np.cumsum(starts, axis=1)
    labels_in_order = np.where(member & (cum > 0), cum - 1, -1)
    assert not np.any((~breaks) & (cum == 0)), \
        "object reachable at eps* before any cluster start: corrupt ordering"
    labels = np.empty((es.size, o.n), dtype=np.int64)
    labels[:, o.order] = labels_in_order
    return labels


def cluster_spans(o: ClusterOrdering, labels: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-cluster (first, last) positions in the ordering.

    Approximate clusters are contiguous runs in the ordering (Def. 4.2);
    the ε*-query candidate test "processed before the first object of S_i"
    (Thm 5.6 cond. 2) reads the ``first`` array.
    """
    m = int(labels.max()) + 1 if labels.size and labels.max() >= 0 else 0
    first = np.full(m, np.iinfo(np.int64).max, dtype=np.int64)
    last = np.full(m, -1, dtype=np.int64)
    member = labels >= 0
    np.minimum.at(first, labels[member], o.pos[member])
    np.maximum.at(last, labels[member], o.pos[member])
    return first, last
