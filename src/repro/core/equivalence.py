"""Exact-clustering equivalence modulo ambiguous border assignment.

Definition 3.5 pins down everything except which cluster an *ambiguous*
border object lands in. Two exact clusterings of the same (ε, MinPts)
problem are therefore equivalent iff:

  1. their noise sets are identical,
  2. they partition the core objects identically,
  3. every border object is assigned, in both, to a cluster containing a
     core whose ε-ball covers it (validity).

This is the correctness contract used by the tests to compare FINEX
queries against the DBSCAN oracle.
"""
from __future__ import annotations


import numpy as np

from repro.core.dbscan import filtered_counts
from repro.neighbors.engine import CSRNeighborhoods


def canonical_core_partition(labels: np.ndarray, core: np.ndarray
                             ) -> set[frozenset]:
    out: dict[int, set] = {}
    for obj in np.nonzero(core)[0]:
        lab = labels[obj]
        assert lab >= 0, f"core object {obj} labeled noise"
        out.setdefault(int(lab), set()).add(int(obj))
    return {frozenset(v) for v in out.values()}


def border_assignment_valid(labels: np.ndarray, core: np.ndarray,
                            csr: CSRNeighborhoods, eps_star: float) -> bool:
    """Every labeled non-core must touch a same-labeled core within ε*."""
    for obj in np.nonzero((labels >= 0) & (~core))[0]:
        s, e = csr.indptr[obj], csr.indptr[obj + 1]
        nbrs = csr.indices[s:e]
        good = csr.dists[s:e] <= np.float32(eps_star)
        ok = np.any(core[nbrs[good]] & (labels[nbrs[good]] == labels[obj]))
        if not ok:
            return False
    return True


def assert_equivalent_exact(labels_a: np.ndarray, labels_b: np.ndarray,
                            csr: CSRNeighborhoods, weights: np.ndarray,
                            eps_star: float, minpts: int,
                            context: str = "") -> None:
    counts = filtered_counts(csr, weights, eps_star)
    core = counts >= minpts

    noise_a = set(np.nonzero(labels_a < 0)[0].tolist())
    noise_b = set(np.nonzero(labels_b < 0)[0].tolist())
    assert noise_a == noise_b, (
        f"{context}: noise sets differ "
        f"(only-A={sorted(noise_a - noise_b)[:10]}, "
        f"only-B={sorted(noise_b - noise_a)[:10]})")

    pa = canonical_core_partition(labels_a, core)
    pb = canonical_core_partition(labels_b, core)
    assert pa == pb, f"{context}: core partitions differ"

    assert border_assignment_valid(labels_a, core, csr, eps_star), \
        f"{context}: invalid border assignment in A"
    assert border_assignment_valid(labels_b, core, csr, eps_star), \
        f"{context}: invalid border assignment in B"


def border_recall(labels: np.ndarray, oracle: np.ndarray, core: np.ndarray
                  ) -> float:
    """Fraction of the oracle's border objects that ``labels`` clusters.

    The paper's Table 3 metric: OPTICS misses border objects (labels them
    noise); FINEX must never miss a non-core border (Thm 5.3) and misses
    only former-cores.
    """
    border = (oracle >= 0) & (~core)
    total = int(border.sum())
    if total == 0:
        return 1.0
    hit = int(((labels >= 0) & border).sum())
    return hit / total
