"""FINEX-build — Algorithms 2 and 3 of the paper.

The ordering sweep is inherently sequential (a stable priority queue with
re-insertion of processed non-cores) and runs on the host; all distance
work — counts, CSR neighborhoods, core distances — was produced by the
device tile sweep in ``repro.neighbors.engine`` beforehand, mirroring the
paper's "materialize neighborhoods in a separate step in advance" strategy.

Fidelity notes:
  * The priority queue is *stable*: ties pop in insertion order, and a
    priority decrease counts as a fresh insertion. Theorem 5.4 requires
    stability; tests/test_paper_properties.py checks the consequence
    (former-cores classified identically by FINEX and OPTICS).
  * Case 3 of Algorithm 3 re-inserts processed non-cores whenever a later
    core lowers their reachability; each non-core re-enters at most
    MinPts−1 times, so the asymptotic complexity is unchanged (§5.1).
  * The finder reference F is updated for *every* neighbor of *every*
    processed core (lines 16–17 of Alg. 3), so at termination F[o] is the
    densest core reaching o — the datum that lets MinPts*-queries place
    border objects without any neighborhood computation (§5.4).
"""
from __future__ import annotations

import heapq
import itertools
from typing import Optional, Tuple

import numpy as np

from repro.core.ordering import ClusterOrdering, FinexOrdering
from repro.neighbors.engine import CSRNeighborhoods, NeighborEngine


class _StablePQ:
    """Min-heap keyed by (priority, insertion-seq) with lazy deletion."""

    def __init__(self):
        self._heap: list = []
        self._seq = itertools.count()
        self._best: dict[int, float] = {}    # obj -> current live priority

    def __len__(self) -> int:
        return len(self._best)

    def __contains__(self, obj: int) -> bool:
        return obj in self._best

    def priority(self, obj: int) -> float:
        return self._best[obj]

    def insert(self, obj: int, priority: float) -> None:
        self._best[obj] = priority
        heapq.heappush(self._heap, (priority, next(self._seq), obj))

    # a decrease re-inserts: the element's tie-break order is its update time
    decrease = insert

    def pop(self) -> Tuple[int, float]:
        while True:
            priority, _, obj = heapq.heappop(self._heap)
            if self._best.get(obj) == priority:
                del self._best[obj]
                return obj, priority
            # stale entry from a later decrease or a removal — skip


def _prepare(engine: NeighborEngine, eps: float, minpts: int,
             csr: Optional[CSRNeighborhoods] = None):
    if csr is None:
        counts, csr = engine.materialize(eps)
    else:
        counts = np.zeros(engine.n, dtype=np.int64)
        for p in range(engine.n):
            idx = csr.indices[csr.indptr[p]:csr.indptr[p + 1]]
            counts[p] = engine.weights[idx].sum()
    C = NeighborEngine.core_distances(csr, counts, engine.weights, minpts)
    return counts, csr, C


def finex_build(engine: NeighborEngine, eps: float, minpts: int,
                csr: Optional[CSRNeighborhoods] = None
                ) -> Tuple[FinexOrdering, CSRNeighborhoods]:
    """Algorithm 2 (with Algorithm 3 queue updates). Returns (index, CSR)."""
    n = engine.n
    counts, csr, C = _prepare(engine, eps, minpts, csr)

    R = np.full(n, np.inf, dtype=np.float64)
    N = counts.astype(np.int64)               # o.N — weighted |N_ε(o)|
    F = np.arange(n, dtype=np.int64)          # o.F — init: self-reference
    # paper initializes o.N to 0 until processed; for the F-comparison we
    # track the "visible" N exactly as Algorithm 2 does:
    visible_N = np.zeros(n, dtype=np.int64)
    processed = np.zeros(n, dtype=bool)
    slot = np.full(n, -1, dtype=np.int64)     # position in order_list or -1
    order_list: list[int] = []                # with tombstones (-1)
    is_core = np.isfinite(C)

    pq = _StablePQ()

    def q_update(c: int) -> None:
        """Algorithm 3: PriorityQueue::update(c, N_ε(c), Õ)."""
        s, e = csr.indptr[c], csr.indptr[c + 1]
        nbrs = csr.indices[s:e]
        dists = csr.dists[s:e]
        Cc = C[c]
        for q, d in zip(nbrs, dists):
            rdist = Cc if Cc >= d else float(d)
            if not processed[q] and q not in pq:
                R[q] = rdist
                pq.insert(int(q), rdist)
            elif q in pq:
                if rdist < R[q]:
                    R[q] = rdist
                    pq.decrease(int(q), rdist)
            else:  # processed
                if not is_core[q] and rdist < R[q]:
                    # globally minimize non-core reachability: re-process
                    processed[q] = False
                    order_list[slot[q]] = -1       # tombstone
                    slot[q] = -1
                    R[q] = rdist
                    pq.insert(int(q), rdist)
            if visible_N[c] > visible_N[F[q]]:
                F[q] = c

    def append(o: int) -> None:
        processed[o] = True
        slot[o] = len(order_list)
        order_list.append(o)
        visible_N[o] = N[o]

    for o in range(n):
        if processed[o]:
            continue
        # o.C, o.N computed; o.R = inf (outer-loop object)
        append(o)
        if is_core[o]:
            q_update(o)
            while len(pq):
                p, _ = pq.pop()
                append(p)
                if is_core[p]:
                    q_update(p)

    order = np.asarray([x for x in order_list if x >= 0], dtype=np.int64)
    assert order.shape[0] == n
    pos = np.empty(n, dtype=np.int64)
    pos[order] = np.arange(n)
    idx = FinexOrdering(eps=float(eps), minpts=int(minpts), order=order,
                        pos=pos, C=C.astype(np.float64), R=R,
                        N=N, F=F)
    return idx, csr


def optics_build(engine: NeighborEngine, eps: float, minpts: int,
                 csr: Optional[CSRNeighborhoods] = None
                 ) -> Tuple[ClusterOrdering, CSRNeighborhoods]:
    """The OPTICS baseline (§3.2): same sweep, no re-insertion, no (N, F).

    Kept as a separate function rather than a flag so the two algorithms
    can be diffed side by side; they share the stable queue implementation,
    which Theorem 5.4 relies on.
    """
    n = engine.n
    counts, csr, C = _prepare(engine, eps, minpts, csr)

    R = np.full(n, np.inf, dtype=np.float64)
    processed = np.zeros(n, dtype=bool)
    order_list: list[int] = []
    is_core = np.isfinite(C)
    pq = _StablePQ()

    def q_update(c: int) -> None:
        s, e = csr.indptr[c], csr.indptr[c + 1]
        Cc = C[c]
        for q, d in zip(csr.indices[s:e], csr.dists[s:e]):
            rdist = Cc if Cc >= d else float(d)
            if not processed[q] and q not in pq:
                R[q] = rdist
                pq.insert(int(q), rdist)
            elif q in pq and rdist < R[q]:
                R[q] = rdist
                pq.decrease(int(q), rdist)

    for o in range(n):
        if processed[o]:
            continue
        processed[o] = True
        order_list.append(o)
        if is_core[o]:
            q_update(o)
            while len(pq):
                p, _ = pq.pop()
                processed[p] = True
                order_list.append(p)
                if is_core[p]:
                    q_update(p)

    order = np.asarray(order_list, dtype=np.int64)
    pos = np.empty(n, dtype=np.int64)
    pos[order] = np.arange(n)
    return ClusterOrdering(eps=float(eps), minpts=int(minpts), order=order,
                           pos=pos, C=C.astype(np.float64), R=R), csr
