"""FINEX-build — Algorithms 2 and 3 of the paper, with bulk queue updates.

The ordering sweep is inherently sequential (a stable priority queue with
re-insertion of processed non-cores) and runs on the host; all distance
work — counts, CSR neighborhoods, core distances — was produced by the
device tile sweep in ``repro.neighbors.engine`` beforehand, mirroring the
paper's "materialize neighborhoods in a separate step in advance" strategy.

Algorithm 3's queue update is where the host used to burn its time: one
Python iteration per (core, neighbor) pair — O(nnz) interpreter overhead.
Here ``q_update`` handles a whole neighbor row at once: reachability
distances, insert/decrease/re-insert case splits and finder-reference
updates are numpy masks, and the queue itself is an array-backed stable
structure whose bulk insert is a vectorized sorted merge. The byte-level
results (order, R, N, F) are identical to the sequential sweep —
``repro.core.reference`` keeps the loop version and
``tests/test_vectorized_equivalence.py`` asserts equality.

Fidelity notes:
  * The priority queue is *stable*: ties pop in insertion order, and a
    priority decrease counts as a fresh insertion. Theorem 5.4 requires
    stability; batch inserts assign insertion sequence numbers in neighbor
    order, reproducing the sequential semantics exactly.
  * Case 3 of Algorithm 3 re-inserts processed non-cores whenever a later
    core lowers their reachability; each non-core re-enters at most
    MinPts−1 times, so the asymptotic complexity is unchanged (§5.1).
  * The finder reference F is updated for *every* neighbor of *every*
    processed core (lines 16–17 of Alg. 3), so at termination F[o] is the
    densest core reaching o — the datum that lets MinPts*-queries place
    border objects without any neighborhood computation (§5.4).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro import obs
from repro.core.ordering import ClusterOrdering, FinexOrdering
from repro.neighbors.engine import CSRNeighborhoods, NeighborEngine


class _StablePQ:
    """Array-backed stable min-queue over object ids 0..n-1.

    Entries are ordered by (priority, insertion time); a priority decrease
    is a fresh insertion (stale entries are skipped lazily on pop, exactly
    like the classic heap + lazy-deletion scheme). The backing store is a
    single (priority, obj) array pair kept globally sorted; ``insert_many``
    merges a whole batch in one vectorized ``searchsorted`` pass — no
    Python-level per-entry work.

    Complexity trade-off: each merge copies the live queue, so per-update
    cost is O(|frontier| + row), i.e. O(Σ frontier) total — linear-factor
    worse than a binary heap's O(row·log n) when the frontier stays Θ(n)
    (expander-like ε-graphs), but far faster in practice on clustered
    data where the frontier is a cluster boundary and the constant-factor
    win of vectorized merges dominates (see BENCH_index.json). A
    log-structured multi-run merge would bound the worst case if such
    workloads appear.
    """

    def __init__(self, n: int):
        self._prio = np.empty(0, dtype=np.float64)
        self._obj = np.empty(0, dtype=np.int64)
        self._head = 0                       # consumed prefix
        self._live = np.full(n, np.inf, dtype=np.float64)
        self._in = np.zeros(n, dtype=bool)
        self._size = 0

    def __len__(self) -> int:
        return self._size

    def in_queue(self, objs: np.ndarray) -> np.ndarray:
        return self._in[objs]

    def insert_many(self, objs: np.ndarray, prios: np.ndarray) -> None:
        """Insert/decrease a batch; insertion order follows array order.

        Stability is positional: within the batch a stable-equivalent
        sort keeps ties in array order, and the merge places new entries
        *after* stored entries of equal priority — so the backing array
        is always ordered by (priority, insertion time) without tracking
        explicit sequence numbers.
        """
        k = objs.shape[0]
        if k == 0:
            return
        newly = ~self._in[objs]
        self._size += int(np.count_nonzero(newly))
        self._in[objs] = True
        self._live[objs] = prios
        # Priorities are float64 images of float32 reachability values
        # (build.py contract), so their low 29 mantissa bits are zero:
        # packing the batch position into them yields one unique int64
        # key — a plain quicksort replaces the costlier stable float sort
        # while keeping batch order on priority ties
        key = prios.view(np.int64) | np.arange(k, dtype=np.int64)
        b = np.argsort(key)
        bp, bo = prios[b], objs[b].astype(np.int64)
        old_p = self._prio[self._head:]
        old_o = self._obj[self._head:]
        if old_p.shape[0]:
            # compact: drop stale entries (superseded priorities) so the
            # array never accumulates them across merges — without this a
            # decrease-heavy workload makes each merge copy an ever-
            # growing tail of dead entries (data-dependent quadratic)
            live = self._in[old_o] & (self._live[old_o] == old_p)
            if not live.all():
                old_p, old_o = old_p[live], old_o[live]
        if old_p.shape[0] == 0:                    # queue drained: no merge
            self._prio, self._obj = bp, bo
            self._head = 0
            return
        # every new entry is younger than every stored one, so 'right' on
        # priority realizes the (priority, insertion time) merge
        at = np.searchsorted(old_p, bp, side="right")
        total = old_p.shape[0] + k
        pos_new = at + np.arange(k)
        is_new = np.zeros(total, dtype=bool)
        is_new[pos_new] = True
        prio = np.empty(total, dtype=np.float64)
        obj = np.empty(total, dtype=np.int64)
        prio[pos_new], obj[pos_new] = bp, bo
        prio[~is_new], obj[~is_new] = old_p, old_o
        self._prio, self._obj = prio, obj
        self._head = 0

    def pop(self) -> Tuple[int, float]:
        while True:
            i = self._head
            self._head += 1
            obj = int(self._obj[i])
            prio = float(self._prio[i])
            if self._in[obj] and self._live[obj] == prio:
                self._in[obj] = False
                self._size -= 1
                return obj, prio
            # stale entry from a later decrease or a pop+re-insert — skip


class _Tombstones:
    """Growable order list with O(1) append and vectorized tombstoning."""

    def __init__(self, n: int):
        self._buf = np.empty(max(n, 16), dtype=np.int64)
        self.len = 0

    def append(self, o: int) -> int:
        if self.len == self._buf.shape[0]:
            self._buf = np.concatenate(
                [self._buf, np.empty_like(self._buf)])
        self._buf[self.len] = o
        self.len += 1
        return self.len - 1

    def kill(self, slots: np.ndarray) -> None:
        self._buf[slots] = -1

    def survivors(self) -> np.ndarray:
        out = self._buf[:self.len]
        return out[out >= 0]


def _prepare(engine: NeighborEngine, eps: float, minpts: int,
             csr: Optional[CSRNeighborhoods] = None):
    if csr is None:
        counts, csr, C = engine.materialize_stats(eps, minpts)
        return counts, csr, C
    if engine.unit_weights:
        counts = np.diff(csr.indptr)
    else:
        counts = np.bincount(
            csr.row_ids(),
            weights=engine.weights[csr.indices].astype(np.float64),
            minlength=engine.n).astype(np.int64)
    C = NeighborEngine.core_distances(csr, counts, engine.weights, minpts)
    return counts, csr, C


def finex_sweep(counts: np.ndarray, csr: CSRNeighborhoods, C: np.ndarray,
                active: Optional[np.ndarray] = None) -> dict:
    """Algorithm 2/3 ordering sweep over precomputed neighborhood stats.

    ``C`` is the float32 core-distance array from
    ``NeighborEngine.core_distances``. With ``active=None`` this is the
    full build sweep; with an id array the outer loop visits exactly
    those objects (in ascending id order) and every other object is
    treated as already processed.  The incremental-maintenance repair
    path (``repro.core.delta``) relies on the sweep never crossing a
    core-incidence component boundary, so handing it the affected
    components reproduces the full sweep's bytes for those objects.

    Returns a dict:
      order        — emitted object ids, emission order (active only)
      R, F         — full-size arrays; non-active entries left at init
      run_id       — per object: index (in trigger order) of the
                     outer-loop run that finally emitted it, -1 if none
      run_triggers — per run, its outer-loop trigger object id
    """
    with obs.span("build.finex_sweep", n=int(counts.shape[0]),
                  active=(-1 if active is None else len(active))) as sp:
        sweep = _finex_sweep_impl(counts, csr, C, active)
        sp.annot(runs=int(sweep["run_triggers"].shape[0]))
    return sweep


def _finex_sweep_impl(counts, csr, C, active=None) -> dict:
    # untraced body of :func:`finex_sweep`
    n = counts.shape[0]
    R = np.full(n, np.inf, dtype=np.float64)
    N = counts.astype(np.int64)               # o.N — weighted |N_ε(o)|
    F = np.arange(n, dtype=np.int64)          # o.F — init: self-reference
    # paper initializes o.N to 0 until processed; for the F-comparison we
    # track the "visible" N exactly as Algorithm 2 does:
    visible_N = np.zeros(n, dtype=np.int64)
    processed = np.zeros(n, dtype=bool)
    run_id = np.full(n, -1, dtype=np.int64)
    run_triggers: list = []
    if active is None:
        outer = range(n)
    else:
        outer = np.sort(np.asarray(active, dtype=np.int64))
        live = np.zeros(n, dtype=bool)
        live[outer] = True
        processed[~live] = True
    slot = np.full(n, -1, dtype=np.int64)     # position in order list or -1
    order_list = _Tombstones(n)
    is_core = np.isfinite(C)
    # row-addressed access (not indptr slicing) so the sweep reads packed
    # and slack-padded CSRs identically — the incremental path hands it
    # a SlackCSR whose rows are not contiguous
    row_starts, row_ends = csr.row_bounds()
    indices, dists = csr.indices, csr.dists

    pq = _StablePQ(n)

    def q_update(c: int) -> None:
        """Algorithm 3: PriorityQueue::update(c, N_ε(c), Õ) — one batch."""
        s, e = row_starts[c], row_ends[c]
        nbrs = indices[s:e]                        # int32 view, no copy
        rdist = np.maximum(dists[s:e], C[c]).astype(np.float64)
        proc = processed[nbrs]
        inq = pq.in_queue(nbrs)
        better = rdist < R[nbrs]
        new_m = ~proc & ~inq                       # case 1: first contact
        dec_m = inq & better                       # case 2: decrease
        re_m = proc & ~is_core[nbrs] & better      # case 3: re-process
        rq = nbrs[re_m]
        if rq.size:
            # globally minimize non-core reachability: pull them back in
            processed[rq] = False
            order_list.kill(slot[rq])
            slot[rq] = -1
        push = new_m | dec_m | re_m
        objs = nbrs[push]
        if objs.size:
            R[objs] = rdist[push]
            pq.insert_many(objs, rdist[push])
        upd = visible_N[c] > visible_N[F[nbrs]]
        if upd.any():
            F[nbrs[upd]] = c

    def append(o: int, run: int) -> None:
        processed[o] = True
        slot[o] = order_list.append(o)
        visible_N[o] = N[o]
        run_id[o] = run

    for o in outer:
        if processed[o]:
            continue
        # o.C, o.N computed; o.R = inf (outer-loop object)
        run = len(run_triggers)
        run_triggers.append(int(o))
        append(o, run)
        if is_core[o]:
            q_update(o)
            while len(pq):
                p, _ = pq.pop()
                append(p, run)
                if is_core[p]:
                    q_update(p)

    return {"order": order_list.survivors(), "R": R, "F": F,
            "run_id": run_id,
            "run_triggers": np.asarray(run_triggers, dtype=np.int64)}


def finex_build(engine: NeighborEngine, eps: float, minpts: int,
                csr: Optional[CSRNeighborhoods] = None,
                run_meta: Optional[dict] = None
                ) -> Tuple[FinexOrdering, CSRNeighborhoods]:
    """Algorithm 2 (with Algorithm 3 queue updates). Returns (index, CSR).

    Pass a dict as ``run_meta`` to receive the sweep's run decomposition
    (``run_id`` per object + ``run_triggers``) — the bookkeeping that
    lets ``FinexIndex.insert``/``delete`` stitch unaffected run
    subsequences instead of re-sweeping the whole dataset.
    """
    with obs.span("build.finex_build", n=engine.n, eps=float(eps),
                  minpts=int(minpts), metric=engine.metric_name):
        return _finex_build_impl(engine, eps, minpts, csr, run_meta)


def _finex_build_impl(engine, eps, minpts, csr=None, run_meta=None):
    # untraced body of :func:`finex_build`
    n = engine.n
    counts, csr, C = _prepare(engine, eps, minpts, csr)
    sweep = finex_sweep(counts, csr, C)
    order = sweep["order"]
    assert order.shape[0] == n
    pos = np.empty(n, dtype=np.int64)
    pos[order] = np.arange(n)
    if run_meta is not None:
        run_meta["run_id"] = sweep["run_id"]
        run_meta["run_triggers"] = sweep["run_triggers"]
    idx = FinexOrdering(eps=float(eps), minpts=int(minpts), order=order,
                        pos=pos, C=C.astype(np.float64), R=sweep["R"],
                        N=counts.astype(np.int64), F=sweep["F"])
    return idx, csr


def optics_build(engine: NeighborEngine, eps: float, minpts: int,
                 csr: Optional[CSRNeighborhoods] = None
                 ) -> Tuple[ClusterOrdering, CSRNeighborhoods]:
    """The OPTICS baseline (§3.2): same sweep, no re-insertion, no (N, F).

    Kept as a separate function rather than a flag so the two algorithms
    can be diffed side by side; they share the stable bulk queue, which
    Theorem 5.4 relies on.
    """
    n = engine.n
    counts, csr, C = _prepare(engine, eps, minpts, csr)

    R = np.full(n, np.inf, dtype=np.float64)
    processed = np.zeros(n, dtype=bool)
    order_list: list = []
    is_core = np.isfinite(C)
    indptr, indices, dists = csr.indptr, csr.indices, csr.dists
    pq = _StablePQ(n)

    def q_update(c: int) -> None:
        s, e = indptr[c], indptr[c + 1]
        nbrs = indices[s:e]
        rdist = np.maximum(dists[s:e], C[c]).astype(np.float64)
        proc = processed[nbrs]
        inq = pq.in_queue(nbrs)
        push = (~proc & ~inq) | (inq & (rdist < R[nbrs]))
        objs = nbrs[push]
        if objs.size:
            R[objs] = rdist[push]
            pq.insert_many(objs, rdist[push])

    for o in range(n):
        if processed[o]:
            continue
        processed[o] = True
        order_list.append(o)
        if is_core[o]:
            q_update(o)
            while len(pq):
                p, _ = pq.pop()
                processed[p] = True
                order_list.append(p)
                if is_core[p]:
                    q_update(p)

    order = np.asarray(order_list, dtype=np.int64)
    pos = np.empty(n, dtype=np.int64)
    pos[order] = np.arange(n)
    return ClusterOrdering(eps=float(eps), minpts=int(minpts), order=order,
                           pos=pos, C=C.astype(np.float64), R=R), csr
