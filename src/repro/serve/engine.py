"""Batched serving engine: prefill + greedy/sampled decode.

The production serve_step (the thing the decode_* dry-run cells lower) is
``make_decode_fn`` — one jit'd token step against a sharded KV cache.
``ServeEngine`` wraps it into a batched request loop for the examples:
continuous batching at smoke scale (fixed batch slots, requests join as
slots free up), greedy or temperature sampling.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, RunConfig
from repro.models.transformer import decode_step, init_cache


def make_decode_fn(cfg: ModelConfig, rc: RunConfig,
                   mesh: Optional[Mesh] = None) -> Callable:
    """jit'd serve_step(params, cache, tokens (B,1), pos ()) per RunConfig."""
    @functools.partial(jax.jit, static_argnames=())
    def step(params, cache, tokens, pos):
        return decode_step(params, cache, tokens, pos, cfg, rc, mesh)
    return step


@dataclass
class Request:
    prompt: np.ndarray                 # (P,) int32
    max_new: int = 32
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-slot batched engine (example-scale continuous batching)."""

    def __init__(self, params, cfg: ModelConfig, rc: RunConfig,
                 batch_slots: int = 4, max_seq: int = 256,
                 temperature: float = 0.0, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.rc = rc
        self.slots = batch_slots
        self.max_seq = max_seq
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.step_fn = make_decode_fn(cfg, rc)
        self.decode_steps = 0

    def _sample(self, logits: jax.Array) -> np.ndarray:
        logits = logits[:, 0, :self.cfg.vocab]
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, -1), np.int32)
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(
            sub, logits / self.temperature), np.int32)

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve all requests to completion (batch = slot-parallel)."""
        queue = list(requests)
        while queue:
            active = queue[:self.slots]
            queue = queue[len(active):]
            B = self.slots
            cache = init_cache(self.cfg, B, self.max_seq, jnp.float32)
            # left-align: feed prompts token by token (prefill-as-decode at
            # example scale; production prefill lowers forward() instead)
            plen = max(len(r.prompt) for r in active)
            toks = np.zeros((B, plen), np.int32)
            for i, r in enumerate(active):
                toks[i, :len(r.prompt)] = r.prompt
            last = None
            for t in range(plen):
                last, cache = self.step_fn(self.params, cache,
                                           jnp.asarray(toks[:, t:t + 1]),
                                           jnp.int32(t))
                self.decode_steps += 1
            nxt = self._sample(last)
            max_new = max(r.max_new for r in active)
            for s in range(max_new):
                for i, r in enumerate(active):
                    if len(r.out) < r.max_new and not r.done:
                        r.out.append(int(nxt[i]))
                last, cache = self.step_fn(self.params, cache,
                                           jnp.asarray(nxt[:, None]),
                                           jnp.int32(plen + s))
                self.decode_steps += 1
                nxt = self._sample(last)
            for r in active:
                r.done = True
        return requests
