from repro.serve.engine import ServeEngine, make_decode_fn

__all__ = ["ServeEngine", "make_decode_fn"]
