"""Benchmark harness entry point: one section per paper table/figure plus
kernels and the dry-run-derived roofline table.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller datasets, skip exactness cross-checks")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    t0 = time.time()
    rows = []

    from benchmarks import kernels_bench, paper_tables
    print("== paper tables (Fig 6/7, Fig 8/9, Table 3, Table 4) ==",
          flush=True)
    paper_tables.run(rows, quick=args.quick)
    for r in rows:
        print(r)

    print("\n== kernel microbenchmarks ==", flush=True)
    krows = []
    kernels_bench.run(krows)
    for r in krows:
        print(r)

    if not args.skip_roofline:
        print("\n== roofline (from multi-pod dry-run store) ==", flush=True)
        from benchmarks import roofline
        try:
            print(roofline.render(roofline.load()))
        except FileNotFoundError:
            print("no dry-run results yet: run "
                  "`python -m repro.launch.dryrun --sweep` first")

    print(f"\ntotal benchmark time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
