import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: compiles named variants of the three chosen
cells (+ one bonus) and records each under `<cell>#<variant>` in the
dry-run store. EXPERIMENTS.md §Perf reads these.

Chosen per the mandate:
  * worst roofline fraction ......... mamba2-130m | train_4k
  * most collective-bound ........... qwen2-moe-a2.7b | train_4k
  * paper-technique representative .. finex (sharded neighborhood plane)
  * bonus (largest dense cell) ...... qwen2-72b | train_4k
"""

import dataclasses
import json
import sys

from repro.launch import dryrun
from repro.launch.dryrun import RESULTS_PATH, load_results, run_cell


def record(arch, shape, variant, overrides=None, finex_kw=None):
    key = f"{arch}|{shape}|16x16#{variant}"
    existing = load_results()
    if key in existing and existing[key].get("status") == "ok" \
            and "--force" not in sys.argv:
        print(f"[cached ] {key}")
        return existing[key]
    if finex_kw is not None:
        rec = _run_finex_variant(finex_kw)
    else:
        rec = run_cell(arch, shape, multi_pod=False, overrides=overrides)
    rec["variant"] = variant
    rec["arch"] = arch      # keep original key fields
    results = load_results()
    results[key] = rec
    tmp = RESULTS_PATH + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    os.replace(tmp, RESULTS_PATH)
    if rec["status"] == "ok":
        r = rec["roofline"]
        print(f"[ok     ] {key} comp={r['compute_term_s']:.3f} "
              f"mem={r['memory_term_s']:.3f} coll={r['collective_term_s']:.3f} "
              f"frac={r['roofline_fraction']:.4f} "
              f"flash={r['roofline_fraction_flash']:.4f}", flush=True)
    else:
        print(f"[error  ] {key}: {rec.get('error', '')[:200]}", flush=True)
    return rec


def _run_finex_variant(kw):
    """finex cell with distributed-sweep knobs (row_chunk, nbins, dtype)."""
    import time
    import traceback
    import jax
    from repro.launch.mesh import make_production_mesh
    from repro.neighbors import distributed as D
    t0 = time.time()
    mesh = make_production_mesh()
    try:
        fn, args, shardings = D.finex_dryrun_lowerable(mesh, **kw.get("lower", {}))
        with mesh:
            lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
            compiled = lowered.compile()
        rec = {"arch": "finex", "shape": "train_4k", "mesh": "16x16",
               "chips": mesh.devices.size, "n_micro": 1,
               "model_flops": 2.0 * (1 << 20) ** 2 * 64, "status": "ok"}
        dryrun._fill_analysis(rec, compiled, t0)
        return rec
    except Exception as e:                              # noqa: BLE001
        return {"arch": "finex", "shape": "train_4k", "mesh": "16x16",
                "status": "error", "error": str(e)[:1500],
                "traceback": traceback.format_exc()[-2000:]}


def main():
    # ---- cell 1: mamba2-130m train (worst fraction; SSD memory-bound) --
    for variant, over in [
        ("baseline", {}),
        ("chunk64", {}),           # handled via config override below
        ("chunk256", {}),
        ("accum_grads", {"accum_mode": "grads"}),
    ]:
        if variant.startswith("chunk"):
            import repro.configs as C
            q = int(variant[5:])
            cfg = dataclasses.replace(C.ARCHS["mamba2-130m"], ssm_chunk=q)
            C.ARCHS["mamba2-130m-tmp"] = cfg
            rec = record("mamba2-130m-tmp", "train_4k", variant)
            del C.ARCHS["mamba2-130m-tmp"]
        else:
            record("mamba2-130m", "train_4k", variant, over)

    # ---- cell 2: qwen2-moe train (collective-bound) --------------------
    for variant, over in [
        ("baseline", {}),
        ("accum_grads", {"accum_mode": "grads"}),
        ("seq_parallel", {"sequence_parallel": True}),
        ("micro1", {"microbatch": 1}),      # no grad accumulation at all
    ]:
        record("qwen2-moe-a2.7b", "train_4k", variant, over)

    # ---- cell 3: finex sharded neighborhood plane ----------------------
    for variant, kw in [
        ("baseline", {"lower": {}}),
        ("rowchunk512", {"lower": {"row_chunk": 512}}),
        ("rowchunk8192", {"lower": {"row_chunk": 8192}}),
        ("nbins8", {"lower": {"nbins": 8}}),
    ]:
        record("finex", "train_4k", variant, finex_kw=kw)

    # ---- bonus: qwen2-72b train (largest dense) ------------------------
    for variant, over in [
        ("baseline", {}),
        ("accum_grads", {"accum_mode": "grads"}),
        ("no_sqrt_remat", {"remat_blocks": 1}),
        ("micro_x2", {"microbatch": 32}),
    ]:
        record("qwen2-72b", "train_4k", variant, over)


if __name__ == "__main__":
    main()
