"""Roofline table reader: renders EXPERIMENTS.md §Roofline from the
dry-run JSON store (benchmarks/results/dryrun.json).

    python -m benchmarks.roofline             # full table
    python -m benchmarks.roofline --mesh 16x16 --markdown
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")


def load(path: str = RESULTS) -> Dict[str, dict]:
    with open(path) as f:
        return json.load(f)


def fmt_row(rec: dict, markdown: bool = False) -> str:
    r = rec["roofline"]
    m = rec["memory"]
    cols = [
        rec["arch"], rec["shape"], rec["mesh"],
        f"{r['compute_term_s']:.3f}", f"{r['memory_term_s']:.3f}",
        f"{r['collective_term_s']:.3f}", r["bottleneck"],
        f"{r['model_flops_ratio']:.2f}", f"{r['roofline_fraction']:.4f}",
        f"{r['roofline_fraction_flash']:.4f}",
        f"{m['peak_per_device'] / 2**30:.1f}",
    ]
    return ("| " + " | ".join(cols) + " |") if markdown else ",".join(cols)


HEADER = ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
          "bottleneck", "6ND/HLO", "frac", "frac_flash", "GiB/dev"]


def render(results: Dict[str, dict], mesh: str = None,
           markdown: bool = False) -> str:
    lines = []
    if markdown:
        lines.append("| " + " | ".join(HEADER) + " |")
        lines.append("|" + "---|" * len(HEADER))
    else:
        lines.append(",".join(HEADER))
    skipped = []
    for key in sorted(results):
        rec = results[key]
        if mesh and rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "ok":
            lines.append(fmt_row(rec, markdown))
        elif rec.get("status") == "skipped":
            skipped.append(f"{rec['arch']}|{rec['shape']}|{rec['mesh']}: "
                           f"{rec['reason']}")
    if skipped:
        lines.append("")
        lines.append("# skipped cells (mandated):")
        for s in skipped:
            lines.append(f"#   {s}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--path", default=RESULTS)
    args = ap.parse_args()
    print(render(load(args.path), args.mesh, args.markdown))


if __name__ == "__main__":
    main()
