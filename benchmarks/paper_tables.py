"""One benchmark per paper table/figure, on synthetic stand-ins for the
paper's license-gated datasets (DESIGN.md §7.4).

Reported per run: wall seconds AND distance-rows computed — the
hardware-independent cost that dominates every algorithm here (the paper's
"neighborhood computations"). Claims validated:

  Fig. 6/7  ε*-queries: FINEX ≪ DBSCAN-from-scratch and AnyDBC, with the
            bell-shaped FINEX cost curve (§6.2).
  Fig. 8/9  MinPts*-queries: FINEX ≪ baselines; DBSCAN flat in MinPts*.
  Table 3   border recall: FINEX ≥ OPTICS everywhere, = 1.0 at ε* = ε,
            converging as ε* shrinks.
  Table 4   build time: FINEX-build ≈ OPTICS-build ≈ DBSCAN (same
            asymptotics, small queue overhead).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import (border_recall, dbscan_from_csr, filtered_counts,
                        FinexIndex, optics_build, query_clustering,
                        QueryStats, assert_equivalent_exact)
from repro.core.anydbc import anydbc
from repro.data.synthetic import gaussian_mixture, heavy_tail_sets
from repro.neighbors.bitset import pack_sets
from repro.neighbors.engine import NeighborEngine

EPS_GRID = [0.25, 0.23, 0.21, 0.19, 0.17, 0.15, 0.13, 0.11, 0.09, 0.07]
MINPTS_GRID = [16, 32, 64, 128, 256]


def _engines(n_vec=2000, n_set=2600):
    x = gaussian_mixture(n_vec, d=8, k=6, noise_frac=0.12, seed=42)
    vec = NeighborEngine(x, metric="euclidean")
    sets, w = heavy_tail_sets(n_set * 3, universe=640, seed=42)
    bits, sizes = pack_sets(sets)
    st = NeighborEngine((bits, sizes), metric="jaccard", weights=w)
    return {"vector": vec, "set": st}


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def fig6_7_eps_star(engines, rows: List[str], check: bool = True) -> None:
    """Clustering runtime over ε* ≤ ε (generating ε=0.25/0.6, MinPts=64/16)."""
    for kind, eng in engines.items():
        eps, minpts = (0.25, 16) if kind == "vector" else (0.6, 16)
        grid = [eps * f for f in
                (1.0, 0.92, 0.84, 0.76, 0.68, 0.6, 0.52, 0.44, 0.36, 0.28)]
        index, t_build = _timed(lambda: FinexIndex.from_engine(eng, eps,
                                                               minpts))
        csr = index.csr
        for eps_star in grid:
            eng.distance_rows_computed = 0
            stats = QueryStats()
            lab_f, t_f = _timed(
                lambda: index.eps_star(eps_star, stats=stats))
            q_f = eng.distance_rows_computed

            # DBSCAN from scratch: charged the full re-materialization of
            # all neighborhoods at ε* plus the BFS
            eng.distance_rows_computed = 0

            def _dbscan_scratch():
                _, csr_star = eng.materialize(eps_star)
                return dbscan_from_csr(csr_star, eng.weights, eps_star,
                                       minpts)
            lab_d, t_d = _timed(_dbscan_scratch)
            q_d = eng.distance_rows_computed

            eng.distance_rows_computed = 0
            (lab_a, st_a), t_a = _timed(
                lambda: anydbc(eng, eps_star, minpts, seed=1, alpha=256))
            q_a = eng.distance_rows_computed

            if check:
                assert_equivalent_exact(lab_f, lab_d, csr, eng.weights,
                                        eps_star, minpts,
                                        f"fig6/7 {kind} {eps_star:.3f}")
            rows.append(
                f"fig6_7,{kind},eps_star={eps_star:.3f},"
                f"finex_s={t_f:.4f},finex_rows={q_f},"
                f"dbscan_s={t_d:.4f},dbscan_rows={q_d},"
                f"anydbc_s={t_a:.4f},anydbc_rows={q_a},"
                f"cands={stats.candidates},verif_pairs={stats.verification_pairs}")


def fig8_9_minpts_star(engines, rows: List[str], check: bool = True) -> None:
    for kind, eng in engines.items():
        eps, minpts = (0.25, 8) if kind == "vector" else (0.5, 8)
        index = FinexIndex.from_engine(eng, eps, minpts)
        idx, csr = index.ordering, index.csr
        for ms in MINPTS_GRID:
            stats = QueryStats()
            eng.distance_rows_computed = 0
            lab_f, t_f = _timed(lambda: index.minpts_star(ms, stats=stats))

            def _dbscan_scratch():
                _, csr_g = eng.materialize(eps)
                return dbscan_from_csr(csr_g, eng.weights, eps, ms)
            lab_d, t_d = _timed(_dbscan_scratch)
            eng.distance_rows_computed = 0
            (lab_a, st_a), t_a = _timed(lambda: anydbc(eng, eps, ms, seed=1,
                                                       alpha=256))
            q_a = eng.distance_rows_computed
            # AnyFINEX (§6.3): noise filter + N attribute + on-demand
            # connectivity — queries bounded by the preserved-core count
            from repro.core.anydbc import anyfinex_minpts_star
            eng.distance_rows_computed = 0
            (lab_af, st_af), t_af = _timed(
                lambda: anyfinex_minpts_star(idx, csr, eng, ms, seed=1))
            if check:
                assert_equivalent_exact(lab_f, lab_d, csr, eng.weights, eps,
                                        ms, f"fig8/9 {kind} {ms}")
                assert_equivalent_exact(lab_af, lab_d, csr, eng.weights, eps,
                                        ms, f"anyfinex {kind} {ms}")
            rows.append(
                f"fig8_9,{kind},minpts_star={ms},"
                f"finex_s={t_f:.4f},finex_bfs_neigh={stats.neighborhoods_computed},"
                f"fast_path={stats.fast_path},"
                f"dbscan_s={t_d:.4f},anydbc_s={t_a:.4f},anydbc_rows={q_a},"
                f"anyfinex_s={t_af:.4f},anyfinex_rows={st_af['queries']}")


def table3_recall(engines, rows: List[str]) -> None:
    recalls_f, recalls_o = {}, {}
    for kind, eng in engines.items():
        eps, minpts = (0.25, 16) if kind == "vector" else (0.6, 16)
        index = FinexIndex.from_engine(eng, eps, minpts)
        fidx, csr = index.ordering, index.csr
        oidx, _ = optics_build(eng, eps, minpts, csr=csr)
        for frac in (1.0, 0.92, 0.84, 0.76, 0.68, 0.6):
            eps_star = float(np.float32(eps * frac))
            oracle = dbscan_from_csr(csr, eng.weights, eps_star, minpts)
            core = filtered_counts(csr, eng.weights, eps_star) >= minpts
            rf = border_recall(query_clustering(fidx, eps_star), oracle, core)
            ro = border_recall(query_clustering(oidx, eps_star), oracle, core)
            recalls_f.setdefault(frac, []).append(rf)
            recalls_o.setdefault(frac, []).append(ro)
    for frac in sorted(recalls_f, reverse=True):
        rows.append(f"table3,eps_frac={frac:.2f},"
                    f"finex_recall={np.mean(recalls_f[frac]):.4f},"
                    f"optics_recall={np.mean(recalls_o[frac]):.4f}")
        assert np.mean(recalls_f[frac]) >= np.mean(recalls_o[frac]) - 1e-9
    assert np.mean(recalls_f[1.0]) == 1.0     # exact at ε* = ε (Cor. 5.5)


def table4_build_times(engines, rows: List[str]) -> None:
    for kind, eng in engines.items():
        eps, minpts = (0.25, 16) if kind == "vector" else (0.6, 16)
        _, t_mat = _timed(lambda: eng.materialize(eps))
        counts, csr = eng.materialize(eps)
        # DBSCAN from scratch = materialization + BFS
        (_, _), t_bfs = _timed(
            lambda: (dbscan_from_csr(csr, eng.weights, eps, minpts), None))
        t_dbscan = t_mat + t_bfs
        _, t_f = _timed(lambda: FinexIndex.from_engine(eng, eps, minpts,
                                                       csr=csr))
        t_finex = t_mat + t_f
        (_, _), t_o = _timed(lambda: optics_build(eng, eps, minpts, csr=csr))
        t_optics = t_mat + t_o
        rows.append(f"table4,{kind},dbscan_s={t_dbscan:.3f},"
                    f"finex_rel={t_finex / t_dbscan:.3f},"
                    f"optics_rel={t_optics / t_dbscan:.3f}")


def run(rows: List[str], quick: bool = False) -> None:
    engines = _engines(n_vec=1200 if quick else 2000,
                       n_set=1500 if quick else 2600)
    fig6_7_eps_star(engines, rows, check=not quick)
    fig8_9_minpts_star(engines, rows, check=not quick)
    table3_recall(engines, rows)
    table4_build_times(engines, rows)
