"""End-to-end index pipeline benchmark: materialize + build + queries.

Times the vectorized device-first pipeline (``FinexIndex``) against the
loop-based seed path kept in ``repro.core.reference`` on a synthetic
dataset, asserts the outputs are identical, and writes ``BENCH_index.json``
so the perf trajectory is tracked PR over PR.

    PYTHONPATH=src python benchmarks/index_bench.py             # 20k points
    PYTHONPATH=src python benchmarks/index_bench.py --n 2000 --skip-seed

Three speedup figures, because the pipeline has a shared irreducible part:
  * ``speedup_end_to_end``    — (materialize + FINEX-build) wall-clock,
    including the device distance sweep that is bit-identical in both
    paths (``device_sweep_s``; on this CPU container it is ~40% of the
    vectorized path, so it bounds this ratio well below the host win).
  * ``speedup_host_pipeline`` — same, with the shared device sweep
    subtracted from both sides: the part the refactor actually changed.
  * ``speedup_finex_build``   — the ordering-sweep stage alone
    (bulk queue updates + segmented core distances vs. per-neighbor
    loops); ≥5× at the default 20k/ε=1.0 setting.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def run(n: int = 20_000, d: int = 8, eps: float = 1.0, minpts: int = 16,
        seed: int = 0, skip_seed: bool = False, out_path: str | None = None
        ) -> dict:
    from repro.core import FinexIndex
    from repro.core.reference import (reference_eps_star_query,
                                      reference_finex_build,
                                      reference_materialize,
                                      reference_minpts_star_query)
    from repro.data.synthetic import gaussian_mixture
    from repro.neighbors.engine import NeighborEngine

    x = gaussian_mixture(n, d=d, k=12, noise_frac=0.1, seed=seed)
    eng = NeighborEngine(x, metric="euclidean")
    # warm up every jit shape both paths hit (distance tiles + the
    # bucketed verification sub-matrices): both paths produce identical
    # candidate sets, so one full vectorized pass compiles for both
    _, warm_csr = eng.materialize(eps)
    warm = FinexIndex.from_engine(eng, eps, minpts, csr=warm_csr)
    warm.eps_star(eps * 0.6)
    warm.minpts_star(minpts * 4)
    del warm, warm_csr

    report: dict = {"n": n, "d": d, "eps": eps, "minpts": minpts,
                    "seed": seed}

    # the device distance sweep is bit-identical and common to both paths
    # (the refactor changed the host pipeline around it) — time it once so
    # the host-side speedup can be reported separately from end-to-end
    import jax.numpy as jnp

    def _device_sweep():
        # stream tile-by-tile like both measured pipelines — holding all
        # tiles at once would keep the full n×n plane resident
        for s in range(0, eng.n, eng.batch_rows):
            eng._dist_block(jnp.asarray(np.arange(
                s, min(s + eng.batch_rows, eng.n),
                dtype=np.int32))).block_until_ready()
    _, t_dev = _timed(_device_sweep)
    report["device_sweep_s"] = round(t_dev, 4)

    # ---------------------------------------------------- vectorized path
    (counts, csr), t_mat = _timed(lambda: eng.materialize(eps))
    index, t_build = _timed(
        lambda: FinexIndex.from_engine(eng, eps, minpts, csr=csr))
    lab_eps, t_eps = _timed(lambda: index.eps_star(eps * 0.6))
    lab_mp, t_mp = _timed(lambda: index.minpts_star(minpts * 4))
    report["vectorized"] = {
        "materialize_s": round(t_mat, 4), "finex_build_s": round(t_build, 4),
        "eps_star_s": round(t_eps, 4), "minpts_star_s": round(t_mp, 4),
        "end_to_end_build_s": round(t_mat + t_build, 4),
        "csr_nnz": int(csr.nnz),
    }

    # ---------------------------------------------------------- seed path
    if not skip_seed:
        (_, csr_ref), t_mat_ref = _timed(lambda: reference_materialize(
            eng, eps))
        (idx_ref, _), t_build_ref = _timed(
            lambda: reference_finex_build(eng, eps, minpts, csr=csr_ref))
        lab_eps_ref, t_eps_ref = _timed(
            lambda: reference_eps_star_query(idx_ref, eng, eps * 0.6))
        lab_mp_ref, t_mp_ref = _timed(
            lambda: reference_minpts_star_query(idx_ref, csr_ref,
                                                minpts * 4))
        report["seed"] = {
            "materialize_s": round(t_mat_ref, 4),
            "finex_build_s": round(t_build_ref, 4),
            "eps_star_s": round(t_eps_ref, 4),
            "minpts_star_s": round(t_mp_ref, 4),
            "end_to_end_build_s": round(t_mat_ref + t_build_ref, 4),
        }
        # identical results, not merely equivalent ones
        assert np.array_equal(idx_ref.order, index.ordering.order)
        assert np.array_equal(idx_ref.R, index.ordering.R)
        assert np.array_equal(lab_eps_ref, lab_eps)
        assert np.array_equal(lab_mp_ref, lab_mp)
        report["identical_outputs"] = True
        host_new = max(t_mat + t_build - t_dev, 1e-9)
        host_ref = t_mat_ref + t_build_ref - t_dev
        report["build"] = {
            "speedup_end_to_end": round(
                (t_mat_ref + t_build_ref) / max(t_mat + t_build, 1e-9), 2),
            # host pipeline only — the shared device sweep subtracted from
            # both sides; this is what the vectorization refactor changed
            "speedup_host_pipeline": round(host_ref / host_new, 2),
            "speedup_finex_build": round(
                t_build_ref / max(t_build, 1e-9), 2),
            "speedup_eps_star": round(t_eps_ref / max(t_eps, 1e-9), 2),
            "speedup_minpts_star": round(t_mp_ref / max(t_mp, 1e-9), 2),
        }

    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--eps", type=float, default=1.0)
    ap.add_argument("--minpts", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-seed", action="store_true",
                    help="only time the vectorized path")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_index.json"))
    args = ap.parse_args()
    report = run(n=args.n, d=args.d, eps=args.eps, minpts=args.minpts,
                 seed=args.seed, skip_seed=args.skip_seed,
                 out_path=args.out)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
