"""End-to-end index pipeline benchmark: materialize + build + queries.

Times the vectorized device-first pipeline (``FinexIndex``) against the
loop-based seed path kept in ``repro.core.reference`` on a synthetic
dataset, asserts the outputs are identical, and writes ``BENCH_index.json``
so the perf trajectory is tracked PR over PR.

    PYTHONPATH=src python benchmarks/index_bench.py             # 20k points
    PYTHONPATH=src python benchmarks/index_bench.py --n 2000 --skip-seed

Speedup figures:
  * ``speedup_end_to_end``    — (materialize + FINEX-build) wall-clock.
  * ``speedup_host_pipeline`` — same, with the dense device sweep
    (``device_sweep_s``) subtracted from both sides — the PR-1 basis,
    kept so the trajectory stays comparable PR over PR (approximate
    since PR 3: the compacted mask path still computes the distance
    plane on device but never transfers or sqrt's it).
  * ``speedup_materialize``   — dense loop materialize vs the
    ε-compacted sweep, the PR 3 headline.
  * ``speedup_finex_build``   — the ordering-sweep stage alone
    (bulk queue updates + segmented core distances vs. per-neighbor
    loops); ≥5× at the default 20k/ε=1.0 setting.

The ``materialize`` section isolates the ε-compacted sweep (PR 3): the
materialize-only wall-clock plus the measured host-boundary traffic of
the compacted flow (bool hit plane / slot rows + O(nnz) pair payload)
against the dense float-plane-plus-mask flow it replaced
(``transfer_reduction``).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def run(n: int = 20_000, d: int = 8, eps: float = 1.0, minpts: int = 16,
        seed: int = 0, skip_seed: bool = False, out_path: str | None = None
        ) -> dict:
    from repro import obs
    from repro.core import FinexIndex
    from repro.core.reference import (reference_eps_star_query,
                                      reference_finex_build,
                                      reference_materialize,
                                      reference_minpts_star_query)
    from repro.data.synthetic import gaussian_mixture
    from repro.neighbors.engine import NeighborEngine

    import jax.numpy as jnp

    # every timed section below measures DISABLED-mode cost (the <2%
    # overhead acceptance gate compares these figures across commits);
    # the telemetry section at the end re-enables tracing explicitly
    obs.configure(enabled=False)

    x = gaussian_mixture(n, d=d, k=12, noise_frac=0.1, seed=seed)
    eng = NeighborEngine(x, metric="euclidean")
    # warm up every jit shape both paths hit (distance tiles + the
    # bucketed verification sub-matrices): both paths produce identical
    # candidate sets, so one full vectorized pass compiles for both
    _, warm_csr = eng.materialize(eps)
    warm = FinexIndex.from_engine(eng, eps, minpts, csr=warm_csr)
    warm.eps_star(eps * 0.6)
    warm.minpts_star(minpts * 4)
    del warm, warm_csr
    # the compacted materialize no longer goes through _dist_block, but the
    # seed path and the shared device-sweep timing below still do — warm
    # its two tile shapes (full + ragged tail) so t_dev excludes compiles
    eng._dist_block(jnp.asarray(np.arange(
        min(eng.batch_rows, eng.n), dtype=np.int32))).block_until_ready()
    tail = np.arange((eng.n // eng.batch_rows) * eng.batch_rows, eng.n,
                     dtype=np.int32)
    if len(tail):
        eng._dist_block(jnp.asarray(tail)).block_until_ready()

    report: dict = {"n": n, "d": d, "eps": eps, "minpts": minpts,
                    "seed": seed,
                    # which registered metric this whole run swept — the
                    # schema guard refuses artifacts that do not say
                    "metric": eng.metric.name}

    # the dense device distance sweep the seed path consumes — timed so
    # the host-side speedup can be reported separately from end-to-end
    # (since PR 3 the compacted path replaces it with the fused
    # mask+gather sweep, so this is a reference figure, not shared cost)
    def _device_sweep():
        # stream tile-by-tile like both measured pipelines — holding all
        # tiles at once would keep the full n×n plane resident
        for s in range(0, eng.n, eng.batch_rows):
            eng._dist_block(jnp.asarray(np.arange(
                s, min(s + eng.batch_rows, eng.n),
                dtype=np.int32))).block_until_ready()
    _, t_dev = _timed(_device_sweep)
    report["device_sweep_s"] = round(t_dev, 4)

    # ---------------------------------------------------- vectorized path
    # median of 3 on the two figures the cross-commit overhead gate
    # reads: single-shot wall clock on this container swings with
    # scheduler windows (same spirit as the incremental section below)
    counts = csr = index = None
    t_mat, t_build = [], []
    for _ in range(3):
        (counts, csr), t = _timed(lambda: eng.materialize(eps))
        t_mat.append(t)
        index, t = _timed(
            lambda: FinexIndex.from_engine(eng, eps, minpts, csr=csr))
        t_build.append(t)
    t_mat = float(np.median(t_mat))
    t_build = float(np.median(t_build))
    lab_eps, t_eps = _timed(lambda: index.eps_star(eps * 0.6))
    lab_mp, t_mp = _timed(lambda: index.minpts_star(minpts * 4))
    report["vectorized"] = {
        "materialize_s": round(t_mat, 4), "finex_build_s": round(t_build, 4),
        "eps_star_s": round(t_eps, 4), "minpts_star_s": round(t_mp, 4),
        "end_to_end_build_s": round(t_mat + t_build, 4),
        "csr_nnz": int(csr.nnz),
    }

    # ------------------------------------------- materialize-only section
    # the ε-compacted sweep is this PR cycle's perf target: time it in
    # isolation and report what actually crossed the host boundary vs the
    # dense (float plane + bool mask) flow it replaced
    stats = dict(eng.last_materialize)
    host_c = int(stats.get("host_bytes", 0))
    host_d = int(stats.get("host_bytes_dense", 0))
    report["materialize"] = {
        "materialize_s": round(t_mat, 4),
        "mode": stats.get("mode"),
        "metric": stats.get("metric"),
        "tiles": stats.get("tiles"),
        "fallback_rows": stats.get("fallback_rows"),
        "host_bytes_dense": host_d,
        "host_bytes_compacted": host_c,
        "transfer_reduction": round(host_d / host_c, 2) if host_c else None,
        "nnz_payload_bytes": int(csr.nnz) * 8,   # int32 col + float32 dist
    }

    # --------------------------------------------------- pruning section
    # the projection-pruned sweep (PR 6) vs the same engine with the
    # screen disabled: identical CSR bytes (hard exactness gate in
    # scripts/bench.sh), candidate fraction and tile-skip counts from
    # the screen, and the wall-clock win. Both sides warm; the screen
    # itself is one-time/eps-independent and reported separately.
    eng_off = NeighborEngine(x, metric="euclidean", prune="off")
    eng_off.materialize(eps)                                 # warm
    (c_off, csr_off), t_off = _timed(lambda: eng_off.materialize(eps))
    pruned_same = (np.array_equal(counts, c_off)
                   and np.array_equal(csr.indptr, csr_off.indptr)
                   and np.array_equal(csr.indices, csr_off.indices)
                   and np.array_equal(csr.dists, csr_off.dists))
    fresh = NeighborEngine(x, metric="euclidean")
    _, t_screen = _timed(fresh._screen_get)
    pr = dict(stats.get("pruning") or {})
    report["pruning"] = {
        **pr,
        "pruned_materialize_s": round(t_mat, 4),
        "unpruned_materialize_s": round(t_off, 4),
        "speedup_vs_unpruned": round(t_off / max(t_mat, 1e-9), 2),
        "screen_build_s": round(t_screen, 4),
        "identical_outputs": bool(pruned_same),
    }

    # ---------------------------------------------- screened ε* section
    # the ε*-verifier consults the same screen before computing any
    # verification distance: labels must match the unscreened engine
    # bit-for-bit (hard gate) while verification_pairs strictly drops
    from repro.core.queries import QueryStats, eps_star_batch
    idx_off = FinexIndex.from_engine(eng_off, eps, minpts, csr=csr_off)
    stars = [eps * f for f in (0.4, 0.6, 0.8)]
    q_on, q_off = QueryStats(), QueryStats()
    lab_on = eps_star_batch(index.ordering, index.engine, stars,
                            stats=q_on)
    lab_off = eps_star_batch(idx_off.ordering, idx_off.engine, stars,
                             stats=q_off)
    report["queries"] = {
        "eps_stars": [round(s, 4) for s in stars],
        "identical_labels": bool(np.array_equal(lab_on, lab_off)),
        "verification_pairs_screened": int(q_on.verification_pairs),
        "verification_pairs_unscreened": int(q_off.verification_pairs),
        "screened_pairs": int(q_on.screened_pairs),
        "verification_pairs_reduction": round(
            q_off.verification_pairs / max(q_on.verification_pairs, 1),
            2),
    }
    del eng_off, fresh, c_off, csr_off, idx_off

    # -------------------------------------------- jaccard pruning section
    # the minhash/bitset-sketch screen (set data): token-block clusters
    # give the projection real structure to separate; the pruned sweep
    # must stay byte-identical to the unpruned one while ruling out a
    # real fraction of the candidate plane
    from repro.neighbors.bitset import pack_sets
    j_eps, universe, kc, block = 0.3, 512, 20, 512 // 20
    rngj = np.random.default_rng(seed + 7)
    cl = rngj.integers(kc, size=n)
    j_sets = []
    for i in range(n):
        toks = np.flatnonzero(rngj.random(block) < 0.85) + cl[i] * block
        extras = rngj.integers(universe, size=2)
        j_sets.append(np.unique(np.concatenate([toks, extras])))
    j_data = pack_sets(j_sets, universe=universe)
    eng_j = NeighborEngine(j_data, metric="jaccard", prune="on")
    eng_j.materialize(j_eps)                                  # warm
    (cj_on, csrj_on), t_j_on = _timed(lambda: eng_j.materialize(j_eps))
    eng_j_off = NeighborEngine(j_data, metric="jaccard", prune="off")
    eng_j_off.materialize(j_eps)                              # warm
    (cj_off, csrj_off), t_j_off = _timed(
        lambda: eng_j_off.materialize(j_eps))
    j_same = (np.array_equal(cj_on, cj_off)
              and np.array_equal(csrj_on.indptr, csrj_off.indptr)
              and np.array_equal(csrj_on.indices, csrj_off.indices)
              and np.array_equal(csrj_on.dists, csrj_off.dists))
    prj = dict(eng_j.last_materialize.get("pruning") or {})
    report["pruning_jaccard"] = {
        **prj,
        "eps": j_eps,
        "universe": universe,
        "clusters": kc,
        "pruned_materialize_s": round(t_j_on, 4),
        "unpruned_materialize_s": round(t_j_off, 4),
        "speedup_vs_unpruned": round(t_j_off / max(t_j_on, 1e-9), 2),
        "identical_outputs": bool(j_same),
    }
    del eng_j, eng_j_off, cj_on, cj_off, csrj_on, csrj_off, j_sets, j_data

    # ------------------------------------------------ incremental section
    # insert/delete deltas vs full rebuilds — the serving story of
    # incremental maintenance: a single insert must be an order of
    # magnitude cheaper than re-running materialize + ordering sweep.
    # Both sides are timed post-compile (a warm-up build/insert runs
    # first at every dataset shape involved).
    def _same_index(a, b):
        oa, ob = a.ordering, b.ordering
        return (all(np.array_equal(getattr(oa, f), getattr(ob, f))
                    for f in ("order", "pos", "C", "R", "N", "F"))
                and np.array_equal(a.csr.indptr, b.csr.indptr)
                and np.array_equal(a.csr.indices, b.csr.indices)
                and np.array_equal(a.csr.dists, b.csr.dists))

    rng = np.random.default_rng(seed + 1)
    point = x[rng.integers(n)][None, :] + 0.03   # lands inside a cluster
    x_ins = np.concatenate([x, point])
    FinexIndex.build(x_ins, eps=eps, minpts=minpts)          # warm n+1
    # median of 3 independent runs on each side: single-shot wall-clock
    # of a sub-second delta against a multi-second rebuild is noisy
    # enough to matter for the regression floor
    reb_ins, t_reb_ins = None, []
    for _ in range(3):
        reb_ins, t = _timed(
            lambda: FinexIndex.build(x_ins, eps=eps, minpts=minpts))
        t_reb_ins.append(t)
    t_reb_ins = float(np.median(t_reb_ins))
    # steady-state maintenance latency: the component labels are lazy,
    # so one warm insert+delete cycle (exact — it restores the original
    # index bytes) materializes them and the strip jit shapes before
    # timing; each repetition restores the base the same way. NOTE:
    # deletes defer their component relabel to the next mutation, so
    # each timed insert below also pays the relabel the restoring
    # delete put off — the honest steady-state figure for this
    # alternating workload, but NOT pure insert latency (a build-then-
    # insert measures ~3x lower)
    base = FinexIndex.build(x, eps=eps, minpts=minpts)
    base.insert(point)
    base.delete(np.array([n]))
    rep_ins, t_ins = None, []
    for i in range(3):
        rep_ins, t = _timed(lambda: base.insert(point))
        t_ins.append(t)
        if i < 2:
            base.delete(np.array([n]))
    t_ins = float(np.median(t_ins))
    identical = _same_index(base, reb_ins)

    del_ids = rng.choice(n + 1, size=max(1, n // 100), replace=False)
    x_del = np.delete(x_ins, del_ids, axis=0)
    FinexIndex.build(x_del, eps=eps, minpts=minpts)          # warm shape
    reb_del, t_reb_del = _timed(
        lambda: FinexIndex.build(x_del, eps=eps, minpts=minpts))
    rep_del, t_del = _timed(lambda: base.delete(del_ids))
    identical = identical and _same_index(base, reb_del)
    report["incremental"] = {
        "single_insert_s": round(t_ins, 4),
        "rebuild_insert_s": round(t_reb_ins, 4),
        "speedup_vs_rebuild": round(t_reb_ins / max(t_ins, 1e-9), 2),
        "insert_mode": rep_ins["mode"],
        "insert_affected_frac": rep_ins["affected_frac"],
        "batch_delete_ids": int(del_ids.size),
        "batch_delete_s": round(t_del, 4),
        "rebuild_delete_s": round(t_reb_del, 4),
        "delete_speedup_vs_rebuild": round(t_reb_del / max(t_del, 1e-9), 2),
        "delete_mode": rep_del["mode"],
        "identical": bool(identical),
    }

    # ---------------------------------------------------- hierarchy section
    # hierarchy as a query (condensed cluster tree): ONE tree build over
    # the existing ordering + CSR answers every (ε*, MinPts*) at once —
    # timed against one warm K=16 mixed planner sweep over the same
    # index. identical_cuts is a hard exactness gate in scripts/bench.sh:
    # every cut must be label-identical to the scalar queries, and the
    # tree + all cuts together must compute ZERO new distance rows.
    from repro.core.queries import Eps, MinPts
    from repro.service.planner import SweepPlanner

    k_eps = [eps * f for f in (0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0)]
    k_mp = [minpts * f for f in (1, 2, 3, 4, 5, 6, 8, 12)]
    settings = [Eps(e) for e in k_eps] + [MinPts(m) for m in k_mp]
    planner = SweepPlanner(index)
    planner.sweep(settings)                                   # warm
    sweep_lab, t_sweep = _timed(lambda: planner.sweep(settings))
    rows_before = eng.distance_rows_computed
    h, t_tree = _timed(index.hierarchy)
    cuts, t_cut = _timed(lambda: np.stack(
        [np.asarray(h.cut(e)) for e in k_eps]
        + [np.asarray(h.cut_minpts(m)) for m in k_mp]))
    cut_rows = eng.distance_rows_computed - rows_before
    # the floored headline: the ε-side cuts against the scalar
    # ε*-queries they replace — the query pays ε*-verification
    # distances per call, the cut replays the CSR and pays none. (The
    # batched sweep amortizes verification across its K rows, so it is
    # reported as context above, not used as the floor denominator;
    # cut_minpts delegates to the same scalar §5.4 kernel the facade
    # uses, so the MinPts side is identical by construction.)
    index.eps_star(k_eps[0])                                  # warm
    _, t_eps_scalar = _timed(
        lambda: [index.eps_star(e) for e in k_eps])
    _, t_eps_cuts = _timed(lambda: [h.cut(e) for e in k_eps])
    report["hierarchy"] = {
        "tree_build_s": round(t_tree, 4),
        "condensed_clusters": int(h.n_clusters),
        "selected_clusters": int(h.n_selected),
        "cuts_k": len(settings),
        "cuts_total_s": round(t_cut, 4),
        "planner_sweep_k16_s": round(t_sweep, 4),
        "tree_plus_cuts_vs_sweep": round(
            t_sweep / max(t_tree + t_cut, 1e-9), 2),
        "eps_cuts_s": round(t_eps_cuts, 4),
        "eps_scalar_queries_s": round(t_eps_scalar, 4),
        "eps_cut_speedup_vs_scalar_queries": round(
            t_eps_scalar / max(t_eps_cuts, 1e-9), 2),
        "distance_rows_during_tree_and_cuts": int(cut_rows),
        "identical_cuts": bool(
            np.array_equal(cuts, np.asarray(sweep_lab)) and cut_rows == 0),
    }

    # ---------------------------------------------------------- seed path
    if not skip_seed:
        (_, csr_ref), t_mat_ref = _timed(lambda: reference_materialize(
            eng, eps))
        (idx_ref, _), t_build_ref = _timed(
            lambda: reference_finex_build(eng, eps, minpts, csr=csr_ref))
        lab_eps_ref, t_eps_ref = _timed(
            lambda: reference_eps_star_query(idx_ref, eng, eps * 0.6))
        lab_mp_ref, t_mp_ref = _timed(
            lambda: reference_minpts_star_query(idx_ref, csr_ref,
                                                minpts * 4))
        report["seed"] = {
            "materialize_s": round(t_mat_ref, 4),
            "finex_build_s": round(t_build_ref, 4),
            "eps_star_s": round(t_eps_ref, 4),
            "minpts_star_s": round(t_mp_ref, 4),
            "end_to_end_build_s": round(t_mat_ref + t_build_ref, 4),
        }
        # identical results, not merely equivalent ones
        assert np.array_equal(idx_ref.order, index.ordering.order)
        assert np.array_equal(idx_ref.R, index.ordering.R)
        assert np.array_equal(lab_eps_ref, lab_eps)
        assert np.array_equal(lab_mp_ref, lab_mp)
        report["identical_outputs"] = True
        # historical PR-1 basis, kept PR-over-PR comparable: the dense
        # device sweep subtracted from both sides (approximate since the
        # ε-compaction — the mask path still computes the distance plane
        # on device, it just never transfers it)
        host_new = max(t_mat + t_build - t_dev, 1e-9)
        host_ref = max(t_mat_ref + t_build_ref - t_dev, 1e-9)
        report["build"] = {
            "speedup_end_to_end": round(
                (t_mat_ref + t_build_ref) / max(t_mat + t_build, 1e-9), 2),
            "speedup_host_pipeline": round(host_ref / host_new, 2),
            # the ε-compaction headline: dense loop materialize vs the
            # compacted sweep, no subtraction games
            "speedup_materialize": round(t_mat_ref / max(t_mat, 1e-9), 2),
            "speedup_finex_build": round(
                t_build_ref / max(t_build, 1e-9), 2),
            "speedup_eps_star": round(t_eps_ref / max(t_eps, 1e-9), 2),
            "speedup_minpts_star": round(t_mp_ref / max(t_mp, 1e-9), 2),
        }

    # --------------------------------------------------- telemetry section
    # tracing-enabled re-run of the core pipeline on a fresh engine: the
    # outputs must stay byte-identical to the untraced run above (hard
    # exactness gate in scripts/bench.sh), and the span rollup + counter
    # snapshot land in the artifact so the perf trajectory carries its
    # own attribution. The overhead ratio here is informational (traced
    # vs untraced materialize); the <2% DISABLED-mode gate compares
    # vectorized.end_to_end_build_s against the committed artifact.
    obs.reset()
    obs.enable()
    eng_tr = NeighborEngine(x, metric="euclidean")
    (c_tr, csr_tr), t_mat_tr = _timed(lambda: eng_tr.materialize(eps))
    idx_tr = FinexIndex.from_engine(eng_tr, eps, minpts, csr=csr_tr)
    lab_eps_tr = idx_tr.eps_star(eps * 0.6)
    lab_mp_tr = idx_tr.minpts_star(minpts * 4)
    snap = obs.snapshot()
    obs.disable()
    obs.reset()
    traced_same = (np.array_equal(counts, c_tr)
                   and np.array_equal(csr.indptr, csr_tr.indptr)
                   and np.array_equal(csr.indices, csr_tr.indices)
                   and np.array_equal(csr.dists, csr_tr.dists)
                   and np.array_equal(index.ordering.order,
                                      idx_tr.ordering.order)
                   and np.array_equal(index.ordering.R, idx_tr.ordering.R)
                   and np.array_equal(lab_eps, lab_eps_tr)
                   and np.array_equal(lab_mp, lab_mp_tr))
    report["telemetry"] = {
        "identical_with_tracing": bool(traced_same),
        "traced_materialize_s": round(t_mat_tr, 4),
        "untraced_materialize_s": round(t_mat, 4),
        "tracing_overhead_ratio": round(t_mat_tr / max(t_mat, 1e-9), 3),
        "span_rollup": snap["spans"],
        "counters": snap["counters"],
    }
    del eng_tr, idx_tr, c_tr, csr_tr

    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--eps", type=float, default=1.0)
    ap.add_argument("--minpts", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-seed", action="store_true",
                    help="only time the vectorized path")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_index.json"))
    args = ap.parse_args()
    report = run(n=args.n, d=args.d, eps=args.eps, minpts=args.minpts,
                 seed=args.seed, skip_seed=args.skip_seed,
                 out_path=args.out)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
