"""Serving-subsystem benchmark: index cache + batched parameter sweeps.

Measures the three serving-side claims on the 20k-point benchmark dataset
(the same dataset/settings family as ``index_bench.py``) and writes
``BENCH_service.json``:

  * ``sweep_vs_sequential``  — a K=16 mixed ε*/MinPts* sweep through
    ``SweepPlanner`` (shared scan / sparse clustering / verification
    distances / incremental core components) against the same 16 settings
    as sequential ``FinexIndex`` facade calls; labels asserted
    byte-identical. Target: ≥ 3×.
  * ``cache_hit_speedup``    — warm ``IndexStore`` hit vs cold build for
    the same (data, ε, MinPts); ``hit_zero_distance_rows`` certifies the
    warm hit answered a cluster request without a single distance row.
  * ``settings_per_s``       — throughput of a mixed request stream
    through the slot-batched ``ClusterService``.

    PYTHONPATH=src python benchmarks/service_bench.py            # 20k
    PYTHONPATH=src python benchmarks/service_bench.py --smoke    # 2k
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def mixed_settings(eps: float, minpts: int, k: int = 16):
    """K mixed settings: half ε*-queries, half MinPts*-queries."""
    ke = k // 2
    eps_fracs = np.linspace(0.35, 0.95, ke)
    mp_mults = np.linspace(1.5, 16.0, k - ke)
    return ([("eps", float(eps * f)) for f in eps_fracs]
            + [("minpts", int(round(minpts * m))) for m in mp_mults])


def run(n: int = 20_000, d: int = 8, eps: float = 1.0, minpts: int = 16,
        k: int = 16, seed: int = 0, requests: int = 24, sweep_k: int = 6,
        out_path: str | None = None) -> dict:
    from repro import obs
    from repro.data.synthetic import gaussian_mixture
    from repro.service import (ClusterRequest, ClusterService, IndexStore,
                               SweepPlanner, SweepRequest)

    # timed sections measure disabled-mode cost; the telemetry section
    # at the end re-enables tracing explicitly
    obs.configure(enabled=False)

    x = gaussian_mixture(n, d=d, k=12, noise_frac=0.1, seed=seed)
    settings = mixed_settings(eps, minpts, k)
    report: dict = {"n": n, "d": d, "eps": eps, "minpts": minpts,
                    "k": k, "seed": seed,
                    "settings": [[kind, v] for kind, v in settings]}

    # ------------------------------------------------- cold build vs hit
    store = IndexStore(capacity=2)
    (index, outcome), t_build = _timed(
        lambda: store.get_or_build(x, eps, minpts))
    assert outcome == "build"
    (index, outcome), t_hit = _timed(
        lambda: store.get_or_build(x, eps, minpts))
    assert outcome == "hit"
    # a warm hit must answer a cluster request with zero distance rows
    rows_before = index.engine.distance_rows_computed
    hit_labels = index.clustering()
    zero_dist = index.engine.distance_rows_computed == rows_before
    report["build_s"] = round(t_build, 4)
    report["hit_s"] = round(t_hit, 6)
    report["cache_hit_speedup"] = round(t_build / max(t_hit, 1e-9), 1)
    report["hit_zero_distance_rows"] = bool(zero_dist)
    report["hit_cluster_count"] = int(hit_labels.max() + 1)

    # ------------------------------------- K-setting sweep vs sequential
    planner = SweepPlanner(index)
    # warm up every jit shape both paths hit (bucketed verification tiles)
    planner.sweep(settings)
    for kind, v in settings:
        _ = index.eps_star(v) if kind == "eps" else index.minpts_star(v)

    sweep_labels, t_sweep = _timed(lambda: planner.sweep(settings))

    def _sequential():
        return np.stack([index.eps_star(v) if kind == "eps"
                         else index.minpts_star(v)
                         for kind, v in settings])
    seq_labels, t_seq = _timed(_sequential)
    assert np.array_equal(sweep_labels, seq_labels), \
        "sweep diverged from sequential facade calls"
    report["sweep_s"] = round(t_sweep, 4)
    report["sequential_s"] = round(t_seq, 4)
    report["sweep_vs_sequential"] = round(t_seq / max(t_sweep, 1e-9), 2)
    report["sweep_identical_to_sequential"] = True

    # ------------------------------------------------ service throughput
    svc = ClusterService(store=store, slots=8)
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(requests):
        if rng.random() < 0.33:
            reqs.append(ClusterRequest(
                data=x, eps=eps, minpts=minpts,
                setting=settings[rng.integers(len(settings))]))
        else:
            picks = rng.integers(len(settings), size=sweep_k)
            reqs.append(SweepRequest(
                data=x, eps=eps, minpts=minpts,
                settings=[settings[i] for i in picks]))
    _, t_svc = _timed(lambda: svc.run(reqs))
    st = svc.stats()
    report["service"] = {
        "requests": requests,
        "seconds": round(t_svc, 4),
        "settings_answered": st["settings_answered"],
        "settings_per_s": round(st["settings_answered"] / max(t_svc, 1e-9),
                                1),
        "batched_sweeps": st["batched_sweeps"],
        "coalesced_settings": st["coalesced_settings"],
        "store": st["store"],
    }

    # ------------------------------------------------- telemetry section
    # tracing-enabled request stream against the warm service: the labels
    # must match the untraced planner sweep byte-for-byte, and the span
    # rollup / counters / rolling windows land in the artifact (the
    # serving-side /stats payload, captured at bench time)
    obs.reset()
    obs.enable()
    traced_labels = planner.sweep(settings)
    svc.run([SweepRequest(data=x, eps=eps, minpts=minpts,
                          settings=settings)
             for _ in range(4)])
    snap = obs.snapshot()
    obs.disable()
    obs.reset()
    report["telemetry"] = {
        "identical_with_tracing": bool(
            np.array_equal(traced_labels, sweep_labels)),
        "span_rollup": snap["spans"],
        "counters": snap["counters"],
        "windows": snap["windows"],
    }

    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--eps", type=float, default=1.0)
    ap.add_argument("--minpts", type=int, default=16)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--sweep-k", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="2k points — schema identical, numbers small")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_service.json"))
    args = ap.parse_args()
    if args.smoke:
        args.n, args.requests = 2000, 8
    report = run(n=args.n, d=args.d, eps=args.eps, minpts=args.minpts,
                 k=args.k, seed=args.seed, requests=args.requests,
                 sweep_k=args.sweep_k, out_path=args.out)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
