"""Serving-subsystem benchmark: index cache + batched parameter sweeps.

Measures the three serving-side claims on the 20k-point benchmark dataset
(the same dataset/settings family as ``index_bench.py``) and writes
``BENCH_service.json``:

  * ``sweep_vs_sequential``  — a K=16 mixed ε*/MinPts* sweep through
    ``SweepPlanner`` (shared scan / sparse clustering / verification
    distances / incremental core components) against the same 16 settings
    as sequential ``FinexIndex`` facade calls; labels asserted
    byte-identical. Target: ≥ 3×.
  * ``cache_hit_speedup``    — warm ``IndexStore`` hit vs cold build for
    the same (data, ε, MinPts); ``hit_zero_distance_rows`` certifies the
    warm hit answered a cluster request without a single distance row.
  * ``settings_per_s``       — throughput of a mixed request stream
    through the slot-batched ``ClusterService``.
  * ``frontend``             — the concurrent front-end's mutation
    coalescing: K single-point inserts staged into ONE windowed delta
    through ``ServiceFrontend`` vs the same K points as sequential
    facade ``.insert`` calls (byte-identity asserted), the slack-array
    splice-reallocation savings, and concurrent read throughput with
    admission rejections + queue-depth p95 captured via the Stats verb.

    PYTHONPATH=src python benchmarks/service_bench.py            # 20k
    PYTHONPATH=src python benchmarks/service_bench.py --smoke    # 2k
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def mixed_settings(eps: float, minpts: int, k: int = 16):
    """K mixed settings: half ε*-queries, half MinPts*-queries."""
    ke = k // 2
    eps_fracs = np.linspace(0.35, 0.95, ke)
    mp_mults = np.linspace(1.5, 16.0, k - ke)
    return ([("eps", float(eps * f)) for f in eps_fracs]
            + [("minpts", int(round(minpts * m))) for m in mp_mults])


def run(n: int = 20_000, d: int = 8, eps: float = 1.0, minpts: int = 16,
        k: int = 16, seed: int = 0, requests: int = 24, sweep_k: int = 6,
        out_path: str | None = None) -> dict:
    from repro import obs
    from repro.data.synthetic import gaussian_mixture
    from repro.service import (ClusterRequest, ClusterService, IndexStore,
                               SweepPlanner, SweepRequest)

    # timed sections measure disabled-mode cost; the telemetry section
    # at the end re-enables tracing explicitly
    obs.configure(enabled=False)

    x = gaussian_mixture(n, d=d, k=12, noise_frac=0.1, seed=seed)
    settings = mixed_settings(eps, minpts, k)
    report: dict = {"n": n, "d": d, "eps": eps, "minpts": minpts,
                    "k": k, "seed": seed,
                    "settings": [[kind, v] for kind, v in settings]}

    # ------------------------------------------------- cold build vs hit
    store = IndexStore(capacity=2)
    (index, outcome), t_build = _timed(
        lambda: store.get_or_build(x, eps, minpts))
    assert outcome == "build"
    (index, outcome), t_hit = _timed(
        lambda: store.get_or_build(x, eps, minpts))
    assert outcome == "hit"
    # a warm hit must answer a cluster request with zero distance rows
    rows_before = index.engine.distance_rows_computed
    hit_labels = index.clustering()
    zero_dist = index.engine.distance_rows_computed == rows_before
    report["build_s"] = round(t_build, 4)
    report["hit_s"] = round(t_hit, 6)
    report["cache_hit_speedup"] = round(t_build / max(t_hit, 1e-9), 1)
    report["hit_zero_distance_rows"] = bool(zero_dist)
    report["hit_cluster_count"] = int(hit_labels.max() + 1)

    # ------------------------------------- K-setting sweep vs sequential
    planner = SweepPlanner(index)
    # warm up every jit shape both paths hit (bucketed verification tiles)
    planner.sweep(settings)
    for kind, v in settings:
        _ = index.eps_star(v) if kind == "eps" else index.minpts_star(v)

    sweep_labels, t_sweep = _timed(lambda: planner.sweep(settings))

    def _sequential():
        return np.stack([index.eps_star(v) if kind == "eps"
                         else index.minpts_star(v)
                         for kind, v in settings])
    seq_labels, t_seq = _timed(_sequential)
    assert np.array_equal(sweep_labels, seq_labels), \
        "sweep diverged from sequential facade calls"
    report["sweep_s"] = round(t_sweep, 4)
    report["sequential_s"] = round(t_seq, 4)
    report["sweep_vs_sequential"] = round(t_seq / max(t_sweep, 1e-9), 2)
    report["sweep_identical_to_sequential"] = True

    # ------------------------------------------------ service throughput
    svc = ClusterService(store=store, slots=8)
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(requests):
        if rng.random() < 0.33:
            reqs.append(ClusterRequest(
                data=x, eps=eps, minpts=minpts,
                setting=settings[rng.integers(len(settings))]))
        else:
            picks = rng.integers(len(settings), size=sweep_k)
            reqs.append(SweepRequest(
                data=x, eps=eps, minpts=minpts,
                settings=[settings[i] for i in picks]))
    _, t_svc = _timed(lambda: svc.run(reqs))
    st = svc.stats()
    report["service"] = {
        "requests": requests,
        "seconds": round(t_svc, 4),
        "settings_answered": st["settings_answered"],
        "settings_per_s": round(st["settings_answered"] / max(t_svc, 1e-9),
                                1),
        "batched_sweeps": st["batched_sweeps"],
        "coalesced_settings": st["coalesced_settings"],
        "store": st["store"],
    }

    # ------------------------------------------------- telemetry section
    # tracing-enabled request stream against the warm service: the labels
    # must match the untraced planner sweep byte-for-byte, and the span
    # rollup / counters / rolling windows land in the artifact (the
    # serving-side /stats payload, captured at bench time)
    obs.reset()
    obs.enable()
    traced_labels = planner.sweep(settings)
    svc.run([SweepRequest(data=x, eps=eps, minpts=minpts,
                          settings=settings)
             for _ in range(4)])
    snap = obs.snapshot()
    obs.disable()
    obs.reset()
    report["telemetry"] = {
        "identical_with_tracing": bool(
            np.array_equal(traced_labels, sweep_labels)),
        "span_rollup": snap["spans"],
        "counters": snap["counters"],
        "windows": snap["windows"],
    }

    # ------------------------------------------- concurrent front-end
    # K single-point inserts: the frontend stages them behind pause()
    # and applies ONE windowed batched delta; the baseline replays the
    # same points as K sequential facade .insert calls. Both final
    # states are asserted byte-identical.
    import threading

    from repro.core import FinexIndex
    from repro.service import (AdmissionError, BuildOp, ClusterOp,
                               MutateRequest, ServiceFrontend, StatsOp,
                               SweepOp)

    K = 16 if n >= 10_000 else 8
    rng_f = np.random.default_rng(seed + 7)
    pts = (x[rng_f.integers(0, n, size=K)]
           + rng_f.normal(scale=0.05, size=(K, d))).astype(x.dtype)
    arrays = index.to_arrays()

    # warm the insert jit shapes (single-row and K-row strips) off-clock
    warm = FinexIndex.from_arrays(arrays, data=x)
    warm.insert(pts[:1])
    FinexIndex.from_arrays(arrays, data=x).insert(pts)

    seq_idx = FinexIndex.from_arrays(arrays, data=x)

    def _seq_inserts():
        for i in range(K):
            seq_idx.insert(pts[i:i + 1])
    _, t_seq_ins = _timed(_seq_inserts)

    # slack-backed sequential inserts: same op sequence, splices land
    # in reserved row slack instead of reallocating the CSR every time
    slack_idx = FinexIndex.from_arrays(arrays, data=x)
    slack_idx.enable_slack()

    def _slack_inserts():
        for i in range(K):
            slack_idx.insert(pts[i:i + 1])
    _, t_slack_ins = _timed(_slack_inserts)
    slack_st = slack_idx.slack_stats()
    splices = slack_st["in_place_splices"] + slack_st["relayouts"]

    fe_store = IndexStore(capacity=2)
    fe_idx = FinexIndex.from_arrays(arrays, data=x)
    fe_store.put(fe_idx)
    fe = ServiceFrontend(store=fe_store, workers=4, window=K + 8,
                         max_queue=K + 8)
    bres = fe.submit(BuildOp("bench", x, eps, minpts)).result(timeout=600)
    assert bres.outcome == "hit"            # bound, not rebuilt
    fe.pause()
    mut_futs = [fe.submit(MutateRequest("bench", "insert",
                                        points=pts[i:i + 1]))
                for i in range(K)]
    t0 = time.perf_counter()
    fe.resume()
    assert fe.drain(timeout=600)
    t_coal = time.perf_counter() - t0
    for f in mut_futs:
        f.result(timeout=60)
    assert fe.batched_deltas == 1, "window did not coalesce to one delta"

    def _same_state(a, b):
        return (all(np.array_equal(getattr(a.csr, f), getattr(b.csr, f))
                    for f in ("indptr", "indices", "dists"))
                and all(np.array_equal(getattr(a.ordering, f),
                                       getattr(b.ordering, f))
                        for f in ("order", "pos", "C", "R", "N", "F"))
                and np.array_equal(a.clustering(), b.clustering()))

    report["frontend"] = {
        "k_inserts": K,
        "sequential_inserts_s": round(t_seq_ins, 4),
        "slack_sequential_inserts_s": round(t_slack_ins, 4),
        "coalesced_window_s": round(t_coal, 4),
        "coalescing_speedup": round(t_seq_ins / max(t_coal, 1e-9), 2),
        "coalescing_identical": _same_state(fe_idx, seq_idx),
        "slack_identical": _same_state(slack_idx, seq_idx),
        "slack_vs_packed_sequential": round(
            t_seq_ins / max(t_slack_ins, 1e-9), 2),
        "slack_in_place_fraction": round(
            slack_st["in_place_splices"] / max(splices, 1), 3),
        "batched_deltas": fe.batched_deltas,
        "coalesced_mutations": fe.coalesced_mutations,
    }

    # admission control + concurrent read throughput, captured through
    # the Stats verb (tracing on so the queue-depth window fills)
    obs.reset()
    obs.enable()
    fe.pause()
    staged = []
    try:
        while True:                       # fill to the admission bound
            staged.append(fe.submit(ClusterOp("bench")))
    except AdmissionError:
        pass
    t0 = time.perf_counter()
    fe.resume()

    def _client(tid):
        r = np.random.default_rng(seed + 100 + tid)
        for _ in range(8):
            picks = r.integers(len(settings), size=sweep_k)
            req = SweepOp("bench", [settings[i] for i in picks])
            while True:
                try:
                    staged.append(fe.submit(req))
                    break
                except AdmissionError:
                    time.sleep(0.002)

    clients = [threading.Thread(target=_client, args=(t,))
               for t in range(4)]
    for t in clients:
        t.start()
    for t in clients:
        t.join()
    assert fe.drain(timeout=600)
    t_conc = time.perf_counter() - t0
    verb = fe.submit(StatsOp()).result(timeout=600)
    fe_labels = fe.submit(SweepOp("bench", settings)).result(timeout=600)
    want_labels = SweepPlanner(fe_idx).sweep(settings)
    fe.shutdown(drain=True, timeout=600)
    obs.disable()
    obs.reset()
    responses = len(staged)
    qd = verb["telemetry"]["windows"].get("frontend.queue_depth", {})
    report["frontend"]["concurrent"] = {
        "workers": 4,
        "clients": 4,
        "responses": responses,
        "seconds": round(t_conc, 4),
        "responses_per_s": round(responses / max(t_conc, 1e-9), 1),
        "rejected": verb["frontend"]["rejected"],
        "queue_depth_p95": qd.get("p95"),
        "windows": verb["frontend"]["windows"],
        "identical_labels": bool(np.array_equal(fe_labels.labels,
                                                want_labels)),
    }
    assert verb["frontend"]["rejected"] >= 1, \
        "admission bound never engaged"

    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--eps", type=float, default=1.0)
    ap.add_argument("--minpts", type=int, default=16)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--sweep-k", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="2k points — schema identical, numbers small")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_service.json"))
    args = ap.parse_args()
    if args.smoke:
        args.n, args.requests = 2000, 8
    report = run(n=args.n, d=args.d, eps=args.eps, minpts=args.minpts,
                 k=args.k, seed=args.seed, requests=args.requests,
                 sweep_k=args.sweep_k, out_path=args.out)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
