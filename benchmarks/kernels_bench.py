"""Kernel microbenchmarks: jit'd wall time of the neighborhood ops on this
host (CPU XLA path; the Pallas kernels are TPU-target and interpret-only
here, so their timing is meaningless — structure is validated in tests)."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.neighbors.bitset import pack_sets


def _bench(fn, *args, iters=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # µs


def run(rows: List[str]) -> None:
    rng = np.random.default_rng(0)
    for n, d in ((1024, 16), (4096, 16)):
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        w = jnp.ones((n,), jnp.float32)
        us_dist = _bench(lambda: ops.pairwise_euclidean(x, x))
        us_count = _bench(lambda: ops.eps_count(x, x, 1.0, w))
        rows.append(f"kernel,pairwise_euclidean,n={n},d={d},us={us_dist:.0f}")
        rows.append(f"kernel,eps_count_fused,n={n},d={d},us={us_count:.0f}")
        # fused counting must not be slower than distance materialization
        rows.append(f"kernel,fusion_speedup,n={n},"
                    f"x{us_dist / max(us_count, 1e-9):.2f}")
        # screened sweep (PR 6): the k-dim screen plane + verify —
        # the bound evaluation must stay cheap relative to the d-dim
        # distance tile it lets the engine skip
        e = jnp.asarray(rng.normal(size=(n, 8)), jnp.float32)
        s2t = jnp.float32(4.0)
        us_sc = _bench(lambda: ops.screened_eps_count(
            x, x, e, e, 1.0, s2t, w))
        rows.append(f"kernel,screened_eps_count,n={n},d={d},us={us_sc:.0f}")
        # device-side bucket-bound plane (PR 8): per-center min squared
        # screen distance over a query tile + the per-ε survival compare
        # — the host never sees the (ntiles, nb) float plane, only the
        # bool survival row
        c = jnp.asarray(rng.normal(size=(256, 8)), jnp.float32)
        thr = jnp.full((256,), 4.0, jnp.float32)
        us_b = _bench(lambda: ops.bound_survive(ops.bound_min2(e, c), thr))
        rows.append(f"kernel,bound_min2_survive,n={n},nb=256,us={us_b:.0f}")
    sets = [set(rng.choice(512, size=12, replace=False)) for _ in range(2048)]
    bits, sizes = pack_sets(sets, 512)
    b = jnp.asarray(bits)
    s = jnp.asarray(sizes)
    us_j = _bench(lambda: ops.jaccard_distance(b, s, b, s))
    rows.append(f"kernel,jaccard_bitmap,n=2048,W={bits.shape[1]},us={us_j:.0f}")


def main() -> None:
    """Standalone smoke entry point (`python -m benchmarks.kernels_bench`)
    — CI runs this in the unit lane so a kernel wrapper that stops
    compiling (or silently falls off the fused path) fails the build."""
    rows: List[str] = []
    run(rows)
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
