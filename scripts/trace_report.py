#!/usr/bin/env python
"""Offline reader for REPRO_TRACE JSONL span exports.

Usage::

    python scripts/trace_report.py trace.jsonl [--top N] [--json]

Validates the span schema strictly (every record must carry the full
key set, ids must be unique, parents must exist in the same thread one
nesting level up) and exits non-zero on any malformed line — CI runs
this as a smoke step over the unit-lane trace artifact, so a schema
drift in ``repro.obs.trace`` fails the build instead of shipping an
unreadable artifact. On success prints the top-N spans by self-time and
a per-name rollup table (count / total / self / device seconds).
"""

from __future__ import annotations

import argparse
import json
import sys

REQUIRED_KEYS = {
    "name": str,
    "id": int,
    "parent": (int, type(None)),
    "depth": int,
    "thread": int,
    "ts": (int, float),
    "wall_s": (int, float),
    "self_s": (int, float),
    "device_s": (int, float),
    "attrs": dict,
}


def load_spans(path):
    """Parse and validate a JSONL trace. Returns the span list; raises
    ``ValueError`` naming the offending line on any malformed record."""
    spans = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"line {lineno}: not valid JSON ({e})")
            if not isinstance(rec, dict):
                raise ValueError(f"line {lineno}: record is not an object")
            for key, typ in REQUIRED_KEYS.items():
                if key not in rec:
                    raise ValueError(f"line {lineno}: missing key {key!r}")
                if not isinstance(rec[key], typ):
                    raise ValueError(
                        f"line {lineno}: key {key!r} has type "
                        f"{type(rec[key]).__name__}, expected {typ}"
                    )
            if isinstance(rec["wall_s"], bool) or rec["wall_s"] < 0:
                raise ValueError(f"line {lineno}: wall_s must be >= 0")
            spans.append(rec)
    by_id = {}
    for rec in spans:
        if rec["id"] in by_id:
            raise ValueError(f"duplicate span id {rec['id']}")
        by_id[rec["id"]] = rec
    # spans are emitted on exit, so children precede their parents in
    # the file — validate nesting over the full id map
    for rec in spans:
        parent = rec["parent"]
        if parent is None:
            if rec["depth"] != 0:
                raise ValueError(
                    f"span {rec['id']} ({rec['name']!r}) has no parent "
                    f"but depth {rec['depth']}"
                )
            continue
        if parent not in by_id:
            raise ValueError(
                f"span {rec['id']} ({rec['name']!r}) references missing "
                f"parent {parent}"
            )
        p = by_id[parent]
        if rec["depth"] != p["depth"] + 1:
            raise ValueError(
                f"span {rec['id']} ({rec['name']!r}) depth {rec['depth']}"
                f" != parent depth {p['depth']} + 1"
            )
        if rec["thread"] != p["thread"]:
            raise ValueError(
                f"span {rec['id']} ({rec['name']!r}) crosses threads: "
                f"{rec['thread']} vs parent {p['thread']}"
            )
    return spans


def rollup(spans):
    """Per-name aggregate: {name: {count, total_s, self_s, device_s}}."""
    agg = {}
    for rec in spans:
        a = agg.setdefault(
            rec["name"],
            {"count": 0, "total_s": 0.0, "self_s": 0.0, "device_s": 0.0},
        )
        a["count"] += 1
        a["total_s"] += rec["wall_s"]
        a["self_s"] += rec["self_s"]
        a["device_s"] += rec["device_s"]
    return agg


def report(spans, top=10):
    """Human-readable report string: top-N by self-time + rollup table."""
    lines = [f"{len(spans)} spans, {len({s['name'] for s in spans})} names"]
    lines.append("")
    lines.append(f"top {top} spans by self-time:")
    lines.append(f"  {'self_s':>10}  {'wall_s':>10}  {'device_s':>10}  span")
    for rec in sorted(spans, key=lambda r: -r["self_s"])[:top]:
        attrs = ", ".join(f"{k}={v}" for k, v in sorted(rec["attrs"].items()))
        label = rec["name"] + (f" [{attrs}]" if attrs else "")
        lines.append(
            f"  {rec['self_s']:>10.4f}  {rec['wall_s']:>10.4f}  "
            f"{rec['device_s']:>10.4f}  {label}"
        )
    lines.append("")
    lines.append("per-phase rollup:")
    lines.append(
        f"  {'count':>6}  {'total_s':>10}  {'self_s':>10}  "
        f"{'device_s':>10}  phase"
    )
    agg = rollup(spans)
    for name, a in sorted(agg.items(), key=lambda kv: -kv[1]["self_s"]):
        lines.append(
            f"  {a['count']:>6}  {a['total_s']:>10.4f}  "
            f"{a['self_s']:>10.4f}  {a['device_s']:>10.4f}  {name}"
        )
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="JSONL span export (REPRO_TRACE output)")
    ap.add_argument("--top", type=int, default=10)
    ap.add_argument(
        "--json",
        action="store_true",
        help="emit the per-phase rollup as JSON instead of the tables",
    )
    args = ap.parse_args(argv)
    try:
        spans = load_spans(args.trace)
    except ValueError as e:
        print(f"malformed trace {args.trace}: {e}", file=sys.stderr)
        raise SystemExit(1)
    if args.json:
        print(json.dumps(rollup(spans), indent=2, sort_keys=True))
    else:
        print(report(spans, top=args.top))


if __name__ == "__main__":
    main()
