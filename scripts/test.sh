#!/usr/bin/env bash
# Tier-1 verify — reproducible from a clean checkout:
#   scripts/test.sh             (fail-fast, quiet: the ROADMAP tier-1 line)
#   scripts/test.sh tests/test_finex_exactness.py -k eps_star
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [ "$#" -gt 0 ]; then
    exec python -m pytest -q "$@"
fi
exec python -m pytest -x -q
