#!/usr/bin/env bash
# Benchmark smoke runner + regression guard — keeps the perf artifacts
# honest AND regression-free.
#   scripts/bench.sh            smoke: small-n runs into $BENCH_DIR (a
#                               temp dir by default; CI sets it to the
#                               artifact upload path), then check the
#                               emitted BENCH_*.json against the smoke
#                               floors (schema keys present, exactness
#                               flags true, ratios finite and above
#                               their committed floors)
#   scripts/bench.sh --full     full 20k runs, refresh the committed
#                               BENCH_index.json / BENCH_service.json and
#                               guard them against the (stricter) full
#                               floors
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MODE="smoke"
if [ "${1:-}" = "--full" ]; then
    MODE="full"
    OUT_DIR="."
    # disabled-mode overhead gate: remember the committed end-to-end
    # build figure BEFORE the run overwrites the artifact — the fresh
    # run (tracing disabled) must stay within 2% of it
    PREV_E2E="$(python - <<'PY' 2>/dev/null || true
import json
print(json.load(open("BENCH_index.json"))["vectorized"]["end_to_end_build_s"])
PY
)"
    export PREV_E2E
    python benchmarks/index_bench.py --out "$OUT_DIR/BENCH_index.json"
    python benchmarks/service_bench.py --out "$OUT_DIR/BENCH_service.json"
else
    if [ -n "${BENCH_DIR:-}" ]; then
        OUT_DIR="$BENCH_DIR"
        mkdir -p "$OUT_DIR"
    else
        OUT_DIR="$(mktemp -d)"
        trap 'rm -rf "$OUT_DIR"' EXIT
    fi
    # 2400 > the prune screen's auto gate (2048): the smoke run must
    # exercise (and exactness-gate) the screened sweep, not skip it
    python benchmarks/index_bench.py --n 2400 \
        --out "$OUT_DIR/BENCH_index.json" >/dev/null
    python benchmarks/service_bench.py --smoke \
        --out "$OUT_DIR/BENCH_service.json" >/dev/null
fi

python - "$OUT_DIR" "$MODE" <<'EOF'
import json, math, os, sys

out_dir, mode = sys.argv[1], sys.argv[2]
failures = []

# Regression floors. "smoke" floors hold even at toy scale (n=2000, CI);
# "full" floors are the committed-artifact bars at the 20k reference
# setting. Exactness flags are hard requirements at every scale: the
# vectorized/compacted/incremental paths must stay byte-identical.
EXACT_FLAGS = {
    # pruning.identical_outputs / .screened: the projection-pruned sweep
    # must (a) actually engage at bench scale and (b) stay byte-identical
    # to the unpruned sweep — a wrong prune is a correctness bug, not a
    # perf regression
    # telemetry.identical_with_tracing: a tracing-enabled re-run must
    # reproduce the untraced outputs byte-for-byte — observability that
    # perturbs the computation is a correctness bug
    # pruning.screen_eval_device: the per-tile skip decision must come
    # from the device-resident bound plane (PR 8) — a host numpy plane
    # sneaking back onto the hot path fails the artifact, not just perf
    # queries.identical_labels: the screened ε*-verifier must reproduce
    # the unscreened labels bit-for-bit
    # hierarchy.identical_cuts: every condensed-tree cut must be
    # label-identical to the scalar ε*/MinPts*-queries AND the tree +
    # cuts must compute zero new distance rows — the PR-10 exactness
    # contract, not a perf figure
    "BENCH_index.json": ["identical_outputs", "incremental.identical",
                         "hierarchy.identical_cuts",
                         "pruning.identical_outputs", "pruning.screened",
                         "pruning.screen_eval_device",
                         "pruning_jaccard.identical_outputs",
                         "pruning_jaccard.screened",
                         "pruning_jaccard.screen_eval_device",
                         "queries.identical_labels",
                         "telemetry.identical_with_tracing"],
    # frontend.coalescing_identical: K single-point inserts coalesced
    # into ONE windowed delta must leave the index byte-identical to K
    # sequential facade inserts; slack_identical pins the slack-array
    # splice layout to the same contract; concurrent.identical_labels
    # pins reads served under 4-thread traffic to the bare planner
    "BENCH_service.json": ["sweep_identical_to_sequential",
                           "hit_zero_distance_rows",
                           "telemetry.identical_with_tracing",
                           "frontend.coalescing_identical",
                           "frontend.slack_identical",
                           "frontend.concurrent.identical_labels"],
}
FLOORS = {
    "smoke": {
        "BENCH_index.json": {
            "materialize.transfer_reduction": 1.5,
            "build.speedup_materialize": 1.5,
            "build.speedup_end_to_end": 1.5,
            # both sides of this ratio are tens of ms at smoke scale
            # (median-of-3, ~4.4x on the reference host): keep a wide
            # margin so shared-runner noise can't fail an unrelated PR
            "incremental.speedup_vs_rebuild": 1.5,
            # >= 1.0 is the no-regression bar: the screen may skip
            # nothing at toy scale, but it must never ADD pairs
            "queries.verification_pairs_reduction": 1.0,
            # ε-cuts replay the CSR with zero distance work while the
            # scalar ε*-queries pay verification — the cut must win even
            # at toy scale (wide margin for shared-runner noise)
            "hierarchy.eps_cut_speedup_vs_scalar_queries": 1.0,
        },
        "BENCH_service.json": {
            "cache_hit_speedup": 10.0,
            # batching barely pays at toy scale; the full floor is 1.5
            "sweep_vs_sequential": 0.7,
            # one windowed delta vs K packed splices: even at toy scale
            # the win is >10x on the reference host; wide margin for CI
            "frontend.coalescing_speedup": 1.2,
            # slack-backed splices must actually land in reserved slack
            # (a relayout-every-time regression drops this toward 0)
            "frontend.slack_in_place_fraction": 0.8,
            "frontend.concurrent.responses_per_s": 0.5,
        },
    },
    "full": {
        "BENCH_index.json": {
            "materialize.transfer_reduction": 2.0,
            "build.speedup_materialize": 2.0,
            "build.speedup_end_to_end": 2.5,
            "build.speedup_finex_build": 2.5,
            # the incremental-maintenance headline: a 20k single-insert
            # delta update must stay several times cheaper than a full
            # rebuild. The bench's steady-state cycle times each insert
            # right after a delete, and deletes now DEFER their
            # component relabel to the next mutation (PR 8) — so the
            # timed insert carries that deferred cost and the old >=10x
            # headline moved partly into delete_speedup below; the
            # insert+delete cycle total is what actually got faster
            # (floor carries margin for the rebuild denominator's
            # scheduler-window noise: measured 2.9-4.0x across runs)
            "incremental.speedup_vs_rebuild": 2.0,
            # batch deletes must also beat a rebuild (PR 8: lazy
            # component relabel + segment-op splice; measured ~3x, floor
            # kept wide for runner noise)
            "incremental.delete_speedup_vs_rebuild": 1.2,
            # screened ε*-verification must skip a real fraction of the
            # verification sub-matrices at reference scale
            "queries.verification_pairs_reduction": 1.2,
            # at the 20k reference setting the warmed projection screen
            # (PR 8) drops nearly all ε*-verification, so the 8 scalar
            # queries reach parity with the 8 zero-distance cuts
            # (measured ~0.8x warm; the cut's win shows at smoke scale
            # and in the distance-rows==0 exactness gate). The floor
            # only guards a pathological cut regression.
            "hierarchy.eps_cut_speedup_vs_scalar_queries": 0.5,
        },
        "BENCH_service.json": {
            "cache_hit_speedup": 50.0,
            "sweep_vs_sequential": 1.5,
            # the acceptance bar: coalesced windowed mutations >= 2x vs
            # sequential single-point inserts at the 20k reference
            # setting (measured far above; floor carries runner margin)
            "frontend.coalescing_speedup": 2.0,
            "frontend.slack_in_place_fraction": 0.8,
            "frontend.concurrent.responses_per_s": 2.0,
        },
    },
}
# Upper bounds (same spirit, inverted): values that must stay BELOW a
# committed ceiling. At the 20k reference geometry the screen must rule
# out a real fraction of the n^2 plane — candidate_fraction creeping
# toward 1.0 means the prune degenerated into pure overhead.
CEILINGS = {
    "smoke": {},
    "full": {
        "BENCH_index.json": {
            "pruning.candidate_fraction": 0.6,
            # the jaccard minhash/bitset screen on token-block clusters:
            # creeping toward 1.0 means the sketch stopped separating
            "pruning_jaccard.candidate_fraction": 0.7,
            # traced vs untraced SAME-process re-run: immune to the
            # scheduler-window noise that makes cross-commit wall-clock
            # comparisons coarse (committed ~1.5-1.6 on the service
            # span mix; creeping past 2 means a hot path grew a span)
            "telemetry.tracing_overhead_ratio": 2.0,
        },
    },
}


def check(path, required, ratio_keys, metric_keys=(), rollup_keys=()):
    with open(f"{out_dir}/{path}") as f:
        r = json.load(f)
    flat = {}

    def walk(d, prefix=""):
        for k, v in d.items():
            flat[f"{prefix}{k}"] = v
            if isinstance(v, dict):
                walk(v, f"{prefix}{k}.")
    walk(r)
    for k in rollup_keys:
        # the telemetry span rollup must actually contain spans — an
        # empty dict means the tracer silently stopped recording
        v = flat.get(k)
        if not isinstance(v, dict) or not v:
            failures.append(f"{path}: {k!r} must be a non-empty span "
                            f"rollup dict (got {v!r})")
    for k in required:
        if k not in flat:
            failures.append(f"{path}: missing key {k!r}")
    for k in ratio_keys:
        v = flat.get(k)
        if not isinstance(v, (int, float)) or not math.isfinite(v) or v <= 0:
            failures.append(f"{path}: ratio {k!r} not a finite positive "
                            f"number (got {v!r})")
    for k in metric_keys:
        # each benchmark section must say which registered metric it ran
        # under — a bare string that the metric registry resolves
        v = flat.get(k)
        if not isinstance(v, str) or not v:
            failures.append(f"{path}: metric {k!r} not a non-empty string "
                            f"(got {v!r})")
    for k in EXACT_FLAGS.get(path, []):
        if flat.get(k) is not True:
            failures.append(f"{path}: exactness flag {k!r} must be true "
                            f"(got {flat.get(k)!r})")
    for k, floor in FLOORS[mode].get(path, {}).items():
        v = flat.get(k)
        if not isinstance(v, (int, float)) or not math.isfinite(v) \
                or v < floor:
            failures.append(f"{path}: {k!r} = {v!r} regressed below the "
                            f"committed {mode} floor {floor}")
    for k, ceil in CEILINGS[mode].get(path, {}).items():
        v = flat.get(k)
        if not isinstance(v, (int, float)) or not math.isfinite(v) \
                or v > ceil:
            failures.append(f"{path}: {k!r} = {v!r} rose above the "
                            f"committed {mode} ceiling {ceil}")


check("BENCH_index.json",
      required=["n", "eps", "minpts", "device_sweep_s", "metric",
                "vectorized.materialize_s", "vectorized.finex_build_s",
                "vectorized.end_to_end_build_s", "vectorized.csr_nnz",
                "identical_outputs",
                "materialize.materialize_s", "materialize.mode",
                "materialize.metric",
                "materialize.host_bytes_dense",
                "materialize.host_bytes_compacted",
                "materialize.transfer_reduction",
                "incremental.single_insert_s",
                "incremental.rebuild_insert_s",
                "incremental.speedup_vs_rebuild",
                "incremental.batch_delete_s", "incremental.batch_delete_ids",
                "incremental.insert_mode", "incremental.delete_mode",
                "incremental.identical",
                "pruning.screened", "pruning.tiles_total",
                "pruning.tiles_skipped", "pruning.candidate_fraction",
                "pruning.pruned_materialize_s",
                "pruning.unpruned_materialize_s",
                "pruning.speedup_vs_unpruned", "pruning.screen_build_s",
                "pruning.identical_outputs", "pruning.screen_eval_device",
                "pruning.screen_eval_s",
                "pruning_jaccard.candidate_fraction",
                "pruning_jaccard.pruned_materialize_s",
                "pruning_jaccard.unpruned_materialize_s",
                "pruning_jaccard.identical_outputs",
                "queries.identical_labels",
                "queries.verification_pairs_screened",
                "queries.verification_pairs_unscreened",
                "queries.screened_pairs",
                "queries.verification_pairs_reduction",
                "hierarchy.tree_build_s", "hierarchy.cuts_k",
                "hierarchy.cuts_total_s",
                "hierarchy.planner_sweep_k16_s",
                "hierarchy.tree_plus_cuts_vs_sweep",
                "hierarchy.eps_cuts_s",
                "hierarchy.eps_scalar_queries_s",
                "hierarchy.eps_cut_speedup_vs_scalar_queries",
                "hierarchy.distance_rows_during_tree_and_cuts",
                "hierarchy.condensed_clusters",
                "hierarchy.identical_cuts",
                "build.speedup_end_to_end", "build.speedup_host_pipeline",
                "build.speedup_finex_build", "build.speedup_materialize",
                "telemetry.identical_with_tracing",
                "telemetry.tracing_overhead_ratio",
                "telemetry.span_rollup", "telemetry.counters"],
      ratio_keys=["build.speedup_end_to_end", "build.speedup_host_pipeline",
                  "build.speedup_finex_build", "build.speedup_eps_star",
                  "build.speedup_minpts_star", "build.speedup_materialize",
                  "materialize.transfer_reduction",
                  "incremental.speedup_vs_rebuild",
                  "incremental.delete_speedup_vs_rebuild",
                  "pruning.speedup_vs_unpruned",
                  "pruning_jaccard.speedup_vs_unpruned",
                  "queries.verification_pairs_reduction",
                  "hierarchy.eps_cut_speedup_vs_scalar_queries",
                  "telemetry.tracing_overhead_ratio"],
      metric_keys=["metric", "materialize.metric"],
      rollup_keys=["telemetry.span_rollup"])
check("BENCH_service.json",
      required=["n", "eps", "minpts", "k", "build_s", "hit_s",
                "hit_zero_distance_rows", "sweep_s", "sequential_s",
                "sweep_identical_to_sequential",
                "service.settings_per_s", "service.batched_sweeps",
                "service.store",
                "telemetry.identical_with_tracing",
                "telemetry.counters", "telemetry.windows",
                "frontend.k_inserts", "frontend.sequential_inserts_s",
                "frontend.slack_sequential_inserts_s",
                "frontend.coalesced_window_s",
                "frontend.coalescing_speedup",
                "frontend.slack_in_place_fraction",
                "frontend.batched_deltas",
                "frontend.concurrent.responses_per_s",
                "frontend.concurrent.rejected",
                "frontend.concurrent.queue_depth_p95"],
      ratio_keys=["cache_hit_speedup", "sweep_vs_sequential",
                  "service.settings_per_s",
                  "frontend.coalescing_speedup",
                  "frontend.slack_vs_packed_sequential",
                  "frontend.concurrent.responses_per_s"],
      rollup_keys=["telemetry.span_rollup"])

# disabled-mode overhead gate (full mode only): the fresh tracing-off
# end-to-end build must stay near the committed figure captured before
# this run overwrote the artifact. Wall-clock on one host — the
# smoke/CI lanes skip it (shared-runner noise), the committed artifacts
# enforce it where they are produced. The ceiling is a coarse drift
# backstop, not a tight overhead bound: A/B runs of IDENTICAL code on
# the reference container land in scheduler windows up to ~1.15x apart
# even with the median-of-3 the bench now takes (the tight, same-
# process overhead check is telemetry.tracing_overhead_ratio above).
prev = os.environ.get("PREV_E2E", "").strip()
if mode == "full" and prev:
    with open(f"{out_dir}/BENCH_index.json") as f:
        new_e2e = json.load(f)["vectorized"]["end_to_end_build_s"]
    ratio = new_e2e / float(prev)
    if ratio > 1.15:
        failures.append(
            f"BENCH_index.json: disabled-mode end_to_end_build_s "
            f"{new_e2e} is {ratio:.3f}x the committed {prev} "
            f"(> 1.15 drift ceiling)")
    else:
        print(f"disabled-mode overhead OK: end_to_end_build_s {new_e2e} "
              f"vs committed {prev} ({ratio:.3f}x <= 1.15)")

if failures:
    print(f"BENCH regression guard FAILED ({mode} floors):")
    for f in failures:
        print(f"  - {f}")
    sys.exit(1)
print(f"BENCH regression guard OK ({mode} floors; "
      f"{out_dir}/BENCH_index.json, {out_dir}/BENCH_service.json)")
EOF
