#!/usr/bin/env bash
# Benchmark smoke runner + schema guard — keeps the perf artifacts honest.
#   scripts/bench.sh            smoke: small-n runs into a temp dir, then
#                               sanity-check the emitted BENCH_*.json
#                               schemas (keys present, ratios finite)
#   scripts/bench.sh --full     full 20k runs, refresh the committed
#                               BENCH_index.json / BENCH_service.json
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [ "${1:-}" = "--full" ]; then
    OUT_DIR="."
    python benchmarks/index_bench.py --out "$OUT_DIR/BENCH_index.json"
    python benchmarks/service_bench.py --out "$OUT_DIR/BENCH_service.json"
else
    OUT_DIR="$(mktemp -d)"
    trap 'rm -rf "$OUT_DIR"' EXIT
    python benchmarks/index_bench.py --n 2000 \
        --out "$OUT_DIR/BENCH_index.json" >/dev/null
    python benchmarks/service_bench.py --smoke \
        --out "$OUT_DIR/BENCH_service.json" >/dev/null
fi

python - "$OUT_DIR" <<'EOF'
import json, math, sys

out_dir = sys.argv[1]
failures = []


def check(path, required, ratio_keys, metric_keys=()):
    with open(f"{out_dir}/{path}") as f:
        r = json.load(f)
    flat = {}

    def walk(d, prefix=""):
        for k, v in d.items():
            flat[f"{prefix}{k}"] = v
            if isinstance(v, dict):
                walk(v, f"{prefix}{k}.")
    walk(r)
    for k in required:
        if k not in flat:
            failures.append(f"{path}: missing key {k!r}")
    for k in ratio_keys:
        v = flat.get(k)
        if not isinstance(v, (int, float)) or not math.isfinite(v) or v <= 0:
            failures.append(f"{path}: ratio {k!r} not a finite positive "
                            f"number (got {v!r})")
    for k in metric_keys:
        # each benchmark section must say which registered metric it ran
        # under — a bare string that the metric registry resolves
        v = flat.get(k)
        if not isinstance(v, str) or not v:
            failures.append(f"{path}: metric {k!r} not a non-empty string "
                            f"(got {v!r})")


check("BENCH_index.json",
      required=["n", "eps", "minpts", "device_sweep_s", "metric",
                "vectorized.materialize_s", "vectorized.finex_build_s",
                "vectorized.end_to_end_build_s", "vectorized.csr_nnz",
                "identical_outputs",
                "materialize.materialize_s", "materialize.mode",
                "materialize.metric",
                "materialize.host_bytes_dense",
                "materialize.host_bytes_compacted",
                "materialize.transfer_reduction",
                "build.speedup_end_to_end", "build.speedup_host_pipeline",
                "build.speedup_finex_build", "build.speedup_materialize"],
      ratio_keys=["build.speedup_end_to_end", "build.speedup_host_pipeline",
                  "build.speedup_finex_build", "build.speedup_eps_star",
                  "build.speedup_minpts_star", "build.speedup_materialize",
                  "materialize.transfer_reduction"],
      metric_keys=["metric", "materialize.metric"])
check("BENCH_service.json",
      required=["n", "eps", "minpts", "k", "build_s", "hit_s",
                "hit_zero_distance_rows", "sweep_s", "sequential_s",
                "sweep_identical_to_sequential",
                "service.settings_per_s", "service.batched_sweeps",
                "service.store"],
      ratio_keys=["cache_hit_speedup", "sweep_vs_sequential",
                  "service.settings_per_s"])

if failures:
    print("BENCH schema check FAILED:")
    for f in failures:
        print(f"  - {f}")
    sys.exit(1)
print(f"BENCH schema check OK ({out_dir}/BENCH_index.json, "
      f"{out_dir}/BENCH_service.json)")
EOF
