"""Hierarchy-as-a-query contract suite.

Pins the PR's central claims: (1) ``ClusterHierarchy.cut`` /
``cut_minpts`` are label-identical to ``eps_star`` / ``minpts_star`` for
every registered metric, before AND after incremental deltas, with ZERO
new distance computations (asserted via the engine counter); (2) the
vectorized condensed tree + stability selection match the brute-force
all-level loop oracle ``reference_hierarchy`` up to canonical keying;
(3) delta-then-hierarchy equals fresh-build-then-hierarchy; (4) the tree
round-trips through the index npz archive; (5) the typed settings
(``Eps`` / ``MinPts`` / ``Hierarchy``) and the tuple shim answer
identically through planner and frontend."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (Eps, FinexIndex, Hierarchy, MinPts,
                        normalize_settings)
from repro.core.hierarchy import HIERARCHY_ARRAY_KEYS
from repro.core.queries import ClusteringResult
from repro.core.reference import reference_hierarchy
from repro.data.synthetic import (gaussian_mixture, heavy_tail_sets,
                                  two_scale_blobs)
from repro.metrics import register_metric
from repro.neighbors.bitset import pack_sets
from repro.service import SweepPlanner


def _chebyshev(q, c):
    return jnp.max(jnp.abs(q[:, None, :] - c[None, :, :]), axis=-1)


try:
    register_metric("hier-cheb", _chebyshev)
except ValueError:
    pass  # already registered by a previous import of this module


def _vectors(n, seed):
    return gaussian_mixture(n, d=4, k=5, seed=seed), None


def _sets(n, seed):
    sets, w = heavy_tail_sets(n, seed=seed)
    return pack_sets(sets, universe=512), w


# (metric, dataset factory, eps, minpts) — the same four-way coverage as
# the incremental suite: euclidean, jaccard's packed bitmap tuple state,
# cosine, and a register_metric user distance
CASES = [
    ("euclidean", _vectors, 0.35, 8),
    ("jaccard", _sets, 0.4, 8),
    ("cosine", _vectors, 0.02, 6),
    ("hier-cheb", _vectors, 0.3, 6),
]
IDS = [c[0] for c in CASES]


def take_rows(data, sel):
    if isinstance(data, tuple):
        return tuple(a[sel] for a in data)
    return data[sel]


def build(data, case, weights=None):
    metric, _, eps, minpts = case
    return FinexIndex.build(data, eps=eps, minpts=minpts, metric=metric,
                            weights=weights)


# --------------------------------------------------------------------------
# canonical tree comparison: cluster ids are an implementation detail
# (stack order vs recursion order), so rows are keyed by
# (birth, size, smallest object id in the subtree) — unique by
# construction — and parents are matched through their keys.
# --------------------------------------------------------------------------
def _subtree_mins(parent, attr, n):
    nc = len(parent)
    mins = np.full(nc, n, dtype=np.int64)
    attr = np.asarray(attr)
    objs = np.flatnonzero(attr >= 0)
    np.minimum.at(mins, attr[objs], objs)
    for c in range(nc - 1, -1, -1):        # parent[c] < c, both sides
        p = int(parent[c])
        if p >= 0:
            mins[p] = min(mins[p], mins[c])
    return mins


def _canon(parent, birth, death, size, stability, selected, attr, n):
    parent = np.asarray(parent, dtype=np.int64)
    mins = _subtree_mins(parent, attr, n)
    keys = [(round(float(birth[c]), 9), int(size[c]), int(mins[c]))
            for c in range(parent.size)]
    assert len(set(keys)) == len(keys), "canonical keys must be unique"
    rows = {}
    for c, key in enumerate(keys):
        pk = keys[parent[c]] if parent[c] >= 0 else None
        rows[key] = (pk, round(float(death[c]), 9),
                     round(float(stability[c]), 6), bool(selected[c]))
    return rows


def _canon_of_hierarchy(h):
    return _canon(h.parent, h.birth, h.death, h.size, h.stability,
                  h.selected, h.leaf_cond, h.n)


def _canon_of_reference(ref, n):
    attr = np.full(n, -1, dtype=np.int64)       # the oracle keeps a dict
    for p, c in ref["attr"].items():
        attr[p] = c
    return _canon(ref["parent"], ref["birth"], ref["death"], ref["size"],
                  ref["stability"], ref["selected"], attr, n)


# --------------------------------------------------------------------------
# (1) cut-equivalence, per metric, pre/post deltas, zero distances
# --------------------------------------------------------------------------
@pytest.mark.parametrize("case", CASES, ids=IDS)
def test_cuts_identical_to_queries_zero_distances(case):
    metric, make, eps, minpts = case
    data, w = make(240, seed=5)
    extra, _ = make(252, seed=5)
    index = build(data, case, weights=w)

    def check_cuts(idx):
        eps_cuts = [eps * f for f in (1.0, 0.7, 0.45, 0.2)]
        mp_cuts = [minpts, minpts + 5, 4 * minpts]
        # the oracles first (ε*-verification may compute distances) ...
        want_e = [np.asarray(idx.eps_star(e)) for e in eps_cuts]
        want_m = [np.asarray(idx.minpts_star(m)) for m in mp_cuts]
        # ... then the whole hierarchy + every cut must cost ZERO rows
        rows_before = idx.engine.distance_rows_computed
        h = idx.hierarchy()
        for e, want in zip(eps_cuts, want_e):
            np.testing.assert_array_equal(h.cut(e), want)
        for m, want in zip(mp_cuts, want_m):
            np.testing.assert_array_equal(h.cut_minpts(m), want)
        assert idx.engine.distance_rows_computed == rows_before
        assert h.n_clusters >= 1 and (np.asarray(h.extract()) >= -1).all()

    check_cuts(index)
    stale = index.hierarchy()

    # deltas invalidate the cache; the rebuilt tree must stay exact
    index.insert(take_rows(extra, slice(240, 252)))
    index.delete(np.arange(0, 24, 2))
    assert index.hierarchy_stats()["built"] is False
    check_cuts(index)
    assert index.hierarchy() is not stale     # lazily rebuilt, not reused
    assert index.hierarchy_stats()["built"] is True


def test_lean_index_hierarchy_is_distance_free():
    """MinPts*-side cuts and the tree itself need no engine at all."""
    x, _ = _vectors(200, seed=3)
    idx = FinexIndex.build(x, eps=0.35, minpts=8)
    want = np.asarray(idx.minpts_star(16))
    lean = FinexIndex(idx.ordering, idx.csr, weights=idx.weights)
    h = lean.hierarchy()
    np.testing.assert_array_equal(h.cut_minpts(16), want)
    np.testing.assert_array_equal(h.cut(0.2), idx.eps_star(0.2))
    assert h.n_clusters == idx.hierarchy().n_clusters


# --------------------------------------------------------------------------
# (2) condensed tree + stability vs the brute-force loop oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("kind", ["vectors", "sets", "two-scale"])
def test_condensed_tree_matches_reference(kind, seed):
    if kind == "vectors":
        x, w = gaussian_mixture(90, d=4, k=4, seed=seed), None
        idx = FinexIndex.build(x, eps=0.5, minpts=5)
    elif kind == "sets":
        # discrete distances: heavy ties exercise the level-contracted
        # multiway merges and the λ floor on duplicate (m = 0) pairs
        sets, w = heavy_tail_sets(90, seed=seed)
        idx = FinexIndex.build(pack_sets(sets, universe=512), eps=0.5,
                               minpts=5, metric="jaccard", weights=w)
    else:
        x, w = two_scale_blobs(120, seed=seed), None
        idx = FinexIndex.build(x, eps=0.45, minpts=5)
    for W in (None, 2, 10):
        h = idx.hierarchy(min_cluster_weight=W)
        ref = reference_hierarchy(idx.ordering, idx.csr, idx.weights,
                                  min_cluster_weight=W)
        assert _canon_of_hierarchy(h) == _canon_of_reference(ref, idx.n)
        np.testing.assert_array_equal(h.extract(),
                                      np.asarray(ref["labels"]))


def test_hierarchy_without_cores_is_empty():
    x, _ = _vectors(60, seed=1)
    idx = FinexIndex.build(x, eps=0.05, minpts=50)   # nobody qualifies
    h = idx.hierarchy()
    assert h.n_clusters == 0 and h.n_selected == 0
    assert (np.asarray(h.extract()) == -1).all()
    np.testing.assert_array_equal(h.cut(0.02), idx.eps_star(0.02))


# --------------------------------------------------------------------------
# (3) delta-then-hierarchy == fresh-build-then-hierarchy
# --------------------------------------------------------------------------
def test_delta_then_hierarchy_matches_fresh_build():
    x = gaussian_mixture(220, d=4, k=5, seed=9)
    extra = gaussian_mixture(240, d=4, k=5, seed=9)[220:]
    idx = FinexIndex.build(x, eps=0.35, minpts=8)
    idx.hierarchy()                       # warm cache, must invalidate
    idx.insert(extra)
    gone = np.arange(10, 40, 3)
    idx.delete(gone)
    mutated = np.delete(np.concatenate([x, extra]), gone, axis=0)
    fresh = FinexIndex.build(mutated, eps=0.35, minpts=8)
    a, b = idx.hierarchy(), fresh.hierarchy()
    for f in ("parent", "birth", "death", "size", "selected",
              "leaf_cond"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    np.testing.assert_allclose(a.stability, b.stability, rtol=1e-12)
    np.testing.assert_array_equal(a.extract(), b.extract())
    np.testing.assert_array_equal(a.cut(0.2), fresh.eps_star(0.2))


# --------------------------------------------------------------------------
# (4) npz round-trip: the tree rides the archive as optional keys
# --------------------------------------------------------------------------
def test_npz_roundtrip_warm_and_cold(tmp_path):
    x = gaussian_mixture(180, d=4, k=4, seed=2)
    idx = FinexIndex.build(x, eps=0.35, minpts=8)

    cold_path = str(tmp_path / "cold.npz")
    idx.save(cold_path)                   # saved before hierarchy(): no keys
    with np.load(cold_path) as z:
        assert not any(k in z for k in HIERARCHY_ARRAY_KEYS)
    cold = FinexIndex.load(cold_path, data=x)
    assert cold.hierarchy_stats()["built"] is False

    h = idx.hierarchy()
    warm_path = str(tmp_path / "warm.npz")
    idx.save(warm_path)
    with np.load(warm_path) as z:
        assert all(k in z for k in HIERARCHY_ARRAY_KEYS)
    warm = FinexIndex.load(warm_path, data=x)
    st = warm.hierarchy_stats()
    assert st["built"] is True and st["clusters"] == h.n_clusters
    g = warm.hierarchy()                  # cache hit, no rebuild needed
    for f in ("parent", "birth", "death", "size", "stability",
              "selected", "leaf_cond"):
        np.testing.assert_array_equal(getattr(g, f), getattr(h, f))
    np.testing.assert_array_equal(g.extract(), h.extract())
    np.testing.assert_array_equal(g.cut(0.2), idx.eps_star(0.2))
    # the lazily-rebuilt cold tree converges to the same answer
    np.testing.assert_array_equal(cold.hierarchy().extract(), h.extract())


# --------------------------------------------------------------------------
# (5) typed settings + unified result type, planner and frontend
# --------------------------------------------------------------------------
def test_normalize_settings_shim():
    norm = normalize_settings(
        [Eps(0.3), ("eps", 0.3), MinPts(12), ("minpts", 12),
         Hierarchy(), Hierarchy(min_cluster_weight=7)])
    assert norm == [("eps", 0.3), ("eps", 0.3), ("minpts", 12),
                    ("minpts", 12), ("hierarchy", 0), ("hierarchy", 7)]
    with pytest.raises(ValueError, match="unknown sweep setting"):
        normalize_settings([("epsilon", 0.2)])
    with pytest.raises(TypeError, match="must be Eps/MinPts"):
        normalize_settings([0.2])


def test_planner_typed_settings_equal_tuples_and_queries():
    x = gaussian_mixture(200, d=4, k=4, seed=4)
    idx = FinexIndex.build(x, eps=0.35, minpts=8)
    planner = SweepPlanner(idx)
    typed = planner.sweep([Eps(0.2), MinPts(16), Hierarchy()])
    tup = planner.sweep([("eps", 0.2), ("minpts", 16), ("hierarchy", 0)])
    np.testing.assert_array_equal(typed, tup)
    np.testing.assert_array_equal(typed[0], np.asarray(idx.eps_star(0.2)))
    np.testing.assert_array_equal(typed[1],
                                  np.asarray(idx.minpts_star(16)))
    np.testing.assert_array_equal(typed[2],
                                  np.asarray(idx.hierarchy().extract()))
    assert isinstance(typed, ClusteringResult)
    assert typed.kind == "sweep"
    assert typed.settings == [("eps", 0.2), ("minpts", 16),
                              ("hierarchy", 0)]
    assert planner.hierarchy().n_clusters == idx.hierarchy().n_clusters


def test_queries_return_clustering_result_with_provenance():
    x = gaussian_mixture(160, d=4, k=4, seed=6)
    idx = FinexIndex.build(x, eps=0.35, minpts=8)
    res = idx.eps_star(0.2)
    assert isinstance(res, ClusteringResult)
    assert res.kind == "eps" and res.value == pytest.approx(0.2)
    assert res.version == idx.version and res.minpts == 8
    assert isinstance(res.labels, np.ndarray)
    assert not isinstance(res.labels, ClusteringResult)
    np.testing.assert_array_equal(res.labels, np.asarray(res))
    assert idx.minpts_star(12).kind == "minpts"
    assert idx.clustering().kind == "generating"
    ext = idx.hierarchy().extract()
    assert ext.kind == "stability" and ext.value == 8
    # results behave as plain label arrays everywhere (old call sites)
    assert res.shape == (idx.n,) and int(res.max()) >= 0
    assert (np.sort(np.unique(res.labels)) == np.unique(res)).all()


def test_frontend_hierarchy_op_and_stats():
    from repro.service import (BuildOp, ClusterOp, HierarchyOp,
                               ServiceFrontend, StatsOp, SweepOp)
    x = gaussian_mixture(200, d=4, k=4, seed=8)
    fe = ServiceFrontend(workers=2, window=4)
    try:
        fe.submit(BuildOp("hx", x, 0.35, 8)).result(timeout=120)
        hier = fe.submit(HierarchyOp("hx")).result(timeout=120)
        swp = fe.submit(
            SweepOp("hx", [Hierarchy(), Eps(0.2), MinPts(16)])
        ).result(timeout=120)
        one = fe.submit(ClusterOp("hx", Eps(0.2))).result(timeout=120)
        stats = fe.submit(StatsOp()).result(timeout=120)
    finally:
        fe.shutdown(drain=True, timeout=120)
    assert hier.kind == "hierarchy" and hier.index == "hx"
    np.testing.assert_array_equal(hier, swp[0])
    np.testing.assert_array_equal(one, swp[1])
    assert one.kind == "eps" and one.value == pytest.approx(0.2)
    assert swp.settings == [("hierarchy", 0), ("eps", 0.2),
                            ("minpts", 16)]
    hs = stats["indexes"]["hx"]["hierarchy"]
    assert hs["built"] is True and hs["clusters"] >= 1
