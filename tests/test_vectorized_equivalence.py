"""The vectorized pipeline must be *byte-identical* to the loop-based
reference (the pre-vectorization implementations kept in
``repro.core.reference``): same CSR, counts, core distances, orderings
(order/pos/C/R/N/F), and query labels — on euclidean, jaccard and
weighted-duplicate datasets. This pins the refactor to the semantics the
paper's proofs (Thms 5.2–5.6) were validated against."""
import numpy as np
import pytest

from repro.core import eps_star_query, finex_build, minpts_star_query, \
    optics_build
from repro.core.reference import (reference_core_distances,
                                  reference_eps_star_query,
                                  reference_finex_build,
                                  reference_materialize,
                                  reference_minpts_star_query,
                                  reference_optics_build)
from repro.data.synthetic import gaussian_mixture, heavy_tail_sets
from repro.neighbors.bitset import pack_sets
from repro.neighbors.engine import NeighborEngine


def _euclidean(seed):
    x = gaussian_mixture(400, d=4, k=5, seed=seed)
    return NeighborEngine(x, metric="euclidean"), 0.35, 8


def _jaccard(seed):
    sets, w = heavy_tail_sets(500, seed=seed)
    bits, sizes = pack_sets(sets)
    return NeighborEngine((bits, sizes), metric="jaccard", weights=w), 0.4, 16


def _weighted(seed):
    rng = np.random.default_rng(seed)
    x = gaussian_mixture(300, d=3, k=4, seed=seed)
    w = rng.integers(1, 6, size=x.shape[0]).astype(np.int64)
    return NeighborEngine(x, metric="euclidean", weights=w), 0.4, 12


CASES = {"euclidean": _euclidean, "jaccard": _jaccard, "weighted": _weighted}


@pytest.fixture(params=sorted(CASES), scope="module")
def case(request):
    engine, eps, minpts = CASES[request.param](seed=3)
    return engine, eps, minpts


def _assert_csr_identical(ref_pair, new_pair):
    (c_ref, csr_ref), (c_new, csr_new) = ref_pair, new_pair
    np.testing.assert_array_equal(c_ref, c_new)
    np.testing.assert_array_equal(csr_ref.indptr, csr_new.indptr)
    np.testing.assert_array_equal(csr_ref.indices, csr_new.indices)
    np.testing.assert_array_equal(csr_ref.dists, csr_new.dists)


def test_materialize_identical(case):
    """Default (mask-emit) compacted sweep == dense loop reference."""
    engine, eps, _ = case
    _assert_csr_identical(reference_materialize(engine, eps),
                          engine.materialize(eps))
    assert engine.last_materialize["mode"] == "mask"


@pytest.mark.parametrize("name", sorted(CASES))
def test_materialize_slot_emit_identical(name):
    """Slot-emit compacted sweep (the fused eps_compact kernels' jnp
    oracle) pins the same bytes as the dense reference."""
    ref_engine, eps, _ = CASES[name](seed=3)
    want = reference_materialize(ref_engine, eps)
    engine, _, _ = CASES[name](seed=3)
    engine.emit = "slots"
    _assert_csr_identical(want, engine.materialize(eps))
    assert engine.last_materialize["mode"] == "slots"


@pytest.mark.parametrize("name", sorted(CASES))
def test_materialize_slot_overflow_falls_back_dense(name):
    """A capacity too small for the longest rows must route those rows
    through the dense-tile fallback — and still be byte-identical."""
    ref_engine, eps, _ = CASES[name](seed=3)
    want = reference_materialize(ref_engine, eps)
    engine, _, _ = CASES[name](seed=3)
    engine.emit = "slots"
    engine._slot_cap = 8            # below the longest neighborhood
    _assert_csr_identical(want, engine.materialize(eps))
    stats = engine.last_materialize
    assert stats["fallback_rows"] > 0, \
        "overflow case did not exercise the dense fallback"
    assert engine._slot_cap > 8     # capacity adapted for later sweeps


def test_counts_only_matches_materialize(case):
    """The fused count kernels agree with the materialized counts."""
    engine, eps, _ = case
    np.testing.assert_array_equal(engine.counts_only(eps),
                                  engine.materialize(eps)[0])


def test_core_distances_identical(case):
    engine, eps, minpts = case
    counts, csr = engine.materialize(eps)
    ref = reference_core_distances(csr, counts, engine.weights, minpts)
    new = NeighborEngine.core_distances(csr, counts, engine.weights, minpts)
    np.testing.assert_array_equal(ref, new)


def test_finex_build_identical(case):
    engine, eps, minpts = case
    ref, csr = reference_finex_build(engine, eps, minpts)
    new, _ = finex_build(engine, eps, minpts, csr=csr)
    for attr in ("order", "pos", "C", "R", "N", "F"):
        np.testing.assert_array_equal(getattr(ref, attr), getattr(new, attr),
                                      err_msg=f"FINEX {attr} diverged")


def test_optics_build_identical(case):
    engine, eps, minpts = case
    ref, csr = reference_optics_build(engine, eps, minpts)
    new, _ = optics_build(engine, eps, minpts, csr=csr)
    for attr in ("order", "pos", "C", "R"):
        np.testing.assert_array_equal(getattr(ref, attr), getattr(new, attr),
                                      err_msg=f"OPTICS {attr} diverged")


@pytest.mark.parametrize("frac", [1.0, 0.8, 0.55, 0.3])
def test_eps_star_labels_identical(case, frac):
    engine, eps, minpts = case
    idx, _ = finex_build(engine, eps, minpts)
    eps_star = float(np.float32(eps * frac))
    ref = reference_eps_star_query(idx, engine, eps_star)
    new = eps_star_query(idx, engine, eps_star)
    np.testing.assert_array_equal(ref, new)


@pytest.mark.parametrize("mult", [1, 2, 4, 16])
def test_minpts_star_labels_identical(case, mult):
    engine, eps, minpts = case
    idx, csr = finex_build(engine, eps, minpts)
    ref = reference_minpts_star_query(idx, csr, minpts * mult)
    new = minpts_star_query(idx, csr, minpts * mult)
    np.testing.assert_array_equal(ref, new)
