"""Per-architecture smoke tests: every assigned config instantiates a
REDUCED same-family variant and runs one forward + one train step on CPU,
asserting output shapes and the absence of NaNs (mandate §f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, RunConfig, ShapeConfig
from repro.data.tokens import TokenStream
from repro.models.transformer import forward, init_params
from repro.train.step import init_state, make_train_step

SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_forward_and_train_step(arch):
    cfg = ARCHS[arch].reduced()
    rc = RunConfig(model=cfg, shape=SMOKE_SHAPE, remat=False,
                   dtype="float32", full_attn_max_seq=256)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)

    stream = TokenStream(cfg, SMOKE_SHAPE.seq_len, SMOKE_SHAPE.global_batch)
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    inputs = batch["tokens"] if cfg.embed_inputs else batch["embeds"]

    logits = forward(params, inputs, cfg, rc)
    assert logits.shape == (2, 64, cfg.padded_vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any(), "NaN logits"

    step_fn = jax.jit(make_train_step(cfg, rc, n_micro=2))
    state = init_state(key, cfg)
    state2, metrics = step_fn(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # parameters actually changed
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(state2.params)))
    assert delta > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_full_config_param_shapes_consistent(arch):
    """FULL configs: parameter shapes are well-formed and the analytic
    count matches the materialized shapes (no allocation)."""
    from repro.models.transformer import param_shapes
    cfg = ARCHS[arch]
    shapes = param_shapes(cfg)
    total = sum(int(np.prod(s.shape)) for s in shapes.values())
    analytic = cfg.param_count()
    # padded vocab inflates embed/lm_head; allow that margin plus the
    # merged-QKV/grouping bookkeeping, but nothing bigger
    pad_slack = (cfg.padded_vocab - cfg.vocab) * cfg.d_model * 2 + 1
    assert analytic <= total <= analytic + pad_slack + 0.01 * analytic, \
        (arch, total, analytic)


@pytest.mark.parametrize("arch", ["qwen2-72b", "hymba-1.5b", "mamba2-130m",
                                  "llama4-maverick-400b-a17b"])
def test_arch_decode_smoke(arch):
    """One decode step on the reduced config (decode-capable archs)."""
    from repro.models.transformer import decode_step, init_cache
    cfg = ARCHS[arch].reduced()
    rc = RunConfig(model=cfg, shape=ShapeConfig("d", 32, 2, "decode"),
                   remat=False, dtype="float32")
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    cache = init_cache(cfg, 2, 32, jnp.float32)
    toks = jax.random.randint(key, (2, 1), 0, cfg.vocab)
    logits, new_cache = decode_step(params, cache, toks, jnp.int32(0),
                                    cfg, rc)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


def test_encoder_has_no_decode():
    cfg = ARCHS["hubert-xlarge"]
    rc = RunConfig(model=cfg, shape=ShapeConfig("d", 32, 2, "decode"))
    assert rc.skip_reason() is not None


def test_long_context_skips():
    from repro.configs import LONG_500K
    expected_runnable = {"mamba2-130m", "hymba-1.5b"}
    runnable = {a for a, c in ARCHS.items()
                if RunConfig(model=c, shape=LONG_500K).skip_reason() is None}
    assert runnable == expected_runnable
