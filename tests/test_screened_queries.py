"""Screened ε*-verification and screen-cache invalidation properties.

The ε*-query verifier (``repro.core.queries``) now consults the engine's
projection screen (``NeighborEngine.screen_admit``) before computing any
verification distance: a core column no candidate admits provably holds
no hit, so it drops from the block.  The contract mirrors the pruned
sweep's — the screen only ever removes *provable* non-hits — so labels
must be byte-identical with the screen on and off, for every registered
metric (projection-less user metrics degrade to the unscreened path),
and on the 8-device mesh lane; on prunable geometry the counted
``verification_pairs`` must strictly drop.

The second half pins cache hygiene: a screen (and its device-resident
bucket-bound plane) built before an insert/delete must never survive the
mutation — a stale plane could prune a bucket that now holds a true
neighbor.  ``append_rows``/``keep_rows`` invalidate, and the mutated
index stays byte-identical to fresh pruned AND unpruned builds.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import FinexIndex
from repro.core.queries import QueryStats, eps_star_batch
from repro.metrics import get_metric, register_metric, registered_metrics
from repro.neighbors.engine import NeighborEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# force the genuinely screened path at test-sized n (see test_pruned_sweep)
PRUNED = dict(prune="on", batch_rows=48, screen_bucket=8)


def _chebyshev(q, c):
    return jnp.max(jnp.abs(q[:, None, :] - c[None, :, :]), axis=-1)


try:
    register_metric("scrq-cheb", _chebyshev)
except ValueError:
    pass  # already registered by a previous import of this module

ALL_METRICS = registered_metrics()  # includes the user metric above


def _index_pair(name, n=240, seed=3, minpts=5):
    """(pruned index, unpruned index, generating eps) over one dataset."""
    m = get_metric(name)
    rng = np.random.default_rng(seed)
    data = m.synthesize(rng, n)
    probe = NeighborEngine(data, metric=get_metric(name), batch_rows=48)
    dense = probe.distances_from(np.arange(probe.n))
    off_diag = dense[~np.eye(probe.n, dtype=bool)]
    eps = float(np.quantile(off_diag, 0.3))
    on = FinexIndex.from_engine(
        NeighborEngine(data, metric=get_metric(name), **PRUNED),
        eps, minpts)
    off = FinexIndex.from_engine(
        NeighborEngine(data, metric=get_metric(name), prune="off",
                       batch_rows=48),
        eps, minpts)
    return on, off, eps


@pytest.mark.parametrize("name", ALL_METRICS)
def test_eps_star_screened_byte_identical_every_metric(name):
    """Scalar and batched ε*-labels agree bit-for-bit, screen on vs off,
    for every registered metric (incl. jaccard's minhash screen and a
    projection-less ``register_metric`` user distance)."""
    on, off, eps = _index_pair(name)
    stars = [0.45 * eps, 0.7 * eps, 0.9 * eps]
    for es in stars:
        np.testing.assert_array_equal(on.eps_star(es), off.eps_star(es))
    sa, sb = QueryStats(), QueryStats()
    A = eps_star_batch(on.ordering, on.engine, stars, stats=sa)
    B = eps_star_batch(off.ordering, off.engine, stars, stats=sb)
    np.testing.assert_array_equal(A, B)
    assert sb.screened_pairs == 0          # no screen, nothing skipped
    m = get_metric(name)
    if m.project(m.canonicalize(m.synthesize(
            np.random.default_rng(0), 8)), 4) is None:
        # projection-less metric: the screened path must degrade to the
        # plain verifier, not silently drop pairs
        assert sa.screened_pairs == 0
        assert sa.verification_pairs == sb.verification_pairs


def test_eps_star_screen_reduces_verification_pairs():
    """On prunable geometry the screen must strictly shrink the
    verification sub-matrices — fewer pairs computed, some skipped —
    with unchanged labels; ``FinexIndex.stats`` surfaces the counter.

    Geometry note: a column drops only when NO candidate admits it, so
    tight isolated blobs never screen (every core has a candidate
    within ε*).  The noisy mixture works because noise bridges merge
    gaussians into sparse clusters much wider than ε*, leaving cores
    far from every candidate of their cluster."""
    from repro.data.synthetic import gaussian_mixture
    x = gaussian_mixture(800, d=8, k=12, noise_frac=0.1, seed=0)
    on = FinexIndex.from_engine(NeighborEngine(x, **PRUNED), 0.6, 8)
    off = FinexIndex.from_engine(
        NeighborEngine(x, prune="off", batch_rows=48), 0.6, 8)
    stars = [0.25, 0.35, 0.5]
    for es in stars:
        np.testing.assert_array_equal(on.eps_star(es), off.eps_star(es))
    vp_on, sp_on = (on.query_stats.verification_pairs,
                    on.query_stats.screened_pairs)
    vp_off, sp_off = (off.query_stats.verification_pairs,
                      off.query_stats.screened_pairs)
    assert vp_off > 0, "geometry produced no verification work"
    assert sp_off == 0
    assert sp_on > 0
    assert vp_on < vp_off
    assert on.stats()["query_screened_pairs"] == sp_on
    # the batched kernel shares sub-matrices across settings but screens
    # the same way: identical labels, strictly fewer pairs
    sa, sb = QueryStats(), QueryStats()
    np.testing.assert_array_equal(
        eps_star_batch(on.ordering, on.engine, stars, stats=sa),
        eps_star_batch(off.ordering, off.engine, stars, stats=sb))
    assert sb.verification_pairs > 0
    assert sa.screened_pairs > 0
    assert sa.verification_pairs < sb.verification_pairs


def test_eps_star_screened_mesh_lane():
    """Mesh-built index (8 host devices, sharded screened emit) answers
    screened ε*-queries byte-identically to the unpruned single-device
    index over the same data."""
    code = """
    import numpy as np
    from repro.core import FinexIndex
    from repro.core.queries import QueryStats, eps_star_batch
    from repro.launch.mesh import make_host_mesh
    from repro.neighbors.distributed import sharded_csr_materialize
    from repro.neighbors.engine import NeighborEngine

    rng = np.random.default_rng(29)
    mesh = make_host_mesh(2, 4)
    centers = rng.normal(scale=60.0, size=(4, 6))
    x = np.concatenate([c + rng.normal(size=(128, 6)) for c in centers]
                       ).astype(np.float32)
    csr = sharded_csr_materialize(x, 1.4, mesh, cap=256, row_chunk=64)
    on = FinexIndex.from_engine(
        NeighborEngine(x, prune="on", batch_rows=48, screen_bucket=8),
        1.4, 6, csr=csr)
    off = FinexIndex.from_engine(
        NeighborEngine(x, prune="off", batch_rows=48), 1.4, 6)
    stars = [0.6, 0.9, 1.25]
    for es in stars:
        np.testing.assert_array_equal(on.eps_star(es), off.eps_star(es))
    sa, sb = QueryStats(), QueryStats()
    np.testing.assert_array_equal(
        eps_star_batch(on.ordering, on.engine, stars, stats=sa),
        eps_star_batch(off.ordering, off.engine, stars, stats=sb))
    assert sa.verification_pairs <= sb.verification_pairs
    print("MESH-SCREENED-EPSSTAR-OK")
    """
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=900)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr[-4000:]}"
    assert "MESH-SCREENED-EPSSTAR-OK" in p.stdout


# --------------------------------------------------- cache invalidation

def test_mutations_drop_screen_cache():
    """``append_rows``/``keep_rows`` must drop the cached screen (and its
    device-resident bound plane) — a stale plane could prune a bucket
    that now holds a true neighbor."""
    rng = np.random.default_rng(31)
    centers = rng.normal(scale=50.0, size=(4, 5))
    x = np.concatenate([c + rng.normal(size=(70, 5)) for c in centers]
                       ).astype(np.float32)
    eng = NeighborEngine(x, **PRUNED)
    eng.materialize(1.3)
    scr = eng._screen_get()
    assert scr is not None and scr.get("min2") is not None, (
        "materialize should have built the screen + bound plane")
    eng.append_rows(x[:7] + 0.01)
    assert eng._screen is None
    eng.materialize(1.3)
    assert eng._screen_get() is not None
    keep = np.ones(eng.n, dtype=bool)
    keep[::9] = False
    eng.keep_rows(keep)
    assert eng._screen is None


def test_stale_screen_never_prunes_new_neighbor():
    """End to end: a pruned index whose screen was built pre-mutation
    stays byte-identical to fresh pruned AND unpruned builds after
    inserting rows OUTSIDE every existing bucket (the adversarial case
    for a stale bound plane) and after deletes; ε*-queries agree too."""
    rng = np.random.default_rng(37)
    centers = rng.normal(scale=50.0, size=(4, 5))
    x = np.concatenate([c + rng.normal(size=(80, 5)) for c in centers]
                       ).astype(np.float32)
    # new rows: a fresh far-away blob + exact duplicates of corpus rows
    far = (rng.normal(scale=50.0, size=(1, 5))
           + rng.normal(size=(12, 5))).astype(np.float32)
    new = np.concatenate([far, x[:5]])

    idx = FinexIndex.from_engine(NeighborEngine(x, **PRUNED), 1.5, 6)
    assert idx.engine._screen_get() is not None      # cache is hot
    idx.insert(new)
    keep = np.ones(idx.n, dtype=bool)
    keep[rng.choice(idx.n, size=20, replace=False)] = False
    idx.delete(np.flatnonzero(~keep))

    x_final = np.concatenate([x, new])[keep]
    for kw in (PRUNED, dict(prune="off", batch_rows=48)):
        ref = FinexIndex.from_engine(NeighborEngine(x_final, **kw), 1.5, 6)
        np.testing.assert_array_equal(idx.csr.indptr, ref.csr.indptr)
        np.testing.assert_array_equal(idx.csr.indices, ref.csr.indices)
        np.testing.assert_array_equal(idx.csr.dists, ref.csr.dists)
        np.testing.assert_array_equal(idx.ordering.order,
                                      ref.ordering.order)
        for es in (0.8, 1.2):
            np.testing.assert_array_equal(idx.eps_star(es),
                                          ref.eps_star(es))
