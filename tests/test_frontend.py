"""Concurrent front-end suite: the ``ServiceFrontend`` contract.

The load-bearing property is **byte-identity under concurrency**: with
``record_ops=True`` the frontend records the effective (coalesced)
per-index op sequence, and every response handed to a client thread must
be byte-identical to replaying that sequence through a bare
``FinexIndex`` facade sequentially — labels, versions, and the final
index state (ordering quintuple + CSR) alike, for every registered
metric.  The rest pins admission control, deterministic mutation
coalescing, read-after-mutate version ordering, graceful shutdown, the
``IndexStore`` single-flight/thread-safety guarantees, the durable spill
catalog, the stale-drop obs counters, and the ``SlackCSR`` splice
identity.
"""

import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro import obs
from repro.core import FinexIndex
from repro.core.delta import SlackCSR
from repro.data.synthetic import gaussian_mixture, heavy_tail_sets
from repro.metrics import register_metric
from repro.neighbors.bitset import pack_sets
from repro.service import (AdmissionError, BuildOp, BuildResult, ClusterOp,
                           IndexKey, IndexStore, MutateRequest, MutateResult,
                           ServiceFrontend, StatsOp, SweepOp, SweepPlanner,
                           SweepResult)


def _chebyshev(q, c):
    return jnp.max(jnp.abs(q[:, None, :] - c[None, :, :]), axis=-1)


try:
    register_metric("fe-cheb", _chebyshev)
except ValueError:
    pass  # already registered by a previous import of this module


def _vectors(n, seed):
    return gaussian_mixture(n, d=4, k=5, seed=seed), None


def _sets(n, seed):
    sets, w = heavy_tail_sets(n, seed=seed)
    return pack_sets(sets, universe=512), w


CASES = [
    ("euclidean", _vectors, 0.35, 8),
    ("jaccard", _sets, 0.4, 8),
    ("fe-cheb", _vectors, 0.3, 6),
]
IDS = [c[0] for c in CASES]


@pytest.fixture(autouse=True)
def _tracing_off():
    """Tests own the obs singleton: start/end clean so counter asserts
    and threshold registrations never leak across tests."""
    obs.configure(sink=None, enabled=False)
    obs.reset()
    yield
    obs.configure(sink=None, enabled=False)
    obs.reset()


def take_rows(data, sel):
    if isinstance(data, tuple):
        return tuple(a[sel] for a in data)
    return data[sel]


def n_rows(data):
    return (data[0] if isinstance(data, tuple) else data).shape[0]


def assert_state_identical(got, want, what=""):
    """Byte-for-byte equality of everything an index serves from."""
    a, b = got.ordering, want.ordering
    for f in ("order", "pos", "C", "R", "N", "F"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), (what, f)
    for f in ("indptr", "indices", "dists"):
        # .csr packs a slack layout back to canonical CSR
        assert np.array_equal(getattr(got.csr, f),
                              getattr(want.csr, f)), (what, f)
    assert np.array_equal(got.weights, want.weights), (what, "weights")
    assert got.version == want.version, (what, "version")
    labels_equal = np.array_equal(got.clustering(), want.clustering())
    assert labels_equal, (what, "clustering")


# ----------------------------------------------- byte-identity under load
def _random_request(name, data, pool_lo, rng, eps, minpts):
    u = rng.random()
    if u < 0.22:
        rows = rng.integers(pool_lo, n_rows(data),
                            size=int(rng.integers(1, 4)))
        return MutateRequest(name, "insert", points=take_rows(data, rows))
    if u < 0.38:
        return MutateRequest(
            name, "delete",
            ids=rng.integers(0, 40, size=int(rng.integers(1, 4))))
    if u < 0.5:
        return ClusterOp(name)
    settings = []
    for _ in range(int(rng.integers(1, 4))):
        if rng.random() < 0.5:
            settings.append(("eps", float(eps * rng.uniform(0.2, 1.0))))
        else:
            settings.append(("minpts", int(minpts * rng.integers(1, 4))))
    return SweepOp(name, settings)


def _replay_and_check(case, name, base, weights, oplog, responses):
    """Replay the effective op sequence sequentially through a bare
    facade; every concurrent response must match byte-for-byte."""
    metric, _, eps, minpts = case
    by_req = {id(req): fut for req, fut in responses}
    idx = None
    for entry in oplog:
        kind = entry[0]
        if kind == "build":
            req = entry[1]
            idx = FinexIndex.build(req.data, eps=req.eps, minpts=req.minpts,
                                   metric=req.metric, weights=req.weights)
            fut = by_req.get(id(req))
            if fut is not None:
                res = fut.result(timeout=60)
                assert isinstance(res, BuildResult)
                assert res.version == idx.version and res.n == idx.n
        elif kind in ("insert", "delete"):
            _, payload, w, riders = entry
            rep = (idx.insert(payload, weights=w) if kind == "insert"
                   else idx.delete(payload))
            for r in riders:
                res = by_req[id(r)].result(timeout=60)
                assert isinstance(res, MutateResult)
                assert res.op == kind, "rider in a wrong-op run"
                assert res.version == rep["version"], "rider version"
                assert res.riders == len(riders)
        elif kind == "sweep":
            _, settings, spans = entry
            labels = SweepPlanner(idx).sweep(settings)
            for req, lo, hi in spans:
                res = by_req[id(req)].result(timeout=60)
                assert isinstance(res, SweepResult)
                assert res.version == idx.version, "read version"
                want = (labels[lo] if isinstance(req, ClusterOp)
                        else labels[lo:hi])
                assert np.array_equal(res.labels, want), \
                    f"{case[0]}: concurrent labels != sequential replay"
        else:  # pragma: no cover
            raise AssertionError(f"unknown oplog entry {kind!r}")
    return idx


@pytest.mark.parametrize("case", CASES, ids=IDS)
def test_concurrent_responses_byte_identical_to_sequential_replay(case):
    """4 client threads, randomized Build/Sweep/Cluster/Mutate
    interleavings: every response is byte-identical to a sequential
    replay of the recorded per-index op order, and the final index state
    matches the replayed facade exactly (slack splices included)."""
    metric, make, eps, minpts = case
    data, _ = make(340, seed=3)          # set factories dedupe: n varies
    pool_lo = n_rows(data) - 40          # tail 40 rows = the insert pool
    base = take_rows(data, np.arange(n_rows(data)) < pool_lo)
    name = "idx"
    fe = ServiceFrontend(store=IndexStore(capacity=4), workers=4, window=8,
                         max_queue=512, record_ops=True)
    try:
        build_req = BuildOp(name, base, eps, minpts, metric=metric)
        build_fut = fe.submit(build_req)
        build_fut.result(timeout=120)
        responses = [(build_req, build_fut)]
        lock = threading.Lock()

        def client(tid):
            rng = np.random.default_rng(100 + tid)
            for _ in range(10):
                req = _random_request(name, data, pool_lo, rng, eps, minpts)
                while True:
                    try:
                        fut = fe.submit(req)
                        break
                    except AdmissionError:
                        time.sleep(0.002)
                with lock:
                    responses.append((req, fut))

        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert fe.drain(timeout=120), "frontend failed to drain"
        served = fe._entries[name].index
        replayed = _replay_and_check(case, name, base, None,
                                     fe.oplog[name], responses)
        assert_state_identical(served, replayed, f"{metric} final state")
        with pytest.raises(Exception):
            # every future resolved: none may still be pending
            next(f for _, f in responses if not f.done())
    finally:
        fe.shutdown(drain=True, timeout=120)


# -------------------------------------------------- coalescing + ordering
def test_paused_window_coalesces_mutations_into_one_delta():
    """K single-point inserts staged behind pause() must apply as ONE
    batched facade delta; every rider shares the post-batch version."""
    x = gaussian_mixture(260, d=3, k=3, seed=0)
    fe = ServiceFrontend(store=IndexStore(capacity=2), workers=2,
                         window=16)
    try:
        fe.submit(BuildOp("a", x[:250], 0.4, 8)).result(timeout=120)
        fe.pause()
        futs = [fe.submit(MutateRequest("a", "insert",
                                        points=x[250 + i:251 + i]))
                for i in range(6)]
        read = fe.submit(SweepOp("a", [("minpts", 16)]))
        fe.resume()
        assert fe.drain(timeout=120)
        results = [f.result(timeout=60) for f in futs]
        assert fe.batched_deltas == 1, "inserts did not coalesce"
        assert fe.coalesced_mutations == 5
        assert all(r.riders == 6 for r in results)
        assert len({r.version for r in results}) == 1, \
            "riders of one delta must share its version"
        # reads are ordered after their window's mutations
        assert read.result(timeout=60).version == results[0].version
        fresh = FinexIndex.build(x[:256], eps=0.4, minpts=8)
        assert np.array_equal(read.result().labels[0],
                              fresh.minpts_star(16))
    finally:
        fe.shutdown(drain=True, timeout=120)


def test_read_after_acked_mutation_never_sees_older_version():
    x = gaussian_mixture(240, d=3, k=3, seed=1)
    fe = ServiceFrontend(store=IndexStore(capacity=2), workers=2, window=4)
    try:
        fe.submit(BuildOp("a", x[:230], 0.4, 8)).result(timeout=120)
        acked = 0
        for i in range(5):
            mt = fe.submit(MutateRequest("a", "insert",
                                         points=x[230 + i:231 + i]))
            acked = mt.result(timeout=60).version
            rd = fe.submit(ClusterOp("a")).result(timeout=60)
            assert rd.version >= acked, \
                "read returned a state older than an acked mutation"
    finally:
        fe.shutdown(drain=True, timeout=120)


def test_bad_setting_fails_alone_not_its_window():
    """One invalid setting must not poison the co-batched reads."""
    x = gaussian_mixture(220, d=3, k=3, seed=2)
    fe = ServiceFrontend(store=IndexStore(capacity=2), workers=1,
                         window=8)
    try:
        fe.submit(BuildOp("a", x, 0.4, 8)).result(timeout=120)
        fe.pause()
        bad = fe.submit(SweepOp("a", [("eps", 0.8)]))     # ε* > ε
        good = fe.submit(SweepOp("a", [("minpts", 16)]))
        fe.resume()
        assert fe.drain(timeout=120)
        with pytest.raises(Exception):
            bad.result(timeout=60)
        fresh = FinexIndex.build(x, eps=0.4, minpts=8)
        assert np.array_equal(good.result(timeout=60).labels[0],
                              fresh.minpts_star(16))
    finally:
        fe.shutdown(drain=True, timeout=120)


def test_op_against_unknown_index_fails_cleanly():
    fe = ServiceFrontend(store=IndexStore(capacity=2), workers=1)
    try:
        with pytest.raises(ValueError, match="unknown index"):
            fe.submit(ClusterOp("nope")).result(timeout=60)
        with pytest.raises(ValueError, match="unknown index"):
            fe.submit(MutateRequest("nope", "delete",
                                    ids=[0])).result(timeout=60)
    finally:
        fe.shutdown(drain=True, timeout=60)


# -------------------------------------------------------------- admission
def test_admission_queue_full_and_inflight_cap():
    obs.enable()
    x = gaussian_mixture(200, d=3, k=3, seed=0)
    fe = ServiceFrontend(store=IndexStore(capacity=2), workers=1,
                         window=4, max_queue=4, max_inflight=2)
    try:
        fe.submit(BuildOp("a", x, 0.4, 8)).result(timeout=120)
        fe.pause()
        fe.submit(ClusterOp("a"))
        fe.submit(ClusterOp("a"))
        # per-index in-flight cap trips before the queue bound
        with pytest.raises(AdmissionError, match="in flight"):
            fe.submit(ClusterOp("a"))
        fe.submit(StatsOp())
        fe.submit(StatsOp())
        with pytest.raises(AdmissionError, match="queue full"):
            fe.submit(StatsOp())
        assert fe.rejected == 2
        counters = obs.snapshot()["counters"]
        assert counters["frontend.rejected"] == 2
        assert counters["frontend.rejected_inflight"] == 1
        assert counters["frontend.rejected_queue_full"] == 1
        fe.resume()
        assert fe.drain(timeout=120)
        st = fe.submit(StatsOp()).result(timeout=60)
        assert st["frontend"]["rejected"] == 2
        assert "frontend.queue_depth" in st["telemetry"]["windows"]
    finally:
        fe.shutdown(drain=True, timeout=120)


def test_graceful_shutdown_refuses_then_fails_leftovers():
    x = gaussian_mixture(200, d=3, k=3, seed=0)
    # autostart=False: nothing dispatches, so the leftovers are exact
    fe = ServiceFrontend(store=IndexStore(capacity=2), workers=1,
                         autostart=False)
    leftovers = [fe.submit(BuildOp("a", x, 0.4, 8)) for _ in range(3)]
    assert not fe.shutdown(drain=False)
    for f in leftovers:
        with pytest.raises(AdmissionError, match="shut down"):
            f.result(timeout=60)
    with pytest.raises(AdmissionError, match="draining"):
        fe.submit(ClusterOp("a"))
    assert fe.failed == 3 and fe.rejected == 1


def test_drained_shutdown_serves_everything_first():
    x = gaussian_mixture(220, d=3, k=3, seed=1)
    fe = ServiceFrontend(store=IndexStore(capacity=2), workers=2,
                         window=4)
    fe.submit(BuildOp("a", x, 0.4, 8)).result(timeout=120)
    futs = [fe.submit(ClusterOp("a")) for _ in range(6)]
    assert fe.shutdown(drain=True, timeout=120)
    want = FinexIndex.build(x, eps=0.4, minpts=8).clustering()
    for f in futs:
        assert np.array_equal(f.result(timeout=60).labels, want)
    assert fe.failed == 0 and fe.completed == 7


# -------------------------------------------- IndexStore: thread safety
def test_store_single_flight_concurrent_get_or_build():
    """N threads racing the same cold key must elect ONE builder; the
    rest wait on its gate and come back with the identical object."""
    x = gaussian_mixture(700, d=4, k=4, seed=5)
    store = IndexStore(capacity=4)
    barrier = threading.Barrier(6)
    out = []
    lock = threading.Lock()

    def worker():
        barrier.wait()
        idx, outcome = store.get_or_build(x, 0.4, 8)
        with lock:
            out.append((idx, outcome))

    threads = [threading.Thread(target=worker) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    st = store.stats()
    assert st["builds"] == 1, "key was double-built under concurrency"
    assert sorted(o for _, o in out).count("build") == 1
    assert all(o in ("build", "hit") for _, o in out)
    assert len({id(i) for i, _ in out}) == 1, "threads got distinct objects"
    assert st["build_waits"] >= 1


def test_store_concurrent_mixed_traffic_stays_consistent(tmp_path):
    """get_or_build/rekey/evict hammered from 4 threads: no exceptions,
    capacity respected, and every returned index answers exactly for
    the dataset it was requested for (no mid-splice state escapes)."""
    from repro.checkpoint.manager import CheckpointManager
    datasets = [gaussian_mixture(240, d=3, k=3, seed=s) for s in range(4)]
    wants = [FinexIndex.build(x, eps=0.4, minpts=8).clustering()
             for x in datasets]
    store = IndexStore(capacity=2,
                       manager=CheckpointManager(str(tmp_path / "c")))
    errors = []

    def worker(tid):
        rng = np.random.default_rng(tid)
        try:
            for _ in range(12):
                i = int(rng.integers(len(datasets)))
                idx, _ = store.get_or_build(datasets[i], 0.4, 8)
                if not np.array_equal(idx.clustering(), wants[i]):
                    raise AssertionError(f"wrong labels for dataset {i}")
        except BaseException as e:       # surfaces in the main thread
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    st = store.stats()
    assert st["resident"] <= 2
    assert st["builds"] + st["reloads"] + st["hits"] == 4 * 12


# ----------------------------------------------- durable spill catalog
def test_catalog_survives_store_restart(tmp_path):
    """build -> spill -> NEW store over the same manager dir: the spilled
    key reloads (zero distance computations) instead of rebuilding, and
    forget() removes it durably."""
    from repro.checkpoint.manager import CheckpointManager
    x1 = gaussian_mixture(300, d=3, k=3, seed=1)
    x2 = gaussian_mixture(250, d=3, k=3, seed=2)
    mandir = str(tmp_path / "cache")
    store = IndexStore(capacity=1, manager=CheckpointManager(mandir))
    i1, _ = store.get_or_build(x1, 0.4, 8)
    want = i1.clustering()
    key1 = IndexKey.of_index(i1)
    store.get_or_build(x2, 0.4, 8)               # spills x1 + catalog write
    assert store.stats()["spills"] == 1

    # "restart": a fresh store instance over the same directory
    store2 = IndexStore(capacity=2, manager=CheckpointManager(mandir))
    assert key1 in store2, "catalog did not rehydrate the spill map"
    i1b, outcome = store2.get_or_build(x1, 0.4, 8)
    assert outcome == "reload", "restart lost the spilled index"
    assert i1b.engine.distance_rows_computed == 0
    np.testing.assert_array_equal(i1b.clustering(), want)

    # decremental maintenance: forget() drops catalog entry + artifacts
    assert store2.forget(key1, delete_spill=True)
    assert not store2.forget(key1)               # idempotent
    store3 = IndexStore(capacity=2, manager=CheckpointManager(mandir))
    assert key1 not in store3
    _, outcome = store3.get_or_build(x1, 0.4, 8)
    assert outcome == "build"


def test_catalog_corruption_degrades_to_rebuild(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    x1 = gaussian_mixture(250, d=3, k=3, seed=1)
    x2 = gaussian_mixture(200, d=3, k=3, seed=2)
    mandir = str(tmp_path / "cache")
    store = IndexStore(capacity=1, manager=CheckpointManager(mandir))
    store.get_or_build(x1, 0.4, 8)
    store.get_or_build(x2, 0.4, 8)
    path = tmp_path / "cache" / f"{IndexStore.CATALOG}.json"
    assert path.exists()
    path.write_text("{ not json")
    with pytest.warns(UserWarning, match="not valid JSON"):
        store2 = IndexStore(capacity=1,
                            manager=CheckpointManager(mandir))
    _, outcome = store2.get_or_build(x1, 0.4, 8)
    assert outcome == "build"                    # degraded, not poisoned


# ------------------------------------------- stale-drop obs (satellite)
def test_stale_drop_surfaces_distinctly_in_counters_and_stats(tmp_path):
    """A refused-stale-spill drop (mutated index evicted before rekey)
    must increment ``stale_drops`` — in store.stats(), the Stats verb,
    and the ``store.stale_drops`` obs counter — distinctly from plain
    capacity drops."""
    from repro.checkpoint.manager import CheckpointManager
    obs.enable()
    x = gaussian_mixture(200, d=3, k=3, seed=7)
    y = gaussian_mixture(180, d=3, k=3, seed=8)
    store = IndexStore(capacity=1,
                       manager=CheckpointManager(str(tmp_path / "c")))
    idx, _ = store.get_or_build(x[:195], 0.4, 8)
    idx.insert(x[195:])                          # mutated, NOT rekey'd
    store.get_or_build(y, 0.4, 8)                # evicts -> refused spill
    st = store.stats()
    assert st["drops"] == 1 and st["stale_drops"] == 1
    assert st["spills"] == 0
    counters = obs.snapshot()["counters"]
    assert counters["store.drops"] == 1
    assert counters["store.stale_drops"] == 1
    # a plain capacity drop (no manager) must NOT count as stale
    plain = IndexStore(capacity=1)
    plain.get_or_build(x[:195], 0.4, 8)
    plain.get_or_build(y, 0.4, 8)
    assert plain.stats()["drops"] == 1
    assert plain.stats()["stale_drops"] == 0
    # the Stats verb carries the distinction end to end
    fe = ServiceFrontend(store=store, workers=1)
    try:
        verb = fe.submit(StatsOp()).result(timeout=60)
        assert verb["store"]["stale_drops"] == 1
    finally:
        fe.shutdown(drain=True, timeout=60)


# ------------------------------------------------- SlackCSR splice layer
def test_slack_csr_packed_view_matches_plain_splices():
    """Slack-backed splices must be byte-identical to packed splices —
    through in-place appends AND forced relayouts."""
    x = gaussian_mixture(240, d=3, k=3, seed=9)
    plain = FinexIndex.build(x[:200], eps=0.4, minpts=8)
    slacked = FinexIndex.build(x[:200], eps=0.4, minpts=8)
    slacked.enable_slack(slack=1.5, min_row_slack=8)
    for i in range(200, 240, 4):
        plain.insert(x[i:i + 4])
        slacked.insert(x[i:i + 4])
    assert_state_identical(slacked, plain, "slack vs packed")
    st = slacked.slack_stats()
    assert st["enabled"] and st["in_place_splices"] >= 1
    assert st["capacity"] >= st["nnz"]

    # zero headroom forces the relayout path every time — still exact
    tight = FinexIndex.build(x[:200], eps=0.4, minpts=8)
    tight.enable_slack(slack=1.0, min_row_slack=0)
    for i in range(200, 240, 4):
        tight.insert(x[i:i + 4])
    assert_state_identical(tight, plain, "relayout vs packed")
    assert tight.slack_stats()["relayouts"] >= 1


def test_slack_csr_rollback_on_failed_insert():
    """A rejected mutation must leave a slack-backed index untouched."""
    x = gaussian_mixture(220, d=3, k=3, seed=10)
    idx = FinexIndex.build(x[:210], eps=0.4, minpts=8)
    idx.enable_slack()
    idx.insert(x[210:215])                       # slack layout active
    before = idx.csr
    with pytest.raises(Exception):
        idx.insert(np.ones((3, 7)))              # wrong dimensionality
    after = idx.csr
    for f in ("indptr", "indices", "dists"):
        assert np.array_equal(getattr(before, f), getattr(after, f))
    ref = FinexIndex.build(x[:210], eps=0.4, minpts=8)
    ref.insert(x[210:215])               # same effective op sequence
    assert_state_identical(idx, ref, "post-rollback")


def test_slack_csr_unit_roundtrip():
    rng = np.random.default_rng(11)
    lens = rng.integers(0, 9, size=32)
    indptr = np.zeros(33, dtype=np.int64)
    np.cumsum(lens, out=indptr[1:])
    nnz = int(indptr[-1])
    from repro.neighbors.engine import CSRNeighborhoods
    csr = CSRNeighborhoods(
        indptr=indptr,
        indices=rng.integers(0, 32, size=nnz).astype(np.int64),
        dists=rng.random(nnz).astype(np.float32), eps=0.5)
    sl = SlackCSR.from_csr(csr)
    packed = sl.packed()
    for f in ("indptr", "indices", "dists"):
        assert np.array_equal(getattr(packed, f), getattr(csr, f))
    starts, ends = sl.row_bounds()
    assert np.array_equal(ends - starts, lens)
