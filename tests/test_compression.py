"""int8 gradient compression: bounded error, unbiased-enough with error
feedback (property-based)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.train.compression import (ErrorFeedback, _dequantize,
                                     _quantize_int8, compress_grads_int8,
                                     compress_with_feedback)


@given(seed=st.integers(0, 100), scale=st.floats(1e-6, 1e4))
@settings(deadline=None, max_examples=30)
def test_quantization_error_bounded(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(64,)) * scale, jnp.float32)
    q, s = _quantize_int8(g)
    deq = _dequantize(q, s)
    max_err = float(jnp.max(jnp.abs(deq - g)))
    assert max_err <= float(s) * 0.5 + 1e-12   # half-ULP of the int8 grid


def test_compress_tree_structure_preserved():
    grads = {"a": jnp.ones((4, 4)), "b": {"c": jnp.full((3,), -2.0)}}
    out = compress_grads_int8(grads, mesh=None)
    assert jax.tree.structure(out) == jax.tree.structure(grads)
    np.testing.assert_allclose(np.asarray(out["a"]), 1.0, rtol=1e-2)


@given(seed=st.integers(0, 50))
@settings(deadline=None, max_examples=15)
def test_error_feedback_accumulates_to_truth(seed):
    """Summing compressed grads with error feedback converges to the sum
    of the true grads (the residual re-injects what quantization drops)."""
    rng = np.random.default_rng(seed)
    steps = 25
    gs = [jnp.asarray(rng.normal(size=(32,)), jnp.float32)
          for _ in range(steps)]
    ef = ErrorFeedback.init({"g": gs[0]})
    total_comp = jnp.zeros((32,))
    total_true = jnp.zeros((32,))
    for g in gs:
        comp, ef = compress_with_feedback({"g": g}, ef)
        total_comp = total_comp + comp["g"]
        total_true = total_true + g
    # residual bounds the divergence: |sum_comp - sum_true| = |residual|
    resid = np.abs(np.asarray(ef.residual["g"]))
    diff = np.abs(np.asarray(total_comp - total_true))
    np.testing.assert_allclose(diff, resid, atol=1e-4)
    # and the residual itself is at most one quantization step
    assert diff.max() < 0.1 * steps ** 0.5
