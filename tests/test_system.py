"""End-to-end system behaviour: the paper's full interactive workflow and
its integration into the training stack."""

from repro.core import (assert_equivalent_exact, dbscan_from_csr,
                        eps_star_query, finex_build, minpts_star_query)
from repro.core.anydbc import anydbc
from repro.data.synthetic import two_scale_blobs
from repro.neighbors.engine import NeighborEngine


def test_interactive_exploration_end_to_end():
    """The Figure-1 scenario: one permissive build answers clusterings at
    multiple densities, all exact; MinPts tuning splits/keeps clusters."""
    x = two_scale_blobs(900, seed=3)
    engine = NeighborEngine(x, metric="euclidean")
    eps, minpts = 0.5, 10
    index, csr = finex_build(engine, eps, minpts)

    # sparse setting: the two dense blobs may merge into one cluster
    sparse = eps_star_query(index, engine, 0.5)
    # dense setting: they must split and the sparse blob dissolves
    dense = eps_star_query(index, engine, 0.12)
    assert dense.max() >= sparse.max(), "tighter eps* cannot merge clusters"

    for eps_star in (0.5, 0.3, 0.12):
        lab = eps_star_query(index, engine, eps_star)
        oracle = dbscan_from_csr(csr, engine.weights, eps_star, minpts)
        assert_equivalent_exact(lab, oracle, csr, engine.weights, eps_star,
                                minpts, f"e2e eps*={eps_star}")
    for ms in (10, 30, 90):
        lab = minpts_star_query(index, csr, ms)
        oracle = dbscan_from_csr(csr, engine.weights, eps, ms)
        assert_equivalent_exact(lab, oracle, csr, engine.weights, eps, ms,
                                f"e2e minpts*={ms}")


def test_anydbc_baseline_exact_and_prunes_vectors():
    x = two_scale_blobs(700, seed=5)
    engine = NeighborEngine(x, metric="euclidean")
    _, csr = engine.materialize(0.4)
    labels, stats = anydbc(engine, 0.4, 8, seed=2)
    oracle = dbscan_from_csr(csr, engine.weights, 0.4, 8)
    assert_equivalent_exact(labels, oracle, csr, engine.weights, 0.4, 8,
                            "anydbc e2e")
    assert stats["pruned"] >= 0


def test_quickstart_example_runs():
    import subprocess
    import sys
    import os
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    p = subprocess.run([sys.executable, "examples/quickstart.py"],
                       env=dict(os.environ,
                                PYTHONPATH=os.path.join(repo, "src")),
                       capture_output=True, text=True, cwd=repo, timeout=600)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "exact" in p.stdout.lower()
