"""FINEX queries must be EXACT (Definition 3.5) against the DBSCAN oracle,
for both metrics, both query types, across parameter ranges — the core
claim of the paper (Thm 5.6, §5.4, Cor 5.5)."""
import numpy as np
import pytest

from repro.core import (
    assert_equivalent_exact, dbscan_from_csr, eps_star_query,
    minpts_star_query, query_clustering, QueryStats)


EPS_V, MINPTS_V = 0.35, 8
EPS_S, MINPTS_S = 0.4, 16


@pytest.mark.parametrize("eps_star", [0.35, 0.3, 0.25, 0.2, 0.12, 0.05])
def test_eps_star_exact_vectors(vec_engine, vec_index, eps_star):
    idx, csr = vec_index
    lab = eps_star_query(idx, vec_engine, eps_star)
    oracle = dbscan_from_csr(csr, vec_engine.weights, eps_star, MINPTS_V)
    assert_equivalent_exact(lab, oracle, csr, vec_engine.weights, eps_star,
                            MINPTS_V, f"eps*={eps_star}")


@pytest.mark.parametrize("minpts_star", [8, 9, 16, 31, 64, 200])
def test_minpts_star_exact_vectors(vec_engine, vec_index, minpts_star):
    idx, csr = vec_index
    lab = minpts_star_query(idx, csr, minpts_star)
    oracle = dbscan_from_csr(csr, vec_engine.weights, EPS_V, minpts_star)
    assert_equivalent_exact(lab, oracle, csr, vec_engine.weights, EPS_V,
                            minpts_star, f"minpts*={minpts_star}")


@pytest.mark.parametrize("eps_star", [0.4, 0.33, 0.25, 0.18, 0.1])
def test_eps_star_exact_sets(set_engine, set_index, eps_star):
    idx, csr = set_index
    lab = eps_star_query(idx, set_engine, eps_star)
    oracle = dbscan_from_csr(csr, set_engine.weights, eps_star, MINPTS_S)
    assert_equivalent_exact(lab, oracle, csr, set_engine.weights, eps_star,
                            MINPTS_S, f"jaccard eps*={eps_star}")


@pytest.mark.parametrize("minpts_star", [16, 17, 40, 128, 500])
def test_minpts_star_exact_sets(set_engine, set_index, minpts_star):
    idx, csr = set_index
    lab = minpts_star_query(idx, csr, minpts_star)
    oracle = dbscan_from_csr(csr, set_engine.weights, EPS_S, minpts_star)
    assert_equivalent_exact(lab, oracle, csr, set_engine.weights, EPS_S,
                            minpts_star, f"jaccard minpts*={minpts_star}")


def test_linear_scan_exact_at_generating_pair(vec_engine, vec_index):
    """Corollary 5.5: Algorithm 1 alone is exact at ε* = ε."""
    idx, csr = vec_index
    lab = query_clustering(idx, EPS_V)
    oracle = dbscan_from_csr(csr, vec_engine.weights, EPS_V, MINPTS_V)
    assert_equivalent_exact(lab, oracle, csr, vec_engine.weights, EPS_V,
                            MINPTS_V, "Cor 5.5")


def test_eps_star_query_does_less_work_than_dbscan(vec_engine, vec_index):
    """§5.3: an ε*-query performs *fewer* distance computations than
    DBSCAN from scratch (candidate×core verification only)."""
    idx, csr = vec_index
    stats = QueryStats()
    eps_star_query(idx, vec_engine, 0.25, stats=stats)
    n = vec_engine.n
    assert stats.verification_pairs < n * n / 10, (
        f"{stats.verification_pairs} pairs vs {n * n} for DBSCAN")


def test_minpts_star_fast_path(vec_engine, vec_index):
    """§5.4 optimization: if no core loses status, components come from
    the sparse clustering with no Algorithm-4 BFS at all."""
    idx, csr = vec_index
    counts = idx.N
    cores = counts[counts >= MINPTS_V]
    if cores.size == 0:
        pytest.skip("no cores")
    # choose MinPts* ≤ every core's N: nobody is demoted
    mstar = int(cores.min())
    if mstar < MINPTS_V:
        pytest.skip("cannot exercise fast path")
    stats = QueryStats()
    lab = minpts_star_query(idx, csr, max(MINPTS_V, mstar), stats=stats)
    assert stats.fast_path
    oracle = dbscan_from_csr(csr, vec_engine.weights, EPS_V,
                             max(MINPTS_V, mstar))
    assert_equivalent_exact(lab, oracle, csr, vec_engine.weights, EPS_V,
                            max(MINPTS_V, mstar), "fast path")


def test_index_attrs_validate(vec_index, set_index):
    for idx, _ in (vec_index, set_index):
        idx.validate()


def test_save_load_roundtrip(tmp_path, vec_index, vec_engine):
    idx, csr = vec_index
    p = str(tmp_path / "index.npz")
    idx.save(p)
    from repro.core.ordering import FinexOrdering
    idx2 = FinexOrdering.load(p)
    lab1 = eps_star_query(idx, vec_engine, 0.2)
    lab2 = eps_star_query(idx2, vec_engine, 0.2)
    assert np.array_equal(lab1, lab2)


@pytest.mark.parametrize("minpts_star", [8, 20, 64, 256])
def test_anyfinex_minpts_star_exact(vec_engine, vec_index, minpts_star):
    """AnyFINEX (§6.3): FINEX noise filter + AnyDBC-style connector."""
    from repro.core.anydbc import anyfinex_minpts_star
    idx, csr = vec_index
    lab, stats = anyfinex_minpts_star(idx, csr, vec_engine, minpts_star)
    oracle = dbscan_from_csr(csr, vec_engine.weights, EPS_V, minpts_star)
    assert_equivalent_exact(lab, oracle, csr, vec_engine.weights, EPS_V,
                            minpts_star, f"anyfinex minpts*={minpts_star}")
    # queries only over preserved cores — never the whole dataset
    assert stats["queries"] <= stats["cores"]


def test_anydbc_baseline_exact(vec_engine, vec_index):
    from repro.core.anydbc import anydbc
    idx, csr = vec_index
    lab, stats = anydbc(vec_engine, EPS_V, MINPTS_V, seed=7)
    oracle = dbscan_from_csr(csr, vec_engine.weights, EPS_V, MINPTS_V)
    assert_equivalent_exact(lab, oracle, csr, vec_engine.weights, EPS_V,
                            MINPTS_V, "anydbc")
    assert stats["queries"] <= vec_engine.n
