"""Registry contract suite: every registered metric — built-in or user
defined — must satisfy the engine's kernel contract (symmetry, zero
self-distance, fused-count == mask row sums, compact == oracle, emit
paths byte-identical), plus the end-to-end custom-metric workflow:
register → build → query → save/load → warm IndexStore hit."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import FinexIndex
from repro.core.reference import reference_materialize
from repro.kernels import ref
from repro.metrics import (CallableMetric, Metric, get_metric,
                           register_metric, registered_metrics)
from repro.neighbors.engine import NeighborEngine, dataset_fingerprint


# a user-defined distance, registered the way a downstream user would:
# a plain jnp callable, no Pallas kernel — it rides the dense fallback
# path and participates in the whole contract suite below
def _chebyshev(x, y):
    m, d = x.shape
    acc = jnp.zeros((m, y.shape[0]), jnp.float32)
    for w0 in range(0, d, 4):
        acc = jnp.maximum(acc, jnp.abs(
            x[:, None, w0:w0 + 4] - y[None, :, w0:w0 + 4]).max(-1))
    return acc


if "chebyshev" not in registered_metrics():
    register_metric("chebyshev", _chebyshev)

ALL_METRICS = registered_metrics()


def _dataset(name, n=90, seed=3):
    m = get_metric(name)
    return m, m.synthesize(np.random.default_rng(seed), n)


def _eps_for(dists):
    """A threshold that keeps a meaningful survivor fraction for any
    distance scale — the 20th percentile of off-diagonal distances."""
    off = dists[~np.eye(dists.shape[0], dtype=bool)]
    return float(np.quantile(off, 0.2))


@pytest.fixture(scope="module", params=ALL_METRICS)
def metric_case(request):
    m, data = _dataset(request.param)
    eng = NeighborEngine(data, metric=m)
    dense = eng.distances_from(np.arange(eng.n))
    return m, data, eng, dense, _eps_for(dense)


def test_symmetry_and_zero_self_distance(metric_case):
    _, _, _, dense, _ = metric_case
    np.testing.assert_allclose(dense, dense.T, rtol=1e-5, atol=1e-5)
    # the euclidean MXU expansion ‖x‖²+‖y‖²−2x·y cancels catastrophically
    # on the diagonal: self-distances are O(sqrt(float32 eps)·scale), not
    # exactly zero — bound them well below any useful ε instead
    np.testing.assert_allclose(np.diag(dense), 0.0, atol=5e-3)
    assert (dense >= 0.0).all()


def test_eps_count_matches_mask_tile_row_sums(metric_case):
    m, _, eng, dense, eps = metric_case
    w = jnp.ones(eng.n, jnp.float32)
    counts = m.eps_count(eng._state, eng._state, jnp.float32(eps), w)
    hit, _ = m.mask_tile(eng._state, eng._state, m.mask_threshold(eps))
    np.testing.assert_array_equal(
        np.asarray(counts).astype(np.int64), np.asarray(hit).sum(axis=1))
    # the mask threshold transform must be exact: the hit plane equals
    # thresholding the dense plane directly
    np.testing.assert_array_equal(np.asarray(hit), dense <= np.float32(eps))


def test_eps_compact_matches_oracle(metric_case):
    m, _, eng, dense, eps = metric_case
    lens, cols, dvals = m.eps_compact(eng._state, eng._state,
                                      jnp.float32(eps), 128)
    ol, oc, od = ref.eps_compact_tile(jnp.asarray(dense), jnp.float32(eps),
                                      128)
    np.testing.assert_array_equal(np.asarray(lens), np.asarray(ol))
    np.testing.assert_array_equal(np.asarray(cols), np.asarray(oc))
    np.testing.assert_array_equal(np.asarray(dvals), np.asarray(od))


def test_gather_pairs_matches_dense_plane(metric_case):
    m, _, eng, dense, eps = metric_case
    hit, payload = m.mask_tile(eng._state, eng._state, m.mask_threshold(eps))
    flat = np.flatnonzero(np.asarray(hit))
    got = np.asarray(m.gather_pairs(payload, jnp.asarray(flat)))
    np.testing.assert_array_equal(got, dense.ravel()[flat])


def test_emit_paths_byte_identical_to_reference(metric_case):
    m, data, _, _, eps = metric_case
    ref_counts, ref_csr = reference_materialize(
        NeighborEngine(data, metric=m), eps)
    for kw in (dict(emit="mask"), dict(emit="slots", slot_cap=128),
               dict(emit="slots", slot_cap=128, batch_rows=32)):
        eng = NeighborEngine(data, metric=m, **kw)
        counts, csr = eng.materialize(eps)
        np.testing.assert_array_equal(counts, ref_counts)
        np.testing.assert_array_equal(csr.indptr, ref_csr.indptr)
        np.testing.assert_array_equal(csr.indices, ref_csr.indices)
        np.testing.assert_array_equal(csr.dists, ref_csr.dists)
        np.testing.assert_array_equal(eng.counts_only(eps), ref_counts)


def test_fingerprint_distinguishes_metrics_and_params():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(40, 8)).astype(np.float32)
    fps = {name: dataset_fingerprint(x, name)
           for name in ALL_METRICS if name != "jaccard"}
    assert len(set(fps.values())) == len(fps)     # same bytes, distinct ids
    for name, fp in fps.items():
        assert fp == dataset_fingerprint(x, name)  # deterministic
        # head = metric spec (name + params when any) + shape + dtype
        assert fp.startswith(f"{get_metric(name).spec}:40x8:float32:")
    # params are part of the identity
    a = CallableMetric("chebyshev", _chebyshev, scale=1.0)
    b = CallableMetric("chebyshev", _chebyshev, scale=2.0)
    assert dataset_fingerprint(x, a) != dataset_fingerprint(x, b)


def test_registry_errors():
    with pytest.raises(ValueError, match="registered metrics"):
        get_metric("euclidaen")
    with pytest.raises(ValueError, match="already registered"):
        register_metric("euclidean", _chebyshev)
    with pytest.raises(TypeError):
        get_metric(get_metric("euclidean"), foo=1)


def test_metric_instances_pass_everywhere_strings_do():
    m, data = _dataset("cosine")
    assert dataset_fingerprint(data, m) == dataset_fingerprint(data, "cosine")
    a = FinexIndex.build(data, eps=0.4, minpts=5, metric=m)
    b = FinexIndex.build(data, eps=0.4, minpts=5, metric="cosine")
    np.testing.assert_array_equal(a.clustering(), b.clustering())
    assert a.metric == "cosine"
    assert isinstance(a.metric_obj, Metric)


def test_custom_metric_end_to_end(tmp_path):
    """register_metric → FinexIndex.build → eps*/minpts* → save/load →
    IndexStore.get_or_build warm hit — the full user workflow."""
    from repro.service import IndexStore

    _, data = _dataset("chebyshev", n=150)
    eps, minpts = 1.6, 6
    index = FinexIndex.build(data, eps=eps, minpts=minpts,
                             metric="chebyshev")
    assert index.metric == "chebyshev"
    lab_e = index.eps_star(1.1)
    lab_m = index.minpts_star(12)
    assert lab_e.shape == lab_m.shape == (150,)
    assert (lab_e >= -1).all() and lab_e.max() >= 0

    path = str(tmp_path / "chebyshev.npz")
    index.save(path)
    reloaded = FinexIndex.load(path, data=data)
    assert reloaded.metric == "chebyshev"
    np.testing.assert_array_equal(reloaded.eps_star(1.1), lab_e)
    np.testing.assert_array_equal(reloaded.minpts_star(12), lab_m)

    store = IndexStore(capacity=2)
    built, outcome = store.get_or_build(data, eps=eps, minpts=minpts,
                                        metric="chebyshev")
    assert outcome == "build"
    rows_before = built.engine.distance_rows_computed
    warm, outcome = store.get_or_build(data, eps=eps, minpts=minpts,
                                       metric="chebyshev")
    assert outcome == "hit" and warm is built
    assert warm.engine.distance_rows_computed == rows_before
    np.testing.assert_array_equal(warm.minpts_star(12), lab_m)
    # the same bytes under a different metric is a different index
    _, outcome = store.get_or_build(data, eps=eps, minpts=minpts,
                                    metric="euclidean")
    assert outcome == "build"
