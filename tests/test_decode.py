"""Decode-path consistency: incremental decode must reproduce the full
forward pass for every family (KV cache, SWA ring buffer, SSD state)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models.layers import attention_chunked, attention_full
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_params)

KEY = jax.random.PRNGKey(3)


def _roundtrip(cfg, T=24, tol=5e-3):
    rc = RunConfig(model=cfg, shape=ShapeConfig("t", T, 2, "decode"),
                   remat=False, dtype="float32", full_attn_max_seq=64)
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, T), 0, cfg.vocab)
    ref_logits = forward(params, toks, cfg, rc)
    cache = init_cache(cfg, 2, T, jnp.float32)
    errs = []
    for t in range(T):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1],
                                jnp.int32(t), cfg, rc)
        errs.append(float(np.abs(np.asarray(lg[:, 0])
                                 - np.asarray(ref_logits[:, t])).max()))
    assert max(errs) < tol, f"decode diverges: {max(errs)}"


def test_decode_matches_forward_dense_gqa():
    _roundtrip(ModelConfig("d", "dense", n_layers=2, d_model=64, n_heads=4,
                           n_kv_heads=2, d_ff=128, vocab=96, head_dim=16,
                           qkv_bias=True))


def test_decode_matches_forward_moe():
    _roundtrip(ModelConfig("m", "moe", n_layers=2, d_model=64, n_heads=4,
                           n_kv_heads=4, d_ff=128, vocab=96, head_dim=16,
                           n_experts=4, top_k=2, moe_dff=32, shared_dff=64,
                           capacity_factor=4.0))


def test_decode_matches_forward_ssm():
    _roundtrip(ModelConfig("s", "ssm", n_layers=2, d_model=64, n_heads=0,
                           n_kv_heads=0, d_ff=0, vocab=96, ssm_state=16,
                           ssm_headdim=16, ssm_chunk=8, tie_embeddings=True))


def test_decode_matches_forward_hybrid_swa_ring():
    _roundtrip(ModelConfig("h", "hybrid", n_layers=2, d_model=64, n_heads=4,
                           n_kv_heads=2, d_ff=128, vocab=96, head_dim=16,
                           ssm_state=8, ssm_headdim=16, ssm_chunk=8,
                           ssm_expand=1, swa_window=8))


@pytest.mark.parametrize("window,causal",
                         [(0, True), (16, True), (0, False)])
def test_chunked_attention_exact(window, causal):
    q = jax.random.normal(KEY, (2, 64, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(4), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(5), (2, 64, 2, 16))
    a = attention_full(q, k, v, causal=causal, window=window)
    b = attention_chunked(q, k, v, chunk=16, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_ssd_chunk_size_invariance():
    """The chunked SSD scan must be exact for any chunk size."""
    from repro.models.mamba2 import SSMParams, ssd_forward
    cfg = ModelConfig("s", "ssm", n_layers=1, d_model=32, n_heads=0,
                      n_kv_heads=0, d_ff=0, vocab=64, ssm_state=8,
                      ssm_headdim=16, ssm_chunk=8, tie_embeddings=True)
    params = init_params(KEY, cfg)
    pp = {k.split("/")[-1]: v[0] for k, v in params.items()
          if k.startswith("layers/s0/")}
    sp = SSMParams(**{f: pp[f] for f in SSMParams._fields})
    x = jax.random.normal(KEY, (2, 32, 32))
    import dataclasses
    outs = []
    for q in (4, 8, 16, 32):
        c2 = dataclasses.replace(cfg, ssm_chunk=q)
        outs.append(np.asarray(ssd_forward(x, sp, c2)))
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-4, atol=1e-4)
