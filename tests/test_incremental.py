"""Incremental maintenance property suite: insert/delete deltas must be
byte-identical to a fresh ``FinexIndex.build`` over the mutated dataset —
ordering quintuple, CSR, run decomposition and query results alike — for
every registered metric, through both the component-local delta path and
the (loud) full-resweep fallback."""

import warnings

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import FinexIndex
from repro.data.synthetic import gaussian_mixture, heavy_tail_sets
from repro.metrics import register_metric
from repro.neighbors.bitset import pack_sets

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _chebyshev(q, c):
    return jnp.max(jnp.abs(q[:, None, :] - c[None, :, :]), axis=-1)


try:
    register_metric("incr-cheb", _chebyshev)
except ValueError:
    pass  # already registered by a previous import of this module


def _vectors(n, seed):
    return gaussian_mixture(n, d=4, k=5, seed=seed), None


def _sets(n, seed):
    sets, w = heavy_tail_sets(n, seed=seed)
    return pack_sets(sets, universe=512), w


# (metric, dataset factory, eps, minpts) — euclidean, jaccard's packed
# bitmap tuple state, cosine, and a register_metric user distance
CASES = [
    ("euclidean", _vectors, 0.35, 8),
    ("jaccard", _sets, 0.4, 8),
    ("cosine", _vectors, 0.02, 6),
    ("incr-cheb", _vectors, 0.3, 6),
]
IDS = [c[0] for c in CASES]


def take_rows(data, sel):
    if isinstance(data, tuple):
        return tuple(a[sel] for a in data)
    return data[sel]


def n_rows(data):
    return (data[0] if isinstance(data, tuple) else data).shape[0]


def build(data, case, weights=None):
    metric, _, eps, minpts = case
    return FinexIndex.build(
        data, eps=eps, minpts=minpts, metric=metric, weights=weights
    )


def assert_identical(got, want, what=""):
    """Byte-for-byte equality of everything the index serves from."""
    a, b = got.ordering, want.ordering
    for f in ("order", "pos", "C", "R", "N", "F"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), (what, f)
    for f in ("indptr", "indices", "dists"):
        got_f, want_f = getattr(got.csr, f), getattr(want.csr, f)
        assert np.array_equal(got_f, want_f), (what, f)
    assert np.array_equal(got.weights, want.weights), (what, "weights")
    # the run decomposition is part of the contract: a stitched index
    # must keep taking the fast delta path exactly like a fresh build
    assert np.array_equal(got._run_id, want._run_id), (what, "run_id")
    triggers_equal = np.array_equal(got._run_triggers, want._run_triggers)
    assert triggers_equal, (what, "run_triggers")
    # component labels may be numbered differently — same partition
    # (lazy on fresh builds: _ensure_comp materializes them on demand)
    pair = {}
    got_comp, want_comp = got._ensure_comp(), want._ensure_comp()
    for la, lb in zip(got_comp.tolist(), want_comp.tolist()):
        assert pair.setdefault(la, lb) == lb, (what, "comp partition")
    assert len(set(pair.values())) == len(pair), (what, "comp injective")
    labels_equal = np.array_equal(got.clustering(), want.clustering())
    assert labels_equal, (what, "clustering")


@pytest.mark.parametrize("case", CASES, ids=IDS)
def test_insert_matches_fresh_build(case):
    """Randomized inserts (single and batched, with duplicate weights on
    the weighted dataset) pin byte-identical results vs a fresh build."""
    _, make, _, _ = case
    for seed, m in [(0, 1), (1, 7), (2, 25)]:
        data, w = make(220, seed)
        n = n_rows(data)
        m = min(m, n // 4)
        head, tail = np.arange(n) < n - m, np.arange(n) >= n - m
        idx = build(
            take_rows(data, head), case, weights=None if w is None else w[head]
        )
        rep = idx.insert(
            take_rows(data, tail), weights=None if w is None else w[tail]
        )
        assert rep["op"] == "insert" and rep["count"] == m
        assert idx.version == 1 and idx.delta_log == [rep]
        fresh = build(data, case, weights=w)
        assert_identical(idx, fresh, f"insert seed={seed} m={m}")


@pytest.mark.parametrize("case", CASES, ids=IDS)
def test_delete_matches_fresh_build(case):
    """Randomized deletes — including core points — pin byte-identical
    results (splits and all) vs a fresh build on the surviving rows."""
    _, make, _, _ = case
    for seed, m in [(3, 1), (4, 9), (5, 40)]:
        data, w = make(220, seed)
        n = n_rows(data)
        rng = np.random.default_rng(seed)
        ids = rng.choice(n, size=min(m, n // 3), replace=False)
        keep = np.ones(n, dtype=bool)
        keep[ids] = False
        idx = build(data, case, weights=w)
        cores_gone = np.isfinite(idx.ordering.C[ids]).sum()
        rep = idx.delete(ids)
        assert rep["op"] == "delete" and rep["count"] == ids.size
        fresh = build(
            take_rows(data, keep), case, weights=None if w is None else w[keep]
        )
        assert_identical(idx, fresh, f"delete seed={seed} cores={cores_gone}")


@pytest.mark.parametrize("case", CASES, ids=IDS)
def test_mutation_chain_matches_fresh_build(case):
    """insert -> delete -> insert chains stay exact and keep exact
    eps*/MinPts*-query behaviour at every step."""
    metric, make, eps, minpts = case
    data, w = make(240, seed=6)
    n = n_rows(data)
    cut = n - 12
    idx = build(
        take_rows(data, np.arange(n) < cut),
        case,
        weights=None if w is None else w[:cut],
    )
    idx.insert(
        take_rows(data, np.arange(n) >= cut),
        weights=None if w is None else w[cut:],
    )
    ids = np.arange(0, n, 31)
    keep = np.ones(n, dtype=bool)
    keep[ids] = False
    idx.delete(ids)
    fresh = build(
        take_rows(data, keep), case, weights=None if w is None else w[keep]
    )
    assert_identical(idx, fresh, "chain")
    assert idx.version == 2 and len(idx.delta_log) == 2
    assert np.array_equal(idx.eps_star(eps * 0.6), fresh.eps_star(eps * 0.6))
    assert np.array_equal(
        idx.minpts_star(minpts * 3), fresh.minpts_star(minpts * 3)
    )


def _bridge_dataset():
    """Two dense blobs joined only through one core bridge point."""
    rng = np.random.default_rng(9)
    a = rng.normal(scale=0.05, size=(40, 2)).astype(np.float32)
    b = (rng.normal(scale=0.05, size=(40, 2)) + [2.0, 0.0]).astype(np.float32)
    bridge = np.array([[0.5, 0.0], [1.0, 0.0], [1.5, 0.0]], np.float32)
    return np.concatenate([a, b, bridge])


def _n_clusters(labels):
    return int(labels.max()) + 1 if (labels >= 0).any() else 0


def test_delete_core_bridge_splits_and_insert_merges():
    """Deleting the core bridge splits the merged cluster in two; putting
    it back merges them again — both as exact deltas."""
    x = _bridge_dataset()
    n = x.shape[0]
    idx = FinexIndex.build(x, eps=0.6, minpts=3)
    assert _n_clusters(idx.clustering()) == 1
    bridge_ids = np.array([n - 3, n - 2, n - 1])
    assert np.isfinite(idx.ordering.C[bridge_ids]).all()

    idx.delete(bridge_ids)
    fresh = FinexIndex.build(x[: n - 3], eps=0.6, minpts=3)
    assert_identical(idx, fresh, "bridge delete")
    assert _n_clusters(idx.clustering()) == 2, "core deletion must split"

    rep = idx.insert(x[n - 3 :])
    assert rep["count"] == 3
    fresh = FinexIndex.build(x, eps=0.6, minpts=3)
    assert_identical(idx, fresh, "bridge insert")
    assert _n_clusters(idx.clustering()) == 1, "insert must re-merge"


def test_rebuild_fallback_is_loud_and_exact():
    """rebuild_threshold=0 forces the full-resweep fallback: a warning is
    raised and the result stays byte-identical."""
    x, _ = _vectors(200, seed=12)
    idx = FinexIndex.build(x[:195], eps=0.35, minpts=8)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        rep = idx.insert(x[195:], rebuild_threshold=0.0)
    assert rep["mode"] == "resweep"
    assert any("re-sweep" in str(w.message) for w in caught)
    fresh = FinexIndex.build(x, eps=0.35, minpts=8)
    assert_identical(idx, fresh, "forced fallback")


def test_legacy_archive_without_run_metadata_falls_back(tmp_path):
    """Archives that predate incremental maintenance still mutate exactly
    through the (loud) resweep fallback, which regenerates the metadata."""
    x, _ = _vectors(150, seed=13)
    idx = FinexIndex.build(x, eps=0.35, minpts=8)
    arrs = idx.to_arrays()
    for k in ("comp", "run_id", "run_triggers", "version", "delta_log"):
        arrs.pop(k, None)
    legacy = FinexIndex.from_arrays(arrs, data=x)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        rep = legacy.delete(np.array([3]))
    assert rep["mode"] == "resweep"
    assert any("run metadata" in str(w.message) for w in caught)
    fresh = FinexIndex.build(np.delete(x, [3], axis=0), eps=0.35, minpts=8)
    assert_identical(legacy, fresh, "legacy")
    # the fallback regenerated run metadata: next mutation is a delta
    rep = legacy.delete(np.array([7]))
    assert rep["mode"] == "delta"


def test_npz_roundtrip_carries_delta_log(tmp_path):
    x, _ = _vectors(150, seed=14)
    idx = FinexIndex.build(x[:145], eps=0.35, minpts=8)
    idx.insert(x[145:])
    path = str(tmp_path / "idx.npz")
    idx.save(path)
    back = FinexIndex.load(path, data=x)
    assert back.version == 1
    assert back.delta_log == idx.delta_log
    assert back.stats()["version"] == 1 and back.stats()["mutations"] == 1
    # and the reloaded index keeps mutating on the fast path, exactly
    rep = back.delete(np.array([0]))
    idx.delete(np.array([0]))
    assert rep["mode"] == idx.delta_log[-1]["mode"]
    assert_identical(back, idx, "post-roundtrip mutation")


def test_mutation_validation_errors():
    x, _ = _vectors(120, seed=15)
    idx = FinexIndex.build(x, eps=0.35, minpts=8)
    assert idx.insert(x[:0])["mode"] == "noop"
    assert idx.delete(np.array([], dtype=np.int64))["mode"] == "noop"
    assert idx.version == 0 and idx.delta_log == []
    with pytest.raises(IndexError, match="out of range|must lie"):
        idx.delete(np.array([120]))
    with pytest.raises(ValueError, match="every object"):
        idx.delete(np.arange(120))
    lean = FinexIndex.from_arrays(idx.to_arrays())  # engine-less
    with pytest.raises(RuntimeError, match="distance engine"):
        lean.insert(x[:1])
    with pytest.raises(RuntimeError, match="distance engine"):
        lean.delete(np.array([0]))


def test_store_rekey_after_mutation(tmp_path):
    """A mutated resident index must be invalidated/re-keyed so sweeps
    and lookups stay exact for both the old and the new dataset."""
    from repro.service import IndexStore, SweepPlanner

    x, _ = _vectors(160, seed=16)
    store = IndexStore(capacity=4)
    idx, outcome = store.get_or_build(x[:155], eps=0.35, minpts=8)
    assert outcome == "build"
    idx.insert(x[155:])
    key = store.rekey(idx)
    assert store.stats()["rekeys"] == 1
    # new identity: presenting the mutated dataset is a warm hit ...
    hit, outcome = store.get_or_build(x, eps=0.35, minpts=8)
    assert outcome == "hit" and hit is idx
    assert key.fingerprint == idx.fingerprint()
    # ... and the old dataset no longer maps to the mutated index
    old, outcome = store.get_or_build(x[:155], eps=0.35, minpts=8)
    assert outcome == "build" and old is not idx
    # planner sweeps over the re-keyed index stay byte-exact
    grid = [("eps", 0.2), ("minpts", 16)]
    rows = SweepPlanner(idx).sweep(grid)
    assert np.array_equal(rows[0], idx.eps_star(0.2))
    assert np.array_equal(rows[1], idx.minpts_star(16))


def test_store_never_spills_mutated_index_under_stale_key(tmp_path):
    """Evicting a mutated-but-not-rekeyed index must NOT write the
    post-mutation state under the pre-mutation key: the original
    dataset's key would reload-fail forever instead of rebuilding."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.service import IndexStore

    x, _ = _vectors(160, seed=17)
    y, _ = _vectors(120, seed=18)
    store = IndexStore(capacity=1, manager=CheckpointManager(str(tmp_path)))
    idx, _ = store.get_or_build(x[:155], eps=0.35, minpts=8)
    idx.insert(x[155:])  # mutated in place, rekey() not called yet
    store.get_or_build(y, eps=0.35, minpts=8)  # evicts the mutated idx
    assert store.stats()["drops"] == 1 and store.stats()["spills"] == 0
    # the original dataset's key must rebuild cleanly, not reload-fail
    again, outcome = store.get_or_build(x[:155], eps=0.35, minpts=8)
    assert outcome == "build"
    fresh = FinexIndex.build(x[:155], eps=0.35, minpts=8)
    assert np.array_equal(again.clustering(), fresh.clustering())
    # the caller still holds the mutated object: rekey admits it back
    store.rekey(idx)
    hit, outcome = store.get_or_build(x, eps=0.35, minpts=8)
    assert outcome == "hit" and hit is idx


def test_nonpositive_duplicate_weights_rejected():
    """Weights are duplicate multiplicities — a 0 would silently skew
    counts, core distances and the delete-repair bookkeeping."""
    x, _ = _vectors(40, seed=19)
    w = np.ones(40, dtype=np.int64)
    w[3] = 0
    with pytest.raises(ValueError, match="weights must be >= 1"):
        FinexIndex.build(x, eps=0.35, minpts=8, weights=w)
    idx = FinexIndex.build(x[:38], eps=0.35, minpts=8)
    with pytest.raises(ValueError, match="weights must be >= 1"):
        idx.insert(x[38:], weights=np.zeros(2, dtype=np.int64))


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(st.data())
    def test_hypothesis_mutations_match_fresh_build(data_strategy):
        """Property form (runs where hypothesis is installed): any small
        insert/delete against a fixed base dataset equals a fresh build."""
        x, _ = _vectors(140, seed=42)
        n = x.shape[0]
        cut = data_strategy.draw(st.integers(min_value=n - 8, max_value=n - 1))
        idx = FinexIndex.build(x[:cut], eps=0.35, minpts=8)
        idx.insert(x[cut:])
        drop = data_strategy.draw(
            st.lists(
                st.integers(min_value=0, max_value=n - 1),
                min_size=1,
                max_size=6,
                unique=True,
            )
        )
        keep = np.ones(n, dtype=bool)
        keep[drop] = False
        if not keep.any():
            return
        idx.delete(np.asarray(drop))
        fresh = FinexIndex.build(x[keep], eps=0.35, minpts=8)
        assert_identical(idx, fresh, "hypothesis")
