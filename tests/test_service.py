"""Serving subsystem: batched sweeps pinned byte-identical to scalar
queries (property-style, across euclidean / jaccard / weighted datasets,
including the degenerate K=1 and ε*=ε / MinPts*=MinPts sweeps), plus
``IndexStore`` residency/spill semantics and the ``ClusterService``
slot-batched request loop."""
import numpy as np
import pytest

from repro.core import (FinexIndex, query_clustering,
                        query_clustering_batch)
from repro.core.reference import reference_sweep_labels
from repro.data.synthetic import gaussian_mixture, heavy_tail_sets
from repro.neighbors.bitset import pack_sets
from repro.neighbors.engine import NeighborEngine
from repro.service import (BuildRequest, ClusterRequest, ClusterService,
                           IndexStore, StatsRequest, SweepPlanner,
                           SweepRequest)


def _euclidean(seed):
    x = gaussian_mixture(400, d=4, k=5, seed=seed)
    return NeighborEngine(x, metric="euclidean"), 0.35, 8


def _jaccard(seed):
    sets, w = heavy_tail_sets(500, seed=seed)
    bits, sizes = pack_sets(sets)
    return NeighborEngine((bits, sizes), metric="jaccard", weights=w), 0.4, 16


def _weighted(seed):
    rng = np.random.default_rng(seed)
    x = gaussian_mixture(300, d=3, k=4, seed=seed)
    w = rng.integers(1, 6, size=x.shape[0]).astype(np.int64)
    return NeighborEngine(x, metric="euclidean", weights=w), 0.4, 12


CASES = {"euclidean": _euclidean, "jaccard": _jaccard, "weighted": _weighted}


@pytest.fixture(params=sorted(CASES), scope="module")
def built(request):
    engine, eps, minpts = CASES[request.param](seed=3)
    return FinexIndex.from_engine(engine, eps, minpts)


def _random_settings(rng, eps, minpts, k):
    out = []
    for _ in range(k):
        if rng.random() < 0.5:
            out.append(("eps", float(eps * rng.uniform(0.05, 1.0))))
        else:
            out.append(("minpts", int(rng.integers(minpts, minpts * 20))))
    return out


# ------------------------------------------------------- batched kernels
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sweep_property_identical_to_scalar_queries(built, seed):
    """Property: every row of a random mixed sweep — always including the
    degenerate ε*=ε and MinPts*=MinPts settings — is byte-identical to
    the corresponding scalar facade call."""
    rng = np.random.default_rng(seed)
    settings = _random_settings(rng, built.eps, built.minpts,
                                int(rng.integers(1, 9)))
    settings += [("eps", built.eps), ("minpts", built.minpts)]
    got = SweepPlanner(built).sweep(settings)
    assert got.shape == (len(settings), built.n)
    for (kind, v), row in zip(settings, got):
        want = built.eps_star(v) if kind == "eps" else built.minpts_star(v)
        np.testing.assert_array_equal(
            row, want, err_msg=f"sweep row diverged at {kind}*={v}")


def test_sweep_k1_degenerate(built):
    for setting in [("eps", built.eps), ("eps", built.eps * 0.4),
                    ("minpts", built.minpts), ("minpts", built.minpts * 5)]:
        got = SweepPlanner(built).sweep([setting])
        assert got.shape == (1, built.n)
        kind, v = setting
        want = built.eps_star(v) if kind == "eps" else built.minpts_star(v)
        np.testing.assert_array_equal(got[0], want)


def test_sweep_matches_loop_reference(built):
    """Tie the batched kernels to the seed-era loop implementations."""
    settings = [("eps", built.eps * 0.5), ("minpts", built.minpts * 3),
                ("eps", built.eps), ("minpts", built.minpts)]
    got = SweepPlanner(built).sweep(settings)
    ref = reference_sweep_labels(built.ordering, built.engine, built.csr,
                                 settings)
    np.testing.assert_array_equal(got, ref)


def test_query_clustering_batch_identical(built):
    es = [built.eps, built.eps * 0.7, built.eps * 0.33, built.eps * 0.05]
    batch = query_clustering_batch(built.ordering, es)
    for e, row in zip(es, batch):
        np.testing.assert_array_equal(row,
                                      query_clustering(built.ordering, e))


def test_sweep_validates_settings(built):
    with pytest.raises(ValueError, match="unknown sweep setting"):
        SweepPlanner(built).sweep([("epsilon", 0.2)])
    with pytest.raises(ValueError, match="MinPts"):
        SweepPlanner(built).sweep([("minpts", built.minpts - 1)])
    with pytest.raises(ValueError, match="exceeds generating"):
        SweepPlanner(built).sweep([("eps", built.eps * 2)])


def test_sweep_without_engine_needs_no_distances(tmp_path, built):
    """A lean-loaded index (no engine) sweeps MinPts* settings fine and
    refuses ε* settings with a clear error."""
    p = str(tmp_path / "idx.npz")
    built.save(p)
    lean = FinexIndex.load(p)
    settings = [("minpts", built.minpts), ("minpts", built.minpts * 4)]
    np.testing.assert_array_equal(SweepPlanner(lean).sweep(settings),
                                  SweepPlanner(built).sweep(settings))
    with pytest.raises(RuntimeError, match="distance engine"):
        SweepPlanner(lean).sweep([("eps", built.eps * 0.5)])


# ------------------------------------------------------------ IndexStore
def test_store_warm_hit_zero_distances():
    x = gaussian_mixture(300, d=3, k=3, seed=0)
    store = IndexStore(capacity=2)
    idx1, out1 = store.get_or_build(x, 0.4, 8)
    assert out1 == "build"
    idx2, out2 = store.get_or_build(x, 0.4, 8)
    assert out2 == "hit" and idx2 is idx1
    rows = idx2.engine.distance_rows_computed
    labels = idx2.clustering()
    assert idx2.engine.distance_rows_computed == rows   # zero distances
    np.testing.assert_array_equal(labels, idx1.clustering())
    assert store.stats()["hits"] == 1


def test_store_distinct_params_are_distinct_entries():
    x = gaussian_mixture(300, d=3, k=3, seed=0)
    store = IndexStore(capacity=4)
    a, _ = store.get_or_build(x, 0.4, 8)
    b, out = store.get_or_build(x, 0.4, 12)
    assert out == "build" and b is not a
    c, out = store.get_or_build(x, 0.3, 8)
    assert out == "build" and c is not a
    assert store.stats()["builds"] == 3


def test_store_distinct_weights_are_distinct_entries(tmp_path):
    """Duplicate weights change every neighborhood count, so they are part
    of the dataset identity — same points with different weights must not
    collide in the cache (and a weighted index survives spill/reload)."""
    from repro.checkpoint.manager import CheckpointManager
    x = gaussian_mixture(250, d=3, k=3, seed=4)
    w = np.random.default_rng(4).integers(1, 5, size=x.shape[0])
    store = IndexStore(capacity=1, manager=CheckpointManager(
        str(tmp_path / "cache")))
    plain, _ = store.get_or_build(x, 0.4, 8)
    weighted, out = store.get_or_build(x, 0.4, 8, weights=w)
    assert out == "build" and weighted is not plain
    want = weighted.minpts_star(20)
    # unit weights passed explicitly hash like no weights at all
    _, out = store.get_or_build(x, 0.4, 8, weights=np.ones(x.shape[0]))
    assert out == "reload"                       # the plain index, spilled
    back, out = store.get_or_build(x, 0.4, 8, weights=w)
    assert out == "reload"
    np.testing.assert_array_equal(back.minpts_star(20), want)


def test_store_lru_spill_and_reload(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    x1 = gaussian_mixture(300, d=3, k=3, seed=1)
    x2 = gaussian_mixture(250, d=3, k=3, seed=2)
    store = IndexStore(capacity=1, manager=CheckpointManager(
        str(tmp_path / "cache")))
    i1, _ = store.get_or_build(x1, 0.4, 8)
    want = i1.clustering()
    want_eps = i1.eps_star(0.25)
    store.get_or_build(x2, 0.4, 8)               # evicts x1 -> disk spill
    assert store.stats()["spills"] == 1
    i1b, out = store.get_or_build(x1, 0.4, 8)
    assert out == "reload"                        # npz read, not a rebuild
    assert store.stats()["builds"] == 2
    np.testing.assert_array_equal(i1b.clustering(), want)
    # the store re-attached the engine from its data registry: ε*-queries
    # work on the reloaded index
    np.testing.assert_array_equal(i1b.eps_star(0.25), want_eps)


def test_store_eviction_without_manager_drops():
    x1 = gaussian_mixture(250, d=3, k=3, seed=1)
    x2 = gaussian_mixture(200, d=3, k=3, seed=2)
    store = IndexStore(capacity=1)               # no spill target
    store.get_or_build(x1, 0.4, 8)
    store.get_or_build(x2, 0.4, 8)
    assert store.stats()["drops"] == 1
    _, out = store.get_or_build(x1, 0.4, 8)      # dropped -> rebuild
    assert out == "build"


# -------------------------------------------------------- ClusterService
def test_service_mixed_requests_and_coalescing():
    x = gaussian_mixture(300, d=3, k=3, seed=0)
    svc = ClusterService(store=IndexStore(capacity=2), slots=8)
    reqs = [
        BuildRequest(data=x, eps=0.4, minpts=8),
        SweepRequest(data=x, eps=0.4, minpts=8,
                     settings=[("eps", 0.3), ("minpts", 16)]),
        ClusterRequest(data=x, eps=0.4, minpts=8, setting=("eps", 0.25)),
        ClusterRequest(data=x, eps=0.4, minpts=8),      # generating pair
        StatsRequest(),
    ]
    svc.run(reqs)
    assert all(r.done for r in reqs)
    assert reqs[0].outcome == "build"
    index, _ = svc.store.get_or_build(x, 0.4, 8)
    np.testing.assert_array_equal(reqs[1].labels[0], index.eps_star(0.3))
    np.testing.assert_array_equal(reqs[1].labels[1], index.minpts_star(16))
    np.testing.assert_array_equal(reqs[2].labels, index.eps_star(0.25))
    np.testing.assert_array_equal(reqs[3].labels, index.clustering())
    # the three query requests coalesced into ONE planner batch
    assert svc.batched_sweeps == 1
    assert svc.store.stats()["builds"] == 1
    st = reqs[4].result
    assert st["settings_answered"] == 4 and st["store"]["builds"] == 1


def test_service_multiple_windows_stay_warm():
    x = gaussian_mixture(300, d=3, k=3, seed=0)
    svc = ClusterService(store=IndexStore(capacity=2), slots=2)
    reqs = [ClusterRequest(data=x, eps=0.4, minpts=8,
                           setting=("minpts", 8 * (1 + i % 4)))
            for i in range(6)]
    svc.run(reqs)
    assert all(r.done for r in reqs)
    # 3 slot windows, one index build, everything after is warm
    assert svc.store.stats()["builds"] == 1
    assert svc.batched_sweeps == 3
    index, _ = svc.store.get_or_build(x, 0.4, 8)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(r.labels,
                                      index.minpts_star(8 * (1 + i % 4)))
