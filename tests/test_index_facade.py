"""FinexIndex facade: parity with the functional layer, persistence, and
checkpoint-manager integration."""
import numpy as np
import pytest

from repro.core import (FinexIndex, eps_star_query, finex_build,
                        minpts_star_query)
from repro.data.synthetic import gaussian_mixture
from repro.neighbors.engine import NeighborEngine

EPS, MINPTS = 0.4, 8


@pytest.fixture(scope="module")
def built():
    x = gaussian_mixture(400, d=4, k=4, seed=13)
    return x, FinexIndex.build(x, eps=EPS, minpts=MINPTS)


def test_facade_matches_functional_layer(built):
    x, index = built
    engine = NeighborEngine(x, metric="euclidean")
    ordering, csr = finex_build(engine, EPS, MINPTS)
    np.testing.assert_array_equal(index.ordering.order, ordering.order)
    np.testing.assert_array_equal(index.eps_star(0.22),
                                  eps_star_query(ordering, engine, 0.22))
    np.testing.assert_array_equal(index.minpts_star(30),
                                  minpts_star_query(ordering, csr, 30))


def test_facade_stats(built):
    _, index = built
    st = index.stats()
    assert st["n"] == index.n and st["eps"] == EPS
    assert 0 < st["cores"] <= st["n"]
    assert st["csr_nnz"] == index.csr.nnz
    index.eps_star(0.2)
    assert index.stats()["query_verification_pairs"] >= 0


def test_save_load_roundtrip(tmp_path, built):
    x, index = built
    p = str(tmp_path / "index.npz")
    index.save(p)
    # without data: MinPts*-queries and the linear scan still work ...
    lean = FinexIndex.load(p)
    np.testing.assert_array_equal(lean.clustering(), index.clustering())
    np.testing.assert_array_equal(lean.minpts_star(25), index.minpts_star(25))
    # ... ε*-queries need the engine back
    with pytest.raises(RuntimeError):
        lean.eps_star(0.2)
    full = FinexIndex.load(p, data=x)
    np.testing.assert_array_equal(full.eps_star(0.2), index.eps_star(0.2))
    # attaching the wrong dataset is caught at load, not at query time
    with pytest.raises(ValueError, match="re-attach the exact dataset"):
        FinexIndex.load(p, data=x[:100])


def test_lean_resave_preserves_weights(tmp_path):
    """A weighted index saved, lean-loaded (no engine) and saved again
    must keep its duplicate weights — not silently reset them to ones."""
    rng = np.random.default_rng(5)
    x = gaussian_mixture(200, d=3, k=3, seed=5)
    w = rng.integers(1, 5, size=x.shape[0]).astype(np.int64)
    index = FinexIndex.build(x, eps=0.4, minpts=8, weights=w)
    p1, p2 = str(tmp_path / "a.npz"), str(tmp_path / "b.npz")
    index.save(p1)
    lean = FinexIndex.load(p1)           # no engine attached
    lean.save(p2)
    back = FinexIndex.load(p2, data=x)
    np.testing.assert_array_equal(back.weights, w)
    np.testing.assert_array_equal(back.engine.weights, w)
    np.testing.assert_array_equal(back.minpts_star(20), index.minpts_star(20))


def test_from_arrays_missing_keys_named(built):
    """A truncated/foreign npz must fail up front with the missing array
    names — not as a bare KeyError deep in reconstruction."""
    _, index = built
    arrs = index.to_arrays()
    arrs.pop("csr_indices")
    arrs.pop("N")
    with pytest.raises(ValueError) as ei:
        FinexIndex.from_arrays(arrs)
    assert "csr_indices" in str(ei.value) and "'N'" in str(ei.value)
    with pytest.raises(ValueError, match="missing required arrays"):
        FinexIndex.from_arrays({})


def test_fingerprint_roundtrip_and_mismatch(tmp_path, built):
    """The dataset fingerprint (shape + dtype + content hash) travels with
    the index; load(data=...) refuses a different dataset instead of
    silently attaching the wrong engine."""
    from repro.neighbors.engine import dataset_fingerprint
    x, index = built
    assert index.fingerprint() == dataset_fingerprint(x, "euclidean")
    p = str(tmp_path / "fp.npz")
    index.save(p)
    # lean load keeps the stored fingerprint; matching data re-attaches
    assert FinexIndex.load(p).fingerprint() == index.fingerprint()
    assert FinexIndex.load(p, data=x).fingerprint() == index.fingerprint()
    # same shape, different content -> error by default, warn on request
    y = np.array(x)
    y[0, 0] += 1.0
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        FinexIndex.load(p, data=y)
    with pytest.warns(UserWarning, match="fingerprint mismatch"):
        FinexIndex.load(p, data=y, fingerprint_mismatch="warn")
    # archives written before fingerprinting still load against any data
    arrs = index.to_arrays()
    del arrs["fingerprint"]
    old = FinexIndex.from_arrays(arrs, data=y)
    assert old.fingerprint() is not None      # recomputed from the engine


def test_save_index_step_collision_raises(tmp_path, built):
    """save_index on a step that already holds train state must raise —
    not silently drop the index (save() skips existing steps)."""
    from repro.checkpoint.manager import CheckpointManager
    _, index = built
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=3)
    mgr.save(3, {"w": np.zeros(4)})
    with pytest.raises(ValueError, match="non-index checkpoint"):
        mgr.save_index(3, index)
    with pytest.raises(ValueError, match="does not hold a FINEX index"):
        mgr.restore_index(3)
    mgr.save_index(4, index)                 # distinct step: fine
    mgr.save_index(4, index)                 # idempotent re-save: fine
    assert mgr.restore_index(4).eps == index.eps
    # a *different* index at the same step must not be silently dropped
    x2 = gaussian_mixture(100, d=3, k=2, seed=1)
    other = FinexIndex.build(x2, eps=0.2, minpts=5)
    with pytest.raises(ValueError, match="different FINEX index"):
        mgr.save_index(4, other)


def test_index_snapshots_survive_keep_n_gc(tmp_path, built):
    """keep-N rotation applies to the train-state stream, not to index
    snapshots — an old index must survive newer training checkpoints."""
    from repro.checkpoint.manager import CheckpointManager
    _, index = built
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=2)
    mgr.save_index(1, index)
    for s in (10, 20, 30, 40):
        mgr.save(s, {"w": np.zeros(3)})
    assert 1 in mgr.all_steps()              # index snapshot kept
    assert mgr.restore_index(1).eps == index.eps
    # the training stream itself still rotates to keep=2
    train_steps = [s for s in mgr.all_steps() if s != 1]
    assert train_steps == [30, 40]


def test_checkpoint_manager_roundtrip(tmp_path, built):
    from repro.checkpoint.manager import CheckpointManager
    x, index = built
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=2)
    mgr.save_index(7, index)
    assert 7 in mgr.all_steps()
    # index snapshots must not hijack the training auto-resume anchor
    assert mgr.latest_step() is None
    mgr.save(2, {"w": np.zeros(3)})
    assert mgr.latest_step() == 2
    back = mgr.restore_index(7, data=x)
    assert back.eps == index.eps and back.minpts == index.minpts
    np.testing.assert_array_equal(back.ordering.order, index.ordering.order)
    np.testing.assert_array_equal(back.eps_star(0.25), index.eps_star(0.25))
    np.testing.assert_array_equal(back.minpts_star(20),
                                  index.minpts_star(20))
