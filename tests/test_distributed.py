"""Multi-device tests, each in a subprocess with its own XLA_FLAGS
(the main session must keep exactly 1 device)."""
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=900)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr[-4000:]}"
    return p.stdout


def test_sharded_train_step_matches_single_device():
    """FSDP+TP train step on a 2x4 mesh must reproduce the single-device
    step bit-for-bit (up to float tolerance)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import RunConfig, ShapeConfig, get_arch
        from repro.data.tokens import TokenStream
        from repro.launch.mesh import make_host_mesh
        from repro.sharding import param_shardings, batch_spec
        from repro.models.transformer import param_shapes
        from repro.train.step import init_state, make_train_step
        from repro.train.optimizer import AdamWState

        cfg = get_arch('stablelm-1.6b').reduced(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
            vocab=256, head_dim=16)
        shape = ShapeConfig('t', 32, 4, 'train')
        batch = {k: jnp.asarray(v)
                 for k, v in TokenStream(cfg, 32, 4).batch_at(0).items()}

        # single device reference
        rc0 = RunConfig(model=cfg, shape=shape, remat=False, dtype='float32')
        ref_fn = jax.jit(make_train_step(cfg, rc0, lr_fn=lambda s: 1e-3,
                                         n_micro=2))
        state = init_state(jax.random.PRNGKey(0), cfg)
        ref_state, ref_m = ref_fn(state, batch)

        # sharded on a (2 data, 4 model) mesh
        mesh = make_host_mesh(2, 4)
        rc = RunConfig(model=cfg, shape=shape, remat=False, dtype='float32')
        ps = param_shardings(param_shapes(cfg, jnp.float32), mesh)
        state_sh = type(state)(params=ps, opt=AdamWState(
            step=NamedSharding(mesh, P()), m=dict(ps), v=dict(ps)))
        batch_sh = {k: NamedSharding(mesh, P(('data',), None))
                    for k in batch}
        with mesh:
            fn = jax.jit(make_train_step(cfg, rc, mesh, lr_fn=lambda s: 1e-3,
                                         n_micro=2),
                         in_shardings=(state_sh, batch_sh))
            sh_state, sh_m = fn(state, batch)
        assert abs(float(ref_m['loss']) - float(sh_m['loss'])) < 1e-4, \
            (float(ref_m['loss']), float(sh_m['loss']))
        for a, b in zip(jax.tree.leaves(ref_state.params),
                        jax.tree.leaves(sh_state.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=5e-5)
        print('SHARDED_OK', float(sh_m['loss']))
    """)
    assert "SHARDED_OK" in out


def test_distributed_neighbor_stats_match_local():
    """shard_map neighborhood sweep == local engine counts/histograms."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.kernels import ref
        from repro.neighbors.distributed import sharded_neighbor_stats
        from repro.launch.mesh import make_host_mesh

        rng = np.random.default_rng(0)
        n, d = 512, 8
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        w = jnp.asarray(rng.integers(1, 4, size=n), jnp.float32)
        eps = jnp.float32(1.5)
        edges = jnp.linspace(0.0, 8.0, 17)

        mesh = make_host_mesh(2, 4)
        cnt, hist = sharded_neighbor_stats(x, x, w, eps, edges, mesh,
                                           row_chunk=64)
        d_full = np.asarray(ref.pairwise_euclidean(x, x))
        cnt_ref = np.where(d_full <= 1.5, np.asarray(w)[None, :], 0).sum(-1)
        hist_ref = np.asarray(ref.tile_histogram(jnp.asarray(d_full), edges))
        np.testing.assert_allclose(np.asarray(cnt), cnt_ref, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(hist), hist_ref)
        print('DIST_NEIGHBORS_OK')
    """)
    assert "DIST_NEIGHBORS_OK" in out


def test_sharded_csr_emit_byte_identical():
    """The sharded ε-compacted CSR-emit must reproduce the single-device
    engine's CSR byte-for-byte (divisible and padded row/corpus extents),
    feed FinexIndex.build(mesh=...), and refuse to truncate on overflow."""
    out = _run("""
        import jax, numpy as np
        from repro.neighbors.distributed import sharded_csr_materialize
        from repro.neighbors.engine import NeighborEngine
        from repro.launch.mesh import make_host_mesh
        from repro.core import FinexIndex

        rng = np.random.default_rng(0)
        mesh = make_host_mesh(2, 4)
        for n in (512, 500):           # 500 exercises row/corpus padding
            x = rng.normal(size=(n, 8)).astype(np.float32)
            csr = sharded_csr_materialize(x, 1.5, mesh, cap=256,
                                          row_chunk=64)
            _, csr_ref = NeighborEngine(x).materialize(1.5)
            np.testing.assert_array_equal(csr.indptr, csr_ref.indptr)
            np.testing.assert_array_equal(csr.indices, csr_ref.indices)
            np.testing.assert_array_equal(csr.dists, csr_ref.dists)

        x = rng.normal(size=(500, 8)).astype(np.float32)
        idx_m = FinexIndex.build(x, eps=1.5, minpts=8, mesh=mesh,
                                 shard_cap=256, shard_row_chunk=64)
        idx_s = FinexIndex.build(x, eps=1.5, minpts=8)
        np.testing.assert_array_equal(idx_m.ordering.order,
                                      idx_s.ordering.order)
        np.testing.assert_array_equal(idx_m.ordering.R, idx_s.ordering.R)
        np.testing.assert_array_equal(idx_m.clustering(), idx_s.clustering())

        try:
            sharded_csr_materialize(x, 10.0, mesh, cap=64, row_chunk=64)
            raise SystemExit('overflow was not refused')
        except ValueError:
            pass
        print('CSR_EMIT_OK')
    """)
    assert "CSR_EMIT_OK" in out


def test_sharded_csr_emit_jaccard_and_registry_metrics():
    """The metric-oblivious sharded CSR-emit (ROADMAP open item): jaccard
    set data — and a non-euclidean vector metric straight from the
    registry — must reproduce the single-device engine's CSR byte for
    byte on the 2x4 host mesh, divisible and non-divisible n alike, and
    feed FinexIndex.build(mesh=...)."""
    out = _run("""
        import numpy as np
        from repro.neighbors.distributed import sharded_csr_materialize
        from repro.neighbors.engine import NeighborEngine
        from repro.neighbors.bitset import pack_sets
        from repro.launch.mesh import make_host_mesh
        from repro.core import FinexIndex

        rng = np.random.default_rng(0)
        mesh = make_host_mesh(2, 4)

        for n in (512, 500):           # 500 exercises row/corpus padding
            sets = [rng.choice(96, size=rng.integers(1, 14), replace=False)
                    for _ in range(n)]
            data = pack_sets(sets, universe=96)
            csr = sharded_csr_materialize(data, 0.6, mesh, cap=256,
                                          row_chunk=64, metric='jaccard')
            _, csr_ref = NeighborEngine(data, metric='jaccard') \\
                .materialize(0.6)
            np.testing.assert_array_equal(csr.indptr, csr_ref.indptr)
            np.testing.assert_array_equal(csr.indices, csr_ref.indices)
            np.testing.assert_array_equal(csr.dists, csr_ref.dists)

            x = rng.normal(size=(n, 8)).astype(np.float32)
            csr = sharded_csr_materialize(x, 0.25, mesh, cap=256,
                                          row_chunk=64, metric='cosine')
            _, csr_ref = NeighborEngine(x, metric='cosine').materialize(0.25)
            np.testing.assert_array_equal(csr.indptr, csr_ref.indptr)
            np.testing.assert_array_equal(csr.indices, csr_ref.indices)
            np.testing.assert_array_equal(csr.dists, csr_ref.dists)

        sets = [rng.choice(96, size=rng.integers(1, 14), replace=False)
                for _ in range(500)]
        data = pack_sets(sets, universe=96)
        idx_m = FinexIndex.build(data, eps=0.6, minpts=4, metric='jaccard',
                                 mesh=mesh, shard_cap=256,
                                 shard_row_chunk=64)
        idx_s = FinexIndex.build(data, eps=0.6, minpts=4, metric='jaccard')
        np.testing.assert_array_equal(idx_m.ordering.order,
                                      idx_s.ordering.order)
        np.testing.assert_array_equal(idx_m.ordering.R, idx_s.ordering.R)
        np.testing.assert_array_equal(idx_m.clustering(), idx_s.clustering())
        print('JACCARD_CSR_EMIT_OK')
    """)
    assert "JACCARD_CSR_EMIT_OK" in out


def test_finex_csr_dryrun_cell_compiles():
    """The finex-csr dry-run cell lowers + compiles on a host mesh."""
    out = _run("""
        import jax
        from repro.neighbors.distributed import finex_csr_dryrun_lowerable
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(2, 4)
        fn, args, shardings = finex_csr_dryrun_lowerable(
            mesh, n=1024, d=16, cap=128, row_chunk=64)
        with mesh:
            jax.jit(fn, in_shardings=shardings).lower(*args).compile()
        print('CSR_DRYRUN_OK')
    """)
    assert "CSR_DRYRUN_OK" in out


def test_sharded_decode_matches_single_device():
    """Flash-decode (seq-sharded cache) == single-device decode."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import RunConfig, ShapeConfig, get_arch
        from repro.launch.mesh import make_host_mesh
        from repro.sharding import param_shardings
        from repro.models.transformer import (param_shapes, init_params,
                                              init_cache, decode_step,
                                              cache_specs)

        cfg = get_arch('qwen2-72b').reduced(n_layers=2, d_model=64,
                                            n_heads=8, n_kv_heads=4,
                                            d_ff=128, vocab=256, head_dim=16)
        rc = RunConfig(model=cfg, shape=ShapeConfig('d', 32, 4, 'decode'),
                       remat=False, dtype='float32')
        params = init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 1), 0, 256)
        cache = init_cache(cfg, 4, 32, jnp.float32)

        ref_logits, _ = decode_step(params, cache, toks, jnp.int32(0),
                                    cfg, rc)

        mesh = make_host_mesh(2, 4)
        ps = param_shardings(param_shapes(cfg, jnp.float32), mesh)
        cs = {k: NamedSharding(mesh, spec)
              for k, spec in cache_specs(cfg, mesh).items()}
        with mesh:
            fn = jax.jit(lambda p, c, t, s: decode_step(p, c, t, s, cfg, rc,
                                                        mesh),
                         in_shardings=(ps, cs,
                                       NamedSharding(mesh, P(('data',), None)),
                                       NamedSharding(mesh, P())))
            sh_logits, _ = fn(params, cache, toks, jnp.int32(0))
        np.testing.assert_allclose(np.asarray(ref_logits),
                                   np.asarray(sh_logits),
                                   rtol=2e-4, atol=2e-4)
        print('DECODE_SHARDED_OK')
    """)
    assert "DECODE_SHARDED_OK" in out


def test_dryrun_entrypoint_single_cell():
    """The dry-run driver itself (512 host devices) on the smallest cell."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-130m",
         "--shape", "decode_32k", "--mesh", "single", "--force",
         "--out", "/tmp/test_dryrun.json"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    assert p.returncode == 0, p.stderr[-3000:]
    assert "[ok" in p.stdout
