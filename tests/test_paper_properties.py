"""Property-based tests of the paper's theorems (hypothesis-driven).

Each theorem/proposition becomes an executable invariant over randomized
datasets and parameters:
  * Prop 3.9  — ε-nested clusters
  * Prop 5.7  — MinPts-nested clusters
  * Thm 4.3   — OPTICS approximate clusters: S ⊆ K, all ε*-cores in S
  * Thm 5.2/5.3 — FINEX never mislabels non-core borders
  * Thm 5.4   — former-cores classified identically by FINEX and OPTICS
  * recall(FINEX) ≥ recall(OPTICS) (§5.2, Table 3's ordering)
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core import (border_recall, dbscan_from_csr, filtered_counts,
                        finex_build, optics_build, query_clustering)
from repro.data.synthetic import gaussian_mixture
from repro.neighbors.engine import NeighborEngine

SETTINGS = dict(deadline=None, max_examples=12,
                suppress_health_check=[HealthCheck.too_slow])


def _setup(seed: int, eps: float, minpts: int):
    x = gaussian_mixture(320, d=3, k=4, seed=seed)
    eng = NeighborEngine(x, metric="euclidean")
    idx, csr = finex_build(eng, eps, minpts)
    return eng, idx, csr


def _assert_nested(dense, sparse, dense_core):
    """Prop 3.9/5.7 on EXACT clusterings: Def-3.4 clusters may overlap on
    ambiguous borders, and exact partitions assign those to one host
    arbitrarily — so the single-host requirement applies to the dense
    cluster's CORES (which are sparse cores, hence unambiguous); every
    member must still be inside *some* sparse cluster (never noise)."""
    for k in range(dense.max() + 1):
        members = np.nonzero(dense == k)[0]
        assert -1 not in set(sparse[members].tolist()), \
            f"dense cluster {k} has members that are sparse noise"
        core_hosts = set(sparse[members[dense_core[members]]].tolist())
        assert len(core_hosts) <= 1, \
            f"dense cluster {k} cores span sparse clusters {core_hosts}"


@given(seed=st.integers(0, 50), frac=st.floats(0.3, 1.0))
@settings(**SETTINGS)
def test_prop_3_9_eps_nested_clusters(seed, frac):
    """Every (ε*, MinPts)-cluster is inside some (ε, MinPts)-cluster."""
    eng, idx, csr = _setup(seed, 0.4, 6)
    eps_star = float(np.float32(0.4 * frac))
    dense = dbscan_from_csr(csr, eng.weights, eps_star, 6)
    sparse = dbscan_from_csr(csr, eng.weights, 0.4, 6)
    dense_core = filtered_counts(csr, eng.weights, eps_star) >= 6
    _assert_nested(dense, sparse, dense_core)


@given(seed=st.integers(0, 50), mult=st.integers(1, 8))
@settings(**SETTINGS)
def test_prop_5_7_minpts_nested_clusters(seed, mult):
    eng, idx, csr = _setup(seed, 0.4, 6)
    dense = dbscan_from_csr(csr, eng.weights, 0.4, 6 * mult)
    sparse = dbscan_from_csr(csr, eng.weights, 0.4, 6)
    dense_core = filtered_counts(csr, eng.weights, 0.4) >= 6 * mult
    _assert_nested(dense, sparse, dense_core)


@given(seed=st.integers(0, 30), frac=st.floats(0.4, 1.0))
@settings(**SETTINGS)
def test_thm_4_3_optics_approx_subset_and_cores(seed, frac):
    """OPTICS approximate clusters: (a) S ⊆ K; (c) every ε*-core ∈ S."""
    x = gaussian_mixture(320, d=3, k=4, seed=seed)
    eng = NeighborEngine(x, metric="euclidean")
    ordering, csr = optics_build(eng, 0.4, 6)
    eps_star = float(np.float32(0.4 * frac))
    approx = query_clustering(ordering, eps_star)
    oracle = dbscan_from_csr(csr, eng.weights, eps_star, 6)
    counts = filtered_counts(csr, eng.weights, eps_star)
    core = counts >= 6
    # S ⊆ K up to ambiguous borders (the exact oracle assigns those to one
    # of their clusters arbitrarily): check via core members, and require
    # no member of S to be oracle-noise
    _assert_nested(approx, oracle, core)
    # all cores clustered (Thm 4.3c)
    assert np.all(approx[core] >= 0), "OPTICS mislabeled an eps*-core"


@given(seed=st.integers(0, 30), frac=st.floats(0.3, 1.0))
@settings(**SETTINGS)
def test_thm_5_3_noncore_borders_never_missed(seed, frac):
    """FINEX linear scan: non-core (at ε) borders are never labeled noise."""
    eng, idx, csr = _setup(seed, 0.4, 6)
    eps_star = float(np.float32(0.4 * frac))
    lab = query_clustering(idx, eps_star)
    oracle = dbscan_from_csr(csr, eng.weights, eps_star, 6)
    counts_gen = filtered_counts(csr, eng.weights, 0.4)
    counts_star = filtered_counts(csr, eng.weights, eps_star)
    noncore_gen = counts_gen < 6
    border_star = (oracle >= 0) & (counts_star < 6)
    mislabeled = noncore_gen & border_star & (lab < 0)
    assert not mislabeled.any(), \
        f"non-core borders labeled noise: {np.nonzero(mislabeled)[0][:5]}"
    # noise at eps* must also be noise in the scan
    assert not ((oracle < 0) & (lab >= 0)).any()


@given(seed=st.integers(0, 30), frac=st.floats(0.3, 1.0))
@settings(**SETTINGS)
def test_thm_5_4_former_cores_parity_with_optics(seed, frac):
    """Former-cores are clustered by FINEX iff OPTICS clusters them."""
    x = gaussian_mixture(320, d=3, k=4, seed=seed)
    eng = NeighborEngine(x, metric="euclidean")
    fidx, csr = finex_build(eng, 0.4, 6)
    oidx, _ = optics_build(eng, 0.4, 6, csr=csr)
    eps_star = float(np.float32(0.4 * frac))
    lf = query_clustering(fidx, eps_star)
    lo = query_clustering(oidx, eps_star)
    former = (fidx.C > eps_star) & (fidx.C <= 0.4)
    diff = (lf[former] >= 0) != (lo[former] >= 0)
    assert not diff.any(), \
        f"former-core parity broken for {np.nonzero(former)[0][diff][:5]}"


@given(seed=st.integers(0, 30), frac=st.floats(0.3, 1.0))
@settings(**SETTINGS)
def test_finex_recall_at_least_optics(seed, frac):
    x = gaussian_mixture(320, d=3, k=4, seed=seed)
    eng = NeighborEngine(x, metric="euclidean")
    fidx, csr = finex_build(eng, 0.4, 6)
    oidx, _ = optics_build(eng, 0.4, 6, csr=csr)
    eps_star = float(np.float32(0.4 * frac))
    oracle = dbscan_from_csr(csr, eng.weights, eps_star, 6)
    core = filtered_counts(csr, eng.weights, eps_star) >= 6
    rf = border_recall(query_clustering(fidx, eps_star), oracle, core)
    ro = border_recall(query_clustering(oidx, eps_star), oracle, core)
    assert rf >= ro - 1e-12, (rf, ro)


@given(seed=st.integers(0, 40))
@settings(**SETTINGS)
def test_core_distance_definition(seed):
    """Def 3.7: C(p) is the k-th smallest distance for cores, inf else."""
    x = gaussian_mixture(200, d=3, k=3, seed=seed)
    eng = NeighborEngine(x, metric="euclidean")
    idx, csr = finex_build(eng, 0.5, 5)
    d = eng.distances_from(np.arange(eng.n))
    kth = np.sort(d, axis=1)[:, 4]
    counts = (d <= np.float32(0.5)).sum(1)
    for p in range(eng.n):
        if counts[p] >= 5:
            assert abs(idx.C[p] - kth[p]) < 1e-5
        else:
            assert np.isinf(idx.C[p])
