"""Shared fixtures. NOTE: no XLA_FLAGS here — the main test session must
see exactly 1 device; multi-device tests spawn subprocesses with their own
flags (tests/test_distributed.py)."""
import pytest

from repro.data.synthetic import gaussian_mixture, heavy_tail_sets
from repro.neighbors.bitset import pack_sets
from repro.neighbors.engine import NeighborEngine


@pytest.fixture(scope="session")
def vec_engine():
    x = gaussian_mixture(600, d=4, k=5, seed=7)
    return NeighborEngine(x, metric="euclidean")


@pytest.fixture(scope="session")
def vec_index(vec_engine):
    from repro.core import finex_build
    idx, csr = finex_build(vec_engine, eps=0.35, minpts=8)
    return idx, csr


@pytest.fixture(scope="session")
def set_engine():
    sets, w = heavy_tail_sets(900, seed=11)
    bits, sizes = pack_sets(sets)
    return NeighborEngine((bits, sizes), metric="jaccard", weights=w)


@pytest.fixture(scope="session")
def set_index(set_engine):
    from repro.core import finex_build
    idx, csr = finex_build(set_engine, eps=0.4, minpts=16)
    return idx, csr
