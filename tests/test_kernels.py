"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes as the kernel contract requires."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.jaccard import (jaccard_distance_pallas,
                                   jaccard_eps_count_pallas,
                                   jaccard_eps_emit_pallas)
from repro.kernels.kthdist import dist_histogram_pallas, kth_smallest_bisect
from repro.kernels.pairwise import (eps_count_pallas, eps_emit_pallas,
                                    pairwise_euclidean_pallas)
from repro.neighbors.bitset import pack_sets

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("m,n,d", [(8, 8, 4), (70, 150, 5), (128, 128, 32),
                                   (129, 257, 7), (1, 300, 16), (300, 1, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_euclidean_matches_ref(m, n, d, dtype):
    x = jnp.asarray(RNG.normal(size=(m, d)), dtype)
    y = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    got = pairwise_euclidean_pallas(x, y, interpret=True)
    want = ref.pairwise_euclidean(x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,n,d,eps", [(64, 200, 8, 1.0), (130, 70, 3, 2.5),
                                       (5, 500, 16, 0.5)])
def test_eps_count_fused_matches_ref(m, n, d, eps):
    x = jnp.asarray(RNG.normal(size=(m, d)), jnp.float32)
    y = jnp.asarray(RNG.normal(size=(n, d)), jnp.float32)
    w = jnp.asarray(RNG.integers(1, 5, size=n), jnp.float32)
    got = eps_count_pallas(x, y, eps, w, interpret=True)
    d_ref = np.asarray(ref.pairwise_euclidean(x, y))
    want = np.where(d_ref <= eps, np.asarray(w)[None, :], 0).sum(-1)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)


@pytest.mark.parametrize("m,n,universe", [(40, 90, 200), (128, 128, 64),
                                          (13, 260, 1000)])
def test_jaccard_pallas_matches_ref_and_python(m, n, universe):
    sets_a = [RNG.choice(universe, size=RNG.integers(1, 20), replace=False)
              for _ in range(m)]
    sets_b = [RNG.choice(universe, size=RNG.integers(1, 20), replace=False)
              for _ in range(n)]
    ba, sa = pack_sets(sets_a, universe)
    bb, sb = pack_sets(sets_b, universe)
    got = np.asarray(jaccard_distance_pallas(
        jnp.asarray(ba), jnp.asarray(sa), jnp.asarray(bb), jnp.asarray(sb),
        interpret=True))
    want = np.asarray(ref.jaccard_distance(
        jnp.asarray(ba), jnp.asarray(sa), jnp.asarray(bb), jnp.asarray(sb)))
    np.testing.assert_allclose(got, want, rtol=1e-6)
    # spot-check against pure-python set arithmetic
    for i, j in [(0, 0), (m // 2, n // 2), (m - 1, n - 1)]:
        A, B = set(map(int, sets_a[i])), set(map(int, sets_b[j]))
        exact = 1.0 - len(A & B) / len(A | B)
        assert abs(got[i, j] - exact) < 1e-6


@pytest.mark.parametrize("m,n,d,eps,cap", [(40, 300, 6, 1.2, 128),
                                           (70, 130, 4, 2.0, 256),
                                           (130, 257, 5, 0.8, 128)])
def test_eps_emit_fused_matches_oracle(m, n, d, eps, cap):
    """Fused threshold+emit == dense-plane compaction oracle, including
    ragged (non-tile-multiple) shapes."""
    x = jnp.asarray(RNG.normal(size=(m, d)), jnp.float32)
    y = jnp.asarray(RNG.normal(size=(n, d)), jnp.float32)
    gl, gc, gd = eps_emit_pallas(x, y, eps, cap, interpret=True)
    dm = ref.pairwise_euclidean(x, y)
    wl, wc, wd = ref.eps_compact_tile(dm, jnp.float32(eps), cap)
    np.testing.assert_array_equal(np.asarray(gl), np.asarray(wl))
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(wc))
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd),
                               rtol=1e-6, atol=1e-6)


def test_eps_emit_overflow_keeps_prefix_and_true_length():
    """Rows longer than the capacity keep their first cap hits and report
    the TRUE length (> cap) so callers can fall back to a dense tile."""
    x = jnp.asarray(RNG.normal(size=(16, 3)), jnp.float32)
    y = jnp.asarray(RNG.normal(size=(400, 3)), jnp.float32)
    cap = 128
    gl, gc, gd = eps_emit_pallas(x, y, 50.0, cap, interpret=True)  # all hit
    assert (np.asarray(gl) == 400).all()
    np.testing.assert_array_equal(np.asarray(gc),
                                  np.tile(np.arange(cap, dtype=np.int32),
                                          (16, 1)))
    dm = np.asarray(ref.pairwise_euclidean(x, y))
    np.testing.assert_allclose(np.asarray(gd), dm[:, :cap], rtol=1e-6)


def test_jaccard_emit_fused_matches_oracle():
    sets = [RNG.choice(200, size=RNG.integers(1, 20), replace=False)
            for _ in range(60)]
    bits, sizes = pack_sets(sets, 200)
    ba, sa = jnp.asarray(bits), jnp.asarray(sizes)
    gl, gc, gd = jaccard_eps_emit_pallas(ba, sa, ba, sa, 0.8, 128,
                                         interpret=True)
    dm = ref.jaccard_distance(ba, sa, ba, sa)
    wl, wc, wd = ref.eps_compact_tile(dm, jnp.float32(0.8), 128)
    np.testing.assert_array_equal(np.asarray(gl), np.asarray(wl))
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(wc))
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), rtol=1e-6)


def test_jaccard_count_fused():
    sets, w = [set(RNG.choice(100, size=8, replace=False)) for _ in range(60)], \
        RNG.integers(1, 4, size=60)
    bits, sizes = pack_sets(sets, 100)
    got = np.asarray(jaccard_eps_count_pallas(
        jnp.asarray(bits), jnp.asarray(sizes), jnp.asarray(bits),
        jnp.asarray(sizes), 0.7, jnp.asarray(w, jnp.float32), interpret=True))
    dm = np.asarray(ref.jaccard_distance(
        jnp.asarray(bits), jnp.asarray(sizes), jnp.asarray(bits),
        jnp.asarray(sizes)))
    want = np.where(dm <= np.float32(0.7), w[None, :], 0).sum(-1)
    np.testing.assert_allclose(got, want)


def test_dist_histogram_rows_sum_to_n():
    x = jnp.asarray(RNG.normal(size=(50, 6)), jnp.float32)
    y = jnp.asarray(RNG.normal(size=(170, 6)), jnp.float32)
    dmax = float(np.asarray(ref.pairwise_euclidean(x, y)).max())
    edges = jnp.linspace(0.0, dmax + 1e-3, 17)
    got = np.asarray(dist_histogram_pallas(x, y, edges, interpret=True))
    want = np.asarray(ref.tile_histogram(ref.pairwise_euclidean(x, y), edges))
    np.testing.assert_allclose(got, want)
    assert (got.sum(1) == 170).all()


def test_kth_smallest_bisect_close_to_sort():
    x = RNG.normal(size=(40, 5)).astype(np.float32)
    y = RNG.normal(size=(300, 5)).astype(np.float32)
    k = 10
    got = kth_smallest_bisect(x, y, k, interpret=True)
    d = np.asarray(ref.pairwise_euclidean(jnp.asarray(x), jnp.asarray(y)))
    want = np.sort(d, axis=1)[:, k - 1]
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_sliding_window_attention_ref_vs_full_mask():
    q = jnp.asarray(RNG.normal(size=(2, 32, 4, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 32, 4, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 32, 4, 16)), jnp.float32)
    from repro.models.layers import attention_full
    got = ref.sliding_window_attention(q, k, v, window=8)
    want = attention_full(q, k, v, causal=True, window=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("T,H,KV,hd,win,bq,bk",
                         [(64, 4, 2, 16, 16, 16, 16),
                          (128, 2, 2, 32, 32, 32, 16),
                          (64, 4, 4, 16, 0, 16, 16),
                          (96, 2, 1, 16, 24, 16, 8)])
def test_flash_swa_kernel_matches_oracle(T, H, KV, hd, win, bq, bk):
    from repro.kernels.flash_swa import flash_swa_attention
    from repro.models.layers import attention_full
    q = jnp.asarray(RNG.normal(size=(2, T, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, T, KV, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, T, KV, hd)), jnp.float32)
    got = flash_swa_attention(q, k, v, window=win, causal=True,
                              bq=bq, bk=bk, interpret=True)
    want = attention_full(q, k, v, causal=True, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
