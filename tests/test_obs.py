"""Observability suite: the tracing layer must never change a result.

Covers the ``repro.obs`` subsystem end to end — rolling-window order
statistics against numpy, latched threshold warnings, the JSONL span
sink round-tripped through ``scripts/trace_report.py``'s strict loader,
disabled-mode no-op guarantees, and (the load-bearing property) byte
identity of build / insert / delete / ε* / MinPts* outputs with tracing
on vs off for euclidean, jaccard, and a ``register_metric`` user metric.
"""

import importlib.util
import json
import pathlib
import warnings

import numpy as np
import pytest

import jax.numpy as jnp

from repro import obs
from repro.core import FinexIndex
from repro.data.synthetic import gaussian_mixture, heavy_tail_sets
from repro.metrics import register_metric
from repro.neighbors.bitset import pack_sets
from repro.obs.rolling import RollingWindow, quantile
from repro.obs.telemetry import ObsWarning, Telemetry
from repro.service import ClusterService, IndexStore, StatsRequest, SweepRequest

_REPORT_PY = pathlib.Path(__file__).resolve().parents[1] / "scripts"
_spec = importlib.util.spec_from_file_location(
    "trace_report", _REPORT_PY / "trace_report.py"
)
trace_report = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(trace_report)


def _chebyshev(q, c):
    return jnp.max(jnp.abs(q[:, None, :] - c[None, :, :]), axis=-1)


try:
    register_metric("obs-cheb", _chebyshev)
except ValueError:
    pass  # already registered by a previous import of this module


@pytest.fixture(autouse=True)
def _tracing_off():
    """Every test starts and ends with the tracer off, no sink, and a
    clean registry — the singleton must not leak state across tests."""
    obs.configure(sink=None, enabled=False)
    obs.reset()
    yield
    obs.configure(sink=None, enabled=False)
    obs.reset()


# ---------------------------------------------------------------- rolling


def test_quantile_matches_numpy():
    rng = np.random.default_rng(0)
    values = rng.standard_normal(37).tolist()
    for q in (0.0, 0.05, 0.5, 0.83, 0.95, 1.0):
        assert quantile(values, q) == pytest.approx(
            float(np.quantile(values, q)), abs=1e-12
        )
    with pytest.raises(ValueError):
        quantile([], 0.5)
    with pytest.raises(ValueError):
        quantile([1.0], 1.5)


def test_rolling_window_median_p95_and_eviction():
    w = RollingWindow(size=8)
    assert w.summary() == {"count": 0, "window": 0}
    assert w.median() is None and w.p95() is None
    rng = np.random.default_rng(1)
    series = rng.uniform(0.0, 10.0, 30)
    for v in series:
        w.push(v)
    tail = series[-8:]
    assert w.values() == pytest.approx(list(tail))
    assert w.median() == pytest.approx(float(np.quantile(tail, 0.5)))
    assert w.p95() == pytest.approx(float(np.quantile(tail, 0.95)))
    s = w.summary()
    assert s["count"] == 30 and s["window"] == 8
    assert s["max"] == pytest.approx(tail.max())
    assert w.stat("mean") == pytest.approx(tail.mean())
    with pytest.raises(ValueError):
        w.stat("p99")


def test_threshold_warns_once_per_breach_and_rearms():
    obs.enable()
    t = Telemetry(window_size=16)
    t.set_threshold("lat", limit=1.0, stat="last")

    def observed_warnings(value):
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            t.observe("lat", value)
        return [w for w in caught if issubclass(w.category, ObsWarning)]

    assert observed_warnings(0.5) == []
    first = observed_warnings(2.0)
    assert len(first) == 1 and "lat" in str(first[0].message)
    # sustained breach stays latched: no second warning
    assert observed_warnings(3.0) == []
    # recovery re-arms the latch, the next breach warns again
    assert observed_warnings(0.2) == []
    assert len(observed_warnings(5.0)) == 1
    th = t.snapshot()["thresholds"]["lat"]
    assert th["breaches"] == 2 and th["breached"] is True
    assert th["limit"] == 1.0 and th["stat"] == "last"


# --------------------------------------------------------- disabled mode


def test_disabled_mode_is_a_shared_noop():
    assert not obs.enabled()
    # the disabled span is one shared singleton, not a per-call object
    assert obs.span("a", n=1) is obs.span("b", m=2)
    with obs.span("nothing", k=3) as sp:
        assert sp.annot(extra=1) is sp
        assert sp.fence([1, 2, 3]) == [1, 2, 3]
    obs.count("c")
    obs.gauge("g", 7.0)
    obs.observe("w", 1.0)
    snap = obs.snapshot()
    assert snap["enabled"] is False
    assert snap["counters"] == {} and snap["gauges"] == {}
    assert snap["windows"] == {} and snap["spans"] == {}


# ------------------------------------------------------- JSONL round-trip


def test_jsonl_sink_round_trips_through_trace_report(tmp_path):
    path = tmp_path / "trace.jsonl"
    obs.enable(sink=str(path))
    with obs.span("outer", phase="test") as outer:
        outer.fence(jnp.arange(4) * 2)
        with obs.span("inner", n=3) as inner:
            inner.annot(nnz=7)
        with obs.span("inner", n=4):
            pass
    obs.disable()
    obs.configure(sink=None)  # close so the file is fully written

    spans = trace_report.load_spans(str(path))
    assert [s["name"] for s in spans] == ["inner", "inner", "outer"]
    by_name = {}
    for s in spans:
        by_name.setdefault(s["name"], []).append(s)
    out = by_name["outer"][0]
    assert out["parent"] is None and out["depth"] == 0
    assert out["attrs"] == {"phase": "test"}
    assert out["device_s"] > 0.0
    for s in by_name["inner"]:
        assert s["parent"] == out["id"] and s["depth"] == 1
    assert by_name["inner"][0]["attrs"] == {"n": 3, "nnz": 7}
    # children subtract from the parent's self-time
    child_wall = sum(s["wall_s"] for s in by_name["inner"])
    assert out["self_s"] == pytest.approx(out["wall_s"] - child_wall)
    agg = trace_report.rollup(spans)
    assert agg["inner"]["count"] == 2 and agg["outer"]["count"] == 1
    assert "inner" in trace_report.report(spans)

    # the strict loader refuses malformed records
    bad = tmp_path / "bad.jsonl"
    rec = dict(spans[-1])
    del rec["wall_s"]
    bad.write_text(json.dumps(rec) + "\n")
    with pytest.raises(ValueError, match="wall_s"):
        trace_report.load_spans(str(bad))
    orphan = tmp_path / "orphan.jsonl"
    rec = dict(spans[0])
    rec["parent"] = 999999
    orphan.write_text(json.dumps(rec) + "\n")
    with pytest.raises(ValueError, match="parent"):
        trace_report.load_spans(str(orphan))


# ----------------------------------------------- tracing on/off identity


def _vectors(n, seed):
    return gaussian_mixture(n, d=4, k=5, seed=seed), None


def _sets(n, seed):
    sets, w = heavy_tail_sets(n, seed=seed)
    return pack_sets(sets, universe=512), w


CASES = [
    ("euclidean", _vectors, 0.35, 8),
    ("jaccard", _sets, 0.4, 8),
    ("obs-cheb", _vectors, 0.3, 6),
]


def _take_rows(data, sel):
    if isinstance(data, tuple):
        return tuple(a[sel] for a in data)
    return data[sel]


def _lifecycle(data, weights, metric, eps, minpts, extra, extra_w):
    """build -> ε*/MinPts* -> insert -> delete -> ε* again; returns every
    array output the caller will compare byte-for-byte."""
    idx = FinexIndex.build(data, eps=eps, minpts=minpts, metric=metric, weights=weights)
    out = [idx.clustering(), idx.eps_star(eps * 0.6), idx.minpts_star(minpts * 2)]
    idx.insert(extra, weights=extra_w)
    idx.delete([0, 3])
    out += [idx.clustering(), idx.eps_star(eps * 0.5)]
    o, csr = idx.ordering, idx.csr
    out += [getattr(o, f) for f in ("order", "pos", "C", "R", "N", "F")]
    out += [np.asarray(csr.indptr), np.asarray(csr.indices), np.asarray(csr.dists)]
    return out


@pytest.mark.parametrize(
    ("metric", "factory", "eps", "minpts"), CASES, ids=[c[0] for c in CASES]
)
def test_tracing_does_not_change_outputs(tmp_path, metric, factory, eps, minpts):
    all_data, all_w = factory(220, seed=3)
    n = (all_data[0] if isinstance(all_data, tuple) else all_data).shape[0]
    head, tail = np.arange(n) < n - 10, np.arange(n) >= n - 10
    data = _take_rows(all_data, head)
    w = None if all_w is None else all_w[head]
    extra = _take_rows(all_data, tail)
    extra_w = None if all_w is None else all_w[tail]

    baseline = _lifecycle(data, w, metric, eps, minpts, extra, extra_w)

    obs.enable(sink=str(tmp_path / "trace.jsonl"))
    traced = _lifecycle(data, w, metric, eps, minpts, extra, extra_w)
    snap = obs.snapshot()
    obs.disable()
    obs.configure(sink=None)

    assert len(baseline) == len(traced)
    for i, (a, b) in enumerate(zip(baseline, traced)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), i
    # the traced run actually recorded the instrumented phases
    phases = (
        "engine.materialize",
        "build.finex_build",
        "index.insert",
        "index.delete",
        "index.eps_star",
        "index.minpts_star",
    )
    for name in phases:
        assert snap["spans"][name]["count"] >= 1, name
    assert snap["counters"]["delta.inserts"] == 1
    assert snap["counters"]["delta.deletes"] == 1
    assert "span.engine.materialize" in snap["windows"]
    # and the sink is a valid trace
    spans = trace_report.load_spans(str(tmp_path / "trace.jsonl"))
    assert {s["name"] for s in spans} >= {"engine.materialize", "build.finex_sweep"}


# ------------------------------------------------ stats()/Stats surfaces


def test_index_stats_surfaces_telemetry_and_strip_report():
    data, _ = _vectors(200, seed=5)
    obs.enable()
    idx = FinexIndex.build(data, eps=0.35, minpts=8)
    st = idx.stats()
    snap = st["telemetry"]
    expected = {"enabled", "counters", "gauges", "windows", "spans", "thresholds"}
    assert set(snap) == expected
    assert snap["enabled"] is True
    assert snap["spans"]["engine.materialize"]["count"] == 1
    assert st["strip"] is None  # no mutation yet -> no strip sweep ran

    full_report = dict(idx.engine.last_full_materialize)
    extra, _ = _vectors(210, seed=5)
    idx.insert(_take_rows(extra, np.arange(200, 210)))
    st = idx.stats()
    # satellite fix: the insert's strip sweep reports separately and the
    # full-sweep report (pruning included) is NOT clobbered
    assert st["strip"] is not None and st["strip"]["mode"] == "strip"
    assert st["strip"]["rows"] == 10
    assert idx.engine.last_full_materialize == full_report
    obs.disable()


def test_service_stats_verb_and_periodic_log():
    data, _ = _vectors(240, seed=9)
    settings = [("eps", 0.2), ("minpts", 16)]
    lines = []
    obs.enable()
    svc = ClusterService(
        store=IndexStore(capacity=2), slots=4, stats_every=2, stats_log=lines.append
    )
    reqs = [
        SweepRequest(data=data, eps=0.35, minpts=8, settings=settings)
        for _ in range(3)
    ]
    stats_req = StatsRequest()
    svc.run(reqs + [stats_req])
    final = svc.stats()["telemetry"]
    obs.disable()

    assert stats_req.done and stats_req.result is not None
    snap = stats_req.result["telemetry"]
    # the Stats verb answers from inside the still-open service.run span,
    # so its snapshot carries the work spans that already closed ...
    assert snap["spans"]["planner.sweep"]["count"] >= 1
    assert snap["counters"]["store.builds"] == 1
    assert snap["counters"]["store.hits"] >= 1
    assert "service.queue_depth" in snap["windows"]
    # ... and the post-run snapshot carries the loop spans themselves
    assert final["spans"]["service.run"]["count"] == 1
    assert final["spans"]["service.window"]["count"] >= 1
    # the periodic stats line fired on the served-request boundary
    assert lines and all(line.startswith("[cluster-service]") for line in lines)
    assert "store hits=" in lines[0]
