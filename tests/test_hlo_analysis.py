"""The roofline's HLO analysis must get loop trip counts and collective
bytes right — verified against computations with known structure."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo


def test_while_trip_count_multiplies_flops():
    def one(x):
        return x @ x

    def scanned(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    t1 = jax.jit(one).lower(sds).compile().as_text()
    t10 = jax.jit(scanned).lower(sds).compile().as_text()
    f1 = analyze_hlo(t1)["dot_flops"]
    f10 = analyze_hlo(t10)["dot_flops"]
    assert f1 > 0
    ratio = f10 / f1
    assert 9.0 <= ratio <= 11.0, ratio     # 10 iterations recovered


def test_dot_flops_exact_for_plain_matmul():
    m, k, n = 64, 128, 32
    fn = jax.jit(lambda a, b: a @ b)
    txt = fn.lower(jax.ShapeDtypeStruct((m, k), jnp.float32),
                   jax.ShapeDtypeStruct((k, n), jnp.float32)
                   ).compile().as_text()
    got = analyze_hlo(txt)["dot_flops"]
    assert got == 2 * m * k * n


def test_nested_scan_multiplies():
    def nested(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    sds = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    t = jax.jit(nested).lower(sds).compile().as_text()
    t1 = jax.jit(lambda x: x @ x).lower(sds).compile().as_text()
    ratio = analyze_hlo(t)["dot_flops"] / analyze_hlo(t1)["dot_flops"]
    assert 11.0 <= ratio <= 13.0, ratio    # 3 × 4 = 12


def test_attention_excess_detected():
    """Score-shaped dots (result ≫ operands) are flagged as flash-fusable."""
    def attn(q, k):
        return jnp.einsum("td,sd->ts", q, k)
    sds = jax.ShapeDtypeStruct((512, 16), jnp.float32)
    txt = jax.jit(attn).lower(sds, sds).compile().as_text()
    out = analyze_hlo(txt)
    assert out.get("attn_excess_bytes", 0) >= 512 * 512 * 4
