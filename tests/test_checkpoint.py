"""Fault tolerance: atomic checkpoints, bit-exact resume, preemption
survival, elastic restore."""
import os
import subprocess
import sys

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch
from repro.train.step import init_state

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def _tiny_state():
    cfg = get_arch("stablelm-1.6b").reduced(n_layers=1, d_model=32,
                                            n_heads=2, n_kv_heads=2,
                                            d_ff=64, vocab=64, head_dim=16)
    return init_state(jax.random.PRNGKey(0), cfg)


def test_roundtrip_bit_exact(tmp_path):
    state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, state)
    restored = mgr.restore(7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_gc(tmp_path):
    state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state, async_=True)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]            # older ones GC'd
    assert mgr.latest_step() == 4


def test_interrupted_save_never_visible(tmp_path):
    """A half-written checkpoint directory must not be picked up."""
    state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state)
    # simulate a writer killed mid-save: a .tmp dir with partial contents
    tmp_dir = tmp_path / ".tmp_step_2"
    tmp_dir.mkdir()
    (tmp_dir / "arrays.npz").write_bytes(b"garbage")
    # and a torn final dir without manifest
    torn = tmp_path / "step_3"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 1               # only the intact one


def test_preemption_resume_bit_exact(tmp_path):
    """Kill a training run mid-flight; restarting must continue to the
    same final loss as an uninterrupted run (deterministic data + state)."""
    ckpt_a = str(tmp_path / "interrupted")
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "stablelm-1.6b", "--smoke", "--steps", "12", "--batch", "2",
            "--seq-len", "32", "--ckpt-every", "4", "--lr", "1e-3"]
    # run 1: preempted hard at step 8 (after a step-8 checkpoint)
    p = subprocess.run(args + ["--ckpt-dir", ckpt_a, "--preempt-at", "8"],
                       env=ENV, capture_output=True, text=True, cwd=REPO)
    assert p.returncode == 42, p.stderr[-2000:]
    # run 2: same command auto-resumes and finishes
    p2 = subprocess.run(args + ["--ckpt-dir", ckpt_a], env=ENV,
                        capture_output=True, text=True, cwd=REPO)
    assert p2.returncode == 0, p2.stderr[-2000:]
    assert "[resume] restored step 8" in p2.stdout
    resumed_final = [l for l in p2.stdout.splitlines() if "step    11" in l]

    # uninterrupted reference
    ckpt_b = str(tmp_path / "straight")
    p3 = subprocess.run(args + ["--ckpt-dir", ckpt_b], env=ENV,
                        capture_output=True, text=True, cwd=REPO)
    assert p3.returncode == 0, p3.stderr[-2000:]
    straight_final = [l for l in p3.stdout.splitlines() if "step    11" in l]
    assert resumed_final and resumed_final == straight_final, \
        (resumed_final, straight_final)


def test_elastic_restore_replicated(tmp_path):
    """restore_for_mesh places a checkpoint onto a (new) mesh."""
    from repro.checkpoint.elastic import restore_for_mesh
    from repro.launch.mesh import make_host_mesh
    state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, state)
    mesh = make_host_mesh(1, 1)           # "different" trivially-sized mesh
    restored = restore_for_mesh(mgr, 5, state, mesh)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
