"""Training substrate: loss decreases, accumulation modes agree,
schedules have the right shape, compression behaves."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, ShapeConfig, get_arch
from repro.data.tokens import TokenStream
from repro.train.optimizer import cosine_schedule, wsd_schedule
from repro.train.step import init_state, make_train_step

CFG = get_arch("stablelm-1.6b").reduced(n_layers=2, d_model=64, n_heads=4,
                                        n_kv_heads=4, d_ff=128, vocab=128,
                                        head_dim=16)
SHAPE = ShapeConfig("t", 32, 4, "train")


def test_loss_decreases():
    rc = RunConfig(model=CFG, shape=SHAPE, remat=False, dtype="float32")
    step_fn = jax.jit(make_train_step(CFG, rc, lr_fn=lambda s: 1e-2,
                                      n_micro=1))
    state = init_state(jax.random.PRNGKey(0), CFG)
    stream = TokenStream(CFG, 32, 4)
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    losses = []
    for _ in range(30):          # overfit one batch
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


@pytest.mark.parametrize("n_micro", [1, 2, 4])
def test_accum_modes_agree(n_micro):
    """grad-of-scanned-loss == per-micro accumulation (same step)."""
    stream = TokenStream(CFG, 32, 4)
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(3).items()}
    outs = {}
    for mode in ("grads", "loss"):
        rc = RunConfig(model=CFG, shape=SHAPE, remat=False, dtype="float32",
                       accum_mode=mode)
        step_fn = jax.jit(make_train_step(CFG, rc, lr_fn=lambda s: 1e-3,
                                          n_micro=n_micro))
        state = init_state(jax.random.PRNGKey(1), CFG)
        state2, m = step_fn(state, batch)
        outs[mode] = (float(m["loss"]), state2.params)
    assert abs(outs["grads"][0] - outs["loss"][0]) < 1e-5
    for a, b in zip(jax.tree.leaves(outs["grads"][1]),
                    jax.tree.leaves(outs["loss"][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-6)


def test_micro_split_invariance():
    """n_micro must not change the gradient (up to accumulation order)."""
    batch = {k: jnp.asarray(v)
             for k, v in TokenStream(CFG, 32, 4).batch_at(5).items()}
    params = {}
    for n_micro in (1, 4):
        rc = RunConfig(model=CFG, shape=SHAPE, remat=False, dtype="float32")
        step_fn = jax.jit(make_train_step(CFG, rc, lr_fn=lambda s: 1e-3,
                                          n_micro=n_micro))
        state = init_state(jax.random.PRNGKey(2), CFG)
        state2, _ = step_fn(state, batch)
        params[n_micro] = state2.params
    for a, b in zip(jax.tree.leaves(params[1]), jax.tree.leaves(params[4])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-6)


def test_wsd_schedule_shape():
    lr = wsd_schedule(1.0, warmup=10, stable=50, decay=20, floor_frac=0.1)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(lr(jnp.int32(40))) - 1.0) < 1e-6      # stable plateau
    assert abs(float(lr(jnp.int32(80))) - 0.1) < 1e-6       # decayed floor
    mid = float(lr(jnp.int32(70)))
    assert 0.1 < mid < 1.0


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100, floor_frac=0.0)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(100))) < 1e-6


def test_remat_matches_no_remat():
    """Gradient checkpointing must not change the computed step."""
    batch = {k: jnp.asarray(v)
             for k, v in TokenStream(CFG, 32, 4).batch_at(9).items()}
    outs = []
    for remat, blocks in ((False, 0), (True, 0), (True, 2)):
        rc = RunConfig(model=CFG, shape=SHAPE, remat=remat, dtype="float32",
                       remat_blocks=blocks)
        step_fn = jax.jit(make_train_step(CFG, rc, lr_fn=lambda s: 1e-3,
                                          n_micro=2))
        state = init_state(jax.random.PRNGKey(4), CFG)
        state2, m = step_fn(state, batch)
        outs.append((float(m["loss"]), state2.params))
    for loss, params in outs[1:]:
        assert abs(loss - outs[0][0]) < 1e-5
        for a, b in zip(jax.tree.leaves(outs[0][1]), jax.tree.leaves(params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-6)
